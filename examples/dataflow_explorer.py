"""Design-space exploration walkthrough for every assigned architecture.

Shows the three hierarchical design spaces of the paper on real block
graphs: tiling (hyperparameter search with fusion feedback), fusion
(Algorithm 2 under C_max), and resource allocation (LP FIFO sizing +
memory tiers) — and how the decisions differ per architecture family.

    PYTHONPATH=src python examples/dataflow_explorer.py
"""

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.core import compile_model
from repro.core.platforms import TPU_V5E


def main() -> None:
    print(f"{'arch':24s} {'kernels':>7s} {'groups':>6s} {'mem%':>6s} "
          f"{'fifoKB':>7s} {'latency_ms':>10s}  implementations")
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        c = compile_model(cfg, tokens=256, platform=TPU_V5E, dse_budget=8)
        s = c.summary()
        impls = sorted(set(s["implementations"]))
        print(f"{arch:24s} {s['kernels']:7d} {s['fusion_groups']:6d} "
              f"{s['memory_ratio']*100:6.1f} "
              f"{c.fifo.total_bytes/1024:7.1f} "
              f"{s['modeled_latency_s']*1e3:10.2f}  {','.join(impls)}")
    print("dataflow_explorer OK")


if __name__ == "__main__":
    main()
