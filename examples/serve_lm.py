"""Serving example: batched requests through the continuous-batching engine
(per-request prefill into the paged KV cache -> block decode across slots,
requests joining as slots free), reporting the paper's metrics (TTFT,
decode tok/s) per request.

    PYTHONPATH=src python examples/serve_lm.py [--arch zamba2-2.7b]
"""

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCHS, get_config
from repro.models import init_params
from repro.serving import ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b",
                    choices=[a for a in sorted(ARCHS)
                             if not ARCHS[a].encoder_only])
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--new-tokens", type=int, default=12)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    engine = ServingEngine(cfg, params, batch_slots=3,
                           max_len=args.prompt_len + args.new_tokens + 8)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, args.prompt_len,
                            dtype=np.int32) for _ in range(args.requests)]
    t0 = time.perf_counter()
    reqs = engine.generate(prompts, max_new_tokens=args.new_tokens)
    dt = time.perf_counter() - t0
    total = sum(len(r.out_tokens) for r in reqs)
    print(f"{args.arch} ({cfg.name}): {len(reqs)} requests, "
          f"{total} tokens, {total/dt:.1f} tok/s aggregate")
    for r in reqs:
        print(f" req{r.rid}: ttft={r.ttft_s*1e3:6.1f}ms "
              f"latency={r.latency_s*1e3:7.1f}ms tokens={r.out_tokens[:6]}")
    assert all(len(r.out_tokens) == args.new_tokens for r in reqs)
    print("serve_lm OK")


if __name__ == "__main__":
    main()
