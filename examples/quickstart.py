"""Quickstart: the StreamTensor pipeline end to end on one block.

Traces a transformer block to the dataflow graph, explores the tiling space,
fuses kernels under the on-chip budget (itensor-typed edges + Algorithm-1
converters), sizes FIFOs with the LP, validates the schedule in the
discrete-event simulator, and runs the equivalent fused Pallas kernels
(interpret mode) against the model's reference layers.

    PYTHONPATH=src python examples/quickstart.py [--arch llama3-8b]
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config
from repro.core import compile_model
from repro.core.platforms import TPU_V5E
from repro.kernels import flash_attention, ref, streamed_ffn
from repro.runtime.simulator import simulate_dataflow


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b", choices=sorted(ARCHS))
    args = ap.parse_args()
    cfg = get_config(args.arch)

    # 1) The compiler: trace -> tile -> fuse -> size FIFOs -> lower.
    print(f"== StreamTensor compile: one {cfg.name} block ==")
    c = compile_model(cfg, tokens=256, platform=TPU_V5E, dse_budget=8)
    s = c.summary()
    print(f" kernels={s['kernels']} fusion_groups={s['fusion_groups']} "
          f"memory_ratio={s['memory_ratio']*100:.1f}% "
          f"fifo_depth={s['fifo_total_depth']}")
    print(f" lowered implementations: {s['implementations']}")

    # 2) Deadlock-freedom: LP-sized FIFOs complete in the simulator.
    timings = {k.name: k.timing for k in c.graph.kernels()}
    sim = simulate_dataflow(c.graph, timings, plan=c.fifo)
    print(f" simulator: completed={sim.completed} "
          f"makespan={sim.makespan:.0f} cycles")

    # 3) The fused kernels themselves (Pallas, interpret mode on CPU).
    rng = jax.random.PRNGKey(0)
    x = jax.random.normal(rng, (128, 64), jnp.float32)
    wg = jax.random.normal(jax.random.PRNGKey(1), (64, 128)) * 0.1
    wu = jax.random.normal(jax.random.PRNGKey(2), (64, 128)) * 0.1
    wd = jax.random.normal(jax.random.PRNGKey(3), (128, 64)) * 0.1
    out = streamed_ffn(x, wg, wu, wd, block_t=32, block_f=64)
    want = ref.ffn_ref(x, wg, wu, wd)
    print(f" streamed_ffn max err: "
          f"{float(jnp.abs(out - want).max()):.2e}")

    q = jax.random.normal(jax.random.PRNGKey(4), (2, 128, 8, 32))
    k = jax.random.normal(jax.random.PRNGKey(5), (2, 128, 2, 32))
    v = jax.random.normal(jax.random.PRNGKey(6), (2, 128, 2, 32))
    fa = flash_attention(q, k, v, block_q=32, block_kv=32)
    fr = ref.attention_ref(q, k, v)
    print(f" flash_attention (GQA 8:2) max err: "
          f"{float(jnp.abs(fa - fr).max()):.2e}")
    print("quickstart OK")


if __name__ == "__main__":
    main()
