"""End-to-end training driver: a ~100M-parameter qwen-family LM for a few
hundred steps on the synthetic packed-document pipeline, with checkpointing
and (simulated) preemption recovery.

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--d-model 256]
"""

import argparse
from dataclasses import replace

import numpy as np

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.distributed.optimizer import AdamWConfig
from repro.launch.mesh import make_host_mesh
from repro.train import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--vocab", type=int, default=32000)
    ap.add_argument("--hundred-m", action="store_true",
                    help="the full ~100M preset (use on real hardware; "
                         "several hours on this 1-core CPU container)")
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()
    if args.hundred_m:
        args.d_model, args.layers, args.vocab = 512, 12, 50257

    cfg = replace(get_config("qwen3-0.6b"),
                  name=f"qwen3-{args.d_model}d{args.layers}L",
                  num_layers=args.layers,
                  d_model=args.d_model,
                  num_heads=8, num_kv_heads=4, head_dim=32,
                  d_ff=4 * args.d_model,
                  vocab_size=args.vocab, max_seq_len=args.seq_len)
    n = cfg.param_count()
    print(f"model: {cfg.name} ({n/1e6:.1f}M params)")

    shape = ShapeConfig("train", args.seq_len, args.batch, "train")
    mesh = make_host_mesh(1, 1)
    trainer = Trainer(
        cfg, shape, mesh,
        TrainerConfig(total_steps=args.steps, checkpoint_every=100,
                      checkpoint_dir=args.ckpt, log_every=20),
        AdamWConfig(lr=1e-3, warmup_steps=30, total_steps=args.steps))
    metrics = trainer.run()
    losses = [l for _, l in trainer.history]
    print(f"loss: first={losses[0]:.3f} best={min(losses):.3f} "
          f"final={losses[-1]:.3f}")
    assert losses[-1] < losses[0], "training must reduce loss"
    print("train_lm OK")


if __name__ == "__main__":
    main()
