"""Tests for Algorithm 1 — stream layout converter inference (paper §5.2.1)."""

import math

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (conversion_cost_bytes, fig5_b, fig5_c, infer_converter,
                        itensor_from_tiling, min_buffer_tiles_sim, row_major,
                        col_major, shared_prefix_length)
from repro.core.converter import convert_stream


class TestPaperWorkedExample:
    """Fig. 5 Case 2: itensor(b) -> itensor(c) needs an 8x2 window."""

    def test_buffer_shape_matches_paper(self):
        spec = infer_converter(fig5_b(), fig5_c())
        assert spec is not None
        assert spec.buf_shape == (8, 2)

    def test_shared_loop_is_d0(self):
        assert shared_prefix_length(fig5_b(), fig5_c()) == 1
        spec = infer_converter(fig5_b(), fig5_c())
        assert spec.shared_prefix_len == 1
        assert spec.reuse_count == 4  # d0 tripcount: buffer reused 4 times

    def test_two_tiles_four_with_pingpong(self):
        spec = infer_converter(fig5_b(), fig5_c())
        assert spec.window_tiles((4, 2)) == 2
        assert spec.pingpong_bytes == 2 * 8 * 2 * 4  # f32

    def test_simulated_minimum_matches_analytic(self):
        assert min_buffer_tiles_sim(fig5_b(), fig5_c()) == 2


class TestMatchingTypes:
    def test_no_converter_when_types_match(self):
        assert infer_converter(fig5_b(), fig5_b()) is None
        assert conversion_cost_bytes(fig5_b(), fig5_b()) == 0.0

    def test_canonically_equal_types_match(self):
        a = row_major((8, 8), (4, 2))
        b = itensor_from_tiling((8, 8), (4, 2), reuse=[(0, 1)])
        assert infer_converter(a, b) is None


class TestTransposeConversion:
    """Row-major -> column-major: nothing shareable, full-tensor window."""

    def test_full_window(self):
        src = row_major((64, 64), (16, 16))
        dst = col_major((64, 64), (16, 16))
        spec = infer_converter(src, dst)
        assert spec.buf_shape == (64, 64)
        assert spec.shared_prefix_len == 0

    def test_sim_agrees_full_buffering_needed(self):
        src = row_major((8, 8), (2, 2))
        dst = col_major((8, 8), (2, 2))
        # Min buffer for a 4x4 tile-grid transpose is (g-1)*g+1 = 13 tiles;
        # the analytic answer conservatively buffers the full 16 (the window
        # must be rectangular — Algorithm 1's worst case, paper §5.2.1).
        sim = min_buffer_tiles_sim(src, dst)
        spec = infer_converter(src, dst)
        assert sim <= spec.window_tiles((2, 2))


class TestErrors:
    def test_dtype_mismatch(self):
        with pytest.raises(ValueError):
            infer_converter(row_major((8, 8), (4, 2)),
                            row_major((8, 8), (4, 2), dtype="bfloat16"))

    def test_data_space_mismatch(self):
        with pytest.raises(ValueError):
            infer_converter(row_major((8, 8), (4, 2)),
                            row_major((16, 8), (4, 2)))


class TestFunctionalConverter:
    def test_emitted_stream_equals_consumer_slicing(self):
        src, dst = fig5_b(), fig5_c()
        data = np.arange(64, dtype=np.float32).reshape(8, 8)
        produced, emitted = convert_stream(src, dst, data)
        assert len(produced) == src.num_tokens
        assert len(emitted) == dst.num_tokens
        # Every emitted tile must be obtainable from the produced set.
        produced_set = {p.tobytes() for p in produced}
        for e in emitted:
            assert e.tobytes() in produced_set


# ------------------------------------------------------------------ #
# Property tests: the analytic window is always sufficient, and tight on
# loop-permutation layouts.
# ------------------------------------------------------------------ #

@st.composite
def layout_pair(draw):
    rank = draw(st.integers(1, 3))
    tiles = [draw(st.sampled_from([1, 2])) for _ in range(rank)]
    grid = [draw(st.integers(1, 4)) for _ in range(rank)]
    data = [t * g for t, g in zip(tiles, grid)]
    o1 = list(draw(st.permutations(list(range(rank)))))
    o2 = list(draw(st.permutations(list(range(rank)))))
    src = itensor_from_tiling(data, tiles, loop_order=o1)
    dst = itensor_from_tiling(data, tiles, loop_order=o2)
    return src, dst


@given(layout_pair())
@settings(max_examples=80, deadline=None)
def test_analytic_window_is_sufficient(pair):
    src, dst = pair
    spec = infer_converter(src, dst)
    sim = min_buffer_tiles_sim(src, dst)
    if spec is None:
        assert sim <= 1
    else:
        assert spec.window_tiles(src.elem_shape) >= sim


@given(layout_pair(), st.integers(2, 3), st.integers(0, 2))
@settings(max_examples=60, deadline=None)
def test_analytic_window_sufficient_with_consumer_reuse(pair, count, pos_seed):
    src, dst = pair
    pos = pos_seed % (dst.iter_rank + 1)
    # Rebuild dst with a reuse loop inserted at `pos`.
    order = sorted(range(dst.rank), key=lambda j: dst.iter_map.results[j])
    dst_r = itensor_from_tiling(dst.data_shape, dst.elem_shape,
                                loop_order=order, reuse=[(pos, count)])
    spec = infer_converter(src, dst_r)
    sim = min_buffer_tiles_sim(src, dst_r)
    if spec is None:
        assert sim <= 1
    else:
        assert spec.window_tiles(src.elem_shape) >= sim


@given(layout_pair())
@settings(max_examples=60, deadline=None)
def test_matching_types_need_no_buffer(pair):
    src, _ = pair
    assert infer_converter(src, src) is None
