"""Partitioning (vs brute force) + memory-tier allocation tests."""

import pytest
from _hypothesis_compat import given, settings, st

from repro.core.allocation import (Buffer, MemoryTier, TPU_TIERS, U55C_TIERS,
                                   allocate)
from repro.core.graph import DataflowGraph, KernelNode
from repro.core.itensor import row_major
from repro.core.partition import brute_force, evaluate, partition


def chain_graph(n=6, bytes_per_edge=1024):
    g = DataflowGraph()
    t = row_major((32, 32), (8, 8), dtype="bfloat16")
    for i in range(n):
        g.add_kernel(KernelNode(name=f"k{i}", op="matmul", out_type=t,
                                in_types=(t,), work_flops=1e6 * (i + 1)))
    for i in range(n - 1):
        g.connect(f"k{i}", f"k{i+1}")
    return g


def test_partition_single_die_trivial():
    g = chain_graph()
    r = partition(g, 1)
    assert r.cut_bytes == 0
    assert set(r.assignment.values()) == {0}


def test_partition_chain_contiguous_cuts():
    g = chain_graph(8)
    r = partition(g, 2)
    # A chain partition should cut at most a couple of edges.
    assert r.cut_bytes <= 2 * row_major((32, 32), (8, 8),
                                        dtype="bfloat16").total_bytes


@pytest.mark.parametrize("dies", [2, 3])
def test_partition_matches_brute_force_on_small_graphs(dies):
    g = chain_graph(5)
    heur = partition(g, dies)
    best = brute_force(g, dies)
    # Local search may not be exact, but must be within 25% of optimum here.
    assert heur.objective <= best.objective * 1.25 + 1e-9


def test_allocation_smallest_tier_first():
    bufs = [Buffer("tiny", 512), Buffer("mid", 64 * 1024),
            Buffer("big", 8 * 2**20)]
    r = allocate(bufs, TPU_TIERS)
    assert r.placement["tiny"] == "SMEM"
    assert r.placement["mid"] == "VMEM"
    assert r.placement["big"] == "VMEM"
    assert not r.spilled


def test_allocation_spills_when_over_capacity():
    bufs = [Buffer(f"b{i}", 20 * 2**20) for i in range(10)]
    r = allocate(bufs, U55C_TIERS)   # 41MB on-chip total
    assert r.spilled                  # cannot fit 200MB on a U55C
    assert len(r.spilled) <= 10


@given(sizes=st.lists(st.integers(64, 2**22), min_size=1, max_size=30))
@settings(max_examples=30, deadline=None)
def test_allocation_places_every_buffer(sizes):
    bufs = [Buffer(f"b{i}", s) for i, s in enumerate(sizes)]
    r = allocate(bufs, TPU_TIERS)
    assert set(r.placement) == {b.name for b in bufs}
    # Tier usage accounting is conservative (>= raw bytes).
    assert sum(r.tier_used.values()) >= sum(sizes)
