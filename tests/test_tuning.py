"""Measured-latency autotuner (DESIGN.md §16).

Four clusters:

  * **Table store** — round-trip persistence, atomic concurrent writers,
    and graceful degradation: a corrupt file, a schema-version mismatch,
    and a backend-fingerprint mismatch each load as an EMPTY table with
    the matching warning ``Diagnostic`` (never an exception, never stale
    entries) so a damaged table degrades to re-tuning, not a crash.
  * **Tuner mechanics** — candidate enumeration (original first, dedup
    by effective block), lint pruning (illegal lattice points are never
    scored), strict-min determinism, frozen-table reproducibility, and
    the measured/analytic provenance stamping.
  * **DSE plumbing** — ``CostSource`` overrides the kernel-latency term,
    ``evaluate_trial`` records per-kernel breakdowns, and
    ``explore(seed_trials=...)`` warm-starts deterministically.
  * **Engine integration** — ``ServingEngine(autotune=path)``: first
    start populates the table, second start performs zero measurement
    dispatches and resolves a bit-identical plan, and greedy tokens are
    unchanged by tuning (block sizes never change kernel math).
"""

import dataclasses
import json
import os
import threading

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.dse import CostSource, evaluate_trial, explore
from repro.core.platforms import TPU_V5E
from repro.core.stream_plan import build_stream_plan, plan_for
from repro.core.trace import trace_block
from repro.tuning import (SCHEMA_VERSION, TuneEntry, TuneTable, Tuner,
                          backend_fingerprint, enumerate_candidates,
                          make_key, measure, measure_candidate,
                          resolve_tuner, use_tuner)


def _cfg(arch="gpt2", **over):
    cfg = get_config(arch).reduced()
    over.setdefault("use_fused_kernels", True)
    return dataclasses.replace(cfg, **over)


def _plan(cfg, tokens=4, kv_len=64, **kw):
    return build_stream_plan(cfg, tokens=tokens, kv_len=kv_len, **kw)


# ------------------------------------------------------- table store

def test_table_round_trip(tmp_path):
    path = str(tmp_path / "t.json")
    t = TuneTable(path=path)
    key = make_key("streamed_ffn", shape=(("t", 4), ("d", 64)),
                   dtype="float32", quant="none", mesh_axes=(),
                   blocks=(("block_t", 256), ("block_f", 128)))
    t.put(key, TuneEntry(latency_s=1.5e-4, source="measured"))
    t.save()
    back = TuneTable.load(path)
    assert not back.diagnostics
    assert len(back) == 1
    got = back.get(key)
    assert got is not None
    assert got.latency_s == pytest.approx(1.5e-4)
    assert got.source == "measured"
    assert back.hits == 1 and back.misses == 0
    assert back.get("no-such-key") is None
    assert back.misses == 1


def test_table_key_is_order_insensitive():
    a = make_key("k", shape=(("t", 4), ("d", 8)), dtype="f32",
                 quant="none", mesh_axes=(), blocks=(("x", 1), ("y", 2)))
    b = make_key("k", shape=(("d", 8), ("t", 4)), dtype="f32",
                 quant="none", mesh_axes=(), blocks=(("y", 2), ("x", 1)))
    assert a == b


def test_table_concurrent_writers_leave_valid_json(tmp_path):
    """Atomic replace: racing saves must each leave a complete, parseable
    file — a reader can never observe a half-written table."""
    path = str(tmp_path / "t.json")
    errs = []

    def writer(i):
        try:
            t = TuneTable(path=path)
            for j in range(20):
                t.put(f"w{i}.e{j}", TuneEntry(latency_s=float(j + 1)))
                t.save()
        except Exception as e:         # pragma: no cover - failure path
            errs.append(e)

    threads = [threading.Thread(target=writer, args=(i,)) for i in range(4)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    assert not errs
    back = TuneTable.load(path)
    assert not back.diagnostics          # parseable, version/backend ok
    assert len(back) == 20               # one writer's complete last save
    assert not os.listdir(str(tmp_path)) == []  # no tmp litter check below
    assert [f for f in os.listdir(str(tmp_path))] == ["t.json"]


def test_table_corrupt_file_degrades_with_warning(tmp_path):
    path = str(tmp_path / "t.json")
    with open(path, "w") as f:
        f.write("{ this is not json")
    t = TuneTable.load(path)
    assert len(t) == 0
    assert any(d.code == "table-corrupt" and d.severity == "warning"
               for d in t.diagnostics)
    # A degraded table still works: fill + save overwrites the wreck.
    t.put("k", TuneEntry(latency_s=1.0))
    t.save()
    assert not TuneTable.load(path).diagnostics


def test_table_schema_version_mismatch(tmp_path):
    path = str(tmp_path / "t.json")
    blob = {"version": SCHEMA_VERSION + 1,
            "backend": backend_fingerprint(),
            "entries": {"k": {"latency_s": 1.0, "source": "measured",
                              "samples": 1}}}
    with open(path, "w") as f:
        json.dump(blob, f)
    t = TuneTable.load(path)
    assert len(t) == 0                   # stale-schema entries dropped
    assert any(d.code == "table-version" for d in t.diagnostics)


def test_table_backend_mismatch(tmp_path):
    path = str(tmp_path / "t.json")
    blob = {"version": SCHEMA_VERSION,
            "backend": "tpu:compiled",   # not this host's fingerprint
            "entries": {"k": {"latency_s": 1.0, "source": "measured",
                              "samples": 1}}}
    with open(path, "w") as f:
        json.dump(blob, f)
    t = TuneTable.load(path)
    assert len(t) == 0                   # foreign measurements dropped
    assert any(d.code == "table-backend" for d in t.diagnostics)


def test_frozen_table_rejects_writes(tmp_path):
    t = TuneTable(path=str(tmp_path / "t.json"), frozen=True)
    with pytest.raises(RuntimeError):
        t.put("k", TuneEntry(latency_s=1.0))
    with pytest.raises(RuntimeError):
        t.save()


# --------------------------------------------------- tuner mechanics

def test_enumerate_candidates_original_first_and_deduped():
    cfg = _cfg()
    plan = _plan(cfg)
    for kind, stage, choice in plan.stage_choices():
        if not choice.fused or stage == "verify_attn":
            continue
        cands = enumerate_candidates(cfg, plan, stage, choice)
        assert cands[0] == choice        # analytic fallback always present
        # Dedup: no two candidates share an effective-block signature.
        from repro.tuning.autotune import _signature
        sigs = [_signature(cfg, plan, stage, c) for c in cands]
        assert len(sigs) == len(set(sigs))
        # Tuning varies stream granularity only — never math flags.
        for c in cands:
            assert c.implementation == choice.implementation
            assert c.block("fuse_norm") == choice.block("fuse_norm")
            assert c.block("w8") == choice.block("w8")


def test_lint_pruning_rejects_illegal_candidates():
    """Full-size gpt2: block 512 does not divide the 768-wide qkv dim, so
    that lattice point survives dedup but must be pruned by the lint —
    never scored, never picked."""
    cfg = dataclasses.replace(get_config("gpt2"), use_fused_kernels=True)
    plan = build_stream_plan(cfg, tokens=256, kv_len=256)
    tuner = Tuner()
    tuned = tuner.tune_plan(cfg, plan)
    assert tuner.stats.pruned > 0
    assert tuner.stats.candidates >= tuner.stats.pruned
    # The winner at every tuned stage is lint-clean or the original.
    from repro.analysis.kernel_lint import check_kernels
    base_dirty = {(d.stage, d.code)
                  for d in check_kernels(plan, cfg, TPU_V5E)
                  if d.severity in ("error", "warning")}
    tuned_dirty = {(d.stage, d.code)
                   for d in check_kernels(tuned, cfg, TPU_V5E)
                   if d.severity in ("error", "warning")}
    assert tuned_dirty <= base_dirty     # tuning never dirties a plan


def test_tuned_registry_plan_verifies_clean():
    """The reduced-config sweep contract: a tuned plan passes the static
    verifier exactly as strictly as the analytic plan it came from."""
    from repro.analysis import clean, verify_plan
    for arch in ("gpt2", "llama3-8b", "qwen3-0.6b"):
        cfg = _cfg(arch)
        plan = _plan(cfg, tune=True)
        diags = verify_plan(plan, cfg, None, slots=2, max_len=64)
        assert clean(diags), (arch, [str(d) for d in diags])


def test_tuner_deterministic_and_frozen_table_reproducible(tmp_path):
    path = str(tmp_path / "t.json")
    cfg = _cfg()
    p1 = _plan(cfg, tune=Tuner(TuneTable(path=path)))
    # Frozen reload: scoring is table-only lookups, plans bit-identical.
    frozen = TuneTable.load(path)
    frozen.frozen = True
    t2 = Tuner(frozen)
    t3 = Tuner(TuneTable.load(path))
    p2 = _plan(cfg, tune=t2)
    p3 = _plan(cfg, tune=t3)
    assert p1 == p2 == p3
    assert t2.stats.measured == 0        # frozen run never measures
    assert t2.table.hits > 0


def test_tuner_stamps_sources_and_syncs_verify_pages():
    cfg = _cfg("llama3-8b")
    plan = _plan(cfg, tokens=8, kv_len=64)
    tuner = Tuner(force_measure=True)    # wall-clock even in interpret
    tuned = tuner.tune_plan(cfg, plan)
    assert tuned.cost_source in ("measured", "hybrid")
    srcs = {f"{k}.{s}": c.source for k, s, c in tuned.stage_choices()
            if c.fused}
    assert any(v == "measured" for v in srcs.values())
    # verify_attn mirrors decode_attn's page size (same paged pool).
    for kind, lp in tuned.layers:
        if lp.verify_attn.fused and lp.decode_attn.fused:
            assert (lp.verify_attn.block("page_size")
                    == lp.decode_attn.block("page_size"))
    # summary carries the provenance satellites.
    summ = tuned.summary()
    assert summ["plan_source"] == tuned.cost_source
    assert summ["stage_sources"]         # measured stages are listed


def test_measure_candidate_interpret_falls_back_to_analytic():
    cfg = _cfg()
    plan = _plan(cfg)
    for kind, stage, choice in plan.stage_choices():
        if not choice.fused:
            continue
        lat, src = measure_candidate(
            cfg, plan, kind, stage, choice, platform=TPU_V5E)
        assert src == "analytic" and lat > 0.0
        break


def test_measure_wall_clock_path():
    calls = []

    def fn():
        calls.append(1)
        return np.zeros(1)

    lat = measure(fn, reps=3, warmup=1)
    assert lat >= 0.0
    assert len(calls) == 4               # warmup + reps


def test_resolve_tuner_specs(tmp_path):
    cfg = _cfg()
    assert resolve_tuner(None, cfg) is None
    assert resolve_tuner(False, cfg) is None
    t = Tuner()
    assert resolve_tuner(t, cfg) is t
    tt = resolve_tuner(str(tmp_path / "x.json"), cfg)
    assert tt.table.path == str(tmp_path / "x.json")
    td = resolve_tuner(str(tmp_path), cfg)
    assert td.table.path == str(tmp_path / f"{cfg.name}.json")
    with pytest.raises(TypeError):
        resolve_tuner(123, cfg)


def test_use_tuner_context_reaches_plan_for():
    cfg = _cfg()
    plan_for.cache_clear()
    tuner = Tuner()
    with use_tuner(tuner):
        plan = plan_for(cfg, 4, 64)
    assert tuner.stats.stages > 0        # plan_for consulted the tuner
    assert plan == tuner.tune_plan(cfg, plan_for(cfg, 4, 64))


# ------------------------------------------------------ DSE plumbing

def _ops(cfg):
    return trace_block(cfg, tokens=8, kv_len=64)


def test_evaluate_trial_records_breakdown():
    cfg = _cfg()
    trial = evaluate_trial(_ops(cfg), TPU_V5E, 64, 64)
    assert trial.breakdown                # per-kernel timing terms
    for name, row in trial.breakdown.items():
        assert row["kernel_s"] >= 0.0 and row["source"] == "analytic"
    assert trial.dma_s > 0.0
    assert trial.cost_source == "analytic"


def test_cost_source_overrides_kernel_latency():
    cfg = _cfg()
    ops = _ops(cfg)
    base = evaluate_trial(ops, TPU_V5E, 64, 64)
    slow = CostSource(mode="measured", lookup=lambda name: 1.0)
    trial = evaluate_trial(ops, TPU_V5E, 64, 64, cost_source=slow)
    assert trial.cost_source == "measured"
    assert trial.latency_s > base.latency_s
    assert all(r["source"] == "measured"
               for r in trial.breakdown.values())
    # Hybrid: misses are filled through the fill callback.
    filled = []
    hy = CostSource(mode="hybrid", lookup=lambda name: None,
                    fill=lambda name, s: filled.append(name) or s)
    evaluate_trial(ops, TPU_V5E, 64, 64, cost_source=hy)
    assert filled                         # every kernel went through fill
    with pytest.raises(ValueError):
        CostSource(mode="bogus")


def test_explore_seed_trials_deterministic():
    cfg = _cfg()
    ops = _ops(cfg)
    r1 = explore(ops, TPU_V5E, budget=6, seed_trials=[(64, 32)])
    r2 = explore(ops, TPU_V5E, budget=6, seed_trials=[(64, 32)])
    assert r1.seed_trials == r2.seed_trials == ((64, 32),)
    assert r1.best.params == r2.best.params
    assert [t.params for t in r1.trials] == [t.params for t in r2.trials]
    # Seeding the known winner reproduces it even with zero random budget.
    r3 = explore(ops, TPU_V5E, budget=1,
                 seed_trials=[tuple(r1.best.params.values())])
    assert r3.best.params == r1.best.params


# ------------------------------------------------- engine integration

@pytest.mark.slow
def test_engine_autotune_build_once_reuse(tmp_path):
    import jax

    from repro.models import init_params
    from repro.serving.engine import ServingEngine

    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    path = str(tmp_path / "gpt2.json")
    prompts = [np.arange(1, 9, dtype=np.int32)]

    eng1 = ServingEngine(cfg, params, batch_slots=2, max_len=64,
                         autotune=path)
    out1 = eng1.generate([p.copy() for p in prompts], max_new_tokens=6)
    assert os.path.exists(path)
    assert eng1.tuner.stats.measured > 0
    assert eng1.metrics["autotuned"] == 1
    assert eng1.metrics["tune_table"] == path
    assert eng1.metrics["tune_entries"] > 0
    assert eng1.metrics["plan_source"] in ("analytic", "measured",
                                           "hybrid")

    plan_for.cache_clear()               # fresh-process stand-in
    eng2 = ServingEngine(cfg, params, batch_slots=2, max_len=64,
                         autotune=path)
    out2 = eng2.generate([p.copy() for p in prompts], max_new_tokens=6)
    assert eng2.tuner.stats.measured == 0   # everything served from disk
    assert eng2.metrics["tune_hits"] > 0
    assert eng1.plan == eng2.plan           # bit-identical resolution
    assert out1[0].out_tokens == out2[0].out_tokens


@pytest.mark.slow
def test_engine_autotune_matches_untuned_tokens(tmp_path):
    """Tuning changes stream granularity, never kernel math: greedy
    tokens from a tuned engine equal the untuned engine's."""
    import jax

    from repro.models import init_params
    from repro.serving.engine import ServingEngine

    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompts = [np.arange(1, 9, dtype=np.int32),
               np.arange(5, 12, dtype=np.int32)]

    plan_for.cache_clear()
    base = ServingEngine(cfg, params, batch_slots=2, max_len=64)
    ref = base.generate([p.copy() for p in prompts], max_new_tokens=6)
    assert base.metrics["autotuned"] == 0
    assert base.metrics["plan_source"] == "analytic"

    plan_for.cache_clear()
    tuned = ServingEngine(cfg, params, batch_slots=2, max_len=64,
                          autotune=str(tmp_path / "t.json"))
    got = tuned.generate([p.copy() for p in prompts], max_new_tokens=6)
    for a, b in zip(ref, got):
        assert a.out_tokens == b.out_tokens


def test_engine_warns_on_degraded_table(tmp_path):
    import jax

    from repro.models import init_params
    from repro.serving.engine import ServingEngine

    path = str(tmp_path / "t.json")
    with open(path, "w") as f:
        f.write("not json at all")
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    plan_for.cache_clear()
    with pytest.warns(UserWarning, match="autotune table degraded"):
        ServingEngine(cfg, params, batch_slots=2, max_len=64,
                      autotune=path)
