"""Unit + property tests for the itensor type system (paper §3.1, Fig. 5)."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (AffineMap, ITensorType, col_major, fig5_b, fig5_c,
                        itensor_from_tiling, row_major)


class TestAffineMap:
    def test_identity(self):
        m = AffineMap.identity(3)
        assert m.apply((1, 2, 3)) == (1, 2, 3)
        assert m.is_identity() and m.is_permutation()

    def test_transpose(self):
        m = AffineMap.transpose2d()
        assert m.apply((7, 9)) == (9, 7)

    def test_projection_reuse_dims(self):
        m = AffineMap(3, (2, 0))  # Fig. 5(c) map
        assert m.reuse_dims == (1,)
        assert m.apply((10, 20, 30)) == (30, 10)

    def test_injectivity_enforced(self):
        with pytest.raises(ValueError):
            AffineMap(2, (0, 0))

    def test_compose_permutation_roundtrip(self):
        m = AffineMap(3, (2, 0))
        ident = m.compose_permutation((0, 1, 2))
        assert ident == m


class TestFig5Examples:
    """The three layouts in paper Fig. 5 with their exact index sequences."""

    def test_fig5_b_stream_order(self):
        t = fig5_b()
        offsets = list(t.stream_offsets())
        # Paper: indices [0,0], [4,0], [0,2], [4,2], ... (transposed walk).
        assert offsets[:4] == [(0, 0), (4, 0), (0, 2), (4, 2)]
        assert len(offsets) == 8
        assert t.data_shape == (8, 8)
        assert t.num_tokens == 8
        assert t.reuse_factor == 1

    def test_fig5_c_stream_order(self):
        t = fig5_c()
        offsets = list(t.stream_offsets())
        # Paper: [0,0], [4,0], [0,0], [4,0], [0,2], ... (d1 re-iterates).
        assert offsets[:5] == [(0, 0), (4, 0), (0, 0), (4, 0), (0, 2)]
        assert t.data_shape == (8, 8)
        assert t.num_tokens == 16
        assert t.reuse_factor == 2

    def test_case1_match_case2_mismatch(self):
        # Two producers with identical types stream-connect (Case 1)...
        assert fig5_b().matches(fig5_b())
        # ...but (b) and (c) mismatch and need a converter (Case 2).
        assert not fig5_b().matches(fig5_c())


class TestConstructors:
    def test_row_major_covers_in_order(self):
        t = row_major((8, 8), (4, 2))
        offsets = list(t.stream_offsets())
        assert offsets[:5] == [(0, 0), (0, 2), (0, 4), (0, 6), (4, 0)]

    def test_col_major_matches_fig5b(self):
        t = col_major((8, 8), (4, 2))
        assert list(t.stream_offsets()) == list(fig5_b().stream_offsets())

    def test_reuse_insertion_matches_fig5c(self):
        t = itensor_from_tiling((8, 8), (4, 2), loop_order=(1, 0),
                                reuse=[(1, 2)])
        assert list(t.stream_offsets()) == list(fig5_c().stream_offsets())

    def test_indivisible_rejected(self):
        with pytest.raises(ValueError):
            itensor_from_tiling((8, 8), (3, 2))

    def test_overlap_rejected(self):
        with pytest.raises(ValueError):
            ITensorType((4, 4), (4, 2), (2, 4), AffineMap(2, (1, 0)))


class TestTokenAccounting:
    def test_bytes(self):
        t = row_major((64, 64), (16, 16), dtype="bfloat16")
        assert t.num_tokens == 16
        assert t.token_bytes == 16 * 16 * 2
        assert t.total_bytes == 64 * 64 * 2
        assert t.data_bytes == 64 * 64 * 2

    def test_reuse_inflates_stream_not_data(self):
        t = fig5_c()
        assert t.total_bytes == 2 * t.data_bytes


class TestTransformations:
    def test_permute_loops_preserves_data_space(self):
        t = row_major((8, 8), (4, 2))
        p = t.permute_loops((1, 0))
        assert p.data_shape == t.data_shape
        assert list(p.stream_offsets()) == list(col_major((8, 8), (4, 2)).stream_offsets())

    def test_vectorize(self):
        t = row_major((64, 64), (16, 16))
        v = t.vectorize((1, 2))
        assert v.elem_shape == (16, 32)
        assert v.num_tokens == t.num_tokens // 2
        assert v.data_shape == t.data_shape

    def test_canonicalize_drops_trip1_reuse(self):
        t = itensor_from_tiling((8, 8), (4, 2), reuse=[(0, 1)])
        c = t.canonicalize()
        assert c.iter_rank == 2
        assert c.equivalent(row_major((8, 8), (4, 2)))


class TestBlockSpecExport:
    def test_block_spec_roundtrip(self):
        t = col_major((8, 8), (4, 2))
        block_shape, index_map = t.block_spec_args()
        assert block_shape == (4, 2)
        # Grid coordinate (i0, i1) -> block coords, matching stream offsets.
        grid = t.tripcounts
        offs = []
        for i0 in range(grid[0]):
            for i1 in range(grid[1]):
                b = index_map(i0, i1)
                offs.append(tuple(bi * ei for bi, ei in zip(b, t.elem_shape)))
        assert offs == list(t.stream_offsets())


# ------------------------------------------------------------------ #
# Property tests
# ------------------------------------------------------------------ #

@st.composite
def tiled_itensor(draw, max_rank=3):
    rank = draw(st.integers(1, max_rank))
    tiles = [draw(st.sampled_from([1, 2, 4])) for _ in range(rank)]
    grid = [draw(st.integers(1, 4)) for _ in range(rank)]
    data = [t * g for t, g in zip(tiles, grid)]
    order = draw(st.permutations(list(range(rank))))
    dtype = draw(st.sampled_from(["float32", "bfloat16", "int8"]))
    return itensor_from_tiling(data, tiles, loop_order=list(order), dtype=dtype)


@given(tiled_itensor())
@settings(max_examples=60, deadline=None)
def test_stream_covers_every_tile_exactly_once(t):
    """Invariant: an exact tiling without reuse emits each tile once."""
    ids = list(t.stream_tile_ids())
    assert sorted(ids) == list(range(t.num_tokens))


@given(tiled_itensor())
@settings(max_examples=60, deadline=None)
def test_offsets_within_bounds_and_aligned(t):
    for off in t.stream_offsets():
        for o, e, d in zip(off, t.elem_shape, t.data_shape):
            assert 0 <= o <= d - e
            assert o % e == 0


@given(tiled_itensor(), st.permutations([0, 1, 2]))
@settings(max_examples=40, deadline=None)
def test_loop_permutation_is_a_bijection_on_tiles(t, perm3):
    perm = [p for p in perm3 if p < t.iter_rank]
    if sorted(perm) != list(range(t.iter_rank)):
        return
    p = t.permute_loops(perm)
    assert sorted(p.stream_tile_ids()) == sorted(t.stream_tile_ids())
    assert p.data_shape == t.data_shape
