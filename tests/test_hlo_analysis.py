"""Validate the loop-aware HLO analyzer against controlled programs."""

import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_analysis import analyze_hlo


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def _xla_cost(compiled):
    """``Compiled.cost_analysis()`` returns one dict per partition on older
    jax (a list) and a plain dict on newer releases."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return ca


def test_plain_matmul_flops_match_xla():
    x = jnp.zeros((128, 256), jnp.float32)
    w = jnp.zeros((256, 512), jnp.float32)
    c = _compile(lambda a, b: a @ b, x, w)
    ours = analyze_hlo(c.as_text())
    want = 2 * 128 * 256 * 512
    assert abs(ours["flops"] - want) / want < 0.05
    xla = _xla_cost(c)["flops"]
    assert abs(ours["flops"] - xla) / xla < 0.05


def test_scan_multiplies_by_trip_count():
    """THE bug this module exists to fix: XLA counts while bodies once."""
    def f(x):
        def body(c, _):
            return c @ c, None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    x = jnp.zeros((128, 128), jnp.float32)
    c = _compile(f, x)
    ours = analyze_hlo(c.as_text())
    one = 2 * 128 ** 3
    assert abs(ours["flops"] - 10 * one) / (10 * one) < 0.05
    xla = _xla_cost(c)["flops"]
    assert xla < 2 * one            # XLA counted the body once
    assert ours["flops"] > 8 * xla  # we restored the factor


def test_nested_scans():
    def f(x):
        def inner(c, _):
            return c @ c, None

        def outer(c, _):
            y, _ = jax.lax.scan(inner, c, None, length=4)
            return y + 1.0, None
        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y

    x = jnp.zeros((64, 64), jnp.float32)
    c = _compile(f, x)
    ours = analyze_hlo(c.as_text())
    want = 3 * 4 * 2 * 64 ** 3
    assert abs(ours["flops"] - want) / want < 0.10


def test_dot_with_batch_dims():
    x = jnp.zeros((8, 64, 32), jnp.float32)
    w = jnp.zeros((8, 32, 16), jnp.float32)
    c = _compile(lambda a, b: jnp.einsum("bij,bjk->bik", a, b), x, w)
    ours = analyze_hlo(c.as_text())
    want = 2 * 8 * 64 * 32 * 16
    assert abs(ours["flops"] - want) / want < 0.05


def test_collectives_counted_with_trip_scaling():
    devs = jax.devices()
    if len(devs) < 2:
        pytest.skip("needs >=2 devices (run under forced host devices)")
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((len(devs),), ("model",))
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32,
                             sharding=NamedSharding(mesh, P(None, "model")))
    x = jax.ShapeDtypeStruct((32, 256), jnp.float32,
                             sharding=NamedSharding(mesh, P()))

    def f(a, b):
        def body(c, _):
            h = c @ b                                   # sharded out
            h = jax.lax.with_sharding_constraint(
                h, NamedSharding(mesh, P()))            # all-gather
            return h, None
        y, _ = jax.lax.scan(body, a, None, length=6)
        return y

    c = jax.jit(f).lower(x, w).compile()
    ours = analyze_hlo(c.as_text())
    # 6 iterations x all-gather of a [32,256] f32 activation.
    assert ours["collective_link_total"] > 0
    n = len(devs)
    per_ag = 32 * 256 * 4 * (n - 1) / n
    total = ours["collective_link_total"]
    assert total >= 5 * per_ag * 0.5   # trip scaling happened


def test_memory_bytes_reasonable():
    x = jnp.zeros((1024, 1024), jnp.float32)
    c = _compile(lambda a: jnp.tanh(a) + 1.0, x)
    ours = analyze_hlo(c.as_text())
    want = 2 * 1024 * 1024 * 4          # read + write
    assert 0.5 * want <= ours["bytes_accessed"] <= 4 * want
