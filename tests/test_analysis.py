"""Static stream verifier (DESIGN.md §15).

Two halves:

  * **Golden seeded-bad fixtures** — five deliberately-broken plans /
    pool schemas / dispatch signatures, each asserting the verifier
    produces the expected diagnostic (pass, stage, severity, code)
    without ever tracing a kernel.
  * **Registry sweep** — every shipped config × {none, kv_int8, w8_kv8}
    × {single-device, 8-device AbstractMesh} builds its StreamPlan and
    verifies *clean* (no errors, no warnings; info-level fallback notes
    are fine) — the strict-by-default engine hook depends on this.

Plus unit coverage for the itensor reconstruction (elem_shape == the
plan's blocks, tripcounts == the stage grid), the ``_DTYPE_BYTES``
extension (fp8 variants, fractional int4), and the engine hook itself.
"""

import copy
import dataclasses

import numpy as np
import pytest

from repro.analysis import (Diagnostic, PlanVerificationError, clean,
                            errors, stage_itensors, verify_plan)
from repro.analysis.effects import check_effects
from repro.configs import ARCHS, get_config
from repro.core.itensor import dtype_bytes
from repro.core.stream_plan import (EAGER, KernelChoice, LayerPlan,
                                    StreamPlan, build_stream_plan)
from repro.models.layers import DISPATCH_EFFECTS
from repro.serving.kv_cache import paged_cache_defs

QUANTS = ("none", "kv_int8", "w8_kv8")


def _cfg(arch="llama3-8b", **over):
    cfg = get_config(arch).reduced()
    over.setdefault("use_fused_kernels", True)
    return dataclasses.replace(cfg, **over)


def _plan(cfg, tokens=4, kv_len=64, mesh=None):
    return build_stream_plan(cfg, tokens=tokens, kv_len=kv_len, mesh=mesh)


def _mesh8():
    from jax.sharding import AbstractMesh
    return AbstractMesh((("data", 2), ("model", 4)))


def _find(diags, code):
    return [d for d in diags if d.code == code]


# ------------------------------------------------- seeded-bad fixtures

def test_bad_non_divisible_block():
    """Fixture 1: an lm_head block_v that doesn't divide the vocab is
    flagged (the wrapper would silently clip it)."""
    cfg = _cfg()
    plan = _plan(cfg)
    bad = dataclasses.replace(plan, lm_head=KernelChoice(
        "streamed_xent", (("block_t", plan.tokens), ("block_v", 192))))
    diags = verify_plan(bad, cfg)
    hits = _find(diags, "non-divisible-block")
    assert hits, [str(d) for d in diags]
    d = hits[0]
    assert d.severity == "warning" and d.pass_name == "kernel"
    assert d.stage == "final.lm_head"
    assert "192" in d.message and d.fix_hint


def test_bad_over_vmem_tile():
    """Fixture 2: a full-size FFN tile that cannot fit in VMEM is a hard
    error — the hand-built plan is never traced."""
    cfg = dataclasses.replace(get_config("llama3-8b"),
                              use_fused_kernels=True)
    lp = LayerPlan(kind="attn", ffn=KernelChoice(
        "streamed_ffn", (("block_t", 512), ("block_f", cfg.d_ff))))
    plan = StreamPlan(
        arch=cfg.name, tokens=512, kv_len=512, platform="TPU-v5e",
        default_tile_size=128, overall_unroll_size=64,
        layers=(("attn", lp),), quant=cfg.quant)
    diags = verify_plan(plan, cfg)
    hits = _find(diags, "vmem-exceeded")
    assert hits, [str(d) for d in diags]
    d = hits[0]
    assert d.severity == "error" and d.pass_name == "kernel"
    assert d.stage == "attn.ffn" and "MiB" in d.message


def test_bad_mismatched_psum_axes():
    """Fixture 3: column-parallel qkv reducing over 'model' while the
    row-parallel FFN psums over 'data' is a coherence error."""
    cfg = dataclasses.replace(get_config("llama3-8b"),
                              use_fused_kernels=True)
    lp = LayerPlan(
        kind="attn",
        qkv=KernelChoice("rmsnorm_matmul",
                         (("block_t", 128), ("block_n", 128)),
                         (("tokens", "data"), ("out", "model"))),
        ffn=KernelChoice("streamed_ffn",
                         (("block_t", 128), ("block_f", 128)),
                         (("d_ff", "data"),)))
    plan = StreamPlan(
        arch=cfg.name, tokens=256, kv_len=256, platform="TPU-v5e",
        default_tile_size=128, overall_unroll_size=64,
        layers=(("attn", lp),), quant=cfg.quant,
        mesh_axes=(("data", 2), ("model", 4)))
    diags = verify_plan(plan, cfg)
    hits = _find(diags, "psum-mismatch")
    assert hits, [str(d) for d in diags]
    d = hits[0]
    assert d.severity == "error" and d.pass_name == "sharding"
    assert d.stage == "attn.ffn"
    assert "'model'" in d.message and "'data'" in d.message


def test_bad_missing_scale_pool():
    """Fixture 4: a quantized pool tree missing a _scale sibling."""
    cfg = _cfg(quant="kv_int8")
    plan = _plan(cfg)
    defs = paged_cache_defs(cfg, 2, 64, 16)
    victim = None
    for group in defs["blocks"] + defs["rest"]:
        for name in list(group):
            if name.endswith("_scale"):
                victim = name
                del group[name]
                break
        if victim:
            break
    assert victim is not None
    diags = check_effects(plan, cfg, page_size=16, cache_defs=defs)
    hits = _find(diags, "missing-scale-pool")
    assert hits
    d = hits[0]
    assert d.severity == "error" and d.pass_name == "effects"
    assert d.stage == f"pool.{victim[:-len('_scale')]}"
    # The intact schema verifies clean.
    good = paged_cache_defs(cfg, 2, 64, 16)
    assert not errors(check_effects(plan, cfg, page_size=16,
                                    cache_defs=good))


def test_bad_cow_self_alias():
    """Fixture 5: a decode signature whose copy-on-write step loses the
    fresh-dst allocator guarantee."""
    cfg = _cfg()
    plan = _plan(cfg)
    sigs = copy.deepcopy(DISPATCH_EFFECTS)
    sigs["decode"]["ops"][0]["cow"]["fresh_dst"] = False
    diags = check_effects(plan, cfg, signatures=sigs)
    hits = _find(diags, "cow-self-alias")
    assert hits
    d = hits[0]
    assert d.severity == "error" and d.pass_name == "effects"
    assert d.stage == "dispatch.decode"
    # The shipped signatures carry no such bug.
    assert not errors(check_effects(plan, cfg))


def test_bad_donated_read_after_write():
    """Reordering a dispatch's ops so the initial-contents read follows
    a write to the donated buffer is rejected."""
    cfg = _cfg()
    plan = _plan(cfg)
    sigs = copy.deepcopy(DISPATCH_EFFECTS)
    sigs["decode"]["ops"] = tuple(reversed(sigs["decode"]["ops"]))
    diags = check_effects(plan, cfg, signatures=sigs)
    hits = _find(diags, "donated-read-after-write")
    assert hits and hits[0].severity == "error"
    assert hits[0].stage == "dispatch.decode"


def test_bad_scale_lockstep_and_null_routing():
    """Dropping updates_scales (under KV quant) or null_routed from a
    page-indexed write is rejected."""
    cfg = _cfg(quant="kv_int8")
    plan = _plan(cfg)
    sigs = copy.deepcopy(DISPATCH_EFFECTS)
    op = dict(sigs["prefill"]["ops"][1])
    op["updates_scales"] = False
    op["null_routed"] = False
    sigs["prefill"]["ops"] = (sigs["prefill"]["ops"][0], op)
    diags = check_effects(plan, cfg, signatures=sigs)
    assert _find(diags, "scale-lockstep")
    assert _find(diags, "unguarded-null-page")
    assert all(d.stage == "dispatch.prefill" for d in errors(diags))


def test_bad_quant_mismatch_and_unknown_kernel():
    cfg = _cfg(quant="kv_int8")
    plan = _plan(_cfg(quant="none"))           # plan from the wrong mode
    diags = verify_plan(plan, cfg)
    assert any(d.code in ("quant-mismatch", "prefetch-arity")
               and d.severity == "error" for d in diags)
    bad = dataclasses.replace(
        plan, lm_head=KernelChoice("warp_gemm", (("block_t", 4),)))
    hits = _find(verify_plan(bad, _cfg(quant="none")), "unknown-kernel")
    assert hits and hits[0].severity == "error"


def test_mesh_mismatch():
    """A plan built for one mesh verified against another is an error."""
    cfg = _cfg()
    plan = _plan(cfg, mesh=_mesh8())
    from jax.sharding import AbstractMesh
    other = AbstractMesh((("data", 4), ("model", 2)))
    diags = verify_plan(plan, cfg, mesh=other)
    hits = _find(diags, "mesh-mismatch")
    assert hits and hits[0].severity == "error"


# ------------------------------------------------------- registry sweep

@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_registry_verifies_clean(arch):
    """Every shipped config × quant mode × mesh verifies clean — the
    invariant that makes verify='strict' safe as the engine default."""
    for quant in QUANTS:
        cfg = _cfg(arch, quant=quant)
        for mesh in (None, _mesh8()):
            plan = _plan(cfg, mesh=mesh)
            diags = verify_plan(plan, cfg, mesh, slots=2, max_len=64)
            assert clean(diags), (
                f"{arch}/{quant}/mesh={mesh is not None}: "
                + "; ".join(str(d) for d in diags if d.severity != "info"))
            assert plan.with_verification(True, ()).verified is True


# --------------------------------------------- itensor reconstruction

def test_stage_itensors_mirror_blocks():
    """Reconstructed itensors are the type-level twin of the BlockSpec:
    elem_shape == effective blocks, tripcounts == the stage grid."""
    cfg = _cfg("gpt2")
    plan = _plan(cfg, tokens=8, kv_len=64)
    its = stage_itensors(plan, cfg)
    assert its, "no fused stages reconstructed"
    for (kind, stage), it in its.items():
        assert it.is_exact_tiling()
        for elem, trips, extent in zip(it.elem_shape, it.tripcounts,
                                       it.data_shape):
            assert elem * trips == extent
    # The qkv stage's token tile is its block_t target (post-clip).
    for kind, lp in plan.layers:
        if lp.qkv.fused and (kind, "qkv") in its:
            it = its[(kind, "qkv")]
            assert it.elem_shape[0] <= max(lp.qkv.block("block_t"),
                                           plan.tokens)


def test_plan_summary_records_verification():
    cfg = _cfg()
    plan = _plan(cfg)
    assert plan.summary()["verified"] is None
    v = plan.with_verification(True, ("[info] x",))
    s = v.summary()
    assert s["verified"] is True and s["diagnostics"] == ["[info] x"]


# ----------------------------------------------------- dtype coverage

def test_dtype_bytes_extended():
    assert dtype_bytes("float8_e5m2") == 1
    assert dtype_bytes("float8_e4m3fn") == 1
    assert dtype_bytes("bfloat16") == 2
    assert dtype_bytes("int4") == 0.5
    assert dtype_bytes("uint4") == 0.5
    with pytest.raises(ValueError):
        dtype_bytes("tf32x9")


# ---------------------------------------------------------- engine hook

def test_engine_verify_strict_default(rng_params):
    import jax

    from repro.serving import ServingEngine
    cfg, params = rng_params
    eng = ServingEngine(cfg, params, batch_slots=2, max_len=64)
    assert eng.verify_mode == "strict"
    assert eng.plan is not None and eng.plan.verified is True
    assert eng.metrics["verified"] == 1
    assert eng.plan.summary()["verified"] is True


def test_engine_verify_rejects_bad_mode(rng_params):
    from repro.serving import ServingEngine
    cfg, params = rng_params
    with pytest.raises(ValueError, match="verify mode"):
        ServingEngine(cfg, params, batch_slots=2, max_len=64,
                      verify="paranoid")


def test_engine_verify_off_skips(rng_params):
    from repro.serving import ServingEngine
    cfg, params = rng_params
    eng = ServingEngine(cfg, params, batch_slots=2, max_len=64,
                        verify="off")
    assert eng.plan.verified is None and eng.metrics["verified"] == 0


@pytest.fixture(scope="module")
def rng_params():
    import jax

    from repro.models import init_params
    cfg = _cfg("qwen1.5-0.5b")
    return cfg, init_params(jax.random.PRNGKey(0), cfg)


def test_diagnostic_validation():
    with pytest.raises(ValueError):
        Diagnostic("fatal", "kernel", "x", "c", "m")
    with pytest.raises(ValueError):
        Diagnostic("error", "vibes", "x", "c", "m")
    d = Diagnostic("error", "kernel", "attn.ffn", "code", "msg", "hint")
    assert "kernel:code" in str(d) and "fix: hint" in str(d)
    err = PlanVerificationError([d])
    assert d in err.diagnostics and "1 error" in str(err)
