"""Chunked prefill: one compiled program for any prompt-length mix.

Four layers of coverage:

  * Model-level equivalence — ``prefill_chunk`` driven chunk-by-chunk
    over the paged pools matches whole-prompt ``prefill`` (logits at the
    last real token, the emitted token, and every K/V row) for BOTH
    cache layouts, to 1e-6 under f32 compute.
  * Compile-count — a mixed burst of >= 4 distinct prompt lengths
    through the engine compiles exactly ONE prefill program and ONE
    decode program (counted by the engine's trace-time probe), while
    every request still bit-matches its serial per-request reference.
  * Chunk-size provenance — the chunk is a whole multiple of the KV page
    size, derived from the StreamPlan's attention query tile.
  * Admission contract — empty / over-long prompts are failed at
    admission (no slot, no pages, engine keeps serving) and the latency
    properties of never-served requests report ``nan`` instead of
    negative garbage.
"""

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import (init_params, prefill, prefill_chunk, resolve_plan,
                          supports_chunked_prefill)
from repro.serving import PagedKVCache, Request, ServingEngine, gather_pages
from repro.serving.kv_cache import stage_chunk

from test_paged_serving import _serial_reference


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


def _cfg(arch="qwen1.5-0.5b", **over):
    cfg = get_config(arch).reduced()
    return dataclasses.replace(cfg, **over) if over else cfg


def _run_chunked(cfg, params, prompt, kv, slot, chunk):
    """Drive ``prefill_chunk`` over a prompt the way the engine does:
    fixed-size page-aligned chunks, NULL pages past capacity, one jitted
    program.  Returns (next_tok, last_logits, cache)."""
    ps = kv.page_size
    assert chunk % ps == 0
    plen = int(prompt.shape[0])
    cache = kv.init_cache()
    step = jax.jit(
        lambda p, t, c, row, cp, off, li: prefill_chunk(
            p, cfg, t, c, row, cp, off, li),
        donate_argnums=(2,))
    nt = lg = None
    for k in range(-(-plen // chunk)):
        off = k * chunk
        kv.ensure(slot, min(off + chunk, kv.max_len))
        row = kv.table_row(slot)
        toks, cpages, last = stage_chunk(prompt, off, chunk, row, ps)
        nt, lg, cache = step(params, jnp.asarray(toks)[None], cache,
                             jnp.asarray(row), jnp.asarray(cpages),
                             jnp.int32(off), jnp.int32(last))
    return nt, lg, cache


# --------------------------------------------------- gating / provenance

def test_supports_chunked_prefill_gating():
    assert supports_chunked_prefill(_cfg())                  # attention
    assert supports_chunked_prefill(_cfg("llama3-8b"))       # GQA
    assert not supports_chunked_prefill(_cfg("zamba2-2.7b"))  # hybrid SSM
    assert not supports_chunked_prefill(_cfg("rwkv6-7b"))     # recurrent
    assert not supports_chunked_prefill(_cfg("qwen2-vl-2b"))  # mrope


def test_chunk_size_is_plan_derived_page_multiple():
    fused = _cfg("llama3-8b", use_fused_kernels=True)
    plan = resolve_plan(fused, 2, kv_len=64)
    ps = plan.decode_page_size(16)
    chunk = plan.prefill_chunk_size(ps)
    assert chunk % ps == 0
    # The chunk covers the attention query tile the DSE chose.
    bq = plan.layer("attn").attention.kw.get("block_q", 128)
    assert chunk >= bq
    assert plan.prefill_chunk_size(ps) - bq < ps    # tight rounding


def test_engine_chunk_is_page_aligned(rng):
    cfg = _cfg()
    params = init_params(rng, cfg)
    eng = ServingEngine(cfg, params, batch_slots=2, max_len=48,
                        decode_block=4, page_size=8)
    assert eng.chunked and eng.chunk % eng.kv.page_size == 0
    assert eng.chunk <= eng.kv.extent
    # Explicit override is rounded up to the page grid.
    eng2 = ServingEngine(cfg, params, batch_slots=2, max_len=48,
                         decode_block=4, page_size=8, prefill_chunk=12)
    assert eng2.chunk == 16
    with pytest.raises(ValueError, match="requires the paged cache"):
        ServingEngine(cfg, params, batch_slots=2, max_len=48,
                      paged=False, chunked=True)
    with pytest.raises(ValueError, match="does not support"):
        ServingEngine(_cfg("rwkv6-7b"), None, batch_slots=2, max_len=48,
                      chunked=True)


# ------------------------------------------------- model-level equality

@pytest.mark.parametrize("layout", ["bshd", "bhsd"])
def test_chunked_matches_whole_prefill(rng, layout):
    """Chunked prefill == whole-prompt prefill to 1e-6 (f32 compute) for
    both cache layouts: last-token logits, emitted token, and every K/V
    row read back through the page indirection."""
    cfg = _cfg(dtype="float32", kv_cache_layout=layout)
    params = init_params(rng, cfg)
    plen, chunk, ps, max_len = 13, 8, 4, 24    # final chunk partial
    prompt = np.random.default_rng(1).integers(
        1, cfg.vocab_size, plen).astype(np.int32)
    kv = PagedKVCache(cfg, slots=2, max_len=max_len, page_size=ps)
    slot = 1
    nt, lg, cache = _run_chunked(cfg, params, prompt, kv, slot, chunk)

    whole_lg, fresh = jax.jit(lambda p, b: prefill(p, cfg, b))(
        params, {"tokens": jnp.asarray(prompt)[None]})
    assert int(np.asarray(nt)[0, 0]) == int(jnp.argmax(whole_lg, -1)[0, 0])
    np.testing.assert_allclose(np.asarray(lg), np.asarray(whole_lg),
                               atol=1e-6)
    table = kv.page_table
    for leaf in ("k", "v"):
        big = cache["blocks"][0][leaf]
        small = fresh["blocks"][0][leaf]
        for g in range(big.shape[0]):
            seq = gather_pages(big[g], table[slot][None], layout=layout)[0]
            want = small[g, 0]
            if layout == "bhsd":
                seq = jnp.swapaxes(seq, 0, 1)
                want = jnp.swapaxes(want, 0, 1)
            np.testing.assert_allclose(
                np.asarray(seq[:plen], np.float32),
                np.asarray(want.astype(big.dtype), np.float32), atol=1e-6)


# --------------------------------------------------- engine compile count

@pytest.mark.slow
@pytest.mark.parametrize("layout", ["bshd", "bhsd"])
def test_engine_one_program_for_mixed_burst(rng, layout):
    """>= 4 distinct prompt lengths in one burst: exactly one compiled
    prefill program (plus one decode program), multi-chunk prompts
    interleaved with running decodes, and every request identical to its
    serial whole-prompt reference."""
    cfg = _cfg(kv_cache_layout=layout)
    params = init_params(rng, cfg)
    nprng = np.random.default_rng(7)
    plens = (5, 9, 12, 16, 23, 31)            # 6 distinct lengths
    prompts = [nprng.integers(1, cfg.vocab_size, n, dtype=np.int32)
               for n in plens]
    new_tokens, max_len = 10, 48
    refs = [_serial_reference(cfg, params, p, new_tokens, max_len)
            for p in prompts]
    eng = ServingEngine(cfg, params, batch_slots=2, max_len=max_len,
                        decode_block=8, page_size=8, prefill_chunk=8)
    assert eng.chunk == 8
    reqs = eng.generate(prompts, max_new_tokens=new_tokens)
    for r, ref in zip(reqs, refs):
        assert r.out_tokens == ref, f"request {r.rid} diverged"
    m = eng.metrics
    assert m["chunked"] == 1
    assert m["prefill_traces"] == 1, "prefill compile count must be " \
        "independent of the prompt-length mix"
    assert m["decode_traces"] == 1
    assert m["prefills"] == len(prompts)
    # 8-token chunks: ceil(plen/8) chunks per prompt.
    assert m["prefill_chunks"] == sum(-(-n // 8) for n in plens)
    assert eng.kv.pages_in_use == 0


@pytest.mark.slow
def test_fallback_configs_still_serve(rng):
    """A config outside the chunked gate (hybrid SSM state) falls back to
    whole-prompt prefill on the same scheduler, one compile per distinct
    length."""
    cfg = _cfg("zamba2-2.7b")
    params = init_params(rng, cfg)
    nprng = np.random.default_rng(8)
    prompts = [nprng.integers(1, cfg.vocab_size, n, dtype=np.int32)
               for n in (6, 10)]
    eng = ServingEngine(cfg, params, batch_slots=2, max_len=32,
                        decode_block=4)
    reqs = eng.generate(prompts, max_new_tokens=4)
    assert all(r.done and not r.failed for r in reqs)
    assert eng.metrics["chunked"] == 0
    assert eng.metrics["prefill_traces"] == 2     # one per distinct length


# ------------------------------------------------- admission / metrics

@pytest.mark.slow
def test_bad_prompts_fail_at_admission_and_engine_keeps_serving(rng):
    """An empty or over-long prompt is failed at admission — it takes no
    slot and no pages, and every valid request still completes and
    matches its serial reference (the old behavior raised mid-generate,
    stranding all active requests with their pages held)."""
    cfg = _cfg()
    params = init_params(rng, cfg)
    nprng = np.random.default_rng(9)
    max_len, new_tokens = 32, 6
    good = [nprng.integers(1, cfg.vocab_size, n, dtype=np.int32)
            for n in (7, 12)]
    prompts = [good[0],
               np.zeros(0, np.int32),                        # empty
               nprng.integers(1, cfg.vocab_size, max_len + 1,
                              dtype=np.int32),               # over-long
               good[1]]
    refs = [_serial_reference(cfg, params, p, new_tokens, max_len)
            for p in good]
    eng = ServingEngine(cfg, params, batch_slots=2, max_len=max_len,
                        decode_block=4)
    reqs = eng.generate(prompts, max_new_tokens=new_tokens)
    assert reqs[0].out_tokens == refs[0]
    assert reqs[3].out_tokens == refs[1]
    for bad, why in ((reqs[1], "empty"), (reqs[2], "exceeds max_len")):
        assert bad.failed and bad.done and why in bad.error
        assert bad.out_tokens == []
        assert math.isnan(bad.ttft_s)
        assert bad.latency_s >= 0                 # failed AT a real time
    assert eng.metrics["rejected"] == 2
    assert eng.kv.pages_in_use == 0               # nothing leaked


def test_latency_properties_guard_unset_timestamps():
    """ttft_s / latency_s used to return negative garbage for requests
    that were never admitted (timestamps default 0.0) — they must report
    nan until the underlying events exist."""
    r = Request(rid=0, prompt=np.zeros(3, np.int32))
    assert math.isnan(r.ttft_s) and math.isnan(r.latency_s)
    r.submitted_at = 100.0
    assert math.isnan(r.ttft_s) and math.isnan(r.latency_s)
    r.first_token_at = 100.5
    assert r.ttft_s == pytest.approx(0.5)
    assert math.isnan(r.latency_s)
    r.finished_at = 101.0
    assert r.latency_s == pytest.approx(1.0)
