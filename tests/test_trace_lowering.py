"""Trace + DSE + lowering integration tests over all assigned archs."""

import pytest

from repro.configs import ARCHS, ASSIGNED_ARCHS, get_config
from repro.core.dse import evaluate_trial, explore
from repro.core.lowering import compile_model, lower_groups
from repro.core.platforms import TPU_V5E, U55C
from repro.core.trace import block_flops, trace_block, trace_lm_head


@pytest.mark.parametrize("arch", list(ARCHS))
def test_trace_block_builds_valid_graph(arch):
    cfg = get_config(arch)
    ops = trace_block(cfg, tokens=128)
    r = evaluate_trial(ops, TPU_V5E, 32, 32, keep_artifacts=True)
    assert r.graph is not None
    r.graph.validate()
    assert r.graph.num_kernels == len(ops)
    # Stream graph must be connected from x_in to x_out through >= 3 kernels.
    assert r.graph.g.number_of_edges() >= len(ops) - 4


@pytest.mark.parametrize("arch", ["zamba2-2.7b", "gemma3-4b"])
def test_pattern_layers_differ(arch):
    cfg = get_config(arch)
    kinds = {cfg.layer_kind(i) for i in range(cfg.num_layers)}
    assert len(kinds) == 2   # hybrid / local:global patterns present
    per = cfg.shared_attn_every or cfg.global_attn_every
    o_plain = trace_block(cfg, tokens=64, layer_index=0)
    o_special = trace_block(cfg, tokens=64, layer_index=per - 1)
    assert len(o_special) != len(o_plain) or arch == "gemma3-4b"


def test_decode_trace_uses_kv_len():
    cfg = get_config("llama3-8b")
    ops = trace_block(cfg, tokens=4, kv_len=1024)
    att = [o for o in ops if o.op == "attention"][0]
    assert att.loop("s").extent == 1024
    # Decode K/V comes from the HBM cache -> not stream-wired.
    ids = {o.output.tensor_id for o in ops}
    assert att.inputs[1].tensor_id not in ids


def test_flops_scale_with_tokens():
    cfg = get_config("qwen3-0.6b")
    f1 = block_flops(cfg, 128)
    f2 = block_flops(cfg, 256)
    assert 1.9 < f2 / f1 < 4.2   # attention term is quadratic in tokens


def test_moe_flops_active_only():
    cfg = get_config("granite-moe-1b-a400m")
    ops = trace_block(cfg, tokens=64)
    experts = [o for o in ops if o.op == "moe_experts"][0]
    d, f = cfg.d_model, cfg.d_ff
    glu = 3 if cfg.gated_ffn else 2
    expect = 64 * cfg.top_k * glu * d * f * 2.0
    assert abs(experts.work_flops - expect) / expect < 1e-6


def test_lm_head_streams_vocab():
    cfg = get_config("gemma3-4b")
    ops = trace_lm_head(cfg, tokens=32)
    head = ops[-1]
    assert head.loop("v").extent == cfg.vocab_size


@pytest.mark.parametrize("arch", list(ASSIGNED_ARCHS))
def test_compile_model_all_archs(arch):
    cfg = get_config(arch)
    c = compile_model(cfg, tokens=128, default_tile_size=32,
                      overall_unroll_size=64)
    assert c.fusion.num_groups >= 1
    assert c.trial.feasible
    # Every kernel belongs to exactly one lowered group.
    covered = [k for g in c.lowered for k in g.kernels]
    assert sorted(covered) == sorted(n for n in c.graph.g.nodes)
    # Stage timing was recorded for the Fig. 10c study.
    assert set(c.stage_seconds) >= {"trace", "partition", "lowering"}


def test_compile_memory_reduction_in_paper_band():
    """Fig. 10a: fusion cuts on-chip intermediate memory to a small fraction
    of the unfused design (paper: 14.8%-16.8% for its four LLMs; we assert
    the order of magnitude on our U55C model of GPT-2)."""
    c = compile_model(get_config("gpt2"), tokens=256, platform=U55C,
                      dse_budget=8)
    assert c.memory_report["ratio"] < 0.5
    assert c.memory_report["after_bytes"] < c.memory_report["before_bytes"]


def test_dse_explores_and_improves():
    cfg = get_config("qwen1.5-0.5b")
    ops = trace_block(cfg, tokens=256)
    res = explore(ops, U55C, budget=10, seed=1)
    assert res.num_trials >= 5
    scores = [t.score for t in res.trials]
    assert res.best.score <= min(scores) + 1e-12
    assert res.best.graph is not None   # artifacts kept for lowering
