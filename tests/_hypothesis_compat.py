"""Optional-``hypothesis`` shim for the property-based test modules.

The property tests are a bonus tier: when ``hypothesis`` is installed they
run as usual; when it is missing (minimal CI images) the ``@given`` tests
are collected but skipped, and the example-based tests in the same modules
still run.  Import from here instead of from ``hypothesis`` directly:

    from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
"""

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:       # pragma: no cover - exercised on minimal images
    HAVE_HYPOTHESIS = False

    class _DummyStrategy:
        """Placeholder returned by every strategy constructor."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    class _StrategiesStub:
        """`st.<anything>(...)` yields dummies; `st.composite` keeps the
        decorated function callable (tests call e.g. ``layout_pair()`` at
        decoration time)."""

        @staticmethod
        def composite(fn):
            return lambda *args, **kwargs: _DummyStrategy()

        def __getattr__(self, name):
            return lambda *args, **kwargs: _DummyStrategy()

    st = _StrategiesStub()

    def given(*args, **kwargs):
        def decorate(fn):
            def skipped_property_test():
                pass  # body never runs; the skip mark short-circuits
            skipped_property_test.__name__ = fn.__name__
            skipped_property_test.__doc__ = fn.__doc__
            return pytest.mark.skip(
                reason="hypothesis not installed")(skipped_property_test)
        return decorate

    def settings(*args, **kwargs):
        return lambda fn: fn
