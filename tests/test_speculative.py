"""Self-speculative decoding: draft-then-verify on the paged engine
(DESIGN.md §11).

Contract pinned here (ISSUE 6 acceptance):

  * Verify attention — the W-row eager reference equals per-row decode
    attention, and the Pallas ``paged_verify_attention`` kernel
    (interpret mode on CPU) equals the eager reference, for MHA and GQA
    heads with and without a sliding window.
  * Greedy exactness — the speculative engine's delivered tokens are
    BIT-IDENTICAL to the non-speculative engine for dense, GQA, and
    sliding-window configs: acceptance only ever keeps tokens that equal
    the model's own greedy argmax, so drafting quality affects speed,
    never output.
  * Zero-acceptance worst case — every verify dispatch still delivers at
    least one token (row 0 is plain greedy decode), so incompressible
    traffic degrades to the non-speculative rate, not below it.
  * Rollback safety — ``rollback_extent`` only ever frees freshly
    allocated, exclusively owned pages (asserted in the allocator);
    rolling back next to COW-shared prefix pages never touches the
    shared pages, and page accounting stays exact through admission /
    rollback / retire churn (``assert_page_accounting`` after every
    rollback via the engine's debug hook).
  * Compile discipline — verify window widths come from a <=3-rung
    ladder, so the verify program traces at most three times no matter
    the draft mix.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_params
from repro.serving import PagedKVCache, ServingEngine
from repro.serving.kv_cache import NULL_PAGE

multi = pytest.mark.skipif(len(jax.devices()) < 8,
                           reason="needs 8 forced host devices")


def _cfg(arch, **over):
    cfg = dataclasses.replace(get_config(arch).reduced(), dtype="float32",
                              use_fused_kernels=True)
    return dataclasses.replace(cfg, **over) if over else cfg


CONFIGS = {
    "dense": lambda: _cfg("gpt2"),
    "gqa": lambda: _cfg("llama3-8b", num_heads=8, num_kv_heads=4,
                        head_dim=8),
    "swa": lambda: _cfg("gemma3-4b", num_heads=8, num_kv_heads=4,
                        head_dim=8),
}


def _repetitive_prompts(cfg):
    """A draft-friendly mix: one strongly periodic prompt (n-gram lookup
    fires), one short arbitrary prompt, one prompt repeating a shared
    block (prefix-cache traffic)."""
    v = cfg.vocab_size
    return [
        np.array(([1, 2, 3, 4, 5, 6, 7, 8] * 4)[:30], np.int32) % v,
        np.array([9, 8, 7, 6, 5], np.int32) % v,
        np.array([1, 2, 3, 4] * 5, np.int32) % v,
    ]


def _run(cfg, params, prompts, *, new_tokens=10, check_pages=False,
         **eng):
    eng.setdefault("batch_slots", 2)
    eng.setdefault("max_len", 96)
    eng.setdefault("decode_block", 4)
    e = ServingEngine(cfg, params, **eng)
    if check_pages:
        e._debug_check_pages = True
    reqs = e.generate([p.copy() for p in prompts],
                      max_new_tokens=new_tokens)
    return e, [r.out_tokens for r in reqs]


# ------------------------------------------------- verify attention math

@pytest.mark.parametrize("hq,hkv", [(4, 4), (4, 2)])   # MHA and GQA
@pytest.mark.parametrize("window", [0, 7])
def test_verify_attention_matches_per_row_decode(hq, hkv, window):
    """Eager verify attention row i == eager decode attention at length
    q_off + i: the verify window is literally W stacked decode steps."""
    from repro.models.layers import decode_attention, verify_attention

    b, s, d, w = 3, 40, 16, 4
    nprng = np.random.default_rng(3)
    q = jnp.asarray(nprng.normal(size=(b, w, hq, d)).astype(np.float32))
    kc = jnp.asarray(nprng.normal(size=(b, s, hkv, d)).astype(np.float32))
    vc = jnp.asarray(nprng.normal(size=(b, s, hkv, d)).astype(np.float32))
    q_off = jnp.asarray(np.array([5, 17, 33], np.int32))

    out = verify_attention(q, kc, vc, q_off, window=window, layout="bshd")
    assert out.shape == (b, w, hq, d)
    for i in range(w):
        # Row i sees positions < q_off + i + 1 — decode_attention takes
        # that extent directly as cache_len.
        ref = decode_attention(q[:, i:i + 1], kc, vc, q_off + i + 1,
                               window=window, layout="bshd")
        np.testing.assert_allclose(np.asarray(out[:, i:i + 1]),
                                   np.asarray(ref), atol=1e-6)


@pytest.mark.parametrize("hq,hkv", [(4, 4), (4, 2)])
@pytest.mark.parametrize("window", [0, 7])
def test_paged_verify_kernel_matches_eager(hq, hkv, window):
    """Pallas paged verify kernel == eager verify attention to 1e-5
    through the page-table indirection, mixed per-slot offsets."""
    from repro.kernels import paged_verify_attention
    from repro.models.layers import verify_attention

    b, d, ps, n_pages, w = 3, 16, 8, 5, 4
    s = ps * n_pages
    nprng = np.random.default_rng(4)
    q = jnp.asarray(nprng.normal(size=(b, w, hq, d)).astype(np.float32))
    k_pool = jnp.asarray(nprng.normal(
        size=(1 + b * n_pages, ps, hkv, d)).astype(np.float32))
    v_pool = jnp.asarray(nprng.normal(
        size=(1 + b * n_pages, ps, hkv, d)).astype(np.float32))
    q_off = np.array([5, 17, 33], np.int32)
    table = np.zeros((b, n_pages), np.int32)
    nxt = 1
    for i in range(b):
        for j in range(-(-(int(q_off[i]) + w) // ps)):
            table[i, j] = nxt
            nxt += 1
    table, q_off = jnp.asarray(table), jnp.asarray(q_off)

    out = paged_verify_attention(q, k_pool, v_pool, table, q_off,
                                 window=window)
    kc = k_pool[table].reshape(b, s, hkv, d)
    vc = v_pool[table].reshape(b, s, hkv, d)
    ref = verify_attention(q, kc, vc, q_off, window=window, layout="bshd")
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=1e-5)
    # Idle slots (offset 0, NULL table row): finite zeros, no NaNs.
    out0 = paged_verify_attention(q, k_pool, v_pool,
                                  jnp.zeros_like(table),
                                  jnp.zeros((b,), jnp.int32))
    assert np.all(np.isfinite(np.asarray(out0)))


# ---------------------------------------------------- rollback allocator

def test_rollback_extent_frees_exclusive_tail():
    cfg = _cfg("qwen1.5-0.5b")
    kv = PagedKVCache(cfg, slots=2, max_len=64, page_size=16)
    kv.ensure(0, 60)                            # 4 pages
    assert kv.pages_in_use == 4
    dropped = kv.rollback_extent(0, 20)         # keep 2
    assert dropped == 2 and kv.pages_in_use == 2
    assert np.count_nonzero(
        np.asarray(kv.page_table)[0] != NULL_PAGE) == 2
    kv.assert_page_accounting()
    # Shrinking to the same extent is a no-op; growing again reuses the
    # freed pages.
    assert kv.rollback_extent(0, 32) == 0
    kv.ensure(0, 60)
    assert kv.pages_in_use == 4
    kv.assert_page_accounting()


def test_rollback_extent_refuses_shared_pages():
    """The guard satellite: a rollback that would free a shared or
    tree-owned page is a custody bug, not a cleanup — it must trip the
    allocator's assertion instead of corrupting the radix tree."""
    cfg = _cfg("qwen1.5-0.5b")
    kv = PagedKVCache(cfg, slots=2, max_len=64, page_size=16)
    pages = kv.ensure(0, 32)                    # 2 pages
    kv.adopt_shared(1, int(pages[-1]))          # slot 1 shares the tail
    with pytest.raises(AssertionError, match="rollback"):
        kv.rollback_extent(0, 1)
    kv.release(1)
    kv.mark_tree(int(pages[-1]))                # tree owns the tail
    with pytest.raises(AssertionError, match="rollback"):
        kv.rollback_extent(0, 1)


# --------------------------------------------------------------- engine

@pytest.mark.parametrize("name", list(CONFIGS))
def test_speculative_bitmatch(name):
    """Speculative greedy tokens == non-speculative greedy tokens, for
    dense / GQA / sliding-window configs, with real accepts happening on
    the repetitive traffic and the verify program compiling at most
    three times (the W ladder)."""
    cfg = CONFIGS[name]()
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompts = _repetitive_prompts(cfg)
    _, base = _run(cfg, params, prompts)
    e, spec = _run(cfg, params, prompts, speculative=True, draft_len=4,
                   check_pages=True)
    assert spec == base
    m = e.metrics
    assert m["verify_dispatches"] > 0
    assert m["spec_tokens"] >= m["verify_dispatches"]   # >= 1 token/dispatch
    assert m["verify_traces"] <= 3                      # the W ladder
    if name != "swa":
        # gpt2/llama random weights collapse to repetition, so n-gram
        # drafting provably fires; the swa smoke weights stay aperiodic
        # (zero drafts is then CORRECT — and still bit-matches above).
        assert m["draft_tokens"] > 0
    e.kv.assert_page_accounting()


def test_zero_acceptance_worst_case():
    """Incompressible traffic: drafts are wrong (or absent), every
    dispatch still delivers exactly row 0's token, outputs bit-match,
    and rollback returns every speculatively provisioned page."""
    cfg = _cfg("gpt2")
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, n, dtype=np.int32)
               for n in (21, 13)]
    _, base = _run(cfg, params, prompts, new_tokens=8)
    e, spec = _run(cfg, params, prompts, new_tokens=8, speculative=True,
                   draft_len=4, check_pages=True)
    assert spec == base
    m = e.metrics
    # Worst case still makes forward progress at >= 1 token per dispatch.
    assert m["spec_tokens"] >= m["verify_dispatches"] > 0
    assert m["dispatches_per_token"] <= 1.0
    e.kv.assert_page_accounting()
    # All slots retired: no page is slot-referenced (tree-cached pages
    # are counted separately and are fine to keep).
    assert e.kv.pages_in_use == 0


def test_rollback_next_to_cow_shared_prefix():
    """Bootstrap-admitted repeat traffic: the slot decodes speculatively
    right on top of COW-shared prefix pages.  The COW swap plus verify
    appends plus rollback must leave the cached tree pages untouched and
    the outputs identical to the plain engine."""
    cfg = _cfg("llama3-8b", num_heads=8, num_kv_heads=4, head_dim=8)
    params = init_params(jax.random.PRNGKey(0), cfg)
    # Page-aligned prompt (bootstrap full hits are page-granular), sized
    # off a probe engine's resolved page size.
    ps = ServingEngine(cfg, params, batch_slots=1, max_len=96,
                       prefix_bootstrap=True).kv.page_size
    prompt = np.array(([3, 1, 4, 1, 5, 9, 2, 6] * 16)[:2 * ps], np.int32)
    # Same prompt twice on ONE slot, so the runs serialize: the second
    # admits fully cached (bootstrap) and speculates over the shared
    # tail page post-COW.
    _, base = _run(cfg, params, [prompt, prompt], new_tokens=10,
                   batch_slots=1, prefix_bootstrap=True)
    e, spec = _run(cfg, params, [prompt, prompt], new_tokens=10,
                   batch_slots=1, prefix_bootstrap=True, speculative=True,
                   draft_len=4, check_pages=True)
    assert spec == base
    assert e.metrics["prefix_bootstraps"] >= 1
    assert e.metrics["cow_copies"] >= 1
    e.kv.assert_page_accounting()


def test_mixed_speculative_and_chunked_prefill():
    """A burst wider than the slot count: chunked prefill of late
    arrivals interleaves with speculative verify dispatches over the
    early ones — parked mid-prefill slots ride the verify window on NULL
    routing, and every request's tokens still bit-match."""
    cfg = _cfg("gpt2")
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(5)
    prompts = [np.array([1, 2, 3, 4] * 8, np.int32),
               rng.integers(1, cfg.vocab_size, 41, dtype=np.int32),
               np.array([7, 7, 8, 9] * 7, np.int32),
               rng.integers(1, cfg.vocab_size, 9, dtype=np.int32),
               np.array(([5, 6] * 20)[:33], np.int32)]
    _, base = _run(cfg, params, prompts, new_tokens=8)
    e, spec = _run(cfg, params, prompts, new_tokens=8, speculative=True,
                   draft_len=4, check_pages=True)
    assert spec == base
    assert e.metrics["prefill_chunks"] > 0      # prefill really interleaved
    assert e.metrics["verify_dispatches"] > 0
    e.kv.assert_page_accounting()


@pytest.mark.slow
def test_rollback_churn_soak():
    """Admission / speculate / rollback / retire churn over more waves
    than slots, page accounting audited after EVERY rollback (the debug
    hook) and at the end."""
    cfg = _cfg("gpt2")
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(6)
    prompts = []
    for i in range(7):
        if i % 2 == 0:
            prompts.append(np.array(([2, 4, 6, 8] * 10)[:17 + i], np.int32))
        else:
            prompts.append(rng.integers(1, cfg.vocab_size, 11 + 3 * i,
                                        dtype=np.int32))
    _, base = _run(cfg, params, prompts, new_tokens=11)
    e, spec = _run(cfg, params, prompts, new_tokens=11, speculative=True,
                   draft_len=4, check_pages=True)
    assert spec == base
    assert e.metrics["rollbacks"] > 0           # churn actually rolled back
    e.kv.assert_page_accounting()


@multi
def test_sharded_speculative_matches_single_device():
    """Forced 8-device mesh: the speculative engine's fused verify
    dispatch runs under shard_map (kv_heads over the model axis) and its
    tokens match the single-device non-speculative engine exactly."""
    from repro.launch.mesh import make_mesh

    cfg = CONFIGS["gqa"]()
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompts = _repetitive_prompts(cfg)
    _, base = _run(cfg, params, prompts, new_tokens=8)
    mesh = make_mesh((2, 4), ("data", "model"))
    e, spec = _run(cfg, params, prompts, new_tokens=8, speculative=True,
                   draft_len=4, batch_slots=4, mesh=mesh)
    assert spec == base
    lp = e.plan.layer("attn")
    assert lp.verify_attn.fused
    assert e.plan.summary()["sharding"]["attn"]["verify_attn"] == {
        "batch": "data", "kv_heads": "model"}
    e.kv.assert_page_accounting()
