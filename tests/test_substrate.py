"""Data pipeline / checkpoint / trainer fault-tolerance / serving tests."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.data import TokenPipeline
from repro.distributed.optimizer import (AdamWConfig, adamw_update,
                                         init_opt_state, lr_schedule)
from repro.launch.mesh import make_host_mesh
from repro.models import init_params
from repro.serving import ServingEngine
from repro.train import (Trainer, TrainerConfig, latest_checkpoint,
                         restore_checkpoint, save_checkpoint)

SHAPE = ShapeConfig("t", 64, 4, "train")


# ------------------------------------------------------------------ data

def test_pipeline_deterministic_replay():
    cfg = get_config("qwen3-0.6b").reduced()
    p1 = TokenPipeline(cfg, SHAPE, seed=7)
    p2 = TokenPipeline(cfg, SHAPE, seed=7)
    b1, b2 = next(p1), next(p2)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # Replay via state restore.
    next(p1)
    p3 = TokenPipeline(cfg, SHAPE, seed=7)
    p3.load_state_dict(p1.state_dict())
    np.testing.assert_array_equal(next(p3)["tokens"], next(p1)["tokens"])


def test_pipeline_shards_disjoint_and_seeded():
    cfg = get_config("qwen3-0.6b").reduced()
    a = TokenPipeline(cfg, SHAPE, seed=1, num_shards=2, shard_id=0)
    b = TokenPipeline(cfg, SHAPE, seed=1, num_shards=2, shard_id=1)
    ba, bb = next(a), next(b)
    assert ba["tokens"].shape[0] == SHAPE.global_batch // 2
    assert not np.array_equal(ba["tokens"], bb["tokens"])


def test_pipeline_tokens_in_vocab_and_labels_shifted():
    cfg = get_config("granite-moe-1b-a400m").reduced()
    b = next(TokenPipeline(cfg, SHAPE, seed=3))
    assert b["tokens"].min() >= 0
    assert b["tokens"].max() < cfg.vocab_size
    assert b["labels"].shape == b["tokens"].shape


def test_pipeline_frontend_embeds():
    cfg = get_config("qwen2-vl-2b").reduced()
    b = next(TokenPipeline(cfg, SHAPE, seed=0))
    assert "embeds" in b and b["embeds"].shape == (4, 64, cfg.d_model)
    assert b["positions"].shape == (3, 4, 64)


# ------------------------------------------------------------ optimizer

def test_adamw_reduces_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1,
                      total_steps=100)
    params = {"w": jnp.array([3.0, -2.0])}
    state = init_opt_state(params)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}
        params, state, m = adamw_update(cfg, params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_lr_schedule_warmup_and_decay():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    assert float(lr_schedule(cfg, jnp.int32(0))) < 0.2
    assert abs(float(lr_schedule(cfg, jnp.int32(10))) - 1.0) < 0.01
    assert float(lr_schedule(cfg, jnp.int32(99))) < 0.2


# ----------------------------------------------------------- checkpoint

def test_checkpoint_roundtrip_and_retention(tmp_path):
    cfg = get_config("qwen1.5-0.5b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    for step in (1, 2, 3, 4):
        save_checkpoint(tmp_path, step, params, keep=2)
    dirs = sorted(d.name for d in tmp_path.iterdir())
    assert dirs == ["step-00000003", "step-00000004"]
    from repro.models import abstract_params
    step, restored, _, _ = restore_checkpoint(
        latest_checkpoint(tmp_path), abstract_params(cfg))
    assert step == 4
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_detects_corruption(tmp_path):
    cfg = get_config("qwen1.5-0.5b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    path = save_checkpoint(tmp_path, 1, params)
    victim = next(f for f in path.iterdir() if f.suffix == ".npy")
    arr = np.load(victim)
    arr = np.asarray(arr).copy()
    arr.flat[0] += 1.0
    np.save(victim, arr)
    from repro.models import abstract_params
    with pytest.raises(IOError, match="checksum"):
        restore_checkpoint(path, abstract_params(cfg))


# ----------------------------------------------------- trainer + faults

def test_trainer_recovers_from_injected_failure(tmp_path):
    cfg = get_config("qwen1.5-0.5b").reduced()
    mesh = make_host_mesh(1, 1)
    tcfg = TrainerConfig(total_steps=8, checkpoint_every=2,
                         checkpoint_dir=str(tmp_path), log_every=100)

    crashed = {"done": False}

    def failure_hook(step):
        if step == 5 and not crashed["done"]:
            crashed["done"] = True
            raise KeyboardInterrupt("simulated preemption")

    t1 = Trainer(cfg, SHAPE, mesh, tcfg, failure_hook=failure_hook)
    with pytest.raises(KeyboardInterrupt):
        t1.run()
    t1.ckpt.wait()
    assert latest_checkpoint(tmp_path) is not None

    # 'Rescheduled' job resumes from the checkpoint and finishes.
    t2 = Trainer(cfg, SHAPE, mesh, tcfg)
    assert t2.resume()
    assert t2.step >= 2
    metrics = t2.run()
    assert t2.step == 8
    assert np.isfinite(metrics["loss"])


def test_elastic_restore_onto_bigger_mesh(tmp_path):
    """Mesh-agnostic checkpoints: save on 1 device, restore sharded."""
    if len(jax.devices()) < 2:
        pytest.skip("needs forced multi-device run")
    cfg = get_config("qwen1.5-0.5b").reduced()
    mesh1 = make_host_mesh(1, 1)
    tcfg = TrainerConfig(total_steps=2, checkpoint_every=2,
                         checkpoint_dir=str(tmp_path), log_every=100)
    t1 = Trainer(cfg, SHAPE, mesh1, tcfg)
    t1.run()
    t1.ckpt.wait()
    n = len(jax.devices())
    mesh2 = make_host_mesh(2, n // 2)
    t2 = Trainer(cfg, SHAPE, mesh2,
                 TrainerConfig(total_steps=4, checkpoint_every=10,
                               checkpoint_dir=str(tmp_path), log_every=100))
    assert t2.resume()
    m = t2.run()
    assert np.isfinite(m["loss"])


# -------------------------------------------------------------- serving

def test_serving_engine_batches_and_meters():
    cfg = get_config("qwen1.5-0.5b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    engine = ServingEngine(cfg, params, batch_slots=2, max_len=64)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, 16, dtype=np.int32)
               for _ in range(5)]
    reqs = engine.generate(prompts, max_new_tokens=4)
    assert all(r.done for r in reqs)
    assert all(len(r.out_tokens) == 4 for r in reqs)
    assert all(r.ttft_s >= 0 and r.latency_s >= r.ttft_s for r in reqs)


def test_serving_greedy_matches_prefill_argmax():
    """First generated token == argmax of prefill logits (greedy)."""
    cfg = get_config("qwen3-0.6b").reduced()
    params = init_params(jax.random.PRNGKey(1), cfg)
    from repro.models import prefill
    rng = np.random.default_rng(1)
    prompt = rng.integers(1, cfg.vocab_size, 12, dtype=np.int32)
    logits, _ = jax.jit(lambda p: prefill(
        p, cfg, {"tokens": jnp.asarray(prompt)[None]}))(params)
    want = int(jnp.argmax(logits[0, -1]))
    engine = ServingEngine(cfg, params, batch_slots=1, max_len=32)
    reqs = engine.generate([prompt], max_new_tokens=2)
    assert reqs[0].out_tokens[0] == want
