"""Multi-device distribution tests (run under forced host devices).

``conftest.py`` keeps the default single-device environment; these tests
skip unless launched with XLA_FLAGS=--xla_force_host_platform_device_count=8
(the CI invocation in README/EXPERIMENTS does both runs).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.distributed import (batch_spec, make_train_step, optimizer_specs,
                               spec_for, tree_specs)
from repro.distributed.compression import (dequantize_int8,
                                           make_compressed_allreduce,
                                           quantize_int8)
from repro.distributed.optimizer import init_opt_state
from repro.launch.mesh import make_mesh
from repro.models import abstract_params, init_params, logical_axes

multi = pytest.mark.skipif(len(jax.devices()) < 8,
                           reason="needs 8 forced host devices")


def _mesh():
    return make_mesh((2, 4), ("data", "model"))


# ------------------------------------------------------------- sharding

def test_spec_divisibility_fallbacks():
    cfg = get_config("gemma3-4b")   # 8 q heads: not divisible by model=16
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # With model=1 everything replicates (no fallback needed; sanity).
    s = spec_for(cfg, ("d_model", "q_dim"), (2560, 2560), mesh)
    assert s == P(None, "model")


@multi
def test_quantum_aware_head_sharding():
    cfg = get_config("llama3-8b")
    mesh = _mesh()   # model axis = 4; 32 heads % 4 == 0 -> sharded
    s = spec_for(cfg, ("d_model", "q_dim"), (4096, 4096), mesh)
    assert s == P(None, "model")
    cfg_vl = get_config("qwen2-vl-2b")  # 12 heads % 4 == 0 -> sharded
    s2 = spec_for(cfg_vl, ("d_model", "q_dim"), (1536, 1536), mesh)
    assert s2 == P(None, "model")
    # head_dim quantum: 6 heads on 4-way axis would split heads -> None.
    from dataclasses import replace
    cfg6 = replace(cfg_vl, num_heads=6, head_dim=256)
    s3 = spec_for(cfg6, ("d_model", "q_dim"), (1536, 1536), mesh)
    assert s3 == P(None, None)


@multi
def test_moe_expert_fallback_to_dff():
    from dataclasses import replace
    mesh = _mesh()
    cfg = get_config("granite-moe-3b-a800m")   # 40 experts % 4 == 0 here
    s = spec_for(cfg, ("experts", "d_model", "d_ff"), (40, 1536, 512), mesh)
    assert s == P("model", None, None)
    cfg42 = replace(cfg, num_experts=42)       # 42 % 4 != 0 -> d_ff shards
    s2 = spec_for(cfg42, ("experts", "d_model", "d_ff"), (42, 1536, 512),
                  mesh)
    assert s2 == P(None, None, "model")


@multi
def test_zero1_optimizer_claims_data_axis():
    cfg = get_config("llama3-8b")
    mesh = _mesh()
    ax = logical_axes(cfg)
    ab = abstract_params(cfg)
    p = tree_specs(cfg, ax, ab, mesh)
    o = optimizer_specs(cfg, ax, ab, mesh)
    wq_p = p["blocks"][0]["attn"]["wq"]
    wq_o = o["blocks"][0]["attn"]["wq"]
    assert "data" not in str(wq_p)
    assert "data" in str(wq_o)      # moments additionally data-sharded


@multi
def test_batch_1_replicates():
    cfg = get_config("zamba2-2.7b")
    mesh = _mesh()
    s = spec_for(cfg, ("batch", None), (1, 1), mesh)
    assert s == P(None, None)


# ----------------------------------------------- sharded training parity

@multi
def test_sharded_train_matches_single_device():
    cfg = get_config("qwen3-0.6b").reduced()
    shape = ShapeConfig("t", 64, 4, "train")
    batch_np = {
        "tokens": np.random.default_rng(0).integers(
            0, cfg.vocab_size, (4, 64)).astype(np.int32),
        "labels": np.random.default_rng(1).integers(
            0, cfg.vocab_size, (4, 64)).astype(np.int32),
    }

    def run(mesh):
        fn, p_specs, o_specs, b_fn = make_train_step(cfg, mesh)
        params = init_params(jax.random.PRNGKey(0), cfg)
        params = jax.device_put(params, jax.tree.map(
            lambda s: NamedSharding(mesh, s), p_specs,
            is_leaf=lambda x: isinstance(x, P)))
        opt = init_opt_state(params)
        specs = b_fn(batch_np)
        batch = {k: jax.device_put(v, NamedSharding(mesh, specs[k]))
                 for k, v in batch_np.items()}
        for _ in range(2):
            params, opt, metrics = fn(params, opt, batch)
        return float(metrics["loss"])

    l1 = run(make_mesh((1, 1), ("data", "model")))
    l8 = run(_mesh())
    assert abs(l1 - l8) < 5e-3


# --------------------------------------------------- gradient compression

def test_int8_quantization_roundtrip():
    x = jnp.linspace(-3.0, 3.0, 128)
    q, s = quantize_int8(x)
    err = x - dequantize_int8(q, s)
    assert float(jnp.abs(err).max()) <= float(s) * 0.51 + 1e-6


@multi
def test_compressed_allreduce_with_error_feedback():
    mesh = make_mesh((8,), ("data",))
    reduce_fn = make_compressed_allreduce(mesh, "data")
    rng = np.random.default_rng(0)
    grads = {"w": jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)}
    exact = grads["w"]   # replicated input -> mean == itself
    mean, err = reduce_fn(grads)
    rel = float(jnp.linalg.norm(mean["w"] - exact)
                / jnp.linalg.norm(exact))
    assert rel < 0.02                      # int8: ~1% error
    # Error feedback: applying the reduce twice with the carried error
    # cancels bias — the accumulated estimate converges to the truth.
    est = mean["w"]
    mean2, _ = reduce_fn(grads, err)
    est2 = 0.5 * (est + mean2["w"])
    rel2 = float(jnp.linalg.norm(est2 - exact) / jnp.linalg.norm(exact))
    assert rel2 <= rel + 1e-6
