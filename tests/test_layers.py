"""Layer-level numerical tests against naive references."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers as L


def full_attention_ref(q, k, v, causal=True, window=0, q_offset=0):
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    g = hq // hkv
    kr = jnp.repeat(k, g, axis=2)
    vr = jnp.repeat(v, g, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kr) / math.sqrt(d)
    qp = q_offset + jnp.arange(sq)[:, None]
    kp = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask = kp <= qp
    if window:
        mask = jnp.logical_and(mask, kp > qp - window)
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vr)


@pytest.mark.parametrize("hq,hkv", [(4, 4), (8, 2)])
@pytest.mark.parametrize("chunk", [16, 64, 128])
def test_streaming_attention_matches_full(hq, hkv, chunk):
    rng = jax.random.PRNGKey(0)
    b, s, d = 2, 96, 16
    q = jax.random.normal(rng, (b, s, hq, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, hkv, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, hkv, d))
    out = L.streaming_attention(q, k, v, causal=True, chunk_size=chunk)
    ref = full_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-4)


@pytest.mark.parametrize("window", [8, 32])
def test_local_attention_matches_windowed_full(window):
    rng = jax.random.PRNGKey(3)
    b, s, h, d = 1, 128, 2, 8
    q = jax.random.normal(rng, (b, s, h, d))
    k = jax.random.normal(jax.random.PRNGKey(4), (b, s, h, d))
    v = jax.random.normal(jax.random.PRNGKey(5), (b, s, h, d))
    out = L.local_attention(q, k, v, window=window)
    ref = full_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-4)


def test_noncausal_attention():
    rng = jax.random.PRNGKey(6)
    b, s, h, d = 2, 64, 4, 8
    q = jax.random.normal(rng, (b, s, h, d))
    k = jax.random.normal(jax.random.PRNGKey(7), (b, s, h, d))
    v = jax.random.normal(jax.random.PRNGKey(8), (b, s, h, d))
    out = L.streaming_attention(q, k, v, causal=False, chunk_size=16)
    ref = full_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-4)


def test_decode_attention_matches_last_row():
    rng = jax.random.PRNGKey(9)
    b, s, hq, hkv, d = 2, 33, 4, 2, 8
    q = jax.random.normal(rng, (b, 1, hq, d))
    kc = jax.random.normal(jax.random.PRNGKey(10), (b, 64, hkv, d))
    vc = jax.random.normal(jax.random.PRNGKey(11), (b, 64, hkv, d))
    out = L.decode_attention(q, kc, vc, jnp.full((b,), s))
    ref = full_attention_ref(q, kc[:, :s], vc[:, :s], causal=True,
                             q_offset=s - 1)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-4)


# ------------------------------------------------------------------ #
# Mamba2 SSD vs sequential recurrence
# ------------------------------------------------------------------ #

def mamba_sequential_ref(x, dt, a_log, b, c, d_skip, init_state=None):
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    a = -jnp.exp(a_log)
    state = (init_state if init_state is not None
             else jnp.zeros((bsz, h, p, n)))
    ys = []
    for t in range(s):
        da = jnp.exp(dt[:, t] * a)                       # [B,H]
        upd = jnp.einsum("bhp,bn->bhpn", x[:, t] * dt[:, t][..., None],
                         b[:, t])
        state = state * da[..., None, None] + upd
        y = jnp.einsum("bhpn,bn->bhp", state, c[:, t])
        ys.append(y + x[:, t] * d_skip[None, :, None])
    return jnp.stack(ys, axis=1), state


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_mamba2_ssd_matches_sequential(chunk):
    rng = jax.random.PRNGKey(0)
    bsz, s, h, p, n = 2, 32, 3, 4, 8
    ks = jax.random.split(rng, 5)
    x = jax.random.normal(ks[0], (bsz, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bsz, s, h)) - 1)
    a_log = jnp.log(jnp.linspace(1.0, 4.0, h))
    b = jax.random.normal(ks[2], (bsz, s, n)) * 0.5
    c = jax.random.normal(ks[3], (bsz, s, n)) * 0.5
    d_skip = jnp.ones((h,))
    y, st = L.mamba2_ssd(x, dt, a_log, b, c, d_skip, chunk=chunk)
    yr, str_ = mamba_sequential_ref(x, dt, a_log, b, c, d_skip)
    np.testing.assert_allclose(y, yr, atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(st, str_, atol=1e-4, rtol=1e-3)


def test_mamba2_decode_continues_prefill():
    rng = jax.random.PRNGKey(1)
    bsz, s, h, p, n = 1, 16, 2, 4, 8
    ks = jax.random.split(rng, 5)
    x = jax.random.normal(ks[0], (bsz, s + 1, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bsz, s + 1, h)))
    a_log = jnp.log(jnp.linspace(1.0, 4.0, h))
    b = jax.random.normal(ks[2], (bsz, s + 1, n)) * 0.5
    c = jax.random.normal(ks[3], (bsz, s + 1, n)) * 0.5
    d_skip = jnp.zeros((h,))
    y_ref, _ = mamba_sequential_ref(x, dt, a_log, b, c, d_skip)
    _, st = L.mamba2_ssd(x[:, :s], dt[:, :s], a_log, b[:, :s], c[:, :s],
                         d_skip, chunk=8)
    y1, _ = L.mamba2_decode_step(x[:, s], dt[:, s], a_log, b[:, s], c[:, s],
                                 d_skip, st)
    np.testing.assert_allclose(y1, y_ref[:, s], atol=1e-4, rtol=1e-3)


# ------------------------------------------------------------------ #
# RWKV6 wkv
# ------------------------------------------------------------------ #

def wkv_ref(r, k, v, w, u):
    bsz, s, h, n = r.shape
    state = jnp.zeros((bsz, h, n, n))
    ys = []
    for t in range(s):
        kv = jnp.einsum("bhk,bhv->bhkv", k[:, t], v[:, t])
        y = jnp.einsum("bhk,bhkv->bhv", r[:, t],
                       state + u[None, :, :, None] * kv)
        state = state * w[:, t][..., None] + kv
        ys.append(y)
    return jnp.stack(ys, 1), state


def test_wkv6_matches_reference():
    rng = jax.random.PRNGKey(2)
    bsz, s, h, n = 2, 24, 2, 4
    ks = jax.random.split(rng, 5)
    r = jax.random.normal(ks[0], (bsz, s, h, n))
    k = jax.random.normal(ks[1], (bsz, s, h, n)) * 0.3
    v = jax.random.normal(ks[2], (bsz, s, h, n))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (bsz, s, h, n)))
    u = jax.random.normal(ks[4], (h, n)) * 0.1
    y, st = L.wkv6(r, k, v, w, u)
    yr, str_ = wkv_ref(r, k, v, w, u)
    np.testing.assert_allclose(y, yr, atol=1e-5, rtol=1e-4)
    np.testing.assert_allclose(st, str_, atol=1e-5, rtol=1e-4)


def test_wkv6_init_state_composes():
    rng = jax.random.PRNGKey(3)
    bsz, s, h, n = 1, 16, 2, 4
    ks = jax.random.split(rng, 5)
    r = jax.random.normal(ks[0], (bsz, s, h, n))
    k = jax.random.normal(ks[1], (bsz, s, h, n)) * 0.3
    v = jax.random.normal(ks[2], (bsz, s, h, n))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (bsz, s, h, n)))
    u = jnp.zeros((h, n))
    y_all, st_all = L.wkv6(r, k, v, w, u)
    _, st_half = L.wkv6(r[:, :8], k[:, :8], v[:, :8], w[:, :8], u)
    y2, st2 = L.wkv6(r[:, 8:], k[:, 8:], v[:, 8:], w[:, 8:], u,
                     init_state=st_half)
    np.testing.assert_allclose(y2, y_all[:, 8:], atol=1e-5, rtol=1e-4)
    np.testing.assert_allclose(st2, st_all, atol=1e-5, rtol=1e-4)


# ------------------------------------------------------------------ #
# RoPE / M-RoPE / conv / norms
# ------------------------------------------------------------------ #

def test_rope_relative_property():
    """RoPE inner products depend only on relative positions."""
    rng = jax.random.PRNGKey(4)
    d = 16
    q = jax.random.normal(rng, (1, 1, 1, d))
    k = jax.random.normal(jax.random.PRNGKey(5), (1, 1, 1, d))

    def score(pq, pk):
        qr = L.apply_rope(q, jnp.array([[pq]]), 1e4)
        kr = L.apply_rope(k, jnp.array([[pk]]), 1e4)
        return float(jnp.sum(qr * kr))

    assert abs(score(5, 3) - score(12, 10)) < 1e-4
    assert abs(score(5, 3) - score(6, 3)) > 1e-5


def test_mrope_equals_rope_for_text():
    """With equal (t,h,w) position streams, M-RoPE == RoPE."""
    rng = jax.random.PRNGKey(6)
    b, s, h, d = 2, 8, 2, 16
    x = jax.random.normal(rng, (b, s, h, d))
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    pos3 = jnp.broadcast_to(pos[None], (3, b, s))
    np.testing.assert_allclose(L.apply_mrope(x, pos3, 1e4),
                               L.apply_rope(x, pos, 1e4),
                               atol=1e-5, rtol=1e-5)


def test_causal_conv_matches_numpy():
    rng = jax.random.PRNGKey(7)
    b, s, d, k = 2, 10, 3, 4
    x = jax.random.normal(rng, (b, s, d))
    w = jax.random.normal(jax.random.PRNGKey(8), (k, d))
    bias = jnp.zeros((d,))
    y, tail = L.causal_conv1d(x, w, bias)
    xp = np.concatenate([np.zeros((b, k - 1, d)), np.asarray(x)], axis=1)
    ref = np.zeros((b, s, d))
    for t in range(s):
        ref[:, t] = sum(xp[:, t + i] * np.asarray(w)[i] for i in range(k))
    np.testing.assert_allclose(y, jax.nn.silu(jnp.asarray(ref)),
                               atol=1e-5, rtol=1e-4)
    np.testing.assert_allclose(tail, x[:, s - (k - 1):], atol=1e-6)


def test_rms_norm_unit_scale():
    x = jnp.ones((2, 4, 8)) * 3.0
    y = L.rms_norm(x, jnp.zeros((8,)))
    np.testing.assert_allclose(y, jnp.ones_like(x), atol=1e-5)


def test_wkv6_chunked_matches_sequential():
    """§Perf rwkv6 hillclimb: chunk-parallel wkv6 == per-token recurrence
    (under the shared decay clamp w >= e^-5)."""
    rng = jax.random.PRNGKey(11)
    bsz, s, h, n = 2, 64, 2, 8
    ks = jax.random.split(rng, 5)
    r = jax.random.normal(ks[0], (bsz, s, h, n))
    k = jax.random.normal(ks[1], (bsz, s, h, n)) * 0.3
    v = jax.random.normal(ks[2], (bsz, s, h, n))
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (bsz, s, h, n))))
    w = jnp.clip(w, np.exp(-5.0), 1.0)
    u = jax.random.normal(ks[4], (h, n)) * 0.1
    y1, st1 = L.wkv6(r, k, v, w, u)
    y2, st2 = L.wkv6_chunked(r, k, v, w, u, chunk=16)
    np.testing.assert_allclose(y1, y2, atol=2e-4, rtol=1e-3)
    np.testing.assert_allclose(st1, st2, atol=2e-4, rtol=1e-3)


def test_wkv6_chunked_ragged_chunk_fallback():
    rng = jax.random.PRNGKey(12)
    bsz, s, h, n = 1, 24, 1, 4   # 24 % 16 != 0 -> gcd fallback
    ks = jax.random.split(rng, 5)
    r = jax.random.normal(ks[0], (bsz, s, h, n))
    k = jax.random.normal(ks[1], (bsz, s, h, n)) * 0.3
    v = jax.random.normal(ks[2], (bsz, s, h, n))
    w = jnp.clip(jax.nn.sigmoid(jax.random.normal(ks[3], (bsz, s, h, n))),
                 np.exp(-5.0), 1.0)
    u = jnp.zeros((h, n))
    y1, st1 = L.wkv6(r, k, v, w, u)
    y2, st2 = L.wkv6_chunked(r, k, v, w, u, chunk=16)
    np.testing.assert_allclose(y1, y2, atol=2e-4, rtol=1e-3)
    np.testing.assert_allclose(st1, st2, atol=2e-4, rtol=1e-3)


def test_streaming_attention_remat_chunk_same_result():
    rng = jax.random.PRNGKey(13)
    b, s, hq, d = 1, 64, 2, 16
    q = jax.random.normal(rng, (b, s, hq, d))
    k = jax.random.normal(jax.random.PRNGKey(14), (b, s, hq, d))
    v = jax.random.normal(jax.random.PRNGKey(15), (b, s, hq, d))
    a = L.streaming_attention(q, k, v, chunk_size=16, remat_chunk=False)
    bb = L.streaming_attention(q, k, v, chunk_size=16, remat_chunk=True)
    np.testing.assert_allclose(a, bb, atol=1e-6)
    # And gradients flow through the rematted path.
    g = jax.grad(lambda qq: L.streaming_attention(
        qq, k, v, chunk_size=16, remat_chunk=True).sum())(q)
    assert bool(jnp.isfinite(g).all())
