"""Mesh-aware StreamPlan + sharded serving tests (DESIGN.md §9).

The multi-device tier needs forced host devices — run with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the CI ``sharded``
job does); without it those tests skip, exactly like
``tests/test_distributed.py``.  The scheduler / KV-traffic-bound unit
tests at the bottom run everywhere.

Contract pinned here (ISSUE 4 acceptance): with a ('data','model') mesh
the engine's fused prefill-chunk + paged-decode path runs under shard_map
(asserted via the plan's stage records and the layers dispatch probe —
no eager fallback), the KV page pools carry a ``kv_heads``-sharded
``NamedSharding``, and greedy tokens match the single-device engine
exactly for dense, GQA, and sliding-window configs.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.mesh import make_mesh
from repro.models import init_params, layers as L, resolve_plan
from repro.models.params import cache_leaf_kind, cache_leaf_name
from repro.serving import ServingEngine

multi = pytest.mark.skipif(len(jax.devices()) < 8,
                           reason="needs 8 forced host devices")

SLOTS, MAX_LEN, DECODE_BLOCK, NEW_TOKENS = 4, 96, 4, 6


def _mesh():
    return make_mesh((2, 4), ("data", "model"))


def _cfg(arch, **over):
    cfg = dataclasses.replace(get_config(arch).reduced(), dtype="float32",
                              use_fused_kernels=True)
    return dataclasses.replace(cfg, **over)


# Dense MHA (layernorm, learned positions, block_matmul qkv), GQA
# (rmsnorm_matmul qkv), and sliding-window (local:global pattern).  Head
# counts are chosen so kv_heads divides the 4-way model axis.
CONFIGS = {
    "dense": lambda: _cfg("gpt2"),
    "gqa": lambda: _cfg("llama3-8b", num_heads=8, num_kv_heads=4,
                        head_dim=8),
    "swa": lambda: _cfg("gemma3-4b", num_heads=8, num_kv_heads=4,
                        head_dim=8),
}


def _prompts(cfg, n=3):
    rng = np.random.default_rng(7)
    return [rng.integers(1, cfg.vocab_size, ln, dtype=np.int32)
            for ln in (11, 37, 7)[:n]]


def _kv_pool_shardings(engine):
    out = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(
            engine._slot_cache)[0]:
        if cache_leaf_kind(cache_leaf_name(path)) == "kv":
            out.append(leaf.sharding)
    return out


# ---------------------------------------------------------- plan records

@multi
def test_plan_records_sharding():
    cfg = CONFIGS["gqa"]()
    plan = resolve_plan(cfg, SLOTS, kv_len=MAX_LEN, mesh=_mesh())
    assert dict(plan.mesh_axes) == {"data": 2, "model": 4}
    lp = plan.layer("attn")
    for stage in (lp.attention, lp.decode_attn):
        assert stage.fused
        assert dict(stage.sharding)["kv_heads"] == "model"
    assert dict(lp.qkv.sharding).get("out") == "model"
    assert dict(lp.ffn.sharding).get("d_ff") == "model"
    # Post-shard block feedback: the ffn tile target is clipped toward
    # d_ff / 4 but never below the 128-lane floor (smoke d_ff is tiny;
    # the wrapper's pick_block handles the true per-shard extent).
    assert dict(lp.ffn.blocks)["block_f"] <= max(128, cfg.d_ff // 4)
    s = plan.summary()
    assert s["sharding"]["attn"]["decode_attn"] == {"batch": "data",
                                                    "kv_heads": "model"}


@multi
def test_plan_replicates_when_quantum_does_not_divide():
    """kv_heads=2 on a 4-way model axis cannot shard — the fallback is
    replication (no kv_heads claim), NEVER eager (stages stay fused)."""
    cfg = _cfg("llama3-8b")          # reduced: 4 q heads over 2 kv heads
    plan = resolve_plan(cfg, SLOTS, kv_len=MAX_LEN, mesh=_mesh())
    lp = plan.layer("attn")
    assert lp.attention.fused and lp.decode_attn.fused
    assert "kv_heads" not in dict(lp.attention.sharding)
    assert "kv_heads" not in dict(lp.decode_attn.sharding)


# ------------------------------------------------- serving exactness

@multi
@pytest.mark.parametrize("name", sorted(CONFIGS))
def test_sharded_engine_matches_single_device(name):
    cfg = CONFIGS[name]()
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompts = _prompts(cfg)

    ref = ServingEngine(cfg, params, batch_slots=SLOTS, max_len=MAX_LEN,
                        decode_block=DECODE_BLOCK)
    ref_reqs = ref.generate(prompts, max_new_tokens=NEW_TOKENS)

    L.reset_dispatch_records()
    eng = ServingEngine(cfg, params, batch_slots=SLOTS, max_len=MAX_LEN,
                        decode_block=DECODE_BLOCK, mesh=_mesh())
    reqs = eng.generate(prompts, max_new_tokens=NEW_TOKENS)

    # Plan stage records: the serving path's stages are fused AND carry
    # the kv_heads sharding claim — no eager fallback anywhere.
    for kind, lp in eng.plan.layers:
        if kind not in ("attn", "local_attn", "global_attn"):
            continue
        assert lp.attention.fused and lp.decode_attn.fused
        assert dict(lp.decode_attn.sharding)["kv_heads"] == "model"
    # ... and the traced dispatches actually went through shard_map.
    assert L.DISPATCH_RECORDS["shard_map"] > 0
    assert L.DISPATCH_RECORDS["single"] == 0

    # KV page pools carry a kv_heads-sharded NamedSharding (model axis on
    # the Hkv dim of [G, P, page_size, Hkv, hd]); 4 shards of the pool.
    assert eng.kv.kv_shards == 4
    for s in _kv_pool_shardings(eng):
        assert s.spec[3] == "model", s.spec
    assert eng.metrics["sharded"] == 1

    # Greedy tokens match the single-device engine exactly.
    for a, b in zip(ref_reqs, reqs):
        assert not a.failed and not b.failed
        assert a.out_tokens == b.out_tokens


@multi
def test_sharded_engine_replicated_heads_still_matches():
    """Non-divisible kv_heads: pools replicate but the fused path still
    serves (and matches) — the fallback chain never reaches eager."""
    cfg = _cfg("llama3-8b")          # kv_heads=2, model axis 4
    params = init_params(jax.random.PRNGKey(1), cfg)
    prompts = _prompts(cfg, n=2)
    ref = ServingEngine(cfg, params, batch_slots=2, max_len=64,
                        decode_block=DECODE_BLOCK)
    r1 = ref.generate(prompts, max_new_tokens=4)
    eng = ServingEngine(cfg, params, batch_slots=2, max_len=64,
                        decode_block=DECODE_BLOCK, mesh=_mesh())
    assert eng.kv.kv_shards == 1     # replicated pools
    r2 = eng.generate(prompts, max_new_tokens=4)
    for a, b in zip(r1, r2):
        assert a.out_tokens == b.out_tokens


# ------------------------------------------------ sharded fused training

@multi
def test_mixer_dispatches_under_shard_map():
    """Regression: the mixer call sites must pass the plan's shard claim
    — every fused wrapper traced under the mesh goes through shard_map
    (RWKV reduced: wkv mixer + streamed-xent head), none single."""
    from repro.models import forward_train
    from repro.distributed.context import use_mesh

    cfg = dataclasses.replace(get_config("rwkv6-7b").reduced(),
                              dtype="float32", use_fused_kernels=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (4, 64)).astype(np.int32)
    batch = {"tokens": toks, "labels": toks}
    l1 = float(jax.jit(lambda p, b: forward_train(p, cfg, b))(params, batch))
    L.reset_dispatch_records()
    with use_mesh(_mesh()):
        l8 = float(jax.jit(lambda p, b: forward_train(p, cfg, b))(
            params, batch))
    assert L.DISPATCH_RECORDS["shard_map"] > 0
    assert L.DISPATCH_RECORDS["single"] == 0
    assert abs(l1 - l8) < 1e-5

@multi
def test_sharded_fused_train_matches_single_device():
    """The mesh-routed train step with ``use_fused_kernels``: shard_map'd
    kernels (row-parallel FFN psum, psum'd streamed-xent parts) with the
    eager-recompute VJP must reproduce the single-device fused loss."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs.base import ShapeConfig
    from repro.distributed import make_train_step
    from repro.distributed.optimizer import init_opt_state

    cfg = CONFIGS["gqa"]()
    batch_np = {
        "tokens": np.random.default_rng(0).integers(
            0, cfg.vocab_size, (4, 64)).astype(np.int32),
        "labels": np.random.default_rng(1).integers(
            0, cfg.vocab_size, (4, 64)).astype(np.int32),
    }

    def run(mesh):
        fn, p_specs, o_specs, b_fn = make_train_step(cfg, mesh)
        params = init_params(jax.random.PRNGKey(0), cfg)
        params = jax.device_put(params, jax.tree.map(
            lambda s: NamedSharding(mesh, s), p_specs,
            is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)))
        opt = init_opt_state(params)
        specs = b_fn(batch_np)
        batch = {k: jax.device_put(v, NamedSharding(mesh, specs[k]))
                 for k, v in batch_np.items()}
        params, opt, metrics = fn(params, opt, batch)
        return float(metrics["loss"])

    l1 = run(make_mesh((1, 1), ("data", "model")))
    l8 = run(_mesh())
    assert abs(l1 - l8) < 1e-5


# ---------------------------------------- adaptive prefill budget (unit)

def test_adaptive_prefill_budget():
    cfg = _cfg("llama3-8b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(cfg, params, batch_slots=4, max_len=64,
                        decode_block=4)
    assert eng.chunked
    c = eng.chunk

    class _R:          # stand-in request
        pass

    # No waiting slots -> no prefill budget.
    assert eng._prefill_budget([None] * 4, [False] * 4) == 0
    # All four slots waiting, none decoding -> full share.
    act = [_R(), _R(), _R(), _R()]
    assert eng._prefill_budget(act, [False] * 4) == 4 * c
    # One waiting against a saturated decode backlog (eff == 1): the
    # backlog lends nothing — budget stays at the waiting share.
    eng.decode_eff = 1.0
    assert eng._prefill_budget(act, [True, True, True, False]) == c
    # Same split with a draining decode stream (recent-EMA eff == 0.25):
    # the three decoding slots lend 75% of their share to prefill.
    eng.decode_eff = 0.25
    assert (eng._prefill_budget(act, [True, True, True, False])
            == int(c * (1 + 0.75 * 3)))
    # Budget never exceeds the all-slots share.
    eng.decode_eff = 0.0
    assert (eng._prefill_budget(act, [True, True, True, False]) == 4 * c)
    assert eng.metrics["sched_budget"] == 4 * c


# ------------------------------- offset flash kernel: live-prefix clamp

def test_offset_flash_kv_clamp_numerics():
    """The meta[1] index-map clamp re-fetches a live block for dead KV
    blocks; pl.when already discards their compute, so results must be
    unchanged even when kv_len covers a small prefix of the extent."""
    from repro.kernels import flash_attention
    from repro.models.layers import streaming_attention
    rng = jax.random.PRNGKey(3)
    b, sq, skv, h, d = 1, 8, 64, 2, 16
    q, k, v = (jax.random.normal(r, s, jnp.float32) for r, s in zip(
        jax.random.split(rng, 3),
        ((b, sq, h, d), (b, skv, h, d), (b, skv, h, d))))
    for kv_len in (9, 16, 24):       # dead tail >> live prefix
        off = jnp.int32(kv_len - sq)
        out = flash_attention(q, k, v, causal=True,
                              q_offset=off, kv_len=jnp.int32(kv_len),
                              block_q=8, block_kv=8)
        ref = streaming_attention(q, k, v, causal=True,
                                  q_offset=kv_len - sq, kv_len=kv_len)
        np.testing.assert_allclose(out, ref, atol=1e-5)
