"""Observability subsystem (DESIGN.md §17): events, metrics, exporters.

Three tiers:

  * pure-unit — histogram bucket/percentile math, counter/gauge/window
    semantics, recorder span ordering under a fake clock, the disabled
    recorder's zero-allocation contract;
  * golden — byte-exact Chrome-trace / JSONL / Prometheus exports of a
    fixed scenario driven by ``ManualClock`` (regenerate with
    ``python tests/test_obs.py --regen`` after INTENDED format changes);
  * engine integration (``slow``) — a mixed chunked+speculative burst
    with telemetry on: the event timeline must agree with the engine's
    own counters and trace-time compile probes, tokens must be
    bit-identical with telemetry off, and the lifetime vs
    ``last_generate`` snapshot views must window correctly.
"""

import dataclasses
import json
import math
import os

import numpy as np
import pytest

from repro.obs import (
    DISPATCH_PREFILL_CHUNK,
    DISPATCH_VERIFY,
    NULL_RECORDER,
    Counter,
    Gauge,
    Histogram,
    ManualClock,
    MetricsView,
    NullRecorder,
    Recorder,
    Registry,
    REQ_ADMITTED,
    REQ_FINISHED,
    REQ_FIRST_TOKEN,
    REQ_QUEUED,
    REQ_REJECTED,
    TRACE_DECODE,
    TRACE_PREFILL,
    TRACE_VERIFY,
    chrome_trace,
    events_jsonl,
    log_buckets,
    prometheus_text,
    resolve_recorder,
    slot_track,
    validate_chrome_trace,
)

DATA = os.path.join(os.path.dirname(__file__), "data", "obs")


# ------------------------------------------------------------ histograms

def test_log_buckets_cover_range():
    b = log_buckets(1e-3, 10.0, 4)
    assert b[0] == pytest.approx(1e-3)
    assert b[-1] >= 10.0
    # log-spaced: constant ratio between consecutive bounds
    ratios = [b[i + 1] / b[i] for i in range(len(b) - 1)]
    assert all(r == pytest.approx(10 ** 0.25) for r in ratios)
    for bad in ((0, 1, 4), (1, 1, 4), (1e-3, 10, 0)):
        with pytest.raises(ValueError):
            log_buckets(*bad)


def test_histogram_bucket_edges_and_units():
    h = Histogram("lat", lo=1e-3, hi=1.0, per_decade=1, unit="s")
    assert h.bounds == pytest.approx((1e-3, 1e-2, 1e-1, 1.0))
    # an observation exactly ON a bound lands in that bound's bucket
    # (le semantics), one epsilon above lands in the next
    h.observe(1e-2)
    h.observe(1e-2 * 1.0001)
    h.observe(5.0)                       # overflow bucket
    assert h.counts() == [0, 1, 1, 0, 1]
    assert h.count() == 3
    assert h.sum() == pytest.approx(1e-2 + 1e-2 * 1.0001 + 5.0)
    assert h.mean() == pytest.approx(h.sum() / 3)


def test_histogram_skips_non_finite():
    h = Histogram("lat")
    h.observe(float("nan"))
    h.observe(float("inf"))
    h.observe(0.5)
    assert h.count() == 1
    assert math.isnan(Histogram("empty").percentile(0.5))


def test_histogram_percentiles_ordered_and_clamped():
    rng = np.random.default_rng(7)
    h = Histogram("lat", lo=1e-5, hi=100.0, per_decade=4)
    vals = np.exp(rng.normal(-2.0, 2.0, size=500))
    for v in vals:
        h.observe(float(v))
    p50, p90, p99 = (h.percentile(q) for q in (0.5, 0.9, 0.99))
    assert p50 <= p90 <= p99
    assert vals.min() <= p50 and p99 <= vals.max()
    # estimates land within a bucket width of the exact quantile
    for q, est in ((0.5, p50), (0.9, p90), (0.99, p99)):
        exact = float(np.quantile(vals, q))
        assert est / exact < 10 ** 0.25 + 1e-9
        assert exact / est < 10 ** 0.25 + 1e-9
    with pytest.raises(ValueError):
        h.percentile(0.0)


def test_histogram_window_views():
    h = Histogram("lat", lo=1e-3, hi=1.0, per_decade=2)
    h.observe(0.001)
    h.observe(0.002)
    h.mark()
    h.observe(0.9)
    assert h.count("lifetime") == 3
    assert h.count("last_generate") == 1
    # window percentiles come from the windowed bucket counts: the
    # estimate lands inside the bucket holding 0.9 (bucket resolution,
    # not exact recovery), far from the lifetime median
    assert 0.316 < h.percentile(0.5, "last_generate") <= 1.0
    assert h.percentile(0.5, "lifetime") < 0.1


def test_counter_and_gauge_semantics():
    c = Counter("n")
    c.inc()
    c.inc(4)
    c.mark()
    c.inc(2)
    assert c.value("lifetime") == 7
    assert c.value("last_generate") == 2
    with pytest.raises(ValueError):
        c.inc(-1)
    g = Gauge("peak")
    g.set(5)
    g.max(3)
    assert g.value() == 5
    g.max(9)
    assert g.value("last_generate") == 9      # gauges are view-independent


def test_registry_kind_mismatch_and_view():
    reg = Registry()
    reg.counter("generated", "tokens out")
    reg.histogram("ttft_s", "time to first token")
    with pytest.raises(TypeError):
        reg.gauge("generated")
    assert reg.counter("generated") is reg["generated"]  # get-or-create
    reg["generated"].inc(3)
    reg["ttft_s"].observe(0.25)
    view = MetricsView(reg)
    assert view["generated"] == 3
    assert view["ttft_s_count"] == 1
    assert view["ttft_s_p50"] == pytest.approx(0.25)
    assert "generated" in dict(view) and "ttft_s_p99" in dict(view)
    with pytest.raises(KeyError):
        view["nope"]
    with pytest.raises(KeyError):
        view["ttft_s"]                  # histograms only expose suffixes
    snap = reg.snapshot("last_generate")
    assert snap["generated"] == 3 and snap["ttft_s_count"] == 1
    reg.mark()
    assert reg.snapshot("last_generate")["generated"] == 0
    assert reg.snapshot("lifetime")["generated"] == 3
    with pytest.raises(ValueError):
        reg.snapshot("bogus")


# --------------------------------------------------------------- events

def test_manual_clock_never_returns_start():
    clk = ManualClock()
    assert clk() > 0.0                  # 0.0 is the engine's unset sentinel
    t1, t2 = clk(), clk()
    assert t1 < t2
    clk.advance(1.0)
    assert clk() > t2 + 1.0


def test_recorder_span_nesting_and_ordering():
    rec = Recorder(ManualClock(tick=1.0))
    with rec.span("outer", track="engine", a=1):
        rec.instant("mid", track="engine")
        with rec.span("inner", track="engine"):
            pass
    # spans emit at EXIT: mid, inner, outer
    assert [e.name for e in rec.events] == ["mid", "inner", "outer"]
    mid, inner, outer = rec.events
    assert outer.ts < mid.ts < inner.ts
    assert inner.end <= outer.end
    assert outer.dur > inner.dur > 0
    assert outer.args == {"a": 1}
    assert rec.count("inner") == 1 and rec.count("nope") == 0


def test_recorder_complete_and_max_events():
    rec = Recorder(ManualClock(tick=1.0), max_events=2)
    rec.complete("d", 1.0, 0.5, track="engine", n=3)
    assert rec.events[0].kind == "span" and rec.events[0].end == 1.5
    rec.instant("a")
    rec.instant("b")                    # past the cap: dropped, counted
    assert len(rec.events) == 2 and rec.dropped == 1
    rec.clear()
    assert rec.events == [] and rec.dropped == 0


def test_null_recorder_is_inert_and_allocation_free():
    nr = NULL_RECORDER
    assert not nr.enabled and nr.events == ()
    nr.instant("x", track="engine", a=1)
    nr.complete("y", 0.0, 1.0)
    assert nr.events == () and nr.count("x") == 0
    # span returns ONE shared context — the hot path allocates nothing
    assert nr.span("a") is nr.span("b")
    with nr.span("a"):
        pass


def test_resolve_recorder():
    assert resolve_recorder(None) is NULL_RECORDER
    assert resolve_recorder(False) is NULL_RECORDER
    clk = ManualClock()
    rec = resolve_recorder(True, clock=clk)
    assert isinstance(rec, Recorder) and rec.clock is clk
    mine = Recorder()
    assert resolve_recorder(mine) is mine
    assert resolve_recorder(mine, clock=clk).clock is clk  # rebound
    assert isinstance(resolve_recorder(NullRecorder()), NullRecorder)
    with pytest.raises(TypeError):
        resolve_recorder("yes")


# --------------------------------------------------------------- goldens

def _golden_events():
    """A fixed mini-lifecycle on a deterministic clock."""
    rec = Recorder(ManualClock(tick=0.001))
    rec.instant(REQ_QUEUED, track="engine", rid=0, prompt_len=12)
    rec.instant(REQ_ADMITTED, track=slot_track(0), rid=0)
    rec.complete("prefill", 0.002, 0.010, track=slot_track(0), rid=0,
                 tokens=12)
    rec.instant(REQ_FIRST_TOKEN, track=slot_track(0), rid=0,
                ttft_s=0.011)
    rec.complete("decode", 0.012, 0.004, track="engine", block=4,
                 slots=1)
    rec.instant("page.alloc", track="kv", page=3, free=5)
    rec.instant(REQ_FINISHED, track=slot_track(0), rid=0, tokens=4,
                failed=False)
    return rec.events


def _golden_registry():
    reg = Registry()
    reg.counter("generated", "tokens generated").inc(4)
    reg.counter("dispatches", "decode dispatches").inc(1)
    reg.gauge("pages_in_use", "allocated KV pages").set(3)
    h = reg.histogram("ttft_s", "time to first token",
                      lo=1e-3, hi=10.0, per_decade=2)
    for v in (0.011, 0.02, 0.5):
        h.observe(v)
    reg.info("quant", "KV quantization mode", value="none")
    reg.info("plan_source", "plan provenance", value="analytic")
    return reg


def _golden(name, text, regen):
    path = os.path.join(DATA, name)
    if regen:
        os.makedirs(DATA, exist_ok=True)
        with open(path, "w") as fh:
            fh.write(text)
        return
    with open(path) as fh:
        assert text == fh.read(), (
            f"{name} drifted from golden — if the format change is "
            f"intended, regenerate: python tests/test_obs.py --regen")


def test_chrome_trace_golden():
    trace = chrome_trace(_golden_events())
    assert validate_chrome_trace(trace) == []
    _golden("trace.json", json.dumps(trace, indent=1) + "\n", False)


def test_events_jsonl_golden():
    _golden("events.jsonl", events_jsonl(_golden_events()), False)


def test_prometheus_golden():
    text = prometheus_text(_golden_registry())
    _golden("metrics.prom", text, False)
    # structural spot-checks, independent of the golden bytes
    assert "repro_generated_total 4" in text
    assert 'repro_ttft_s_bucket{le="+Inf"} 3' in text
    assert "repro_ttft_s_count 3" in text
    assert 'repro_info{quant="none",plan_source="analytic"} 1' in text
    p = [float(line.split()[-1]) for line in text.splitlines()
         if line.startswith("repro_ttft_s_p")]
    assert len(p) == 3 and p[0] <= p[1] <= p[2]


def test_chrome_trace_tracks_stable():
    trace = chrome_trace(_golden_events())
    meta = [e for e in trace["traceEvents"] if e["ph"] == "M"]
    names = [e["args"]["name"] for e in meta]
    # named tracks first (first-seen), then slots sorted numerically
    assert names == ["engine", "kv", "slot0"]
    assert [e["tid"] for e in meta] == [1, 2, 3]


def test_validate_chrome_trace_catches_problems():
    assert validate_chrome_trace({}) == ["traceEvents missing or empty"]
    bad = {"traceEvents": [
        {"ph": "Z", "pid": 1, "tid": 1},
        {"ph": "X", "pid": 1, "tid": 9, "name": "d", "ts": -1.0},
    ]}
    errs = validate_chrome_trace(bad)
    assert any("bad ph" in e for e in errs)
    assert any("bad ts" in e for e in errs)
    assert any("no thread_name" in e for e in errs)


# ---------------------------------------------- engine integration (slow)

def _engine(telemetry=False, clock=None, **kw):
    import jax

    from repro.configs import get_config
    from repro.models import init_params
    from repro.serving import ServingEngine

    cfg = dataclasses.replace(get_config("gpt2").reduced(),
                              dtype="float32", use_fused_kernels=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    kw.setdefault("batch_slots", 2)
    kw.setdefault("max_len", 96)
    kw.setdefault("decode_block", 4)
    eng = ServingEngine(cfg, params, telemetry=telemetry, clock=clock,
                        **kw)
    return cfg, eng


def _burst_prompts(cfg):
    v = cfg.vocab_size
    return [
        np.array(([1, 2, 3, 4, 5, 6, 7, 8] * 4)[:30], np.int32) % v,
        np.array([9, 8, 7, 6, 5], np.int32) % v,
        np.array([1, 2, 3, 4] * 5, np.int32) % v,
    ]


@pytest.mark.slow
def test_engine_burst_timeline_matches_counters():
    """Mixed chunked+speculative burst: the event timeline, the metric
    counters, and the trace-time compile probes must all agree, and
    telemetry must not perturb the greedy tokens."""
    kw = dict(chunked=True, prefill_chunk=16, speculative=True,
              draft_len=4)
    cfg, eng = _engine(telemetry=True, clock=ManualClock(tick=1e-4), **kw)
    prompts = _burst_prompts(cfg)
    reqs = eng.generate([p.copy() for p in prompts], max_new_tokens=10)

    # event counts == the engine's own counters
    n = len(prompts)
    for name, want in ((REQ_QUEUED, n), (REQ_ADMITTED, n),
                       (REQ_FIRST_TOKEN, n), (REQ_FINISHED, n)):
        assert eng.obs.count(name) == want, name
    assert (eng.obs.count(DISPATCH_PREFILL_CHUNK)
            == eng.metrics["prefill_chunks"])
    assert (eng.obs.count(DISPATCH_VERIFY)
            == eng.metrics["verify_dispatches"])
    # event counts == the trace-time compile probes (same bump sites)
    for name, probe in ((TRACE_PREFILL, "prefill"),
                        (TRACE_DECODE, "decode"),
                        (TRACE_VERIFY, "verify")):
        assert eng.obs.count(name) == eng._traces[probe]

    # per-request lifecycle ordering on the shared clock
    by_rid = {}
    for e in eng.obs.events:
        if e.name.startswith("req."):
            by_rid.setdefault(e.args["rid"], {})[e.name] = e.ts
    for r in reqs:
        t = by_rid[r.rid]
        assert (t[REQ_QUEUED] <= t[REQ_ADMITTED]
                <= t[REQ_FIRST_TOKEN] <= t[REQ_FINISHED])
        assert t[REQ_QUEUED] == r.submitted_at
        assert t[REQ_FINISHED] == r.finished_at
        assert r.ttft_s == pytest.approx(
            t[REQ_FIRST_TOKEN] - t[REQ_QUEUED])
        assert 0.0 <= r.queue_wait_s <= r.ttft_s <= r.latency_s
        assert r.tpot_s >= 0.0

    # every dispatch span sits inside the generate() window
    t_lo = min(e.ts for e in eng.obs.events)
    t_hi = max(e.end for e in eng.obs.events)
    for e in eng.obs.events:
        assert t_lo <= e.ts <= e.end <= t_hi

    # trace export is loadable; per-slot tracks exist
    trace = chrome_trace(eng.obs.events)
    assert validate_chrome_trace(trace) == []
    lanes = {e["args"]["name"] for e in trace["traceEvents"]
             if e["ph"] == "M"}
    assert {"engine", "slot0", "slot1"} <= lanes

    # pure observer: identical tokens with telemetry off, zero events
    _, off = _engine(telemetry=False, **kw)
    reqs_off = off.generate([p.copy() for p in prompts],
                            max_new_tokens=10)
    assert [r.out_tokens for r in reqs] == [r.out_tokens
                                            for r in reqs_off]
    assert off.obs.events == () and not off.obs.enabled


@pytest.mark.slow
def test_engine_rejection_nan_semantics_and_event():
    """Admission-rejected requests: finite latency, nan ttft, a
    REQ_REJECTED event, and a windowed ``rejected`` counter."""
    cfg, eng = _engine(telemetry=True, clock=ManualClock(tick=1e-4))
    good = np.array([1, 2, 3, 4, 5], np.int32)
    bad = np.ones(97, np.int32)                  # > max_len
    reqs = eng.generate([good, bad], max_new_tokens=4)
    r = reqs[1]
    assert r.failed and math.isnan(r.ttft_s) and math.isnan(r.tpot_s)
    assert math.isnan(r.queue_wait_s)            # never admitted
    assert r.latency_s >= 0.0                    # failed AT a real time
    assert eng.obs.count(REQ_REJECTED) == 1
    assert eng.metrics["rejected"] == 1
    assert eng.metrics["ttft_s_count"] == 1      # nan never observed
    # the good request is untouched
    assert reqs[0].out_tokens and not reqs[0].failed


@pytest.mark.slow
def test_engine_snapshot_windows():
    """lifetime accumulates across generate() calls; last_generate
    covers exactly the most recent one."""
    cfg, eng = _engine(telemetry=True, clock=ManualClock(tick=1e-4))
    p = [np.array([1, 2, 3, 4, 5, 6], np.int32)]
    eng.generate([q.copy() for q in p], max_new_tokens=4)
    g1 = eng.metrics["generated"]
    eng.generate([q.copy() for q in p], max_new_tokens=4)
    life = eng.snapshot("lifetime")
    win = eng.snapshot("last_generate")
    assert life["generated"] == 2 * g1
    assert win["generated"] == g1
    assert life["ttft_s_count"] == 2 and win["ttft_s_count"] == 1
    # gauges are point-in-time in both views
    assert life["pages_in_use"] == win["pages_in_use"]
    # the back-compat mapping is the lifetime view
    assert eng.metrics["generated"] == life["generated"]
    assert dict(eng.metrics)["generated"] == life["generated"]


if __name__ == "__main__":
    import sys
    if "--regen" in sys.argv:
        os.makedirs(DATA, exist_ok=True)
        _golden("trace.json",
                json.dumps(chrome_trace(_golden_events()), indent=1)
                + "\n", True)
        _golden("events.jsonl", events_jsonl(_golden_events()), True)
        _golden("metrics.prom", prometheus_text(_golden_registry()), True)
        print(f"regenerated goldens under {DATA}")
    else:
        raise SystemExit(pytest.main([__file__, "-v"] + sys.argv[1:]))
