"""Prefix cache subsystem: radix-tree page sharing + copy-on-write.

Five layers of coverage (DESIGN.md §10):

  * Radix tree units — walk/insert/claim/evict over a real allocator:
    refcount moves, LRU-leaf eviction order, eviction under allocation
    pressure, and the free-list accounting invariant
    (``assert_page_accounting``) catching a seeded corruption.
  * COW primitives — ``paged_append`` / ``place_chunk_pages`` with
    ``cow_src``/``cow_dst``: the shared page survives the divergent
    write bit-for-bit; a model-level ``prefill_chunk`` drive shows the
    partial-last-page COW through the whole stack, starting at a nonzero
    page offset against a pre-populated table row.
  * Engine exactness — two requests sharing a page-aligned prefix
    physically share those pages (same physical ids in both table rows,
    refcount 2, pool bytes counted once) and greedy tokens bit-match the
    cold-start engine for dense, GQA, and sliding-window configs; the
    bootstrap mode's mid-page COW divergence never mutates the cached
    run.
  * Scheduler knobs — ``admission="sjf"|"prefix"`` orderings and the
    adaptive decode block (floored at the static value, bounded compiled
    program count, token-exact).
  * Churn soak — random join/leave over shared prefixes with the
    accounting invariant checked between waves; an allocator failure
    mid-chunked-prefill fails that request alone and returns its
    already-placed pages exactly once.

The 8-virtual-device test (sharded pools + replicated table + per-shard
bytes counting shared pages once) skips without forced host devices,
exactly like ``tests/test_sharded_serving.py``.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_params, prefill, prefill_chunk
from repro.models.params import cache_leaf_kind, cache_leaf_name
from repro.serving import (PagedKVCache, PrefixCache, ServingEngine,
                           gather_pages, paged_append, place_chunk_pages)
from repro.serving.kv_cache import NULL_PAGE, stage_chunk

multi = pytest.mark.skipif(len(jax.devices()) < 8,
                           reason="needs 8 forced host devices")


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


def _cfg(arch="qwen1.5-0.5b", **over):
    cfg = get_config(arch).reduced()
    return dataclasses.replace(cfg, **over) if over else cfg


def _kv(slots=4, max_len=64, ps=4):
    return PagedKVCache(_cfg(), slots=slots, max_len=max_len, page_size=ps)


def _prompt(n, seed=0):
    return np.random.default_rng(seed).integers(
        1, _cfg().vocab_size, n).astype(np.int32)


def _engine(cfg, params, **over):
    kw = dict(batch_slots=2, max_len=64, decode_block=4, page_size=4,
              prefill_chunk=8)
    kw.update(over)
    return ServingEngine(cfg, params, **kw)


# ------------------------------------------------------ radix tree units

def test_radix_walk_insert_and_rewalk():
    kv = _kv()
    pc = PrefixCache(kv, chunk=8)
    p = _prompt(16, 1)
    kv.ensure(0, 16)                              # 4 exclusive pages
    assert pc.insert(0, p) == 4 and pc.nodes == 4
    assert pc.lookup_pages(p) == 4
    assert pc.insert(0, p) == 0                   # idempotent
    # A prompt diverging at page 2 matches exactly the first 2 chunks.
    q = p.copy()
    q[9] += 1
    assert pc.lookup_pages(q) == 2
    # Duplicate token chunks under DIFFERENT parents are distinct nodes.
    r = np.concatenate([p[4:8], p[4:8], p[8:]]).astype(np.int32)
    assert pc.lookup_pages(r) == 0
    kv.assert_page_accounting()


def test_claim_moves_refcounts_and_release_keeps_pages_cached():
    kv = _kv()
    pc = PrefixCache(kv, chunk=8)
    p = _prompt(16, 2)
    kv.ensure(0, 16)
    pc.insert(0, p)
    pages = list(kv.slot_pages(0))
    kv.release(0)
    pc.release_slot(0)
    assert kv.pages_in_use == 0 and kv.pages_cached == 4
    kv.assert_page_accounting()
    # Claim: chunk-aligned cap at plen-1 -> 16 tokens claims 8 (1 chunk).
    hit = pc.claim(1, p)
    assert hit.prefill_start == 8 and hit.hit_pages == 2
    assert hit.prompt_pages == 4 and hit.cow is None and not hit.full
    assert list(kv.slot_pages(1)) == pages[:2]
    assert list(kv.table_row(1)[:2]) == pages[:2]
    assert all(kv.page_refs(pg) == 1 for pg in pages[:2])
    assert kv.pages_in_use == 2 and kv.pages_cached == 2
    kv.release(1)
    pc.release_slot(1)
    assert kv.pages_in_use == 0 and kv.pages_cached == 4
    kv.assert_page_accounting()


def test_evict_lru_leaf_order_and_pressure():
    # Pool: 2 slots x 8 pages; cache two 4-page prompts, then demand the
    # whole pool — eviction must reclaim all cached pages, LRU first.
    kv = PagedKVCache(_cfg(), slots=2, max_len=32, page_size=4)
    pc = PrefixCache(kv, chunk=4)
    pa, pb = _prompt(16, 3), _prompt(16, 4)
    kv.ensure(0, 16)
    pc.insert(0, pa)
    kv.release(0)
    pc.release_slot(0)
    kv.ensure(0, 16)
    pc.insert(0, pb)
    kv.release(0)
    pc.release_slot(0)
    assert kv.pages_cached == 8 and pc.nodes == 8
    # pa's leaf is older than pb's: first eviction takes pa's deepest...
    # (leaf-only: the deepest cached chunk of the LRU chain).
    assert pc.evict_lru_leaf()
    assert pc.nodes == 7 and pc.evictions == 1
    assert pc.lookup_pages(pa) == 3 and pc.lookup_pages(pb) == 4
    kv.assert_page_accounting()
    # Allocation pressure: both slots want full capacity; every cached
    # page is reclaimed through the evictor hook, nothing raises.
    kv.ensure(0, 32)
    kv.ensure(1, 32)
    assert kv.pages_cached == 0 and pc.nodes == 0
    assert kv.pages_in_use == 16 and not kv._free
    kv.assert_page_accounting()
    # Fully referenced pool: eviction cannot help; ensure now raises...
    with pytest.raises(ValueError, match="slot capacity"):
        kv.ensure(0, 33)
    kv.release(0)
    kv.release(1)
    kv.assert_page_accounting()


def test_eviction_prunes_interior_pages_pinned_by_suffix_claims():
    """Regression: ``extend_claim`` lets a request adopt only a SUFFIX
    of a chain, so unreferenced ancestors can sit above referenced
    descendants; leaf-only eviction then found nothing and allocation
    failed while reclaimable cached pages sat pinned.  Eviction must
    prune the unreferenced subtree — freeing the cached ancestors and
    merely disowning the still-referenced suffix pages."""
    kv = PagedKVCache(_cfg(), slots=2, max_len=32, page_size=4)
    pc = PrefixCache(kv, chunk=4)
    pa = _prompt(32, 21)                           # 8 full pages
    kv.ensure(0, 32)
    pc.insert(0, pa)
    a_pages = list(kv.slot_pages(0))
    # Same-wave slot 1 computed pages 0..3 itself, then caught up and
    # adopted only the suffix nodes 4..6 (chunk-capped at plen-1).
    kv.ensure(1, 16)
    off, caught = pc.extend_claim(1, pa, 16)
    assert off == 28 and caught == 3
    kv.release(0)
    pc.release_slot(0)
    assert kv.pages_cached == 5                    # nodes 0..3 + node 7
    # Pressure: slot 0 wants full capacity again.  Free list holds 4
    # (16 - 8 - 4); the rest must come from eviction, which has to
    # prune through the referenced suffix' unreferenced ancestors —
    # leaf-only eviction would raise here with 4 reclaimable pages
    # pinned.  Eviction frees only what the demand needs, so at most
    # one cached page may survive.
    kv.ensure(0, 32)
    assert kv.pages_in_use == 15                   # 8 + 4 + 3 adopted
    assert kv.pages_cached + len(kv._free) == 1
    kv.assert_page_accounting()
    # Slot 1's adopted suffix pages survived as disowned references...
    for pg in a_pages[4:7]:
        assert kv.page_refs(pg) == 1
    kv.release(1)
    kv.release(0)
    kv.assert_page_accounting()
    # A not-yet-needed cached ancestor may legitimately survive the
    # pressure (eviction frees only what demand asked for).
    assert kv.pages_in_use == 0 and kv.pages_cached <= 1


def test_accounting_invariant_catches_corruption():
    kv = _kv()
    kv.ensure(0, 16)
    kv.assert_page_accounting()
    kv._free.append(kv._owned[0][0])              # seed a double-free
    with pytest.raises(AssertionError, match="referenced page"):
        kv.assert_page_accounting()


def test_release_is_exact_once_and_idempotent():
    kv = _kv()
    pc = PrefixCache(kv, chunk=8)
    p = _prompt(16, 5)
    kv.ensure(0, 16)
    pc.insert(0, p)
    free_before = len(kv._free)
    kv.release(0)
    # Tree pages stay cached: NOT pushed to the free list (the old
    # unconditional extend would have double-freed them at eviction).
    assert len(kv._free) == free_before
    kv.release(0)                                 # idempotent no-op
    assert len(kv._free) == free_before
    kv.assert_page_accounting()


# ------------------------------------------------------- COW primitives

def test_paged_append_cow_preserves_shared_page():
    ps, h, hd = 4, 2, 8
    nprng = np.random.default_rng(6)
    pool = jnp.asarray(nprng.normal(size=(4, ps, h, hd)).astype(np.float32))
    shared = np.asarray(pool[1])
    # Slot 0 diverges at position 2 inside shared page 1 -> COW to page 3.
    table = jnp.asarray([[3, 2]], np.int32)       # already redirected
    new = jnp.full((1, 1, h, hd), 9.0, jnp.float32)
    out = paged_append(pool, table, jnp.asarray([2], np.int32), new,
                       layout="bshd", cow_src=jnp.asarray([1], np.int32),
                       cow_dst=jnp.asarray([3], np.int32))
    np.testing.assert_array_equal(np.asarray(out[1]), shared)   # intact
    np.testing.assert_array_equal(np.asarray(out[3][:2]), shared[:2])
    np.testing.assert_array_equal(np.asarray(out[3][2]), 9.0)
    # NULL pair no-ops for idle slots.
    out2 = paged_append(pool, table, jnp.asarray([2], np.int32), new,
                        layout="bshd",
                        cow_src=jnp.asarray([NULL_PAGE], np.int32),
                        cow_dst=jnp.asarray([NULL_PAGE], np.int32))
    np.testing.assert_array_equal(np.asarray(out2[1]), shared)


def test_place_chunk_pages_cow_preserves_shared_page():
    ps, h, hd = 4, 2, 8
    nprng = np.random.default_rng(7)
    pool = jnp.asarray(nprng.normal(size=(4, ps, h, hd)).astype(np.float32))
    shared = np.asarray(pool[2])
    chunk = jnp.asarray(nprng.normal(size=(1, ps, h, hd)).astype(np.float32))
    out = place_chunk_pages(pool, chunk, jnp.asarray([3], np.int32),
                            layout="bshd", cow_src=jnp.int32(2),
                            cow_dst=jnp.int32(3))
    np.testing.assert_array_equal(np.asarray(out[2]), shared)   # intact
    np.testing.assert_array_equal(np.asarray(out[3]), np.asarray(chunk[0]))


def test_prefill_chunk_cow_partial_last_page(rng):
    """The partial-last-page COW through the whole stack: prompt B is a
    mid-page prefix of cached prompt A; B claims A's pages INCLUDING the
    tail page, then runs ONE final chunk at a nonzero page offset against
    the pre-populated row, copy-on-writing the tail page.  B's logits
    match its whole-prompt prefill and A's page is untouched."""
    cfg = _cfg(dtype="float32")
    params = init_params(rng, cfg)
    ps, chunk, max_len = 4, 4, 32
    kv = PagedKVCache(cfg, slots=2, max_len=max_len, page_size=ps)
    pc = PrefixCache(kv, chunk=chunk, bootstrap=True)
    pa = _prompt(16, 8)                            # 4 full pages
    pb = pa[:11]                                   # ends mid-page (3 in 3rd)

    # Prefill A chunk-by-chunk into slot 0 (the engine's recipe).
    cache = kv.init_cache()
    step = jax.jit(
        lambda p, t, c, row, cp, off, li, cs, cd: prefill_chunk(
            p, cfg, t, c, row, cp, off, li, cs, cd), donate_argnums=(2,))
    for k in range(4):
        off = k * chunk
        kv.ensure(0, off + chunk)
        row = kv.table_row(0)
        toks, cpages, last = stage_chunk(pa, off, chunk, row, ps)
        _, _, cache = step(params, jnp.asarray(toks)[None], cache,
                           jnp.asarray(row), jnp.asarray(cpages),
                           jnp.int32(off), jnp.int32(last),
                           jnp.int32(NULL_PAGE), jnp.int32(NULL_PAGE))
    pc.insert(0, pa)

    # B: full-page walk matches 2 pages, tail (tokens 8..10) matches the
    # cached 3rd chunk -> bootstrap claim takes it as a COW candidate.
    hit = pc.claim(1, pb)
    assert hit.full and hit.cow == 2 and hit.hit_pages == 3
    a_page = int(kv.slot_pages(1)[2])      # the claimed (shared) page
    a_rows = np.asarray(
        jax.tree_util.tree_leaves(cache)[0][0, a_page])   # snapshot

    # Drive B's final chunk at offset 8 — nothing of B was computed yet:
    # the chunk attends to the CLAIMED pages through the row.
    cow_src, cow_dst = kv.cow_page(1, 2)
    assert cow_src == a_page and cow_dst != a_page
    kv.ensure(1, 12)
    row = kv.table_row(1)
    toks, cpages, last = stage_chunk(pb, 8, chunk, row, ps)
    assert cpages[0] == cow_dst
    nt, lg, cache = step(params, jnp.asarray(toks)[None], cache,
                         jnp.asarray(row), jnp.asarray(cpages),
                         jnp.int32(8), jnp.int32(last),
                         jnp.int32(cow_src), jnp.int32(cow_dst))

    whole_lg, _ = jax.jit(lambda p, b: prefill(p, cfg, b))(
        params, {"tokens": jnp.asarray(pb)[None]})
    np.testing.assert_allclose(np.asarray(lg), np.asarray(whole_lg),
                               atol=1e-5)
    assert int(np.asarray(nt)[0, 0]) == int(jnp.argmax(whole_lg, -1)[0, 0])
    # A's shared page is bit-identical after B's divergent write.
    np.testing.assert_array_equal(
        np.asarray(jax.tree_util.tree_leaves(cache)[0][0, a_page]), a_rows)
    kv.assert_page_accounting()


# ------------------------------------------- engine: sharing exactness

@pytest.mark.parametrize("arch", ["gpt2", "llama3-8b", "gemma3-4b"])
def test_shared_prefix_bit_matches_cold_engine(rng, arch):
    """Dense (learned positions), GQA, and sliding-window: a hot engine
    (prefix cache warm from an earlier wave) produces bit-identical
    greedy tokens to a cold engine for prompts sharing a k-page prefix,
    while prefilling fewer chunks."""
    cfg = _cfg(arch)
    params = init_params(rng, cfg)
    nprng = np.random.default_rng(9)
    shared = nprng.integers(1, cfg.vocab_size, 24, dtype=np.int32)
    mk = lambda tail: np.concatenate(
        [shared, nprng.integers(1, cfg.vocab_size, tail,
                                dtype=np.int32)]).astype(np.int32)
    warm, p1, p2 = mk(5), mk(7), mk(3)

    cold = _engine(cfg, params, prefix_cache=False)
    ref = cold.generate([p1, p2], max_new_tokens=5)

    hot = _engine(cfg, params)
    hot.generate([warm], max_new_tokens=2)          # populate the tree
    chunks0 = hot.metrics["prefill_chunks"]
    out = hot.generate([p1, p2], max_new_tokens=5)
    for a, b in zip(ref, out):
        assert a.out_tokens == b.out_tokens, "hot engine diverged"
    m = hot.metrics
    assert m["prefix_hit_pages"] >= 2 * 4            # >= 2 chunks each
    assert m["prefix_hit_rate"] > 0
    # The shared 24-token prefix (3 chunks) is claimed, not recomputed:
    # each hot request prefills at least 2 chunks fewer than cold.
    assert (m["prefill_chunks"] - chunks0
            <= cold.metrics["prefill_chunks"] - 4)
    hot.kv.assert_page_accounting()
    assert hot.kv.pages_in_use == 0 and hot.kv.pages_cached > 0


def test_two_requests_physically_share_pages(rng):
    """The acceptance contract: both table rows carry the SAME physical
    ids for the shared prefix (refcount 2 while both are live), pool
    bytes count the shared pages once, and both requests bit-match their
    cold references."""
    cfg = _cfg()
    params = init_params(rng, cfg)
    nprng = np.random.default_rng(10)
    shared = nprng.integers(1, cfg.vocab_size, 24, dtype=np.int32)
    mk = lambda tail, s: np.concatenate(
        [shared, np.random.default_rng(s).integers(
            1, cfg.vocab_size, tail, dtype=np.int32)]).astype(np.int32)
    p1, p2 = mk(7, 1), mk(5, 2)

    rows, refs, in_use = {}, {}, {}

    class Probe(ServingEngine):
        def _dispatch_chunk(self, slot, r, *a):
            if r.rid not in rows:
                rows[r.rid] = self.kv.table_row(slot).copy()
                refs[r.rid] = self.kv._refs.copy()
                in_use[r.rid] = self.kv.pages_in_use
            return super()._dispatch_chunk(slot, r, *a)

    cold = _engine(cfg, params, prefix_cache=False)
    ref_out = cold.generate([p1, p2], max_new_tokens=5)

    eng = Probe(cfg, params, batch_slots=2, max_len=64, decode_block=4,
                page_size=4, prefill_chunk=8)
    eng.generate([p1[:26]], max_new_tokens=2)       # warm the prefix
    rows.clear(), refs.clear(), in_use.clear()
    out = eng.generate([p1, p2], max_new_tokens=5)
    assert [r.out_tokens for r in out] == [r.out_tokens for r in ref_out]
    # Both admissions claimed the same 6 physical pages (the 24-token
    # shared prefix) straight into their table rows...
    k = 6
    assert list(rows[0][:k]) == list(rows[1][:k])
    assert NULL_PAGE not in rows[0][:k]
    # ...with refcount 2 while both were live — counted ONCE in the pool
    # (at either snapshot at most one slot has any exclusive pages yet).
    assert all(refs[1][pg] == 2 for pg in rows[1][:k])
    assert in_use[0] == k and in_use[1] <= k + 2
    # Pool-bytes-counted-once shows up as a lower allocation peak than
    # the cold engine serving the identical wave.
    assert eng.kv.peak_pages < cold.kv.peak_pages
    eng.kv.assert_page_accounting()


def test_bootstrap_cow_divergence_never_mutates_other_slot(rng):
    """Bootstrap mode: a fully-cached prompt skips prefill (decode-path
    first token, COW on the shared last page — both the page-aligned and
    the mid-page variants) and its divergent decode writes never touch
    the cached run, which replays bit-identically afterwards."""
    cfg = _cfg()
    params = init_params(rng, cfg)
    plong = _prompt(32, 11)                          # page-aligned
    pmid = plong[:27].copy()                         # ends mid-page

    def cold(p):
        e = _engine(cfg, params, batch_slots=1, prefix_cache=False)
        return e.generate([p], max_new_tokens=6)[0].out_tokens

    boot = _engine(cfg, params, batch_slots=1, prefix_bootstrap=True)
    boot.generate([plong], max_new_tokens=6)         # cold: fills tree
    r1 = boot.generate([plong], max_new_tokens=6)    # page-aligned hit
    assert boot.metrics["prefix_bootstraps"] == 1
    assert boot.metrics["cow_copies"] == 1
    assert r1[0].out_tokens == cold(plong)
    r2 = boot.generate([pmid], max_new_tokens=6)     # mid-page tail hit
    assert boot.metrics["prefix_bootstraps"] == 2
    assert boot.metrics["cow_copies"] == 2
    assert r2[0].out_tokens == cold(pmid)
    # The COW'd divergences (r1 and r2 decoded into private copies) left
    # the cached pages intact: plong replays exactly.
    r3 = boot.generate([plong], max_new_tokens=6)
    assert r3[0].out_tokens == cold(plong)
    boot.kv.assert_page_accounting()
    assert boot.kv.pages_in_use == 0


# ------------------------------------------------------ scheduler knobs

def test_admission_policy_validation(rng):
    cfg = _cfg()
    params = init_params(rng, cfg)
    with pytest.raises(ValueError, match="admission policy"):
        _engine(cfg, params, admission="lifo")
    with pytest.raises(ValueError, match="requires prefix_cache"):
        _engine(cfg, params, admission="prefix", prefix_cache=False)
    with pytest.raises(ValueError, match="requires chunked"):
        _engine(cfg, params, chunked=False, prefix_cache=True)
    with pytest.raises(ValueError, match="requires prefix_cache"):
        _engine(cfg, params, prefix_cache=False, prefix_bootstrap=True)


def test_admission_sjf_serves_short_first(rng):
    cfg = _cfg()
    params = init_params(rng, cfg)
    long_p, short_p = _prompt(40, 12), _prompt(6, 13)
    eng = _engine(cfg, params, batch_slots=1, admission="sjf")
    reqs = eng.generate([long_p, short_p], max_new_tokens=3)
    assert all(r.done and not r.failed for r in reqs)
    assert reqs[1].first_token_at < reqs[0].first_token_at


def test_admission_prefix_serves_cached_first(rng):
    cfg = _cfg()
    params = init_params(rng, cfg)
    cached, fresh = _prompt(24, 14), _prompt(24, 15)
    eng = _engine(cfg, params, batch_slots=1, admission="prefix")
    eng.generate([cached], max_new_tokens=2)
    reqs = eng.generate([fresh, cached], max_new_tokens=3)
    assert all(r.done and not r.failed for r in reqs)
    # The hot prompt jumps the queue: its prefill is mostly free.
    assert reqs[1].first_token_at < reqs[0].first_token_at
    assert eng.metrics["prefix_hit_pages"] > 0


def test_adaptive_decode_block_grows_with_active_slots(rng):
    cfg = _cfg()
    params = init_params(rng, cfg)
    nprng = np.random.default_rng(16)
    prompts = [nprng.integers(1, cfg.vocab_size, n, dtype=np.int32)
               for n in (6, 8, 10, 12)]
    base = ServingEngine(cfg, params, batch_slots=4, max_len=64,
                         decode_block=2, page_size=4)
    ref = base.generate(prompts, max_new_tokens=12)
    eng = ServingEngine(cfg, params, batch_slots=4, max_len=64,
                        decode_block=2, page_size=4,
                        adaptive_decode_block=True)
    out = eng.generate(prompts, max_new_tokens=12)
    assert [r.out_tokens for r in out] == [r.out_tokens for r in ref]
    # 4 efficient slots scale the block to the 4x cap; the floor is the
    # static value; the power-of-two ladder bounds compiles at 3.
    assert eng.metrics["decode_block"] == 2
    assert eng.metrics["decode_block_last"] in (2, 4, 8)
    assert eng._decode_block_size(0) == 2
    assert eng.metrics["decode_traces"] <= 3
    assert eng.metrics["dispatches"] <= base.metrics["dispatches"]


def test_decode_block_size_ladder(rng):
    cfg = _cfg()
    params = init_params(rng, cfg)
    eng = ServingEngine(cfg, params, batch_slots=8, max_len=32,
                        decode_block=4, adaptive_decode_block=True)
    eng.decode_eff = 1.0
    assert eng._decode_block_size(1) == 4          # floor
    assert eng._decode_block_size(2) == 8
    assert eng._decode_block_size(8) == 16         # 4x cap
    eng.decode_eff = 0.3                           # wasted ticks pull back
    assert eng._decode_block_size(4) == 4
    eng2 = ServingEngine(cfg, params, batch_slots=8, max_len=32,
                         decode_block=4)
    eng2.decode_eff = 1.0
    assert eng2._decode_block_size(8) == 4         # knob off: static


# -------------------------------------------------- churn / failure soak

def test_midprefill_failure_returns_pages_exactly_once(rng):
    """An allocator failure between chunks fails THAT request, returns
    its already-placed pages exactly once, and the stream keeps serving
    (the old engine would have raised mid-generate with pages held)."""
    cfg = _cfg()
    params = init_params(rng, cfg)
    eng = _engine(cfg, params, batch_slots=2)
    good, doomed = _prompt(6, 17), _prompt(40, 18)

    calls = {"n": 0}
    orig = eng.kv.alloc_page

    def failing_alloc():
        calls["n"] += 1
        if calls["n"] > 6:                        # mid-prefill of doomed
            raise RuntimeError("KV page pool exhausted (injected)")
        return orig()

    eng.kv.alloc_page = failing_alloc
    reqs = eng.generate([doomed, good], max_new_tokens=4)
    eng.kv.alloc_page = orig
    assert reqs[0].failed and "exhausted" in reqs[0].error
    assert reqs[1].done and not reqs[1].failed and reqs[1].out_tokens
    assert eng.metrics["rejected"] == 1
    eng.kv.assert_page_accounting()
    assert eng.kv.pages_in_use == 0


def test_decode_cow_pool_exhaustion_fails_one_request(rng):
    """Regression: a fully-referenced pool plus a pending bootstrap COW
    (which needs one transient extra page while src and dst are both
    live) used to raise straight through ``generate()``, stranding every
    active request.  It must fail only the slot whose COW cannot be
    satisfied; the retired slot's pages fall back to cached and unblock
    the neighbour's COW."""
    cfg = _cfg()
    params = init_params(rng, cfg)
    eng = ServingEngine(cfg, params, batch_slots=2, max_len=16,
                        decode_block=4, page_size=4, prefill_chunk=4,
                        prefix_bootstrap=True)
    p, q = _prompt(16, 30), _prompt(16, 31)
    eng.generate([p], max_new_tokens=2)           # cache all 4 pages
    eng.generate([q], max_new_tokens=2)           # ...and the other 4
    reqs = eng.generate([p, q], max_new_tokens=2)
    # Both full-hit: 8/8 pages referenced, no page free for slot 0's
    # COW -> it fails gracefully; slot 1 then evicts slot 0's returned
    # pages for its own COW and completes.
    assert reqs[0].failed and "exhausted" in reqs[0].error
    assert reqs[1].done and not reqs[1].failed and reqs[1].out_tokens
    eng.kv.assert_page_accounting()
    assert eng.kv.pages_in_use == 0


@pytest.mark.slow
@pytest.mark.parametrize("bootstrap", [False, True])
def test_churn_soak_accounting_invariants(rng, bootstrap):
    """Random join/leave over a small pool of shared prefixes: after
    every wave the refcount/free-list partition holds, no page leaks,
    and every request completes."""
    cfg = _cfg()
    params = init_params(rng, cfg)
    eng = ServingEngine(cfg, params, batch_slots=3, max_len=48,
                        decode_block=4, page_size=4, prefill_chunk=8,
                        prefix_bootstrap=bootstrap)
    nprng = np.random.default_rng(19)
    bases = [nprng.integers(1, cfg.vocab_size, 16, dtype=np.int32)
             for _ in range(3)]
    for wave in range(4):
        prompts = []
        for _ in range(5):
            base = bases[nprng.integers(0, len(bases))]
            cut = int(nprng.integers(4, 17))
            tail = nprng.integers(
                1, cfg.vocab_size, int(nprng.integers(0, 9)),
                dtype=np.int32)
            prompts.append(np.concatenate([base[:cut], tail])
                           .astype(np.int32)[:40])
        reqs = eng.generate(prompts,
                            max_new_tokens=int(nprng.integers(2, 7)))
        assert all(r.done and not r.failed for r in reqs)
        eng.kv.assert_page_accounting()
        assert eng.kv.pages_in_use == 0
    assert eng.metrics["prefix_hit_pages"] > 0
    assert eng.metrics["prefix_hit_rate"] > 0


# ------------------------------------------------------------- sharded

@multi
def test_sharded_shared_pages_counted_once(rng):
    """Under a ('data','model') mesh the shared pages live in the
    kv_heads-sharded pools unchanged (the table is replicated), greedy
    tokens match the single-device hot engine, and per-shard byte
    accounting counts a shared page once."""
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_mesh

    cfg = _cfg("llama3-8b", dtype="float32", use_fused_kernels=True,
               num_heads=8, num_kv_heads=4, head_dim=8)
    params = init_params(rng, cfg)
    nprng = np.random.default_rng(20)
    shared = nprng.integers(1, cfg.vocab_size, 24, dtype=np.int32)
    p1 = np.concatenate([shared, nprng.integers(
        1, cfg.vocab_size, 7, dtype=np.int32)]).astype(np.int32)

    outs, peaks = {}, {}
    for name, mesh in (("single", None),
                       ("sharded", make_mesh((2, 4), ("data", "model")))):
        eng = _engine(cfg, params, mesh=mesh)
        eng.generate([p1[:26]], max_new_tokens=2)      # warm
        reqs = eng.generate([p1, p1], max_new_tokens=4)
        outs[name] = [r.out_tokens for r in reqs]
        peaks[name] = eng.metrics["kv_bytes_peak"]
        assert eng.metrics["prefix_hit_pages"] > 0
        eng.kv.assert_page_accounting()
        if mesh is not None:
            assert eng.kv.kv_shards == 4
            # Replicated table, kv_heads-sharded pools.
            assert eng.kv.page_table.sharding.spec == P(None, None)

            def claims_model(spec):
                return any(e == "model" or (isinstance(e, tuple)
                                            and "model" in e)
                           for e in spec)

            kv_specs = [leaf.sharding.spec for path, leaf in
                        jax.tree_util.tree_flatten_with_path(
                            eng._slot_cache)[0]
                        if cache_leaf_kind(cache_leaf_name(path)) == "kv"]
            assert kv_specs and all(claims_model(s) for s in kv_specs)
            # Shared pages counted once, then split across shards.
            assert (eng.kv.peak_bytes_per_shard
                    == eng.kv.peak_bytes_in_use // 4)
    assert outs["single"] == outs["sharded"]
    assert peaks["single"] == peaks["sharded"]
