"""Paged KV cache + paged decode attention + continuous batching.

Three layers of coverage:

  * ``PagedKVCache`` unit tests — free-list alloc/release, page reuse
    after release, append/gather round trip through the page-table
    indirection, prefill placement, and the shared cache-leaf schema
    (unknown leaves raise instead of being silently whole-replaced).
  * Kernel equivalence — the ``paged_attention`` Pallas kernel
    (interpret mode on CPU) against the eager contiguous
    ``decode_attention`` to 1e-5 for GPT-2-shaped (MHA) and
    llama3-shaped (GQA) heads across mixed per-slot lengths, with and
    without a sliding window.
  * Engine exactness — the continuous-batching engine (mixed prompt
    lengths, mid-stream join/leave, paged or contiguous, eager or
    plan-fused) produces per-request outputs identical to a per-request
    serial decode loop.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import decode_step, init_params, prefill, resolve_plan
from repro.models.params import (cache_leaf_kind, cache_leaf_name,
                                 kv_seq_axis)
from repro.serving import PagedKVCache, ServingEngine, gather_pages, \
    paged_append
from repro.serving.kv_cache import NULL_PAGE


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


def _cfg(arch="qwen1.5-0.5b", **over):
    cfg = get_config(arch).reduced()
    return dataclasses.replace(cfg, **over) if over else cfg


# ------------------------------------------------------------ allocator

def test_alloc_release_and_page_reuse():
    cfg = _cfg()
    kv = PagedKVCache(cfg, slots=2, max_len=64, page_size=16)
    assert kv.pages_per_slot == 4 and kv.num_pages == 9
    p0 = kv.ensure(0, 33)                      # 3 pages
    assert len(p0) == 3 and NULL_PAGE not in p0
    assert kv.pages_in_use == 3
    assert kv.bytes_in_use == 3 * kv.page_bytes
    p0b = kv.ensure(0, 20)                     # shrink request: no-op
    assert list(p0b) == list(p0)
    kv.ensure(1, 64)
    assert kv.pages_in_use == 7 and kv.peak_pages == 7
    kv.ensure(0, 64)                           # fills the pool exactly
    assert kv.pages_in_use == 8 and not kv._free
    released = set(kv.slot_pages(0).tolist())
    kv.release(0)
    assert kv.pages_in_use == 4
    assert kv.slot_pages(0).size == 0
    assert np.all(np.asarray(kv.page_table)[0] == NULL_PAGE)
    # Released pages are handed back out to the next occupant.
    p1 = kv.ensure(0, 48)
    assert len(p1) == 3 and set(p1.tolist()) <= released
    assert NULL_PAGE not in p1
    assert kv.peak_pages == 8                  # peak unchanged by churn
    with pytest.raises(ValueError, match="slot capacity"):
        kv.ensure(0, 65)                       # beyond max_len: explicit


def test_unknown_cache_leaf_raises():
    with pytest.raises(ValueError, match="unregistered cache leaf"):
        cache_leaf_kind("mystery_state")
    assert cache_leaf_kind("k") == "kv"
    assert cache_leaf_kind("ssm") == "state"


@pytest.mark.parametrize("layout", ["bshd", "bhsd"])
def test_append_gather_round_trip(layout):
    """Tokens appended through the page indirection read back, in order,
    from ``gather_pages`` — for both cache layouts."""
    ps, n_pages, h, hd, b = 4, 3, 2, 8, 2
    pool = jnp.zeros((1 + b * n_pages, ps, h, hd), jnp.float32)
    table = jnp.asarray(
        np.arange(1, 1 + b * n_pages, dtype=np.int32).reshape(b, n_pages))
    nprng = np.random.default_rng(0)
    toks = nprng.normal(size=(ps * n_pages, b, h, hd)).astype(np.float32)
    for t in range(ps * n_pages):
        new = jnp.asarray(toks[t])[:, None]              # [B, 1, H, hd]
        if layout == "bhsd":
            new = new.transpose(0, 2, 1, 3)              # [B, H, 1, hd]
        pool = paged_append(pool, table, jnp.full((b,), t, jnp.int32),
                            new, layout=layout)
    seq = gather_pages(pool, table, layout=layout)
    if layout == "bhsd":
        seq = seq.transpose(0, 2, 1, 3)
    np.testing.assert_array_equal(np.asarray(seq),
                                  toks.transpose(1, 0, 2, 3))
    # NULL page untouched by table-routed appends.
    np.testing.assert_array_equal(np.asarray(pool[NULL_PAGE]), 0.0)


@pytest.mark.parametrize("layout", ["bshd", "bhsd"])
def test_paged_append_overrun_routes_to_null(layout):
    """Regression: writes at/past the table's extent used to clamp onto
    the slot's LAST REAL KV row (silently overwriting it); they must land
    in the NULL page.  Simulates an over-run decode block: fill a slot to
    capacity, then keep appending past it — the final page's contents
    survive."""
    ps, n_pages, h, hd, b = 4, 2, 2, 8, 1
    extent = ps * n_pages
    pool = jnp.zeros((1 + n_pages, ps, h, hd), jnp.float32)
    table = jnp.asarray([[1, 2]], np.int32)
    nprng = np.random.default_rng(11)
    toks = nprng.normal(size=(extent, b, h, hd)).astype(np.float32)

    def to_layout(a):
        new = jnp.asarray(a)[:, None]                     # [B, 1, H, hd]
        return new.transpose(0, 2, 1, 3) if layout == "bhsd" else new

    for t in range(extent):
        pool = paged_append(pool, table, jnp.full((b,), t, jnp.int32),
                            to_layout(toks[t]), layout=layout)
    filled = np.asarray(pool)
    # Over-run ticks: positions extent .. extent+2 (as a scan running past
    # max_len does) write junk that must not touch the slot's pages.
    for t in range(extent, extent + 3):
        pool = paged_append(pool, table, jnp.full((b,), t, jnp.int32),
                            to_layout(np.full((b, h, hd), 7.0, np.float32)),
                            layout=layout)
    after = np.asarray(pool)
    np.testing.assert_array_equal(after[1:], filled[1:])   # pages intact
    assert np.any(after[NULL_PAGE] == 7.0)                 # junk sunk


def test_place_prefill_round_trip(rng):
    """A batch-1 prefill cache placed into pages gathers back exactly,
    and state leaves land in the slot row."""
    from repro.serving.kv_cache import place_prefill

    cfg = _cfg("zamba2-2.7b")                  # hybrid: kv + ssm/conv leaves
    params = init_params(rng, cfg)
    plen, slots, max_len, page = 12, 3, 32, 8
    kv = PagedKVCache(cfg, slots=slots, max_len=max_len, page_size=page)
    cache = kv.init_cache()
    toks = jax.random.randint(rng, (1, plen), 0, cfg.vocab_size)
    _, fresh = jax.jit(lambda p: prefill(p, cfg, {"tokens": toks}))(params)
    slot = 1
    pages = jnp.asarray(kv.ensure(slot, plen))
    placed = place_prefill(cache, fresh, jnp.int32(slot), pages,
                           layout=cfg.kv_cache_layout)
    table = kv.page_table
    ax = kv_seq_axis(cfg.kv_cache_layout)
    for path, big in jax.tree_util.tree_flatten_with_path(placed)[0]:
        small = fresh
        for k in path:
            small = small[k.key if hasattr(k, "key") else k.idx]
        if cache_leaf_kind(cache_leaf_name(path)) == "kv":
            for g in range(big.shape[0]):
                seq = gather_pages(big[g], table[slot][None],
                                   layout=cfg.kv_cache_layout)[0]
                got = jnp.moveaxis(seq, ax + 3, 0)[:plen]
                want = jnp.moveaxis(small[g, 0], ax + 3, 0) \
                    .astype(big.dtype)
                np.testing.assert_array_equal(
                    np.asarray(got, np.float32),
                    np.asarray(want, np.float32))
        else:
            np.testing.assert_array_equal(
                np.asarray(big[:, slot], np.float32),
                np.asarray(small[:, 0].astype(big.dtype), np.float32))


# ------------------------------------------------------ kernel vs eager

@pytest.mark.parametrize("hq,hkv", [(4, 4), (4, 2)])   # MHA and GQA
@pytest.mark.parametrize("window", [0, 7])
def test_paged_kernel_matches_eager_decode(hq, hkv, window):
    """Pallas paged decode attention == eager contiguous decode attention
    to 1e-5, across mixed per-slot lengths (bf16 cache, f32 queries)."""
    from repro.kernels import paged_decode_attention
    from repro.models.layers import decode_attention

    b, d, ps, n_pages = 3, 16, 8, 4
    s = ps * n_pages
    nprng = np.random.default_rng(2)
    q = jnp.asarray(nprng.normal(size=(b, 1, hq, d)).astype(np.float32))
    k_pool = jnp.asarray(nprng.normal(
        size=(1 + b * n_pages, ps, hkv, d)).astype(np.float32)
    ).astype(jnp.bfloat16)
    v_pool = jnp.asarray(nprng.normal(
        size=(1 + b * n_pages, ps, hkv, d)).astype(np.float32)
    ).astype(jnp.bfloat16)
    lengths = np.array([5, 17, 32], np.int32)
    table = np.zeros((b, n_pages), np.int32)
    nxt = 1
    for i in range(b):
        for j in range(-(-int(lengths[i]) // ps)):
            table[i, j] = nxt
            nxt += 1
    table, lengths = jnp.asarray(table), jnp.asarray(lengths)

    out = paged_decode_attention(q, k_pool, v_pool, table, lengths,
                                 window=window)
    kc = k_pool[table].reshape(b, s, hkv, d)
    vc = v_pool[table].reshape(b, s, hkv, d)
    ref = decode_attention(q, kc, vc, lengths, window=window, layout="bshd")
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=1e-5)
    # Inactive slot (length 0, NULL-page table row): finite zeros.
    out0 = paged_decode_attention(q, k_pool, v_pool,
                                  jnp.zeros_like(table),
                                  jnp.zeros((b,), jnp.int32))
    assert np.all(np.asarray(out0) == 0.0)


# -------------------------------------------------------------- engine

def _serial_reference(cfg, params, prompt, new_tokens, max_len):
    """Per-request greedy decode through the contiguous eager path."""
    logits, cache = jax.jit(lambda p, b: prefill(p, cfg, b))(
        params, {"tokens": jnp.asarray(prompt)[None]})
    ax = kv_seq_axis(cfg.kv_cache_layout)

    def pad(path, a):
        if cache_leaf_kind(cache_leaf_name(path)) == "kv":
            pads = [(0, 0)] * a.ndim
            pads[a.ndim + ax] = (0, max_len - a.shape[ax])
            return jnp.pad(a, pads)
        return a

    cache = jax.tree_util.tree_map_with_path(pad, cache)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out = [int(tok[0, 0])]
    pos = int(prompt.shape[0])
    lengths = jnp.full((1,), pos, jnp.int32)
    step = jax.jit(lambda p, t, c, po, le: decode_step(
        p, cfg, t, c, po, le)[0::2])
    for _ in range(new_tokens - 1):
        tok, cache = step(params, tok, cache, jnp.int32(pos), lengths)
        out.append(int(tok[0, 0]))
        pos += 1
        lengths = lengths + 1
    return out


@pytest.mark.slow
@pytest.mark.parametrize("paged", [True, False])
def test_engine_mixed_lengths_and_midstream_join(rng, paged):
    """5 requests with heterogeneous prompt lengths over 2 slots: requests
    join as slots free mid-stream; every request's output equals its
    serial per-request reference, and true-token metrics hold."""
    cfg = _cfg()
    params = init_params(rng, cfg)
    nprng = np.random.default_rng(3)
    plens = (16, 9, 12, 16, 5)
    prompts = [nprng.integers(1, cfg.vocab_size, n, dtype=np.int32)
               for n in plens]
    new_tokens, max_len = 12, 48
    refs = [_serial_reference(cfg, params, p, new_tokens, max_len)
            for p in prompts]
    engine = ServingEngine(cfg, params, batch_slots=2, max_len=max_len,
                           decode_block=8, paged=paged)
    reqs = engine.generate(prompts, max_new_tokens=new_tokens)
    for r, ref in zip(reqs, refs):
        assert r.out_tokens == ref, f"request {r.rid} diverged"
    assert all(r.done for r in reqs)
    # True tokens: 5 requests x 12, no padded-slot or overshoot inflation.
    assert engine.metrics["generated"] == len(prompts) * new_tokens
    assert engine.metrics["ticks"] <= engine.metrics["scan_ticks"]
    if paged:
        assert engine.kv is not None
        assert engine.kv.pages_in_use == 0          # all pages returned
        # The paged win: bytes-in-use peak stays below the contiguous
        # slots*max_len reservation.
        assert 0 < engine.metrics["kv_bytes_peak"] \
            <= engine.kv.peak_pages * engine.kv.page_bytes
        assert engine.metrics["kv_bytes_peak"] < \
            engine.metrics["kv_bytes_reserved"]


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["gpt2", "llama3-8b"])
def test_engine_fused_paged_attention_matches_eager(rng, arch):
    """Acceptance: the plan-selected Pallas paged-attention decode path
    produces greedy outputs identical to the eager engine for GPT-2
    (layernorm/MHA) and llama3 (RMSNorm/GQA) across mixed lengths."""
    base = dataclasses.replace(get_config(arch).reduced(), dtype="float32")
    fused = dataclasses.replace(base, use_fused_kernels=True)
    plan = resolve_plan(fused, 2, kv_len=40)
    assert plan.layer("attn").decode_attn.implementation == \
        "paged_attention"
    assert plan.decode_page_size() >= 1
    params = init_params(rng, base)
    nprng = np.random.default_rng(4)
    prompts = [nprng.integers(1, base.vocab_size, n, dtype=np.int32)
               for n in (12, 7, 16)]
    r0 = ServingEngine(base, params, batch_slots=2, max_len=40,
                       decode_block=8).generate(prompts, max_new_tokens=10)
    r1 = ServingEngine(fused, params, batch_slots=2, max_len=40,
                       decode_block=8).generate(prompts, max_new_tokens=10)
    for a, b in zip(r0, r1):
        assert a.out_tokens == b.out_tokens, f"request {a.rid} diverged"


@pytest.mark.slow
def test_engine_paged_bhsd_layout(rng):
    """The attention-native bhsd cache layout runs paged too."""
    cfg = _cfg(kv_cache_layout="bhsd")
    params = init_params(rng, cfg)
    nprng = np.random.default_rng(5)
    prompts = [nprng.integers(1, cfg.vocab_size, n, dtype=np.int32)
               for n in (10, 6)]
    refs = [_serial_reference(cfg, params, p, 8, 32) for p in prompts]
    engine = ServingEngine(cfg, params, batch_slots=2, max_len=32,
                           decode_block=8)
    reqs = engine.generate(prompts, max_new_tokens=8)
    for r, ref in zip(reqs, refs):
        assert r.out_tokens == ref


@pytest.mark.slow
def test_engine_single_request_no_padding_inflation(rng):
    """A lone request on a 3-slot engine: the two idle slots ride along in
    every dispatch but contribute nothing to ``generated``."""
    cfg = _cfg()
    params = init_params(rng, cfg)
    prompt = np.random.default_rng(6).integers(
        1, cfg.vocab_size, 8, dtype=np.int32)
    engine = ServingEngine(cfg, params, batch_slots=3, max_len=32,
                           decode_block=8)
    reqs = engine.generate([prompt], max_new_tokens=9)
    assert len(reqs[0].out_tokens) == 9
    assert engine.metrics["generated"] == 9
    assert engine.metrics["prefills"] == 1
