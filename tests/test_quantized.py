"""Quantized serving: int8/fp8 KV pages + weight-only int8 matmuls
(DESIGN.md §14).

Coverage, bottom-up:

  * Round-trip bounds — ``quantize_kv`` error stays within half an LSB
    of the per-(page, head) scale (int8) / the e4m3 relative precision
    (fp8), including the monotone whole-page requant an append can
    trigger.
  * Paged primitives — quantize-on-write append / window append / chunk
    placement read back through ``gather_pages_dequant`` within those
    bounds; the COW pair duplicates the scale row in the same step as
    the value page, and ``assert_page_accounting`` catches a seeded
    value/scale lockstep violation.
  * Weight-only int8 — per-output-channel quantization is exact on
    zero columns; the fused ``rmsnorm_matmul``/``streamed_ffn`` w8
    twins match the dequantized eager reference; the plan only flags
    ``w8`` where a kernel twin exists.
  * Model parity — one ``prefill_chunk`` + ``decode_step`` +
    ``verify_step`` per (arch, mode) comparing the fused quantized
    kernels against the dense-dequant eager path (GQA and
    sliding-window archs).
  * Engine — greedy tokens under kv_int8 are identical between the
    speculative and plain decode paths and between cold and prefix-hot
    admissions; the quantized pools cut ``kv_bytes_peak`` to ≤ 0.55x
    the bf16 baseline; the accuracy gate (``serving.accuracy``) holds
    greedy equality with f32 on gpt2 (MHA, layernorm) and llama3-8b
    (GQA) for kv_int8 and w8_kv8.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.stream_plan import build_stream_plan
from repro.models import init_params, layers as L
from repro.models.model import decode_step, prefill_chunk, verify_step
from repro.serving import PagedKVCache, ServingEngine
from repro.serving.accuracy import jitter_params, run_accuracy
from repro.serving.kv_cache import (NULL_PAGE, gather_pages,
                                    gather_pages_dequant, kv_quant_dtype,
                                    kv_quant_qmax, paged_append_q,
                                    paged_append_window_q,
                                    place_chunk_pages_q, quantize_kv,
                                    stage_chunk)


def _cfg(arch="qwen1.5-0.5b", **over):
    cfg = get_config(arch).reduced()
    return dataclasses.replace(cfg, **over) if over else cfg


# ------------------------------------------------------ round-trip bounds

@pytest.mark.parametrize("kind", ["int8", "fp8"])
def test_roundtrip_error_bound(kind):
    dtype = kv_quant_dtype(kind)
    qmax = kv_quant_qmax(dtype)
    x = jax.random.normal(jax.random.PRNGKey(0), (3, 4, 16), jnp.float32)
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / qmax
    codes = quantize_kv(x, scale, dtype)
    back = codes.astype(jnp.float32) * scale
    err = np.abs(np.asarray(back - x))
    if kind == "int8":
        assert err.max() <= float(scale.max()) * 0.5 + 1e-7
    else:  # e4m3: 3 mantissa bits -> half-ulp relative error 2^-4
        bound = np.abs(np.asarray(x)) * 2.0 ** -4 + float(scale.max()) * 0.5
        assert (err <= bound + 1e-7).all()


def test_quantize_kv_zero_scale_is_safe():
    dtype = kv_quant_dtype("int8")
    x = jnp.zeros((2, 4), jnp.float32)
    codes = quantize_kv(x, jnp.zeros((2, 1)), dtype)
    assert not np.any(np.asarray(codes))


# ------------------------------------------------------ paged primitives

def _quant_pool(kind, pages=5, ps=4, h=2, hd=8):
    dtype = kv_quant_dtype(kind)
    pool = jnp.zeros((pages, ps, h, hd), dtype)
    scale = jnp.zeros((pages, h), jnp.float32)
    return pool, scale


@pytest.mark.parametrize("kind", ["int8", "fp8"])
def test_append_q_gather_dequant_parity(kind):
    pool, scale = _quant_pool(kind)
    table = jnp.asarray([[1, 2]], jnp.int32)
    toks = jax.random.normal(jax.random.PRNGKey(1), (6, 1, 1, 2, 8),
                             jnp.float32)
    for i in range(6):
        pool, scale = paged_append_q(pool, scale, table,
                                     jnp.asarray([i], jnp.int32),
                                     toks[i], layout="bshd")
    dense = np.asarray(gather_pages_dequant(pool, scale, table,
                                            layout="bshd"))[0, :6]
    ref = np.asarray(toks)[:, 0, 0]
    # Monotone requant re-encodes old rows when a page's scale grows:
    # int8 error stays within ~1.5 LSB of the final per-head scale; fp8
    # codes are floating, so the error is relative (ulp = 2^-3) plus the
    # same requant slack.
    lsb = 1.5 * np.asarray(scale)[np.asarray(table)[0]].max() + 1e-6
    bound = lsb if kind == "int8" else np.abs(ref) * 2.0 ** -3 + lsb
    assert (np.abs(dense - ref) <= bound).all()


def test_append_window_q_matches_sequential_appends(kind="int8"):
    pool_w, scale_w = _quant_pool(kind)
    pool_s, scale_s = _quant_pool(kind)
    table = jnp.asarray([[1, 2]], jnp.int32)
    win = jax.random.normal(jax.random.PRNGKey(2), (1, 3, 2, 8),
                            jnp.float32)
    pool_w, scale_w = paged_append_window_q(pool_w, scale_w, table,
                                            jnp.asarray([2], jnp.int32),
                                            win, layout="bshd")
    for i in range(3):
        pool_s, scale_s = paged_append_q(pool_s, scale_s, table,
                                         jnp.asarray([2 + i], jnp.int32),
                                         win[:, i:i + 1], layout="bshd")
    np.testing.assert_array_equal(np.asarray(pool_w), np.asarray(pool_s))
    np.testing.assert_allclose(np.asarray(scale_w), np.asarray(scale_s))


@pytest.mark.parametrize("kind", ["int8", "fp8"])
def test_place_chunk_q_roundtrip(kind):
    pool, scale = _quant_pool(kind)
    seq = jax.random.normal(jax.random.PRNGKey(3), (1, 8, 2, 8),
                            jnp.float32)
    pool, scale = place_chunk_pages_q(pool, scale, seq,
                                      jnp.asarray([1, 3], jnp.int32),
                                      layout="bshd")
    dense = np.asarray(gather_pages_dequant(
        pool, scale, jnp.asarray([[1, 3]], jnp.int32), layout="bshd"))[0]
    ref = np.asarray(seq)[0]
    lsb = 0.5 * np.asarray(scale).max() + 1e-6
    bound = lsb if kind == "int8" else np.abs(ref) * 2.0 ** -3 + lsb
    assert (np.abs(dense - ref) <= bound).all()


def test_cow_copies_scale_row_with_value_page():
    pool, scale = _quant_pool("int8")
    seed = jax.random.normal(jax.random.PRNGKey(4), (1, 4, 2, 8),
                             jnp.float32)
    pool, scale = place_chunk_pages_q(pool, scale, seed,
                                      jnp.asarray([1], jnp.int32),
                                      layout="bshd")
    # Divergent write onto page 3, COW'd from shared page 1.  A tiny
    # token cannot grow the scale, so untouched rows must be VERBATIM
    # copies and the scale row must equal the source's.
    tok = 1e-4 * jax.random.normal(jax.random.PRNGKey(5), (1, 1, 2, 8),
                                   jnp.float32)
    table = jnp.asarray([[3]], jnp.int32)
    pool2, scale2 = paged_append_q(pool, scale, table,
                                   jnp.asarray([1], jnp.int32), tok,
                                   layout="bshd",
                                   cow_src=jnp.int32(1), cow_dst=jnp.int32(3))
    np.testing.assert_allclose(np.asarray(scale2)[3], np.asarray(scale)[1])
    got, src = np.asarray(pool2)[3], np.asarray(pool)[1]
    np.testing.assert_array_equal(got[0], src[0])
    np.testing.assert_array_equal(got[2:], src[2:])
    # ...and the shared source page itself never mutated.
    np.testing.assert_array_equal(np.asarray(pool2)[1], src)


def test_accounting_catches_lockstep_violation():
    cfg = _cfg(quant="kv_int8")
    kv = PagedKVCache(cfg, slots=1, max_len=32, page_size=8)
    kv.assert_page_accounting(kv.init_cache())
    broken = {k: [dict(g) for g in v] for k, v in kv._defs.items()}
    for g in broken["blocks"] + broken["rest"]:
        g.pop("k_scale", None)
    kv._defs = broken
    with pytest.raises(AssertionError):
        kv.assert_page_accounting()


# ------------------------------------------------------ weight-only int8

def test_channelwise_quant_exact_on_zero_columns():
    w = jnp.zeros((8, 4), jnp.float32).at[:, 1].set(
        jnp.linspace(-2.0, 2.0, 8))
    codes, scales = L.quantize_channelwise(w)
    assert float(scales[0]) == 0.0
    back = L.dequantize_channelwise(codes, scales, jnp.float32)
    np.testing.assert_allclose(np.asarray(back)[:, 0], 0.0)
    np.testing.assert_allclose(np.asarray(back)[:, 1], np.asarray(w)[:, 1],
                               atol=2.0 / 127)


def test_fused_norm_matmul_w8_matches_dequant_eager():
    key = jax.random.PRNGKey(6)
    x = jax.random.normal(key, (1, 8, 32), jnp.float32)
    scale = 0.1 * jax.random.normal(jax.random.fold_in(key, 1), (32,))
    w = jax.random.normal(jax.random.fold_in(key, 2), (32, 16),
                          jnp.float32)
    got = L.fused_norm_matmul(x, scale, w, w8=1)
    codes, ws = L.quantize_channelwise(w)
    want = L.rms_norm(x, scale) @ L.dequantize_channelwise(
        codes, ws, jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=1e-5)


def test_fused_ffn_w8_matches_dequant_eager():
    key = jax.random.PRNGKey(7)
    x = jax.random.normal(key, (1, 8, 16), jnp.float32)
    p = {"wg": jax.random.normal(jax.random.fold_in(key, 1), (16, 32)),
         "wu": jax.random.normal(jax.random.fold_in(key, 2), (16, 32)),
         "wd": jax.random.normal(jax.random.fold_in(key, 3), (32, 16))}
    got = L.fused_ffn(x, p, activation="silu", gated=True, w8=1)

    def dq(w):
        return L.dequantize_channelwise(*L.quantize_channelwise(w),
                                        jnp.float32)
    want = (jax.nn.silu(x @ dq(p["wg"])) * (x @ dq(p["wu"]))) @ dq(p["wd"])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=5e-5, rtol=1e-4)


def test_plan_flags_w8_only_where_kernel_twins_exist():
    cfg = _cfg("llama3-8b", quant="w8", use_fused_kernels=True)
    plan = build_stream_plan(cfg, tokens=64)
    assert plan.quant == "w8"
    flagged = [lp for _, lp in plan.layers
               if ("w8", 1) in lp.ffn.blocks or ("w8", 1) in lp.qkv.blocks]
    assert flagged, "w8 plan never flagged a weight-quantized stage"
    for _, lp in plan.layers:
        for choice in (lp.qkv, lp.ffn):
            if ("w8", 1) in choice.blocks:
                assert choice.implementation in ("rmsnorm_matmul",
                                                 "streamed_ffn",
                                                 "streamed_mlp")


# ------------------------------------------------------ model-level parity

@pytest.mark.parametrize("arch,mode", [("llama3-8b", "kv_int8"),
                                       ("gemma3-4b", "kv_fp8")])
def test_fused_quantized_stages_match_dequant_eager(arch, mode):
    """One chunked-prefill + decode + verify dispatch per path: the
    quantized Pallas kernels (scalar-prefetched page scales / per-position
    chunk scales) against the dense ``gather_pages_dequant`` eager
    reference, on GQA (llama3) and sliding-window (gemma3) stacks."""
    cfg_e = _cfg(arch, dtype="float32", quant=mode)
    cfg_f = dataclasses.replace(cfg_e, use_fused_kernels=True)
    params = jitter_params(init_params(jax.random.PRNGKey(0), cfg_e))
    kv = PagedKVCache(cfg_e, slots=1, max_len=64, page_size=8)
    cache = kv.init_cache()
    kv.ensure(0, 24)
    row = kv.table_row(0)
    prompt = np.random.default_rng(0).integers(
        1, cfg_e.vocab_size, 16).astype(np.int32)
    toks, cpages, last = stage_chunk(prompt, 0, 16, row, kv.page_size)
    out = {}
    for cfg in (cfg_e, cfg_f):
        _, lg, cc = prefill_chunk(params, cfg, jnp.asarray(toks)[None],
                                  cache, jnp.asarray(row),
                                  jnp.asarray(cpages), jnp.int32(0),
                                  jnp.int32(last))
        out[cfg.use_fused_kernels] = (np.asarray(lg), cc)
    np.testing.assert_allclose(out[True][0], out[False][0], atol=2e-4)
    cc = out[False][1]
    pos = jnp.asarray([16], jnp.int32)
    dec = {}
    for cfg in (cfg_e, cfg_f):
        _, lg, _ = decode_step(params, cfg, jnp.asarray([[5]], jnp.int32),
                               cc, pos, pos, page_table=kv.page_table)
        dec[cfg.use_fused_kernels] = np.asarray(lg)
    np.testing.assert_allclose(dec[True], dec[False], atol=2e-4)
    ver = {}
    for cfg in (cfg_e, cfg_f):
        _, lg, _ = verify_step(params, cfg,
                               jnp.asarray([[5, 7, 9]], jnp.int32),
                               cc, pos, pos, page_table=kv.page_table)
        ver[cfg.use_fused_kernels] = np.asarray(lg)
    np.testing.assert_allclose(ver[True], ver[False], atol=2e-4)


# ------------------------------------------------------ engine + gate

def _prompts(n, seed=11, length=12, vocab=256):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, vocab, length).astype(np.int32)
            for _ in range(n)]


def test_engine_speculative_matches_plain_under_kv_int8():
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompts = _prompts(2, vocab=cfg.vocab_size)
    kw = dict(batch_slots=2, max_len=64, decode_block=4, quant="kv_int8")
    plain = ServingEngine(cfg, params, **kw)
    r0 = plain.generate([p.copy() for p in prompts], max_new_tokens=10)
    spec = ServingEngine(cfg, params, speculative=True, **kw)
    r1 = spec.generate([p.copy() for p in prompts], max_new_tokens=10)
    assert [r.out_tokens for r in r0] == [r.out_tokens for r in r1]
    assert plain.metrics["quant"] == "kv_int8"
    assert plain.metrics["kv_itemsize_effective"] < 1.1
    plain.kv.assert_page_accounting(plain._slot_cache)
    spec.kv.assert_page_accounting(spec._slot_cache)


def test_engine_prefix_hot_matches_cold_under_kv_int8():
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(cfg, params, batch_slots=2, max_len=64,
                        decode_block=4, page_size=4, prefill_chunk=8,
                        quant="kv_int8")
    prompt = _prompts(1, vocab=cfg.vocab_size, length=16)[0]
    cold = eng.generate([prompt.copy()], max_new_tokens=8)
    hits0 = eng.metrics.get("prefix_hits", 0)
    hot = eng.generate([prompt.copy()], max_new_tokens=8)
    assert cold[0].out_tokens == hot[0].out_tokens
    assert eng.metrics.get("prefix_hits", 0) >= hits0
    eng.kv.assert_page_accounting(eng._slot_cache)


def test_kv_int8_cuts_bytes_to_half():
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompts = _prompts(2, vocab=cfg.vocab_size)
    peak = {}
    for quant in ("none", "kv_int8"):
        eng = ServingEngine(cfg, params, batch_slots=2, max_len=64,
                            decode_block=4, quant=quant)
        eng.generate([p.copy() for p in prompts], max_new_tokens=6)
        peak[quant] = eng.metrics["kv_bytes_peak"]
    assert peak["kv_int8"] <= 0.55 * peak["none"]


def test_engine_rejects_kv_quant_without_paging():
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="paged"):
        ServingEngine(cfg, params, batch_slots=2, max_len=64,
                      paged=False, quant="kv_int8")


@pytest.mark.parametrize("arch", ["gpt2", "llama3-8b"])
def test_accuracy_gate_greedy_matches_f32(arch):
    rep = run_accuracy(arch, modes=("kv_int8", "w8_kv8"), steps=6)
    for mode in ("kv_int8", "w8_kv8"):
        assert rep[mode]["tokens_equal"], \
            f"{arch}/{mode} diverged from the f32 greedy stream"
        assert np.isfinite(rep[mode]["max_logit_err"])
        assert rep[mode]["max_logit_err"] < 0.5
        assert rep[mode]["kv_itemsize"] < 1.1
