"""Per-kernel allclose tests: interpret-mode Pallas vs pure-jnp oracles.

Every kernel sweeps shapes (aligned + ragged fallbacks) and dtypes per the
brief; tolerances reflect bf16 inputs with f32 accumulation.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.itensor import col_major, itensor_from_tiling, row_major
from repro.kernels import (block_matmul, convert_layout, flash_attention,
                           mamba2_ssd_pallas, moe_experts_pallas, ref,
                           rmsnorm_matmul, streamed_ffn, streamed_mlp,
                           streamed_xent_loss, streamed_xent_parts,
                           wkv6_pallas)

TOL = {jnp.float32: dict(atol=1e-5, rtol=1e-4),
       jnp.bfloat16: dict(atol=5e-2, rtol=5e-2)}


def rand(key, shape, dtype, scale=1.0):
    return (scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m,k,n", [(64, 64, 64), (128, 256, 96),
                                   (96, 48, 160), (32, 512, 128)])
def test_block_matmul(m, k, n, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 2)
    x = rand(ks[0], (m, k), dtype)
    w = rand(ks[1], (k, n), dtype)
    out = block_matmul(x, w, block_m=64, block_n=64, block_k=64)
    want = ref.matmul_ref(x, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("t,d,f", [(64, 64, 128), (128, 96, 256),
                                   (32, 128, 96)])
@pytest.mark.parametrize("act", ["silu", "gelu"])
def test_streamed_ffn(t, d, f, act, dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    x = rand(ks[0], (t, d), dtype)
    wg = rand(ks[1], (d, f), dtype, 0.1)
    wu = rand(ks[2], (d, f), dtype, 0.1)
    wd = rand(ks[3], (f, d), dtype, 0.1)
    out = streamed_ffn(x, wg, wu, wd, activation=act, block_t=32,
                       block_f=64)
    want = ref.ffn_ref(x, wg, wu, wd, activation=act)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


def test_streamed_mlp():
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    x = rand(ks[0], (64, 96), jnp.float32)
    wu = rand(ks[1], (96, 128), jnp.float32, 0.1)
    wd = rand(ks[2], (128, 96), jnp.float32, 0.1)
    out = streamed_mlp(x, wu, wd, activation="gelu", block_t=32, block_f=64)
    want = ref.mlp_ref(x, wu, wd, activation="gelu")
    np.testing.assert_allclose(out, want, atol=1e-5, rtol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("t,d,n", [(64, 128, 96), (96, 64, 192)])
def test_rmsnorm_matmul(t, d, n, dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    x = rand(ks[0], (t, d), dtype)
    scale = rand(ks[1], (d,), jnp.float32, 0.1)
    w = rand(ks[2], (d, n), dtype, 0.1)
    out = rmsnorm_matmul(x, scale, w, block_t=32, block_n=48)
    want = ref.rmsnorm_matmul_ref(x, scale, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("hq,hkv", [(4, 4), (8, 2), (6, 1)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_gqa(hq, hkv, causal, dtype):
    b, s, d = 2, 128, 32
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    q = rand(ks[0], (b, s, hq, d), dtype)
    k = rand(ks[1], (b, s, hkv, d), dtype)
    v = rand(ks[2], (b, s, hkv, d), dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=32, block_kv=32)
    want = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               **TOL[dtype])


@pytest.mark.parametrize("window", [16, 64])
def test_flash_attention_sliding_window(window):
    b, s, h, d = 1, 128, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = rand(ks[0], (b, s, h, d), jnp.float32)
    k = rand(ks[1], (b, s, h, d), jnp.float32)
    v = rand(ks[2], (b, s, h, d), jnp.float32)
    out = flash_attention(q, k, v, causal=True, window=window,
                          block_q=32, block_kv=32)
    want = ref.attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-4)


def test_flash_attention_kv_len_mask():
    b, s, h, d = 1, 64, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    q = rand(ks[0], (b, 1, h, d), jnp.float32)
    k = rand(ks[1], (b, s, h, d), jnp.float32)
    v = rand(ks[2], (b, s, h, d), jnp.float32)
    out = flash_attention(q, k, v, causal=False, kv_len=40,
                          block_q=1, block_kv=16)
    want = ref.attention_ref(q, k, v, causal=False, kv_len=40)
    np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("t,d,vp,vocab", [(32, 64, 256, 200),
                                          (64, 32, 512, 512)])
def test_streamed_xent(t, d, vp, vocab, dtype):
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    hidden = rand(ks[0], (t, d), dtype)
    head = rand(ks[1], (d, vp), dtype, 0.1)
    labels = jax.random.randint(ks[2], (t,), 0, vocab)
    lse, gold = streamed_xent_parts(hidden, head, labels,
                                    vocab_size=vocab, block_t=16,
                                    block_v=64)
    lse_r, gold_r = ref.xent_parts_ref(hidden, head, labels, vocab)
    np.testing.assert_allclose(lse, lse_r, **TOL[dtype])
    np.testing.assert_allclose(gold, gold_r, **TOL[dtype])
    loss = streamed_xent_loss(hidden, head, labels, vocab_size=vocab,
                              block_t=16, block_v=64)
    loss_r = ref.xent_loss_ref(hidden, head, labels, vocab)
    np.testing.assert_allclose(loss, loss_r, **TOL[dtype])


def test_streamed_xent_ignore_index():
    ks = jax.random.split(jax.random.PRNGKey(8), 3)
    hidden = rand(ks[0], (32, 64), jnp.float32)
    head = rand(ks[1], (64, 128), jnp.float32, 0.1)
    labels = jax.random.randint(ks[2], (32,), 0, 128)
    labels = labels.at[:8].set(-100)
    loss = streamed_xent_loss(hidden, head, labels, vocab_size=128,
                              block_t=16, block_v=64)
    loss_r = ref.xent_loss_ref(hidden, head, labels, 128)
    np.testing.assert_allclose(loss, loss_r, atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("chunk", [8, 16, 32])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mamba2_ssd_kernel(chunk, dtype):
    bsz, s, h, p, n = 2, 64, 3, 8, 16
    ks = jax.random.split(jax.random.PRNGKey(9), 5)
    x = rand(ks[0], (bsz, s, h, p), dtype)
    dt = jax.nn.softplus(rand(ks[1], (bsz, s, h), jnp.float32) - 1)
    a_log = jnp.log(jnp.linspace(1.0, 4.0, h))
    b = rand(ks[2], (bsz, s, n), dtype, 0.5)
    c = rand(ks[3], (bsz, s, n), dtype, 0.5)
    d_skip = jnp.ones((h,))
    y, st = mamba2_ssd_pallas(x, dt, a_log, b, c, d_skip, chunk=chunk)
    yr, str_ = ref.mamba2_ref(x, dt, a_log, b, c, d_skip)
    tol = dict(atol=1e-4, rtol=1e-3) if dtype == jnp.float32 else \
        dict(atol=1e-1, rtol=1e-1)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), **tol)
    np.testing.assert_allclose(st, str_, **tol)


@pytest.mark.parametrize("chunk", [8, 32])
def test_wkv6_kernel(chunk):
    bsz, s, h, n = 2, 32, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(10), 5)
    r = rand(ks[0], (bsz, s, h, n), jnp.float32)
    k = rand(ks[1], (bsz, s, h, n), jnp.float32, 0.3)
    v = rand(ks[2], (bsz, s, h, n), jnp.float32)
    w = jax.nn.sigmoid(rand(ks[3], (bsz, s, h, n), jnp.float32))
    u = rand(ks[4], (h, n), jnp.float32, 0.1)
    y, st = wkv6_pallas(r, k, v, w, u, chunk=chunk)
    yr, str_ = ref.wkv6_ref(r, k, v, w, u)
    np.testing.assert_allclose(y, yr, atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(st, str_, atol=1e-4, rtol=1e-3)


@pytest.mark.parametrize("e,topk", [(4, 2), (8, 8)])
def test_moe_experts_kernel(e, topk):
    t, d, f = 32, 48, 64
    ks = jax.random.split(jax.random.PRNGKey(11), 5)
    x = rand(ks[0], (t, d), jnp.float32)
    wg = rand(ks[1], (e, d, f), jnp.float32, 0.1)
    wu = rand(ks[2], (e, d, f), jnp.float32, 0.1)
    wd = rand(ks[3], (e, f, d), jnp.float32, 0.1)
    logits = rand(ks[4], (t, e), jnp.float32)
    probs = jax.nn.softmax(logits)
    thresh = jax.lax.top_k(probs, topk)[0][:, -1:]
    gates = jnp.where(probs >= thresh, probs, 0.0)
    gates = gates / gates.sum(-1, keepdims=True)
    out = moe_experts_pallas(x, gates, wg, wu, wd, block_t=16)
    want = ref.moe_experts_ref(x, gates, wg, wu, wd)
    np.testing.assert_allclose(out, want, atol=1e-5, rtol=1e-4)


# ------------------------------------------------------------------ #
# Stream layout converter (Algorithm 1, executable)
# ------------------------------------------------------------------ #

@pytest.mark.parametrize("pair", [
    ((32, 32), (8, 8)),
    ((64, 32), (16, 8)),
])
def test_convert_layout_row_to_col(pair):
    data_shape, tile = pair
    src = row_major(data_shape, tile)
    dst = col_major(data_shape, tile)
    data = jnp.arange(np.prod(data_shape), dtype=jnp.float32) \
        .reshape(data_shape)
    out = convert_layout(data, src, dst)
    want = ref.convert_layout_ref(data, src, dst)
    np.testing.assert_array_equal(out, want)


def test_convert_layout_identity_fifo():
    src = row_major((32, 32), (8, 8))
    data = jnp.arange(1024, dtype=jnp.float32).reshape(32, 32)
    out = convert_layout(data, src, src)
    want = ref.convert_layout_ref(data, src, src)
    np.testing.assert_array_equal(out, want)


def test_convert_layout_partial_shared_prefix():
    """Fig. 5 case: shared outer loop -> window smaller than the tensor."""
    from repro.core.converter import infer_converter
    src = itensor_from_tiling((32, 16), (4, 4), loop_order=(0, 1))
    dst = itensor_from_tiling((32, 16), (4, 4), loop_order=(1, 0))
    spec = infer_converter(src, dst)
    assert spec is not None
    data = jnp.arange(512, dtype=jnp.float32).reshape(32, 16)
    out = convert_layout(data, src, dst)
    want = ref.convert_layout_ref(data, src, dst)
    np.testing.assert_array_equal(out, want)
