"""Tiling space tests: itensor derivation, unroll balancing, vectorization."""

import math

import pytest
from _hypothesis_compat import given, settings, st

from repro.core.platforms import TPU_V5E, U55C
from repro.core.tiling import (PARALLEL, REDUCTION, LinalgOpSpec, LoopDim,
                               OperandSpec, TilingDecision, TilingSpace,
                               default_decision, largest_divisor_leq, tile_op)


def matmul_spec(name="mm", t=64, n=32, k=128, tensor_in="x", tensor_out="y"):
    return LinalgOpSpec(
        name=name, op="matmul",
        loops=(LoopDim("t", t), LoopDim("n", n),
               LoopDim("k", k, REDUCTION)),
        inputs=(OperandSpec(tensor_in, ("t", "k")),
                OperandSpec("w_" + name, ("k", "n"), is_weight=True)),
        output=OperandSpec(tensor_out, ("t", "n")),
        flops_per_point=2.0)


def test_largest_divisor():
    assert largest_divisor_leq(64, 16) == 16
    assert largest_divisor_leq(48, 32) == 24
    assert largest_divisor_leq(7, 4) == 1
    assert largest_divisor_leq(10, 100) == 10


def test_default_decision_reduction_innermost():
    op = matmul_spec()
    d = default_decision(op, 16)
    assert d.loop_order == ("t", "n", "k")   # parallel outer, reduction inner
    assert all(op.loop(n).extent % s == 0 for n, s in d.tile_sizes.items())


def test_tile_op_itensor_shapes():
    op = matmul_spec(t=64, n=32, k=128)
    dec = default_decision(op, 16)
    tk = tile_op(op, dec)
    # Output streams one (16,16) tile per (t,n) tile pair; k collapsed.
    assert tk.out_type.elem_shape == (16, 16)
    assert tk.out_type.data_shape == (64, 32)
    assert tk.out_type.num_tokens == (64 // 16) * (32 // 16)
    # Input x[t,k]: iterated over (t,n,k) loop nest -> n is a reuse dim.
    x = tk.in_types[0]
    assert x.data_shape == (64, 128)
    assert x.reuse_factor == 32 // 16        # re-streamed once per n tile
    # Weight bytes: full weight tensor.
    assert tk.weight_bytes == 128 * 32 * 2


def test_reduction_dim_not_in_output():
    op = matmul_spec()
    dec = default_decision(op, 16)
    tk = tile_op(op, dec)
    # Out itensor's iteration space excludes the reduction loop entirely.
    assert tk.out_type.num_tokens == math.prod(tk.out_type.grid_shape)


def test_intensity_aware_unroll_targets_longest():
    # Two matmuls; the second has 8x the work -> should get more unroll.
    big = matmul_spec("big", t=64, n=64, k=512, tensor_in="a", tensor_out="b")
    small = matmul_spec("small", t=64, n=64, k=64, tensor_in="b",
                        tensor_out="c")
    space = TilingSpace(ops=[big, small], default_tile_size=32,
                        overall_unroll_size=32)
    dec = space.decide(U55C)
    assert dec["big"].unroll >= dec["small"].unroll
    assert dec["big"].unroll > 1


def test_build_graph_connects_chain():
    a = matmul_spec("a", tensor_in="x", tensor_out="t1")
    b = matmul_spec("b", t=64, n=16, k=32, tensor_in="t1", tensor_out="t2")
    space = TilingSpace(ops=[a, b], default_tile_size=16)
    g = space.build_graph(TPU_V5E)
    assert g.num_kernels == 2
    assert g.successors("a") == ["b"]
    # Edge data spaces line up even though tile decisions may differ.
    for u, v, k, data in g.edges():
        assert data["src_type"].data_shape == data["dst_type"].data_shape


def test_vectorization_widens_edge_tokens():
    a = matmul_spec("a", t=512, n=512, k=2048, tensor_in="x",
                    tensor_out="t1")
    b = matmul_spec("b", t=512, n=512, k=512, tensor_in="t1",
                    tensor_out="t2")
    space = TilingSpace(ops=[a, b], default_tile_size=16,
                        overall_unroll_size=128)
    decisions = space.decide(TPU_V5E)
    g = space.build_graph(TPU_V5E, decisions)
    (u, v, k, data), = list(g.edges())
    f = min(decisions["a"].vector_factor, decisions["b"].vector_factor)
    if f > 1:
        assert data["src_type"].elem_shape[-1] == 16 * f


@given(t=st.sampled_from([32, 64, 96]), n=st.sampled_from([32, 48, 64]),
       k=st.sampled_from([64, 128]), tile=st.sampled_from([8, 16, 24, 32]))
@settings(max_examples=40, deadline=None)
def test_tiling_stream_covers_tensor(t, n, k, tile):
    """Property: output stream tiles cover the full tensor exactly once."""
    op = matmul_spec(t=t, n=n, k=k)
    dec = default_decision(op, tile)
    tk = tile_op(op, dec)
    seen = set()
    for off in tk.out_type.stream_offsets():
        assert off not in seen
        seen.add(off)
    grid = tk.out_type.grid_shape
    assert len(seen) == math.prod(grid)


@given(tile=st.sampled_from([8, 16, 32, 64]),
       unroll=st.sampled_from([8, 32, 128]))
@settings(max_examples=20, deadline=None)
def test_decide_is_deterministic(tile, unroll):
    ops = [matmul_spec("a", tensor_in="x", tensor_out="t1"),
           matmul_spec("b", tensor_in="t1", tensor_out="t2")]
    s1 = TilingSpace(ops=ops, default_tile_size=tile,
                     overall_unroll_size=unroll)
    s2 = TilingSpace(ops=ops, default_tile_size=tile,
                     overall_unroll_size=unroll)
    d1, d2 = s1.decide(U55C), s2.decide(U55C)
    assert {k: (v.tile_sizes, v.unroll) for k, v in d1.items()} == \
           {k: (v.tile_sizes, v.unroll) for k, v in d2.items()}
