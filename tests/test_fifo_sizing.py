"""Tests for the token behavior model and LP-based FIFO sizing (paper §5.3)."""

import math
import random

import networkx as nx
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (DataflowGraph, KernelNode, KernelTiming,
                        EqualizationStrategy, max_tokens_exact,
                        max_tokens_paper, row_major, simulate_fifo_occupancy,
                        size_fifos, solve_start_times)
from repro.core.fifo_sizing import (paper_lp_thresholds, solve_lp_scipy,
                                    verify_plan_against_paper_lp)


def timing(d, ii, t):
    return KernelTiming.from_tokens(d, ii, t)


class TestTokenCurves:
    def test_fig8a_scenario(self):
        """Fig. 8(a): source pushes at t=4..8 (D=4, II=1), target pulls at
        t=5,7,9,11,13 (delay=5, II=2); InterFIFO peaks at 3 tokens at t=8."""
        src = timing(4, 1, 5)
        tgt = timing(0, 2, 5)
        max_occ, _ = simulate_fifo_occupancy(src, tgt, delay=5, num_tokens=5)
        assert max_occ == 3
        assert max_tokens_exact(src, tgt, delay=5, num_tokens=5) >= max_occ

    def test_exact_equals_simulation_on_known_cases(self):
        cases = [
            (timing(5, 1, 10), timing(0, 3, 10), 5, 10),
            (timing(2, 4, 8), timing(0, 1, 8), 6, 8),    # slow source
            (timing(0, 1, 16), timing(0, 1, 16), 0, 16),  # matched rates
            (timing(3, 2, 12), timing(0, 2, 12), 20, 12),  # late start
        ]
        for src, tgt, delay, t in cases:
            sim, _ = simulate_fifo_occupancy(src, tgt, delay, t)
            exact = max_tokens_exact(src, tgt, delay, t)
            assert exact >= sim
            assert exact <= max(sim, 1) + 1  # exact never overshoots by >1

    def test_paper_eq1_fast_source(self):
        # Source faster: FIFO accumulates until source drains (Eq. 1 regime).
        src, tgt = timing(0, 1, 100), timing(0, 4, 100)
        got = max_tokens_paper(src, tgt, delay=0, num_tokens=100)
        sim, _ = simulate_fifo_occupancy(src, tgt, 0, 100)
        assert got >= sim

    def test_paper_eq2_slow_source(self):
        # Source slower: occupancy bounded by tokens produced before target
        # catches up (Eq. 2 regime).
        src, tgt = timing(0, 4, 100), timing(0, 1, 100)
        got = max_tokens_paper(src, tgt, delay=12, num_tokens=100)
        sim, _ = simulate_fifo_occupancy(src, tgt, 12, 100)
        assert got >= sim
        assert got <= sim + 1


@given(
    d_src=st.integers(0, 10), ii_src=st.integers(1, 6),
    ii_tgt=st.integers(1, 6), extra_delay=st.integers(0, 20),
    t=st.integers(1, 64),
)
@settings(max_examples=120, deadline=None)
def test_exact_max_tokens_upper_bounds_simulation(d_src, ii_src, ii_tgt,
                                                  extra_delay, t):
    """Property: the exact staircase bound is a safe FIFO depth, and tight."""
    src = timing(d_src, ii_src, t)
    tgt = timing(0, ii_tgt, t)
    delay = d_src + extra_delay
    sim, _ = simulate_fifo_occupancy(src, tgt, delay, t)
    exact = max_tokens_exact(src, tgt, delay, t)
    assert exact >= sim, "analytic depth smaller than observed occupancy"
    assert exact <= sim + 1, "analytic depth loose by more than one slot"


class TestEqualization:
    def test_conservative_reduces_depths(self):
        """Paper §5.3.3: Conservative IIs never need deeper FIFOs."""
        g = _chain_graph([(0, 1, 64), (0, 2, 64), (0, 4, 64)])
        timings = {k.name: k.timing for k in g.kernels()}
        normal = size_fifos(g, timings, strategy="normal")
        conservative = size_fifos(g, timings, strategy="conservative")
        assert conservative.total_depth <= normal.total_depth

    def test_strategy_validation(self):
        with pytest.raises(ValueError):
            EqualizationStrategy("bogus").apply({}, {})


def _chain_graph(specs):
    """Build k0 -> k1 -> ... with (D, II, T) per kernel."""
    g = DataflowGraph()
    t_prev = None
    for i, (d, ii, t) in enumerate(specs):
        it = row_major((t, 16), (1, 16))
        node = KernelNode(name=f"k{i}", op="elementwise", out_type=it,
                          in_types=(t_prev,) if t_prev is not None else (),
                          timing=timing(d, ii, t))
        g.add_kernel(node)
        if i > 0:
            g.connect(f"k{i-1}", f"k{i}", dst_type=it)
        t_prev = it
    return g


class TestStartTimeSolver:
    def test_fig8f_example(self):
        """Kernel0 -> {Kernel1, Kernel2}, Kernel1 -> Kernel2 (Fig. 8(f))."""
        g = DataflowGraph()
        it = row_major((8, 16), (1, 16))
        for name, d in [("k0", 2.0), ("k1", 3.0), ("k2", 1.0)]:
            g.add_kernel(KernelNode(name=name, op="x", out_type=it,
                                    timing=timing(d, 1, 8)))
        g.connect("k0", "k1", dst_type=it)
        g.connect("k0", "k2", dst_type=it)
        g.connect("k1", "k2", dst_type=it)
        timings = {k.name: k.timing for k in g.kernels()}
        s = solve_start_times(g, timings)
        # delay[0][2] must cover the longer path D[0] + D[1] = 5.
        assert s["k0"] == 0
        assert s["k1"] == 2
        assert s["k2"] == 5
        plan = size_fifos(g, timings)
        assert plan.delays[("k0", "k2", 0)] == 5
        assert verify_plan_against_paper_lp(g, timings, plan)

    def test_dp_matches_scipy_lp(self):
        g = _random_dag(seed=7, n=8)
        timings = {k.name: k.timing for k in g.kernels()}
        s_dp = solve_start_times(g, timings)
        s_lp = solve_lp_scipy(g, timings)
        assert s_lp is not None
        obj = lambda s: sum(s[v] - s[u] for u, v, k, _ in g.edges())
        assert obj(s_dp) <= obj(s_lp) + 1e-6

    def test_plan_satisfies_paper_path_constraints_random(self):
        for seed in range(5):
            g = _random_dag(seed=seed, n=7)
            timings = {k.name: k.timing for k in g.kernels()}
            plan = size_fifos(g, timings)
            assert verify_plan_against_paper_lp(g, timings, plan)


def _random_dag(seed, n):
    rng = random.Random(seed)
    g = DataflowGraph()
    it = row_major((16, 16), (1, 16))
    for i in range(n):
        g.add_kernel(KernelNode(
            name=f"k{i}", op="x", out_type=it,
            timing=timing(rng.randint(0, 10), rng.randint(1, 4), 16)))
    for j in range(1, n):
        for i in range(j):
            if rng.random() < 0.4:
                g.connect(f"k{i}", f"k{j}", dst_type=it)
    # Ensure connectivity to make the instance non-trivial.
    for j in range(1, n):
        if not g.predecessors(f"k{j}"):
            g.connect(f"k{j-1}", f"k{j}", dst_type=it)
    return g


class TestDeadlockFreedom:
    def test_sized_fifos_never_deadlock_in_simulation(self):
        """End-to-end: run the discrete-event sim with the planned depths and
        check all tokens drain (no deadlock, paper Pitfall 4)."""
        for seed in range(4):
            g = _random_dag(seed=seed, n=6)
            timings = {k.name: k.timing for k in g.kernels()}
            plan = size_fifos(g, timings)
            from repro.runtime.simulator import simulate_dataflow
            result = simulate_dataflow(g, timings, plan)
            assert result.completed, f"deadlock with seed {seed}"
