"""StreamPlan fused execution path: numerical equivalence vs eager.

The DSE-driven plan (core/stream_plan.py) dispatches model blocks to the
fused Pallas kernels; these tests pin the contract that the fused path is a
pure implementation swap: same math, fp32-tolerance outputs, *identical*
gradients (fused wrappers recompute the backward through the eager path).

Covered configs: GPT-2 (layernorm, GELU MLP, qkv bias, learned positions)
and llama3 (RMSNorm, SwiGLU, GQA, RoPE) for all three entry points; zamba2
and rwkv6 cover the Mamba2/WKV mixer kernels; qwen1.5 covers the serving
engine's block-decode fast path end to end.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import (decode_step, forward_train, init_params, prefill,
                          resolve_plan)

B, S = 2, 32
ARCHS = ["gpt2", "llama3-8b"]      # layernorm/MLP and rmsnorm/SwiGLU/GQA


def _cfg(arch, fused=False):
    cfg = dataclasses.replace(get_config(arch).reduced(), dtype="float32")
    return dataclasses.replace(cfg, use_fused_kernels=fused)


def _pad_cache_seq(cache, max_len):
    def pad(path, a):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if name in ("k", "v"):
            return jnp.pad(a, ((0, 0), (0, 0), (0, max_len - a.shape[2]),
                               (0, 0), (0, 0)))
        return a
    return jax.tree_util.tree_map_with_path(pad, cache)


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


def _batch(cfg, rng, seq=S):
    toks = jax.random.randint(rng, (B, seq), 0, cfg.vocab_size)
    return {"tokens": toks, "labels": toks}


# ----------------------------------------------------------------- plan

@pytest.mark.parametrize("arch", ARCHS)
def test_plan_selects_fused_kernels(arch):
    """The compiler pipeline must actually pick fused kernels (otherwise
    the equivalence tests below compare eager with eager)."""
    plan = resolve_plan(_cfg(arch, fused=True), B * S)
    lp = plan.layer("attn")
    assert lp.attention.implementation == "flash_attention"
    assert lp.ffn.implementation in ("streamed_ffn", "streamed_mlp")
    if get_config(arch).norm == "rmsnorm":
        assert lp.qkv.implementation == "rmsnorm_matmul"
    assert plan.lm_head.implementation == "streamed_xent"


def test_plan_respects_eager_flag():
    assert resolve_plan(_cfg("gpt2", fused=False), B * S) is None


# ------------------------------------------------------- entry points

@pytest.mark.parametrize("arch", ARCHS)
def test_forward_train_equivalence(arch, rng):
    eager, fused = _cfg(arch), _cfg(arch, fused=True)
    params = init_params(rng, eager)
    batch = _batch(eager, rng)
    l0 = jax.jit(lambda p, b: forward_train(p, eager, b))(params, batch)
    l1 = jax.jit(lambda p, b: forward_train(p, fused, b))(params, batch)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_equivalence(arch, rng):
    eager, fused = _cfg(arch), _cfg(arch, fused=True)
    params = init_params(rng, eager)
    batch = _batch(eager, rng)
    lg0, c0 = jax.jit(lambda p: prefill(p, eager, batch))(params)
    lg1, c1 = jax.jit(lambda p: prefill(p, fused, batch))(params)
    np.testing.assert_allclose(np.asarray(lg0), np.asarray(lg1),
                               rtol=1e-4, atol=2e-4)
    # Decode caches (K/V at the prompt) must agree too — the fused QKV
    # projections feed the same cache the eager path fills.
    for a, b in zip(jax.tree.leaves(c0), jax.tree.leaves(c1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=2e-4)


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step_equivalence(arch, rng):
    eager, fused = _cfg(arch), _cfg(arch, fused=True)
    params = init_params(rng, eager)
    batch = _batch(eager, rng)
    _, cache = jax.jit(lambda p: prefill(p, eager, batch))(params)
    cache = _pad_cache_seq(cache, S + 16)
    tok = jnp.zeros((B, 1), jnp.int32)
    lengths = jnp.full((B,), S, jnp.int32)
    _, lg0, nc0 = jax.jit(lambda p, c: decode_step(
        p, eager, tok, c, jnp.int32(S), lengths))(params, cache)
    _, lg1, nc1 = jax.jit(lambda p, c: decode_step(
        p, fused, tok, c, jnp.int32(S), lengths))(params, cache)
    np.testing.assert_allclose(np.asarray(lg0), np.asarray(lg1),
                               rtol=1e-4, atol=2e-4)
    for a, b in zip(jax.tree.leaves(nc0), jax.tree.leaves(nc1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=2e-4)


def test_gradients_match_eager_exactly(rng):
    """Fused wrappers define their VJP as the eager recompute — gradients
    are the eager path's gradients up to float associativity noise."""
    eager, fused = _cfg("llama3-8b"), _cfg("llama3-8b", fused=True)
    params = init_params(rng, eager)
    batch = _batch(eager, rng)
    g0 = jax.jit(jax.grad(lambda p: forward_train(p, eager, batch)))(params)
    g1 = jax.jit(jax.grad(lambda p: forward_train(p, fused, batch)))(params)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


# --------------------------------------------------- mixer kernel paths

@pytest.mark.parametrize("arch", ["zamba2-2.7b", "rwkv6-7b"])
def test_mixer_forward_equivalence(arch, rng):
    """Mamba2 SSD / RWKV6 WKV Pallas kernels vs the jnp scan forms."""
    eager, fused = _cfg(arch), _cfg(arch, fused=True)
    plan = resolve_plan(fused, B * S)
    assert any(lp.mixer.fused for _, lp in plan.layers)
    params = init_params(rng, eager)
    batch = _batch(eager, rng)
    l0 = jax.jit(lambda p, b: forward_train(p, eager, b))(params, batch)
    l1 = jax.jit(lambda p, b: forward_train(p, fused, b))(params, batch)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-4, atol=1e-4)


def test_moe_experts_dispatch(rng):
    eager, fused = (_cfg("granite-moe-1b-a400m"),
                    _cfg("granite-moe-1b-a400m", fused=True))
    plan = resolve_plan(fused, B * S)
    assert plan.layer("attn").ffn.implementation == "moe_experts"
    params = init_params(rng, eager)
    batch = _batch(eager, rng)
    l0 = jax.jit(lambda p, b: forward_train(p, eager, b))(params, batch)
    l1 = jax.jit(lambda p, b: forward_train(p, fused, b))(params, batch)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-4, atol=1e-4)


# ------------------------------------------------ engine decode fast path

@pytest.mark.slow
def test_engine_block_decode_matches_per_token_loop(rng):
    """The >=8-ticks-per-dispatch scan over the paged cache produces the
    exact same greedy continuation as a one-token-at-a-time decode loop
    against a contiguous cache."""
    from repro.serving import ServingEngine

    cfg = get_config("qwen1.5-0.5b").reduced()
    params = init_params(rng, cfg)
    nprng = np.random.default_rng(0)
    prompt = nprng.integers(1, cfg.vocab_size, 16, dtype=np.int32)
    new_tokens = 12

    logits, cache = jax.jit(lambda p: prefill(
        p, cfg, {"tokens": jnp.asarray(prompt)[None]}))(params)
    cache = _pad_cache_seq(cache, 64)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    ref = [int(tok[0, 0])]
    lengths = jnp.full((1,), 16, jnp.int32)
    step = jax.jit(lambda p, t, c, pos, le: decode_step(
        p, cfg, t, c, pos, le)[0::2])
    pos = 16
    for _ in range(new_tokens - 1):
        tok, cache = step(params, tok, cache, jnp.int32(pos), lengths)
        ref.append(int(tok[0, 0]))
        pos += 1
        lengths = lengths + 1

    engine = ServingEngine(cfg, params, batch_slots=1, max_len=64,
                           decode_block=8)
    reqs = engine.generate([prompt], max_new_tokens=new_tokens)
    assert reqs[0].out_tokens == ref
    # Fast-path invariants: >= 8 ticks per jitted dispatch, TRUE token
    # accounting (prefill token + harvested decode tokens; scan overshoot
    # past the budget is excluded), no host-side per-wave cache pad (the
    # engine module no longer defines one).
    assert engine.metrics["decode_block"] >= 8
    assert engine.metrics["generated"] == new_tokens
    assert engine.metrics["scan_ticks"] == \
        engine.metrics["dispatches"] * engine.metrics["decode_block"]
    assert engine.metrics["ticks"] <= engine.metrics["scan_ticks"]
    import repro.serving.engine as eng_mod
    assert not hasattr(eng_mod, "_pad_cache_seq")


@pytest.mark.slow
def test_engine_continuous_refill(rng):
    """3 requests over 2 slots: the third joins the moment a slot frees
    (no wave barrier) and the donated paged cache survives the handoff."""
    from repro.serving import ServingEngine

    cfg = get_config("qwen1.5-0.5b").reduced()
    params = init_params(rng, cfg)
    nprng = np.random.default_rng(1)
    prompts = [nprng.integers(1, cfg.vocab_size, 8, dtype=np.int32)
               for _ in range(3)]
    engine = ServingEngine(cfg, params, batch_slots=2, max_len=40,
                           decode_block=8)
    reqs = engine.generate(prompts, max_new_tokens=10)
    assert all(len(r.out_tokens) == 10 for r in reqs)
    assert all(r.done for r in reqs)
    assert engine.metrics["generated"] == 30
    # All pages returned to the free list once every request retired.
    assert engine.kv is not None and engine.kv.pages_in_use == 0
    # Same prompt => same greedy continuation regardless of slot/joining.
    solo = engine.generate([prompts[0]], max_new_tokens=10)
    assert solo[0].out_tokens == reqs[0].out_tokens
