"""Per-architecture smoke tests (reduced configs, CPU).

For every assigned arch: one forward/train step with shape + finiteness
asserts, a gradient step that decreases loss, and the strong consistency
check prefill + decode_step == full forward at the next position.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, ASSIGNED_ARCHS, get_config
from repro.models import (abstract_params, decode_step, forward_hidden,
                          forward_train, init_cache, init_params, prefill)
from repro.models.params import padded_vocab

B, S = 2, 64


def make_batch(cfg, rng, seq=S):
    ks = jax.random.split(rng, 3)
    if cfg.frontend != "none":
        batch = {"embeds": 0.1 * jax.random.normal(
            ks[0], (B, seq, cfg.d_model), jnp.float32)}
    else:
        batch = {"tokens": jax.random.randint(ks[0], (B, seq), 0,
                                              cfg.vocab_size)}
    batch["labels"] = jax.random.randint(ks[1], (B, seq), 0, cfg.vocab_size)
    return batch


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", list(ARCHS))
def test_forward_shapes_and_finite(arch, rng):
    cfg = get_config(arch).reduced()
    params = init_params(rng, cfg)
    batch = make_batch(cfg, rng)
    hidden = jax.jit(lambda p, b: forward_hidden(p, cfg, b))(params, batch)
    assert hidden.shape == (B, S, cfg.d_model)
    assert bool(jnp.isfinite(hidden.astype(jnp.float32)).all())
    loss = jax.jit(lambda p, b: forward_train(p, cfg, b))(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss))
    # Loss at init should be near ln(vocab) for a random head.
    assert abs(float(loss) - np.log(cfg.vocab_size)) < 2.0


@pytest.mark.parametrize("arch", ["llama3-8b", "zamba2-2.7b", "rwkv6-7b",
                                  "granite-moe-1b-a400m", "hubert-xlarge"])
def test_one_sgd_step_decreases_loss(arch, rng):
    cfg = get_config(arch).reduced()
    params = init_params(rng, cfg)
    batch = make_batch(cfg, rng)

    @jax.jit
    def step(p):
        loss, g = jax.value_and_grad(lambda q: forward_train(q, cfg, batch))(p)
        new = jax.tree.map(lambda a, b: a - 0.5 * b, p, g)
        return loss, new

    l0, params = step(params)
    l1, _ = step(params)
    assert bool(jnp.isfinite(l0)) and bool(jnp.isfinite(l1))
    assert float(l1) < float(l0)


def _pad_cache_seq(cache, max_len):
    """Pad prefill caches' seq dim (axis 2 of k/v leaves) to max_len."""
    def pad(path, a):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if name in ("k", "v"):
            pad_n = max_len - a.shape[2]
            return jnp.pad(a, ((0, 0), (0, 0), (0, pad_n), (0, 0), (0, 0)))
        return a
    return jax.tree_util.tree_map_with_path(pad, cache)


@pytest.mark.parametrize("arch", [a for a in ASSIGNED_ARCHS
                                  if not ARCHS[a].encoder_only])
def test_prefill_decode_matches_forward(arch, rng):
    """decode_step(prefill(x[:s]), x[s]) == prefill(x[:s+1]) logits."""
    cfg = get_config(arch).reduced()
    if cfg.frontend != "none":
        pytest.skip("frontend archs decode from token ids; covered via gpt2 "
                    "path and the qwen2-vl decode smoke below")
    params = init_params(rng, cfg)
    seq = 32
    tokens = jax.random.randint(rng, (B, seq + 1), 0, cfg.vocab_size)
    ref_logits, _ = jax.jit(lambda p: prefill(p, cfg,
                                              {"tokens": tokens}))(params)
    _, cache = jax.jit(lambda p: prefill(p, cfg,
                                         {"tokens": tokens[:, :seq]}))(params)
    max_len = 48
    cache = _pad_cache_seq(cache, max_len)
    nt, logits, _ = jax.jit(
        lambda p, c: decode_step(p, cfg, tokens[:, seq:seq + 1], c,
                                 jnp.int32(seq),
                                 jnp.full((B,), seq, jnp.int32)))(params,
                                                                  cache)
    np.testing.assert_allclose(
        np.asarray(logits[:, 0]), np.asarray(ref_logits[:, 0]),
        atol=0.15, rtol=0.05)   # bf16 compute tolerance
    assert nt.shape == (B, 1)


def test_qwen2vl_decode_from_cache(rng):
    """VLM: prefill from patch embeddings, then decode text tokens."""
    cfg = get_config("qwen2-vl-2b").reduced()
    params = init_params(rng, cfg)
    batch = make_batch(cfg, rng, seq=16)
    _, cache = jax.jit(lambda p: prefill(p, cfg, batch))(params)
    cache = _pad_cache_seq(cache, 32)
    toks = jnp.zeros((B, 1), jnp.int32)
    nt, logits, nc = jax.jit(
        lambda p, c: decode_step(p, cfg, toks, c, jnp.int32(16),
                                 jnp.full((B,), 16, jnp.int32)))(params,
                                                                 cache)
    assert bool(jnp.isfinite(logits).all())
    # Cache got updated in place at position 16.
    k_new = jax.tree.leaves(nc)[0]
    assert k_new.shape == jax.tree.leaves(cache)[0].shape


@pytest.mark.parametrize("arch", list(ASSIGNED_ARCHS))
def test_abstract_params_match_real(arch, rng):
    cfg = get_config(arch).reduced()
    real = init_params(rng, cfg)
    ab = abstract_params(cfg)
    rs = jax.tree.map(lambda a: (a.shape, str(a.dtype)), real)
    bs = jax.tree.map(lambda a: (a.shape, str(a.dtype)), ab)
    assert rs == bs


def test_vocab_padding_never_predicted(rng):
    cfg = get_config("granite-moe-1b-a400m").reduced()   # 256 -> padded 256
    assert padded_vocab(cfg.vocab_size) % 256 == 0
    params = init_params(rng, cfg)
    tokens = jax.random.randint(rng, (B, 16), 0, cfg.vocab_size)
    logits, _ = jax.jit(lambda p: prefill(p, cfg, {"tokens": tokens}))(params)
    assert int(jnp.argmax(logits[:, -1], -1).max()) < cfg.vocab_size


def test_gemma_pattern_local_global(rng):
    cfg = get_config("gemma3-4b").reduced()
    kinds = [cfg.layer_kind(i) for i in range(cfg.num_layers)]
    assert "global_attn" in kinds and "local_attn" in kinds


def test_zamba_shared_params_single_copy():
    cfg = get_config("zamba2-2.7b")
    from repro.models import model_defs
    defs = model_defs(cfg)
    assert "shared" in defs
    # Shared block is NOT stacked over groups.
    wq = defs["shared"]["attn"]["wq"]
    assert wq.shape == (cfg.d_model, cfg.q_dim)


def test_bhsd_cache_layout_matches_bshd(rng):
    """§Perf I5c: the attention-native cache layout is bit-equivalent."""
    from dataclasses import replace
    base = get_config("llama3-8b").reduced()
    tokens = jax.random.randint(rng, (B, 17), 0, base.vocab_size)
    logits = {}
    for layout in ("bshd", "bhsd"):
        cfg = replace(base, kv_cache_layout=layout)
        params = init_params(jax.random.PRNGKey(0), cfg)
        _, cache = jax.jit(lambda p: prefill(
            p, cfg, {"tokens": tokens[:, :16]}))(params)
        axis = 3 if layout == "bhsd" else 2
        def pad(path, a, axis=axis):
            name = path[-1].key if hasattr(path[-1], "key") else ""
            if name in ("k", "v"):
                widths = [(0, 0)] * a.ndim
                widths[axis] = (0, 32 - a.shape[axis])
                return jnp.pad(a, widths)
            return a
        cache = jax.tree_util.tree_map_with_path(pad, cache)
        _, lg, _ = jax.jit(lambda p, c: decode_step(
            p, cfg, tokens[:, 16:17], c, jnp.int32(16),
            jnp.full((B,), 16, jnp.int32)))(params, cache)
        logits[layout] = np.asarray(lg)
    # bhsd uses bf16-out score/AV einsums (f32 softmax) -> bf16-level tol.
    np.testing.assert_allclose(logits["bshd"], logits["bhsd"],
                               atol=5e-2, rtol=5e-2)
