"""Stream layout converter generation — paper §5.2.1, Algorithm 1.

When a producer's output itensor type differs from the consumer's input type,
a converter with a local ping-pong buffer re-orders the stream on the fly.
Algorithm 1 infers the *minimal* ping-pong buffer analytically from the two
itensor types.

We implement the algorithm in its semantic form: find the maximal *outermost
shared loop prefix* of the two iteration spaces (equal trip counts, equal
steps, feeding the same data dim with equal element extents — or both being
reuse dims).  Data dims fed by shared-prefix loops only need one element
extent of buffering (the buffer is re-used across those loops, paper §4.3.1);
every other data dim must be buffered at full extent, because within one
shared-prefix iteration the two streams may touch its tiles in arbitrary
relative order.

This reproduces the paper's Fig. 5 worked example exactly: converting
itensor(b) -> itensor(c) shares only loop d0 (feeding the second data dim), so
the window is ``8x2`` (two 4x2 tiles), doubled to four tiles by ping-ponging.

``min_buffer_tiles_sim`` computes the true minimum by stream simulation and is
used by the test-suite (hypothesis) to check that the analytic window is always
sufficient and is tight on aligned layouts.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .itensor import ITensorType, dtype_bytes


@dataclass(frozen=True)
class ConverterSpec:
    """Result of Algorithm 1.

    Attributes:
        buf_shape: logical window shape in data elements (before ping-pong).
        shared_prefix_len: paper's ``beforeLoop`` — number of outermost loops
            shared by producer and consumer; the buffer is inserted below them
            and re-used once per shared iteration.
        reuse_count: how many times the window buffer is re-used
            (= product of shared-prefix trip counts).
        dtype: element dtype.
    """

    buf_shape: Tuple[int, ...]
    shared_prefix_len: int
    reuse_count: int
    dtype: str

    @property
    def window_bytes(self) -> float:
        return math.prod(self.buf_shape) * dtype_bytes(self.dtype)

    @property
    def pingpong_bytes(self) -> float:
        """On-chip memory cost: ping + pong copies of the window."""
        return 2.0 * self.window_bytes

    def window_tiles(self, elem_shape: Sequence[int]) -> int:
        return int(math.prod(self.buf_shape) // max(1, math.prod(elem_shape)))


def _loop_feeds(t: ITensorType) -> Dict[int, int]:
    """Map loop position -> data dim it feeds (reuse loops absent)."""
    return {p: j for j, p in enumerate(t.iter_map.results)}


def shared_prefix_length(src: ITensorType, res: ITensorType) -> int:
    """Maximal outermost loop prefix shared by the two iteration spaces."""
    src_feed, res_feed = _loop_feeds(src), _loop_feeds(res)
    m = 0
    for p in range(min(src.iter_rank, res.iter_rank)):
        if src.tripcounts[p] != res.tripcounts[p]:
            break
        sj, rj = src_feed.get(p), res_feed.get(p)
        if sj != rj:
            break  # feed different data dims, or reuse-vs-data mismatch
        if src.steps[p] != res.steps[p]:
            break
        if sj is not None and src.elem_shape[sj] != res.elem_shape[sj]:
            break
        m += 1
    return m


def infer_converter(src: ITensorType, res: ITensorType) -> Optional[ConverterSpec]:
    """Algorithm 1: minimal ping-pong buffer for a src -> res layout conversion.

    Returns ``None`` when the types already match (no converter required).
    Raises if the conversion is impossible (different data space or dtype).
    """
    if src.dtype != res.dtype:
        raise ValueError(f"dtype mismatch: {src.dtype} vs {res.dtype}")
    if src.data_shape != res.data_shape:
        raise ValueError(
            f"data space mismatch: {src.data_shape} vs {res.data_shape}")
    if src.canonicalize() == res.canonicalize():
        return None

    m = shared_prefix_length(src, res)
    src_results = src.iter_map.results
    buf_shape = tuple(
        src.elem_shape[j] if src_results[j] < m else src.data_shape[j]
        for j in range(src.rank)
    )
    reuse = math.prod(src.tripcounts[:m]) if m else 1
    return ConverterSpec(
        buf_shape=buf_shape,
        shared_prefix_len=m,
        reuse_count=int(reuse),
        dtype=src.dtype,
    )


def conversion_cost_bytes(src: ITensorType, res: ITensorType) -> float:
    """On-chip bytes required to fuse ``src -> res`` (0 when types match)."""
    spec = infer_converter(src, res)
    return 0.0 if spec is None else spec.pingpong_bytes


def fusion_verdict(src: ITensorType, res: ITensorType) -> str:
    """Classify producer -> consumer stream compatibility WITHOUT building
    a converter — the static-analysis query (analysis/itensor_check.py).

    Returns one of:
      * ``"match"``        — types equivalent; a raw FIFO fuses them.
      * ``"converter"``    — a bounded ping-pong window re-orders the
        stream (some loop prefix is shared, so at least one data dim
        buffers at element granularity).
      * ``"rebuffer"``     — no shared prefix covers any data dim: the
        converter degenerates to a full-tensor buffer, i.e. the "fusion"
        silently materializes the whole intermediate.
      * ``"incompatible"`` — different data space or dtype; no converter
        exists (``infer_converter`` would raise).
    """
    if src.dtype != res.dtype or src.data_shape != res.data_shape:
        return "incompatible"
    if src.canonicalize() == res.canonicalize():
        return "match"
    m = shared_prefix_length(src, res)
    results = src.iter_map.results
    if all(results[j] >= m for j in range(src.rank)):
        return "rebuffer"      # every data dim buffered at full extent
    return "converter"


# --------------------------------------------------------------------- #
# Reference / verification machinery
# --------------------------------------------------------------------- #

def min_buffer_tiles_sim(src: ITensorType, res: ITensorType) -> int:
    """True minimal converter capacity in *tiles*, by stream simulation.

    Model: tiles arrive in producer order (one-shot; no re-fetch).  The
    converter may hold up to B tiles and must emit tiles in consumer order; a
    held tile may be emitted many times (consumer reuse) and can be evicted
    only after its final emission.  The minimum feasible B equals the peak
    number of simultaneously-live tiles under the eager emission policy.

    Requires equal element shapes (a converter never re-tiles tokens, only
    re-orders them; re-tiling layouts fall back to full-window buffering in
    Algorithm 1 and are excluded here).
    """
    if src.elem_shape != res.elem_shape:
        raise ValueError("simulation requires matching element shapes")
    arrivals: List[int] = []
    seen = set()
    for tid in src.stream_tile_ids():
        if tid not in seen:  # producer reuse re-sends, consumer needs 1 copy
            seen.add(tid)
            arrivals.append(tid)
    demand = list(res.stream_tile_ids())

    remaining: Dict[int, int] = {}
    for tid in demand:
        remaining[tid] = remaining.get(tid, 0) + 1

    live: set = set()
    frontier = 0
    peak = 0
    for tid in arrivals:
        live.add(tid)
        peak = max(peak, len(live))
        # Advance the consumer as far as possible.
        while frontier < len(demand) and demand[frontier] in live:
            t = demand[frontier]
            frontier += 1
            remaining[t] -= 1
            if remaining[t] == 0:
                live.discard(t)
    if frontier != len(demand):
        raise RuntimeError("conversion infeasible: consumer demands unseen tile")
    return peak


def convert_stream(src: ITensorType, res: ITensorType,
                   data: np.ndarray) -> Tuple[List[np.ndarray], List[np.ndarray]]:
    """Functional reference of a materialized converter (paper Fig. 7(a)).

    Streams ``data`` tile-by-tile in ``src`` order through a window buffer of
    the Algorithm-1 shape and emits tiles in ``res`` order.  Returns
    ``(src_order_tiles, res_order_tiles)`` so tests can check that the emitted
    stream equals directly slicing ``data`` in consumer order.
    """
    if tuple(data.shape) != src.data_shape:
        raise ValueError(f"data shape {data.shape} != {src.data_shape}")

    def slice_at(off: Sequence[int], elem: Sequence[int]) -> np.ndarray:
        idx = tuple(slice(o, o + e) for o, e in zip(off, elem))
        return data[idx]

    produced = [slice_at(off, src.elem_shape) for off in src.stream_offsets()]
    emitted = [slice_at(off, res.elem_shape) for off in res.stream_offsets()]
    return produced, emitted
