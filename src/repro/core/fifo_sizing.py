"""LP-based FIFO sizing — paper §5.3.4, Eqs. 3–5.

The token behavior model turns FIFO sizing into choosing the inter-kernel
start ``delay`` values: a FIFO of depth ``max_tokens(delay)`` never
back-pressures its producer, so the dataflow accelerator runs stall-free and
deadlock-free.  The paper minimizes the sum of edge delays subject to, for
every kernel pair, every path's delay-sum exceeding the largest accumulated
initial delay over all paths between the pair (Eqs. 4–5).

Physically every kernel has a single start time, so edge delays telescope:
``delay(i,j) = s(j) - s(i)``.  Under this (physically forced) consistency the
LP reduces to the longest-path problem ``s(v) = max_{u->v} s(u) + D(u)``,
which we solve exactly by DP over the DAG.  The test-suite cross-checks the DP
against ``scipy.optimize.linprog`` on the compact LP and against brute-force
path enumeration of the paper's original formulation on small random DAGs.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import networkx as nx

from .graph import DataflowGraph, KernelTiming
from .token_model import EqualizationStrategy, max_tokens_exact, max_tokens_paper


@dataclass
class FifoPlan:
    """Sized FIFOs for every stream edge.

    Attributes:
        start_times: kernel -> optimal start time ``s(v)`` (cycles).
        delays: edge (u, v, key) -> delay value used for sizing.
        depths: edge -> FIFO depth in tokens.
        fifo_bytes: edge -> memory cost (depth * token bytes).
        strategy: equalization strategy used.
    """

    start_times: Dict[str, float]
    delays: Dict[Tuple[str, str, int], float]
    depths: Dict[Tuple[str, str, int], int]
    fifo_bytes: Dict[Tuple[str, str, int], float]
    strategy: str

    @property
    def total_bytes(self) -> float:
        return sum(self.fifo_bytes.values())

    @property
    def total_depth(self) -> int:
        return sum(self.depths.values())


def solve_start_times(graph: DataflowGraph,
                      timings: Dict[str, KernelTiming]) -> Dict[str, float]:
    """Optimal start times: longest accumulated-D path from the sources.

    This is the exact optimum of the paper's LP restricted to consistent
    (single-start-time) delays; see module docstring.
    """
    s: Dict[str, float] = {}
    for n in graph.topo_order():
        best = 0.0
        for p in graph.predecessors(n):
            best = max(best, s[p] + timings[p].initial_delay)
        s[n] = best
    return s


def size_fifos(
    graph: DataflowGraph,
    timings: Dict[str, KernelTiming],
    strategy: str = "normal",
    use_exact_curves: bool = True,
) -> FifoPlan:
    """Solve the FIFO sizing problem for every edge of ``graph``.

    Args:
        graph: dataflow graph (typically one fusion group).
        timings: per-kernel (L, D, II) — profiled or modelled.
        strategy: 'normal' or 'conservative' equalization (paper §5.3.3).
        use_exact_curves: size with the exact staircase maximum instead of the
            closed forms (both are available; exact is never smaller than
            required and is what we deploy).
    """
    tokens = {k.name: k.num_out_tokens for k in graph.kernels()}
    eq = EqualizationStrategy(strategy)
    eq_timings = eq.apply(timings, tokens)

    start = solve_start_times(graph, eq_timings)
    delays: Dict[Tuple[str, str, int], float] = {}
    depths: Dict[Tuple[str, str, int], int] = {}
    fifo_bytes: Dict[Tuple[str, str, int], float] = {}

    size_fn = max_tokens_exact if use_exact_curves else max_tokens_paper
    for u, v, key, data in graph.edges():
        delay = start[v] - start[u]
        # The number of tokens crossing this edge is the producer stream
        # length (paper: T is inferred statically from tensor shapes).
        t = data["src_type"].num_tokens
        # Multi-rate extension (beyond the paper's 1:1 token assumption):
        # a consumer firing T_c times against T_p producer tokens pulls at
        # an effective II of II_c * T_c / T_p per producer token.
        tc = tokens[v]
        cons = eq_timings[v]
        if tc != t and t > 0:
            cons = KernelTiming.from_tokens(
                cons.initial_delay, cons.pipeline_ii * tc / t, t)
        depth = size_fn(eq_timings[u], cons, delay, t)
        depth = max(2, depth)  # ping/pong minimum so producer never blocks
        if tc and t > tc:
            depth = max(depth, -(-t // tc))   # one whole firing's pop fits
        delays[(u, v, key)] = delay
        depths[(u, v, key)] = depth
        fifo_bytes[(u, v, key)] = depth * data["src_type"].token_bytes
    return FifoPlan(start_times=start, delays=delays, depths=depths,
                    fifo_bytes=fifo_bytes, strategy=strategy)


# --------------------------------------------------------------------- #
# Reference LP solvers (verification only)
# --------------------------------------------------------------------- #

def solve_lp_scipy(graph: DataflowGraph,
                   timings: Dict[str, KernelTiming]) -> Optional[Dict[str, float]]:
    """Compact LP with start-time variables, solved by scipy (tests only).

    minimize   sum_{(i,j) in E} (s_j - s_i)
    subject to s_j - s_i >= D_i             for every edge (i, j)
               s_root = 0                   for source kernels
    """
    try:
        from scipy.optimize import linprog
    except Exception:  # pragma: no cover - scipy always present in this env
        return None

    nodes = list(graph.g.nodes)
    idx = {n: i for i, n in enumerate(nodes)}
    n_var = len(nodes)
    # Objective: for each edge (i, j): +1 on s_j, -1 on s_i.
    c = [0.0] * n_var
    for u, v, k, _ in graph.edges():
        c[idx[v]] += 1.0
        c[idx[u]] -= 1.0
    a_ub: List[List[float]] = []
    b_ub: List[float] = []
    for u, v, k, _ in graph.edges():
        row = [0.0] * n_var
        row[idx[u]] = 1.0
        row[idx[v]] = -1.0     # s_u - s_v <= -D_u
        a_ub.append(row)
        b_ub.append(-timings[u].initial_delay)
    res = linprog(c, A_ub=a_ub, b_ub=b_ub, bounds=[(0, None)] * n_var,
                  method="highs")
    if not res.success:
        return None
    return {n: float(res.x[idx[n]]) for n in nodes}


def paper_lp_thresholds(graph: DataflowGraph,
                        timings: Dict[str, KernelTiming]) -> Dict[Tuple[str, str], float]:
    """Eq. 5: threshold(u, v) = max over paths of accumulated D, for tests."""
    out: Dict[Tuple[str, str], float] = {}
    nodes = list(graph.g.nodes)
    for u in nodes:
        for v in nodes:
            if u == v:
                continue
            best = None
            for path in nx.all_simple_paths(graph.g, u, v):
                acc = sum(timings[p].initial_delay for p in path[:-1])
                best = acc if best is None else max(best, acc)
            if best is not None:
                out[(u, v)] = best
    return out


def verify_plan_against_paper_lp(graph: DataflowGraph,
                                 timings: Dict[str, KernelTiming],
                                 plan: FifoPlan) -> bool:
    """Check plan delays satisfy the paper's path constraints (Eq. 4)."""
    thresholds = paper_lp_thresholds(graph, timings)
    for (u, v), thr in thresholds.items():
        for path in nx.all_simple_paths(graph.g, u, v):
            acc = 0.0
            for a, b in zip(path, path[1:]):
                key = next(iter(graph.g[a][b]))
                acc += plan.delays[(a, b, key)]
            if acc + 1e-9 < thr:
                return False
    return True
