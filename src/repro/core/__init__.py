"""StreamTensor core: itensor type system, fusion, FIFO sizing, design spaces."""

from .affine import AffineMap
from .allocation import AllocationResult, Buffer, MemoryTier, allocate
from .dma import DmaPlan, dma_seconds, plan_dma
from .dse import DSEResult, TrialResult, evaluate_trial, explore
from .lowering import CompiledDataflow, compile_model, lower_groups
from .partition import PartitionResult, partition
from .platforms import PLATFORMS, TPU_V5E, U55C, Platform, get_platform
from .tiling import (LinalgOpSpec, LoopDim, OperandSpec, TiledKernel,
                     TilingDecision, TilingSpace, tile_op)
from .trace import block_flops, trace_block, trace_lm_head
from .converter import (ConverterSpec, conversion_cost_bytes, infer_converter,
                        min_buffer_tiles_sim, shared_prefix_length)
from .fifo_sizing import FifoPlan, size_fifos, solve_start_times
from .fusion import FusionPlan, explore_fusion, fusion_memory_report
from .graph import DataflowGraph, KernelNode, KernelTiming
from .itensor import (ITensorType, col_major, fig5_b, fig5_c,
                      itensor_from_tiling, row_major)
from .stream_plan import (KernelChoice, LayerPlan, StreamPlan,
                          build_stream_plan, plan_for)
from .token_model import (EqualizationStrategy, max_tokens_exact,
                          max_tokens_paper, simulate_fifo_occupancy)

__all__ = [
    "AffineMap", "ITensorType", "itensor_from_tiling", "row_major", "col_major",
    "fig5_b", "fig5_c", "ConverterSpec", "infer_converter",
    "conversion_cost_bytes", "min_buffer_tiles_sim", "shared_prefix_length",
    "DataflowGraph", "KernelNode", "KernelTiming", "FusionPlan",
    "explore_fusion", "fusion_memory_report", "FifoPlan", "size_fifos",
    "solve_start_times", "EqualizationStrategy", "max_tokens_exact",
    "max_tokens_paper", "simulate_fifo_occupancy",
    "AllocationResult", "Buffer", "MemoryTier", "allocate",
    "DmaPlan", "dma_seconds", "plan_dma",
    "DSEResult", "TrialResult", "evaluate_trial", "explore",
    "CompiledDataflow", "compile_model", "lower_groups",
    "PartitionResult", "partition",
    "PLATFORMS", "TPU_V5E", "U55C", "Platform", "get_platform",
    "LinalgOpSpec", "LoopDim", "OperandSpec", "TiledKernel",
    "TilingDecision", "TilingSpace", "tile_op",
    "block_flops", "trace_block", "trace_lm_head",
    "KernelChoice", "LayerPlan", "StreamPlan", "build_stream_plan",
    "plan_for",
]
