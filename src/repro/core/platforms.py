"""Hardware platform models.

Two first-class platforms:

  * ``U55C``   — the paper's evaluation FPGA (AMD Alveo U55C, Vitis 2024.1,
    W4A8, 250 MHz).  Used by the paper-reproduction benchmarks (Tables 4/5,
    Fig. 9) to model kernel (L, D, II) the way the paper profiles them with
    vendor HLS.
  * ``TPU_V5E`` — the grading target of this repo.  Constants come from the
    brief: 197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link ICI.

The platform object is the single source of truth for:
  * roofline terms (compute / memory / collective seconds),
  * the fusion budget ``C_max`` (paper §5.2.2: total on-chip memory), and
  * the (L, D, II) timing model of dataflow kernels (paper §5.3.1), which on
    FPGA comes from HLS profiling and here from an analytic
    work/bandwidth/parallelism model calibrated to the platform.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

from .graph import KernelNode, KernelTiming


@dataclass(frozen=True)
class Platform:
    """A dataflow / accelerator platform model.

    Attributes:
        name: display name.
        freq_hz: clock frequency used by the cycle-level token model.
        peak_flops: peak arithmetic throughput (FLOP/s) in the native
            compute precision.
        peak_int8_ops: peak INT8 OPS (paper Table 6 row) when different.
        hbm_bw: external memory bandwidth, bytes/s.
        link_bw: per-link interconnect bandwidth, bytes/s (ICI on TPU,
            inter-FPGA on the paper platform; 0 = single device only).
        onchip_bytes: total fast on-chip memory (BRAM+URAM / VMEM).
        smem_bytes: small scratch tier (LUTRAM / SMEM).
        dma_ports: independent external-memory ports (HBM pseudo-channels /
            DMA engines); bounds how many kernels can stream from DRAM at once.
        compute_lanes: parallel MAC lanes available to one kernel at unroll 1
            -- the unit the tiling space's unroll factors multiply.
        thermal_power_w: design power for the energy model (paper Fig. 9).
    """

    name: str
    freq_hz: float
    peak_flops: float
    hbm_bw: float
    link_bw: float
    onchip_bytes: float
    smem_bytes: float = 0.0
    peak_int8_ops: float = 0.0
    dma_ports: int = 32
    compute_lanes: int = 512
    thermal_power_w: float = 0.0

    # ------------------------------------------------------------ roofline
    def compute_seconds(self, flops: float, chips: int = 1) -> float:
        return flops / (chips * self.peak_flops)

    def memory_seconds(self, bytes_moved: float, chips: int = 1) -> float:
        return bytes_moved / (chips * self.hbm_bw)

    def collective_seconds(self, coll_bytes: float, chips: int = 1) -> float:
        if self.link_bw <= 0:
            return 0.0
        return coll_bytes / (chips * self.link_bw)

    def roofline_seconds(self, flops: float, bytes_moved: float,
                         coll_bytes: float = 0.0, chips: int = 1) -> float:
        """max of the three terms — the roofline lower bound on step time."""
        return max(self.compute_seconds(flops, chips),
                   self.memory_seconds(bytes_moved, chips),
                   self.collective_seconds(coll_bytes, chips))

    # ------------------------------------------------------- token model
    def kernel_timing(self, node: KernelNode, unroll: int = 1) -> KernelTiming:
        """Model (L, D, II) of a dataflow kernel (paper §5.3.1).

        On the paper's flow these numbers come from vendor-HLS profiling; we
        model them from first principles so the same LP/fusion machinery runs
        offline:

          * ``II``  — cycles between output tokens: the larger of the compute
            bound (token FLOPs / (lanes * unroll * 2 flop/MAC/cycle)) and the
            DRAM bound for weight-streaming kernels.
          * ``D``   — initial delay: one full input token must arrive plus the
            kernel's own pipeline fill (modeled as one II plus a fixed
            pipeline depth).
          * ``L``   — ``D + (T-1) * II``.
        """
        tokens = max(1, node.num_out_tokens)
        flops_per_token = node.work_flops / tokens
        # MACs per cycle one kernel can retire at this unroll.
        macs_per_cycle = max(1.0, float(self.compute_lanes * unroll))
        compute_cycles = flops_per_token / (2.0 * macs_per_cycle)
        # Weight-streaming bound: bytes of parameters read per token.
        bw_per_port = self.hbm_bw / max(1, self.dma_ports)
        weight_bytes_per_token = node.weight_bytes / tokens
        mem_cycles = weight_bytes_per_token / (bw_per_port / self.freq_hz)
        ii = max(1.0, compute_cycles, mem_cycles)
        pipeline_depth = 32.0  # fixed stage fill, HLS-typical
        d = ii + pipeline_depth
        return KernelTiming.from_tokens(d, ii, tokens)

    def seconds(self, cycles: float) -> float:
        return cycles / self.freq_hz

    # --------------------------------------------------------------- misc
    def fusion_budget(self, fraction: float = 1.0) -> float:
        """C_max for Algorithm 2 — paper uses total on-chip memory."""
        return self.onchip_bytes * fraction


# --------------------------------------------------------------------- #
# Platform instances (paper Table 6 + the brief's TPU v5e constants)
# --------------------------------------------------------------------- #

U55C = Platform(
    name="AMD-U55C",
    freq_hz=250e6,
    peak_flops=24.5e12 / 2,   # 24.5 INT8 TOPS; ~half in W4A8 MACs w/ packing
    peak_int8_ops=24.5e12,
    hbm_bw=460e9,
    link_bw=0.0,
    onchip_bytes=41 * 2**20,
    smem_bytes=4 * 2**20,
    dma_ports=32,             # HBM2 pseudo-channels
    compute_lanes=1024,       # DSP-derived MAC lanes at 250 MHz
    thermal_power_w=150.0,
)

A100 = Platform(
    name="NVIDIA-A100",
    freq_hz=1.065e9,
    peak_flops=624e12 / 2,    # W8A8 via INT8 tensor cores (paper Table 6)
    peak_int8_ops=624e12,
    hbm_bw=1935e9,
    link_bw=600e9 / 12,
    onchip_bytes=40 * 2**20,
    thermal_power_w=300.0,
)

RTX2080TI = Platform(
    name="NVIDIA-2080Ti",
    freq_hz=1.35e9,
    peak_flops=215.2e12 / 2,
    peak_int8_ops=215.2e12,
    hbm_bw=616e9,
    link_bw=0.0,
    onchip_bytes=5.5 * 2**20,
    thermal_power_w=250.0,
)

TPU_V5E = Platform(
    name="TPU-v5e",
    freq_hz=940e6,
    peak_flops=197e12,        # bf16, from the brief
    peak_int8_ops=394e12,
    hbm_bw=819e9,             # from the brief
    link_bw=50e9,             # ~50 GB/s per ICI link, from the brief
    onchip_bytes=128 * 2**20,  # VMEM
    smem_bytes=1 * 2**20,
    dma_ports=16,
    compute_lanes=128 * 128,  # one MXU systolic array
    thermal_power_w=200.0,
)

PLATFORMS: Dict[str, Platform] = {
    "u55c": U55C, "a100": A100, "2080ti": RTX2080TI, "tpu_v5e": TPU_V5E,
}


def get_platform(name: str) -> Platform:
    try:
        return PLATFORMS[name.lower()]
    except KeyError:
        raise KeyError(f"unknown platform {name!r}; have {sorted(PLATFORMS)}")
