"""Minimal affine-map algebra for itensor iteration maps.

StreamTensor's iteration maps (paper §3.1.2) are projection/permutation maps:
every data dimension is fed by exactly one iteration dimension, and iteration
dimensions may be dropped (re-iteration / reuse dims, Fig. 5(c)).  We therefore
represent a map ``(d0, .., d{n-1}) -> (d_{r0}, .., d_{r_{m-1}})`` as the tuple
``results = (r0, .., r_{m-1})`` of iteration-dim positions, one per data dim.

This covers everything in the paper; general affine expressions are not needed
and would weaken the analytical converter-size inference of Algorithm 1.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Iterator, Sequence, Tuple


@dataclass(frozen=True)
class AffineMap:
    """Projection/permutation map from an iteration space to a data space.

    Attributes:
        num_dims: rank of the iteration space (number of loops).
        results:  for each data dimension ``j``, ``results[j]`` is the
                  iteration-dim position that indexes it.
    """

    num_dims: int
    results: Tuple[int, ...]

    def __post_init__(self) -> None:
        if any(not (0 <= r < self.num_dims) for r in self.results):
            raise ValueError(
                f"map results {self.results} out of range for {self.num_dims} dims"
            )
        if len(set(self.results)) != len(self.results):
            raise ValueError(f"map results must be injective, got {self.results}")

    # ------------------------------------------------------------------ #
    @property
    def num_results(self) -> int:
        return len(self.results)

    @property
    def reuse_dims(self) -> Tuple[int, ...]:
        """Iteration dims that feed no data dim (re-iteration dims)."""
        used = set(self.results)
        return tuple(d for d in range(self.num_dims) if d not in used)

    def is_permutation(self) -> bool:
        return self.num_dims == self.num_results

    def is_identity(self) -> bool:
        return self.results == tuple(range(self.num_dims))

    # ------------------------------------------------------------------ #
    def apply(self, indices: Sequence[int]) -> Tuple[int, ...]:
        """Map one iteration-index vector to a data-index vector."""
        if len(indices) != self.num_dims:
            raise ValueError(f"expected {self.num_dims} indices, got {len(indices)}")
        return tuple(indices[r] for r in self.results)

    def compose_permutation(self, perm: Sequence[int]) -> "AffineMap":
        """Return the map obtained by permuting the *iteration* dims.

        ``perm[k]`` is the old position of the new k-th loop, so result
        positions must be rewritten through the inverse permutation.
        """
        inv = {old: new for new, old in enumerate(perm)}
        return AffineMap(self.num_dims, tuple(inv[r] for r in self.results))

    def drop_dims(self, dims: Sequence[int]) -> "AffineMap":
        """Remove iteration dims (must all be reuse dims) and renumber."""
        dims_set = set(dims)
        if dims_set & set(self.results):
            raise ValueError("cannot drop iteration dims that feed data dims")
        remaining = [d for d in range(self.num_dims) if d not in dims_set]
        renum = {old: new for new, old in enumerate(remaining)}
        return AffineMap(len(remaining), tuple(renum[r] for r in self.results))

    # ------------------------------------------------------------------ #
    @staticmethod
    def identity(rank: int) -> "AffineMap":
        return AffineMap(rank, tuple(range(rank)))

    @staticmethod
    def transpose2d() -> "AffineMap":
        return AffineMap(2, (1, 0))

    @staticmethod
    def permutation(perm: Sequence[int]) -> "AffineMap":
        return AffineMap(len(perm), tuple(perm))

    def __str__(self) -> str:
        ins = ", ".join(f"d{i}" for i in range(self.num_dims))
        outs = ", ".join(f"d{r}" for r in self.results)
        return f"({ins}) -> ({outs})"


def lexicographic_indices(tripcounts: Sequence[int]) -> Iterator[Tuple[int, ...]]:
    """Row-major (last dim fastest) enumeration of an iteration space."""
    yield from itertools.product(*(range(t) for t in tripcounts))
