"""Linalg tiling space — paper §5.1.

The tiling space decides, for every dataflow kernel: tile sizes, loop
permutation, unroll factors, and input/output vectorization.  Its input is a
graph of *Linalg-like op specs* — einsum-style structured ops with named
iteration dims (parallel or reduction) and per-operand dim maps — produced by
``trace.py`` from a model block.  Its output is a tiled kernel whose operand
**itensor types** are derived mechanically (paper §4.1):

  * the tiled loop nest's tripcounts/steps define the iteration space,
  * each operand's dim map defines the affine iteration map (loops that do not
    index the operand become *reuse* dims — the Fig. 5(c) pattern appears for
    free on e.g. matmul inputs),
  * tile extents define the element shape.

Paper heuristics reproduced:
  * ``default_tile_size`` applied across all dims (clipped to the largest
    divisor of the extent; exact tilings only).
  * Intensity-aware unrolling: a max-heap repeatedly selects the kernel with
    the longest modeled latency and doubles its unroll factor until the global
    ``overall_unroll_size`` budget is exhausted.
  * Permutation: reduction loops outermost *inside* the pipelined tile body
    (II -> 1: no loop-carried dependence in the inner parallel loops), while
    the inter-tile nest keeps reduction tiles innermost so outputs stream as
    soon as their reduction completes.
  * Vectorization factors inferred from the unroll factor on the innermost
    parallel data dim (itensor ``vectorize``).
"""

from __future__ import annotations

import heapq
import math
from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Sequence, Tuple

from .affine import AffineMap
from .graph import DataflowGraph, KernelNode, KernelTiming
from .itensor import ITensorType, dtype_bytes
from .platforms import Platform

PARALLEL = "parallel"
REDUCTION = "reduction"


@dataclass(frozen=True)
class LoopDim:
    """One named iteration dimension of a structured op."""
    name: str
    extent: int
    kind: str = PARALLEL

    def __post_init__(self) -> None:
        if self.kind not in (PARALLEL, REDUCTION):
            raise ValueError(f"bad loop kind {self.kind}")
        if self.extent <= 0:
            raise ValueError(f"bad extent {self.extent}")


@dataclass(frozen=True)
class OperandSpec:
    """A tensor operand: which iteration dims index each data dim.

    ``tensor_id`` names the logical tensor; producer/consumer ops that share a
    ``tensor_id`` get a stream edge in the dataflow graph.
    """
    tensor_id: str
    dims: Tuple[str, ...]
    dtype: str = "bfloat16"
    is_weight: bool = False   # resident parameter, streamed from DRAM


@dataclass(frozen=True)
class LinalgOpSpec:
    """Einsum-like structured op (the paper's tiled ``linalg.generic``)."""
    name: str
    op: str
    loops: Tuple[LoopDim, ...]
    inputs: Tuple[OperandSpec, ...]
    output: OperandSpec
    flops_per_point: float = 2.0   # FLOPs per iteration-space point

    def loop(self, name: str) -> LoopDim:
        for l in self.loops:
            if l.name == name:
                return l
        raise KeyError(f"{self.name}: no loop {name}")

    @property
    def loop_names(self) -> Tuple[str, ...]:
        return tuple(l.name for l in self.loops)

    @property
    def iter_points(self) -> int:
        return math.prod(l.extent for l in self.loops)

    @property
    def work_flops(self) -> float:
        return self.iter_points * self.flops_per_point

    def operand_shape(self, spec: OperandSpec) -> Tuple[int, ...]:
        return tuple(self.loop(d).extent for d in spec.dims)

    def validate(self) -> None:
        names = self.loop_names
        if len(set(names)) != len(names):
            raise ValueError(f"{self.name}: duplicate loop names")
        for spec in (*self.inputs, self.output):
            for d in spec.dims:
                self.loop(d)
        for d in spec.dims:
            if self.loop(d).kind == REDUCTION and d in self.output.dims:
                raise ValueError(f"{self.name}: reduction dim {d} in output")


# --------------------------------------------------------------------- #
# Tiling decisions
# --------------------------------------------------------------------- #

@dataclass
class TilingDecision:
    """Per-kernel configuration chosen by the tiling space."""
    tile_sizes: Dict[str, int]          # loop name -> tile extent
    loop_order: Tuple[str, ...]         # inter-tile loop nest, outermost first
    unroll: int = 1
    vector_factor: int = 1
    reduction_outer_intra: bool = True  # paper's permutation heuristic


def largest_divisor_leq(n: int, cap: int) -> int:
    """Largest divisor of ``n`` that is <= cap (>=1)."""
    cap = max(1, min(n, cap))
    for d in range(cap, 0, -1):
        if n % d == 0:
            return d
    return 1


def default_decision(op: LinalgOpSpec, default_tile_size: int) -> TilingDecision:
    """Paper §5.1: one global ``default_tile_size`` across all dims, then the
    permutation heuristic (parallel tiles outer / reduction tiles innermost at
    the inter-tile level so outputs stream eagerly)."""
    tiles = {l.name: largest_divisor_leq(l.extent, default_tile_size)
             for l in op.loops}
    par = [l.name for l in op.loops if l.kind == PARALLEL]
    red = [l.name for l in op.loops if l.kind == REDUCTION]
    return TilingDecision(tile_sizes=tiles, loop_order=tuple(par + red))


@dataclass
class TiledKernel:
    """A structured op after tiling: itensor types on every port."""
    spec: LinalgOpSpec
    decision: TilingDecision
    in_types: Tuple[ITensorType, ...]
    out_type: ITensorType
    local_accum_bytes: float            # on-chip accumulator footprint
    weight_bytes: float

    @property
    def name(self) -> str:
        return self.spec.name

    def to_kernel_node(self) -> KernelNode:
        return KernelNode(
            name=self.spec.name,
            op=self.spec.op,
            out_type=self.out_type,
            in_types=self.in_types,
            work_flops=self.spec.work_flops,
            weight_bytes=self.weight_bytes,
            local_bytes=self.local_accum_bytes,
            tags={"decision": self.decision,
                  "tensor_ids": tuple(i.tensor_id for i in self.spec.inputs),
                  "out_tensor_id": self.spec.output.tensor_id},
        )


def _operand_itensor(op: LinalgOpSpec, spec: OperandSpec,
                     dec: TilingDecision, *, is_output: bool) -> ITensorType:
    """Derive an itensor type for one operand of a tiled op (paper §4.1).

    The iteration space is the inter-tile loop nest (one loop per op loop dim
    in ``dec.loop_order``); loops not indexing the operand are reuse dims.
    For the *output*, reduction loops are excluded from the iteration space:
    the result tile is pushed once, after its reduction completes (the
    accumulator holds it on-chip until then).
    """
    order = [n for n in dec.loop_order]
    if is_output:
        order = [n for n in order if op.loop(n).kind != REDUCTION]
    tripcounts, steps = [], []
    pos: Dict[str, int] = {}
    for k, n in enumerate(order):
        l = op.loop(n)
        t = dec.tile_sizes[n]
        tripcounts.append(l.extent // t)
        steps.append(t)
        pos[n] = k
    results = tuple(pos[d] for d in spec.dims)
    elem = tuple(dec.tile_sizes[d] for d in spec.dims)
    # Canonicalize away tripcount-1 loops that feed no data dim.
    it = ITensorType(elem_shape=elem, tripcounts=tuple(tripcounts),
                     steps=tuple(steps),
                     iter_map=AffineMap(len(order), results),
                     dtype=spec.dtype)
    return it.canonicalize()


def tile_op(op: LinalgOpSpec, dec: TilingDecision) -> TiledKernel:
    """Apply a tiling decision; mechanical itensor-type derivation."""
    op.validate()
    for n, t in dec.tile_sizes.items():
        if op.loop(n).extent % t != 0:
            raise ValueError(f"{op.name}: tile {t} does not divide "
                             f"{op.loop(n).extent} ({n})")
    if sorted(dec.loop_order) != sorted(op.loop_names):
        raise ValueError(f"{op.name}: loop_order must permute {op.loop_names}")

    in_types = tuple(_operand_itensor(op, s, dec, is_output=False)
                     for s in op.inputs)
    out_type = _operand_itensor(op, op.output, dec, is_output=True)
    # Note: the decision's vector_factor widens *FIFO tokens* (paper §4.3.3);
    # it is applied symmetrically per edge in ``TilingSpace.build_graph`` so
    # producer/consumer types stay paired.

    # Accumulator: one output tile per in-flight reduction (ping-pong'd).
    acc_elems = math.prod(dec.tile_sizes[d] for d in op.output.dims)
    has_red = any(l.kind == REDUCTION for l in op.loops)
    local = (2.0 if has_red else 1.0) * acc_elems * dtype_bytes(op.output.dtype)
    weight_bytes = 0.0
    for s in op.inputs:
        if s.is_weight:
            weight_bytes += (math.prod(op.operand_shape(s))
                             * dtype_bytes(s.dtype))
    return TiledKernel(spec=op, decision=dec, in_types=in_types,
                       out_type=out_type, local_accum_bytes=local,
                       weight_bytes=weight_bytes)


# --------------------------------------------------------------------- #
# Graph-level tiling: build a DataflowGraph from op specs
# --------------------------------------------------------------------- #

@dataclass
class TilingSpace:
    """The tiling design space for a graph of structured ops (paper §5.1).

    Hyperparameters (explored by ``dse.py``):
        default_tile_size: global tile extent applied across all dims.
        overall_unroll_size: total unroll budget distributed by the
            intensity-aware algorithm.
    """
    ops: List[LinalgOpSpec]
    default_tile_size: int = 64
    overall_unroll_size: int = 64

    def decide(self, platform: Platform) -> Dict[str, TilingDecision]:
        decisions = {op.name: default_decision(op, self.default_tile_size)
                     for op in self.ops}
        self._intensity_aware_unroll(decisions, platform)
        for op in self.ops:
            d = decisions[op.name]
            d.vector_factor = self._infer_vector_factor(op, d)
        return decisions

    # -- paper §5.1: max-heap latency balancing ------------------------- #
    def _intensity_aware_unroll(self, decisions: Dict[str, TilingDecision],
                                platform: Platform) -> None:
        """Iteratively double the unroll of the longest-latency kernel until
        the total unroll budget ``overall_unroll_size`` is reached."""
        def latency(op: LinalgOpSpec, unroll: int) -> float:
            node = tile_op(op, decisions[op.name]).to_kernel_node()
            return platform.kernel_timing(node, unroll=unroll).latency

        heap: List[Tuple[float, str, LinalgOpSpec]] = []
        for op in self.ops:
            heapq.heappush(heap, (-latency(op, 1), op.name, op))
        budget = self.overall_unroll_size - len(self.ops)  # every kernel >= 1
        while heap and budget > 0:
            neg_lat, name, op = heapq.heappop(heap)
            d = decisions[name]
            if d.unroll * 2 - d.unroll > budget:
                break
            budget -= d.unroll          # doubling adds `unroll` more lanes
            d.unroll *= 2
            heapq.heappush(heap, (-latency(op, d.unroll), name, op))

    def _infer_vector_factor(self, op: LinalgOpSpec,
                             d: TilingDecision) -> int:
        """Vectorization inferred from unroll on the innermost parallel data
        dim (paper: 'vectorization factors are inferred by analyzing the loop
        iteration space and tensor shapes')."""
        if not op.output.dims:
            return 1
        inner = op.output.dims[-1]
        tile = d.tile_sizes[inner]
        grid = op.loop(inner).extent // tile
        f = 1
        while f * 2 <= d.unroll and grid % (f * 2) == 0:
            f *= 2
        return f

    # ------------------------------------------------------------------ #
    def build_graph(self, platform: Platform,
                    decisions: Optional[Dict[str, TilingDecision]] = None,
                    ) -> DataflowGraph:
        """Tile every op and wire producer->consumer edges by tensor id.

        This is the paper's Linalg-to-dataflow conversion (§4.1): each tiled
        loop nest becomes a ``kernel`` whose boundary types are itensors.
        """
        decisions = decisions or self.decide(platform)
        graph = DataflowGraph()
        producer_of: Dict[str, str] = {}
        tiled: Dict[str, TiledKernel] = {}
        for op in self.ops:
            tk = tile_op(op, decisions[op.name])
            tiled[op.name] = tk
            node = tk.to_kernel_node()
            node.timing = platform.kernel_timing(
                node, unroll=decisions[op.name].unroll)
            graph.add_kernel(node)
            if op.output.tensor_id in producer_of:
                raise ValueError(f"tensor {op.output.tensor_id} produced twice")
            producer_of[op.output.tensor_id] = op.name
        for op in self.ops:
            for i, spec in enumerate(op.inputs):
                p = producer_of.get(spec.tensor_id)
                if p is None:
                    continue   # graph input or weight: DMA at kernel boundary
                src = tiled[p].out_type
                dst = tiled[op.name].in_types[i]
                # Vectorize the FIFO token symmetrically (paper §4.3.3): both
                # ends widen by the common factor so the pairing stays typed.
                f = min(decisions[p].vector_factor,
                        decisions[op.name].vector_factor)
                src, dst = _widen_edge(src, dst, f)
                graph.connect(p, op.name, src_type=src, dst_type=dst,
                              operand=i)
        graph.validate()
        return graph


def _widen_edge(src: ITensorType, dst: ITensorType,
                factor: int) -> Tuple[ITensorType, ITensorType]:
    """Widen both end types of an edge by the same token vector factor."""
    while factor > 1:
        fs = [1] * src.rank
        fs[-1] = factor
        fd = [1] * dst.rank
        fd[-1] = factor
        try:
            return src.vectorize(fs), dst.vectorize(fd)
        except ValueError:
            factor //= 2
    return src, dst
