"""DMA materialization: pack / widen planning — paper §4.2, §4.3.1.

At every fused-kernel boundary, tensors living in external memory are moved by
DMAs whose behavior is fully determined by the boundary itensor type
(paper Fig. 7(a)-(b)): load order, staging ping-pong buffer, and stream push
layout.  To maximize external bandwidth, StreamTensor

  * **packs** the tensor into a tiled layout so each tile is contiguous
    (a ``[64,64]`` tensor tiled ``[16,16]`` becomes ``[4,4,16,16]``), making
    every DMA burst long; and
  * **widens** elements into vectors matching the memory bus (512-bit DDR/HBM
    with uint8 -> ``vector<64>``).

Pack/widen fold into static tensors (pre-trained parameters) at zero runtime
cost; for activations they cancel against the unpack/unwiden of the adjacent
layer when the tiling space aligns layouts (paper §4.2).  The TPU analogue is
choosing parameter layouts tile-contiguous at load time and keeping the last
dim a multiple of the 128-lane register width.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

from .itensor import ITensorType, dtype_bytes


@dataclass(frozen=True)
class DmaPlan:
    """Materialized DMA for one kernel-boundary tensor (paper Fig. 7(b)).

    Attributes:
        tensor_shape: logical tensor shape.
        packed_shape: tiled storage layout (grid dims + tile dims + vector).
        vector_width: elements fused into one bus word ("widen").
        burst_elems: contiguous elements per DMA burst after packing.
        staging_bytes: on-chip ping-pong staging buffer (2x one token).
        bursts: number of bursts per pass.
        efficiency: fraction of peak bus bandwidth achieved (long bursts
            amortize row-activation overhead; model: burst/(burst+setup)).
        is_static: parameter tensor -> pack folds offline, no runtime cost.
    """

    tensor_shape: Tuple[int, ...]
    packed_shape: Tuple[int, ...]
    vector_width: int
    burst_elems: int
    staging_bytes: float
    bursts: int
    efficiency: float
    is_static: bool

    @property
    def total_bytes(self) -> float:
        return math.prod(self.tensor_shape) * self._elem_bytes

    @property
    def _elem_bytes(self) -> float:
        # packed_shape carries no dtype; staging/total use the planner's.
        return self.__dict__.get("_eb", 1.0)


def plan_dma(itype: ITensorType, *, bus_bits: int = 512,
             burst_setup_elems: int = 16,
             is_static: bool = False) -> DmaPlan:
    """Derive the pack/widen plan from a boundary itensor type.

    Pack: storage order = grid-major over the *stream* order's data walk, tile
    elements contiguous.  Widen: group ``bus_bits / elem_bits`` elements.
    """
    eb = dtype_bytes(itype.dtype)
    vector_width = max(1, int(bus_bits // (eb * 8)))
    tile_elems = math.prod(itype.elem_shape)
    # Widen cannot exceed one tile; clip to a divisor of the tile.
    while vector_width > 1 and tile_elems % vector_width != 0:
        vector_width //= 2
    grid = itype.grid_shape
    packed = tuple(grid) + tuple(itype.elem_shape)
    burst = tile_elems  # a packed tile is fully contiguous
    eff = burst / (burst + burst_setup_elems)
    plan = DmaPlan(
        tensor_shape=itype.data_shape,
        packed_shape=packed,
        vector_width=vector_width,
        burst_elems=burst,
        staging_bytes=2.0 * itype.token_bytes,
        bursts=int(math.prod(grid)) * itype.reuse_factor,
        efficiency=eff,
        is_static=is_static,
    )
    object.__setattr__(plan, "_eb", eb)
    return plan


def unpacked_efficiency(itype: ITensorType,
                        burst_setup_elems: int = 16) -> float:
    """Bandwidth efficiency *without* packing: bursts break at tile rows.

    Row-major storage means one tile reads ``elem_shape[:-1]`` separate rows
    of ``elem_shape[-1]`` contiguous elements each.
    """
    row = itype.elem_shape[-1] if itype.elem_shape else 1
    return row / (row + burst_setup_elems)


def dma_seconds(plan: DmaPlan, hbm_bw: float) -> float:
    """Transfer time accounting for burst efficiency (0 for folded statics —
    parameters are charged once by the caller, not per pass)."""
    return plan.total_bytes / (hbm_bw * plan.efficiency)


def pack_fold_report(plans: Sequence[DmaPlan]) -> dict:
    """How much pack/widen runtime cost folds away (paper §4.2: only model
    inputs/outputs pay; statics fold into the parameter files)."""
    total = sum(p.total_bytes for p in plans)
    folded = sum(p.total_bytes for p in plans if p.is_static)
    return {"total_bytes": total, "folded_bytes": folded,
            "runtime_bytes": total - folded,
            "folded_fraction": folded / total if total else 0.0}
