"""Iterative tensor (itensor) type system — paper §3.1.

An itensor explicitly encodes the *stream layout* of a tensor flowing between
dataflow kernels:

  * ``elem_shape``  — the shape of the tensor slice (tile) communicated as one
    stream token;
  * ``tripcounts`` / ``steps`` — the iteration space: nested loops with these
    trip counts, where loop ``k`` advances by ``steps[k]`` data elements per
    iteration;
  * ``iter_map``    — an affine (projection/permutation) map from iteration
    indices to data-space offsets.  Iteration dims absent from the map are
    *reuse* dims: the covered data is re-streamed once per iteration
    (Fig. 5(c) of the paper).

Together these uniquely determine the order in which tiles of the underlying
tensor appear on the stream, which is exactly the information classic
``tensor<8x8xf32>`` types lack (paper §3.1.1).  Two kernels may be fused with a
raw FIFO iff their itensor types match; otherwise a stream-layout converter
with an analytically-inferred ping-pong buffer is required (converter.py).

TPU correspondence (see DESIGN.md §4): an itensor is the type-level twin of a
Pallas ``BlockSpec`` schedule — ``elem_shape == block_shape``,
``tripcounts == grid``, ``iter_map == index_map``.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Iterator, List, Optional, Sequence, Tuple

import numpy as np

from .affine import AffineMap, lexicographic_indices

_DTYPE_BYTES = {
    "float32": 4, "f32": 4, "bfloat16": 2, "bf16": 2, "float16": 2, "f16": 2,
    "int32": 4, "i32": 4, "int8": 1, "i8": 1, "uint8": 1, "u8": 1,
    # Sub-byte packings are exact fractions, matching the paged cache's
    # ``kv_itemsize_effective`` (= pool bytes / logical elements).
    "int4": 0.5, "i4": 0.5, "uint4": 0.5, "u4": 0.5,
    # Both fp8 encodings the quantized KV pools may carry (DESIGN.md §14).
    "float8_e4m3fn": 1, "float8_e4m3": 1, "f8_e4m3": 1, "f8": 1,
    "float8_e5m2": 1, "f8_e5m2": 1, "e5m2": 1,
}


def dtype_bytes(dtype: str) -> float:
    """Bytes per element for an itensor dtype string.

    Exact (possibly fractional) for the table above; falls back to numpy
    for anything else.  ``np.dtype`` does not know jax's extended dtypes
    (bfloat16, fp8) — those must come from the table, so the fallback
    failure is rewritten into a diagnosable error naming the dtype.
    """
    try:
        return _DTYPE_BYTES[dtype]
    except KeyError:
        pass
    try:
        return np.dtype(dtype).itemsize
    except TypeError as e:
        raise ValueError(f"unknown itensor dtype {dtype!r}") from e


@dataclass(frozen=True)
class ITensorType:
    """The iterative tensor type (paper Fig. 5).

    Invariants (checked):
      * ``len(tripcounts) == len(steps) == iter_map.num_dims``
      * ``iter_map.num_results == len(elem_shape)`` (one loop per data dim)
      * for each data dim ``j`` fed by loop ``k = iter_map.results[j]``:
        ``elem_shape[j] <= steps[k]`` (tiles do not overlap) and the covered
        extent is ``tripcounts[k] * steps[k]``.
    """

    elem_shape: Tuple[int, ...]
    tripcounts: Tuple[int, ...]
    steps: Tuple[int, ...]
    iter_map: AffineMap
    dtype: str = "float32"

    def __post_init__(self) -> None:
        if len(self.tripcounts) != len(self.steps):
            raise ValueError("tripcounts and steps must have equal rank")
        if self.iter_map.num_dims != len(self.tripcounts):
            raise ValueError(
                f"iter_map has {self.iter_map.num_dims} dims, iteration space "
                f"has {len(self.tripcounts)}"
            )
        if self.iter_map.num_results != len(self.elem_shape):
            raise ValueError(
                f"iter_map has {self.iter_map.num_results} results, element "
                f"shape has rank {len(self.elem_shape)}"
            )
        if any(t <= 0 for t in self.tripcounts) or any(s <= 0 for s in self.steps):
            raise ValueError("tripcounts/steps must be positive")
        for j, k in enumerate(self.iter_map.results):
            if self.elem_shape[j] > self.steps[k]:
                raise ValueError(
                    f"data dim {j}: element extent {self.elem_shape[j]} exceeds "
                    f"step {self.steps[k]} of loop d{k} (tiles would overlap)"
                )

    # -------------------------------------------------------------- shapes
    @property
    def rank(self) -> int:
        """Data-space rank."""
        return len(self.elem_shape)

    @property
    def iter_rank(self) -> int:
        return len(self.tripcounts)

    @property
    def data_shape(self) -> Tuple[int, ...]:
        """Extent of the underlying tensor covered by the stream."""
        return tuple(
            self.tripcounts[k] * self.steps[k] for k in self.iter_map.results
        )

    @property
    def grid_shape(self) -> Tuple[int, ...]:
        """Number of distinct tiles along each data dim."""
        return tuple(self.tripcounts[k] for k in self.iter_map.results)

    @property
    def reuse_dims(self) -> Tuple[int, ...]:
        return self.iter_map.reuse_dims

    @property
    def reuse_factor(self) -> int:
        """How many times each tile is (re-)streamed."""
        f = 1
        for d in self.reuse_dims:
            f *= self.tripcounts[d]
        return f

    # -------------------------------------------------------------- tokens
    @property
    def num_tokens(self) -> int:
        """Total stream length in tiles for one pass (paper's ``T``)."""
        return math.prod(self.tripcounts)

    @property
    def token_bytes(self) -> float:
        return math.prod(self.elem_shape) * dtype_bytes(self.dtype)

    @property
    def total_bytes(self) -> float:
        return self.num_tokens * self.token_bytes

    @property
    def data_bytes(self) -> float:
        return math.prod(self.data_shape) * dtype_bytes(self.dtype)

    def is_exact_tiling(self) -> bool:
        """True if tiles abut exactly (step == element extent on every dim)."""
        return all(
            self.elem_shape[j] == self.steps[k]
            for j, k in enumerate(self.iter_map.results)
        )

    # -------------------------------------------------------- stream order
    def stream_offsets(self) -> Iterator[Tuple[int, ...]]:
        """Yield data-space offsets of tiles in stream order.

        The iteration space is walked row-major (last loop fastest), exactly
        the ``scf.for`` nest semantics of the paper's examples.
        """
        steps, results = self.steps, self.iter_map.results
        for idx in lexicographic_indices(self.tripcounts):
            yield tuple(idx[k] * steps[k] for k in results)

    def stream_tile_ids(self) -> Iterator[int]:
        """Yield linearized tile ids (row-major over ``grid_shape``)."""
        grid = self.grid_shape
        strides = [0] * len(grid)
        acc = 1
        for j in reversed(range(len(grid))):
            strides[j] = acc
            acc *= grid[j]
        results, steps = self.iter_map.results, self.steps
        for idx in lexicographic_indices(self.tripcounts):
            tid = 0
            for j, k in enumerate(results):
                tid += idx[k] * strides[j]
            yield tid

    # -------------------------------------------------------- equivalence
    def matches(self, other: "ITensorType") -> bool:
        """Structural type match (paper's fusion legality check, Fig. 5 Case1)."""
        return self == other

    def canonicalize(self) -> "ITensorType":
        """Drop trip-count-1 reuse dims; they do not affect stream order."""
        drop = [d for d in self.reuse_dims if self.tripcounts[d] == 1]
        if not drop:
            return self
        keep = [d for d in range(self.iter_rank) if d not in drop]
        return ITensorType(
            elem_shape=self.elem_shape,
            tripcounts=tuple(self.tripcounts[d] for d in keep),
            steps=tuple(self.steps[d] for d in keep),
            iter_map=self.iter_map.drop_dims(drop),
            dtype=self.dtype,
        )

    def equivalent(self, other: "ITensorType") -> bool:
        """Semantic equality: same tile sequence on the wire."""
        a, b = self.canonicalize(), other.canonicalize()
        if (a.elem_shape, a.dtype, a.data_shape) != (b.elem_shape, b.dtype, b.data_shape):
            return False
        if a.num_tokens != b.num_tokens:
            return False
        if a == b:
            return True
        # Fall back to bounded enumeration — used in verification only.
        for x, y in zip(a.stream_offsets(), b.stream_offsets()):
            if x != y:
                return False
        return True

    # ------------------------------------------------------ transformations
    def with_dtype(self, dtype: str) -> "ITensorType":
        return replace(self, dtype=dtype)

    def permute_loops(self, perm: Sequence[int]) -> "ITensorType":
        """Reorder the loop nest; ``perm[k]`` = old position of new loop k."""
        if sorted(perm) != list(range(self.iter_rank)):
            raise ValueError(f"bad permutation {perm}")
        return ITensorType(
            elem_shape=self.elem_shape,
            tripcounts=tuple(self.tripcounts[p] for p in perm),
            steps=tuple(self.steps[p] for p in perm),
            iter_map=self.iter_map.compose_permutation(perm),
            dtype=self.dtype,
        )

    def vectorize(self, factors: Sequence[int]) -> "ITensorType":
        """Widen the token by ``factors`` along each data dim (paper §4.3.3).

        The innermost loops shrink accordingly; tokens become
        ``elem_shape * factors`` blocks.  Requires divisibility.
        """
        if len(factors) != self.rank:
            raise ValueError("need one factor per data dim")
        new_elem, new_trip, new_step = (
            list(self.elem_shape), list(self.tripcounts), list(self.steps))
        for j, f in enumerate(factors):
            if f == 1:
                continue
            k = self.iter_map.results[j]
            if self.tripcounts[k] % f != 0:
                raise ValueError(
                    f"tripcount {self.tripcounts[k]} of loop d{k} not divisible "
                    f"by vector factor {f}")
            new_elem[j] = self.elem_shape[j] * f
            new_trip[k] = self.tripcounts[k] // f
            new_step[k] = self.steps[k] * f
        return ITensorType(tuple(new_elem), tuple(new_trip), tuple(new_step),
                           self.iter_map, self.dtype)

    # ------------------------------------------------------------- pallas
    def block_spec_args(self) -> Tuple[Tuple[int, ...], "_IndexMap"]:
        """Return ``(block_shape, index_map)`` for ``pl.BlockSpec``.

        Only valid for exact tilings.  The returned index map takes one grid
        coordinate per *iteration* dim and returns block coordinates per data
        dim — reuse dims are simply ignored by it, which is exactly Pallas'
        semantics for revisiting the same block.
        """
        if not self.is_exact_tiling():
            raise ValueError("BlockSpec export requires an exact tiling")
        results = self.iter_map.results

        def index_map(*grid_idx):
            return tuple(grid_idx[k] for k in results)

        return self.elem_shape, index_map

    # ------------------------------------------------------------- display
    def __str__(self) -> str:
        es = "x".join(map(str, self.elem_shape))
        space = "x".join(map(str, self.tripcounts)) + "*" + "x".join(map(str, self.steps))
        return f"itensor<{es}x{self.dtype}, [{space}], {self.iter_map}>"


# ---------------------------------------------------------------------- #
# Constructors
# ---------------------------------------------------------------------- #

def itensor_from_tiling(
    data_shape: Sequence[int],
    tile_shape: Sequence[int],
    loop_order: Optional[Sequence[int]] = None,
    reuse: Optional[Sequence[Tuple[int, int]]] = None,
    dtype: str = "float32",
) -> ITensorType:
    """Build an itensor for an exact tiling of ``data_shape``.

    Args:
        data_shape: underlying tensor shape; each dim must be divisible by the
            corresponding tile extent.
        tile_shape: element (token) shape.
        loop_order: order in which *data* dims are walked, outermost first.
            Default: row-major (``range(rank)``).  E.g. ``(1, 0)`` streams a
            matrix column-of-tiles-major — the Fig. 5(b) layout.
        reuse: list of ``(position, count)`` pairs inserting re-iteration loops
            at the given position of the final loop nest (Fig. 5(c)).
        dtype: element dtype.
    """
    rank = len(data_shape)
    if len(tile_shape) != rank:
        raise ValueError("tile rank must equal data rank")
    for d, t in zip(data_shape, tile_shape):
        if d % t != 0:
            raise ValueError(f"data extent {d} not divisible by tile extent {t}")
    order = list(loop_order) if loop_order is not None else list(range(rank))
    if sorted(order) != list(range(rank)):
        raise ValueError(f"loop_order must be a permutation, got {order}")

    # Loop k walks data dim order[k].
    tripcounts = [data_shape[order[k]] // tile_shape[order[k]] for k in range(rank)]
    steps = [tile_shape[order[k]] for k in range(rank)]
    # Data dim j is fed by the loop at position order.index(j).
    results = [order.index(j) for j in range(rank)]

    if reuse:
        # Insert reuse loops (outer positions first to keep indices stable).
        for pos, count in sorted(reuse, reverse=True):
            tripcounts.insert(pos, count)
            steps.insert(pos, 1)
            results = [r + 1 if r >= pos else r for r in results]

    return ITensorType(
        elem_shape=tuple(tile_shape),
        tripcounts=tuple(tripcounts),
        steps=tuple(steps),
        iter_map=AffineMap(len(tripcounts), tuple(results)),
        dtype=dtype,
    )


def row_major(data_shape: Sequence[int], tile_shape: Sequence[int],
              dtype: str = "float32") -> ITensorType:
    return itensor_from_tiling(data_shape, tile_shape, dtype=dtype)


def col_major(data_shape: Sequence[int], tile_shape: Sequence[int],
              dtype: str = "float32") -> ITensorType:
    rank = len(data_shape)
    order = list(range(rank))
    order[-1], order[-2] = order[-2], order[-1]
    return itensor_from_tiling(data_shape, tile_shape, loop_order=order, dtype=dtype)


# Paper Fig. 5 worked examples, used across the test-suite. ------------- #

def fig5_b() -> ITensorType:
    """tensor<8x8xf32> as 4x2 tiles, iteration [4,2]*[2,4], map (d0,d1)->(d1,d0)."""
    return ITensorType((4, 2), (4, 2), (2, 4), AffineMap(2, (1, 0)), "float32")


def fig5_c() -> ITensorType:
    """Fig. 5(c): iteration [4,2,2]*[2,1,4], map (d0,d1,d2)->(d2,d0)."""
    return ITensorType((4, 2), (4, 2, 2), (2, 1, 4), AffineMap(3, (2, 0)), "float32")
