"""Model -> structured-op tracing (the Torch-MLIR/Linalg front-end analogue).

The paper enters at PyTorch and lowers to Linalg generic ops (Fig. 4).  Our
front-end is the ``ModelConfig``: ``trace_block`` emits the block's compute
graph as einsum-like ``LinalgOpSpec``s with named iteration dims, which the
tiling space (§5.1) tiles into dataflow kernels with itensor-typed ports.

Every assigned architecture family is covered:
  * dense / vlm / audio — (q|k|v|o) projections + attention + (Swi/Ge)GLU FFN
  * moe                 — router + top-k expert FFN (active-expert FLOPs)
  * hybrid (zamba2)     — Mamba2 chain (+ shared attention block every k)
  * ssm (rwkv6)         — time-mix (wkv recurrence) + channel-mix

Composite kernels (attention, ssm_scan, wkv) are deliberately kept as single
structured ops: their internals are the *kernel design* the paper delegates to
ADL/HLS (or, here, Pallas); StreamTensor's job is the inter-kernel dataflow.
"""

from __future__ import annotations

import math
from typing import List, Optional, Tuple

from ..configs.base import ModelConfig
from .tiling import PARALLEL, REDUCTION, LinalgOpSpec, LoopDim, OperandSpec


def _p(name: str, extent: int) -> LoopDim:
    return LoopDim(name, extent, PARALLEL)


def _r(name: str, extent: int) -> LoopDim:
    return LoopDim(name, extent, REDUCTION)


def _elementwise(name: str, op: str, t: int, d: int, src: Tuple[str, ...],
                 out: str, dtype: str, flops: float = 1.0,
                 dim_name: str = "d") -> LinalgOpSpec:
    loops = (_p("t", t), _p(dim_name, d))
    return LinalgOpSpec(
        name=name, op=op, loops=loops,
        inputs=tuple(OperandSpec(s, ("t", dim_name), dtype) for s in src),
        output=OperandSpec(out, ("t", dim_name), dtype),
        flops_per_point=flops)


def _matmul(name: str, t: int, n: int, k: int, src: str, weight: str,
            out: str, dtype: str, n_name: str = "n",
            k_name: str = "k") -> LinalgOpSpec:
    """out[t, n] = sum_k src[t, k] * W[k, n] — weight streamed from DRAM."""
    return LinalgOpSpec(
        name=name, op="matmul",
        loops=(_p("t", t), _p(n_name, n), _r(k_name, k)),
        inputs=(OperandSpec(src, ("t", k_name), dtype),
                OperandSpec(weight, (k_name, n_name), dtype, is_weight=True)),
        output=OperandSpec(out, ("t", n_name), dtype),
        flops_per_point=2.0)


def _norm(name: str, t: int, d: int, src: str, out: str,
          dtype: str) -> LinalgOpSpec:
    # Normalization is elementwise over (t, d) with an internal row reduction
    # (mean/var); the stream boundary is what matters to the dataflow level,
    # so flops_per_point folds the reduce+scale cost (~4 flops/elem).
    return _elementwise(name, "norm", t, d, (src,), out, dtype, flops=4.0)


# --------------------------------------------------------------------- #
# Family block tracers.  ``t`` = flattened tokens (batch * seq).
# --------------------------------------------------------------------- #

def _attention_ops(cfg: ModelConfig, t: int, s: int, pre: str, base: str,
                   dtype: str, sliding_window: int = 0) -> List[LinalgOpSpec]:
    """Attention sub-graph: q/k/v proj -> rope -> attention -> o proj.

    ``s`` is the key/value length attended per query (kv-cache length at
    decode, window size for local layers, seq length otherwise).
    """
    d, dq, dkv = cfg.d_model, cfg.q_dim, cfg.kv_dim
    eff_s = min(s, sliding_window) if sliding_window else s
    ops = [
        _matmul(f"{base}.q_proj", t, dq, d, pre, f"{base}.wq", f"{base}.q",
                dtype, n_name="dq"),
        _matmul(f"{base}.k_proj", t, dkv, d, pre, f"{base}.wk", f"{base}.k",
                dtype, n_name="dkv"),
        _matmul(f"{base}.v_proj", t, dkv, d, pre, f"{base}.wv", f"{base}.v",
                dtype, n_name="dkv"),
    ]
    if cfg.rope != "none":
        ops.append(_elementwise(f"{base}.rope_q", "rope", t, dq,
                                (f"{base}.q",), f"{base}.qr", dtype,
                                flops=4.0, dim_name="dq"))
        ops.append(_elementwise(f"{base}.rope_k", "rope", t, dkv,
                                (f"{base}.k",), f"{base}.kr", dtype,
                                flops=4.0, dim_name="dkv"))
        q_in, k_in = f"{base}.qr", f"{base}.kr"
    else:
        q_in, k_in = f"{base}.q", f"{base}.k"
    # Composite attention kernel: QK^T + softmax + AV.  Iteration space
    # (t, s_red, dq); ~4 MAC-flops per point covers both matmuls, plus the
    # softmax folded into the constant.
    #
    # K/V streaming legality: the projections emit [t, dkv] while attention
    # consumes [s, dq].  Only when the extents agree (full self-attention,
    # no GQA head broadcast) can K/V stream straight into the attention
    # kernel; at decode (s = cache length) or under GQA expansion, K/V
    # round-trip the HBM KV-cache — a DMA boundary, represented by unwired
    # tensor ids.  This matches the physical design: the cache IS external
    # memory (paper §5.3.5 'dynamic tensor shape' hints size it).
    stream_kv = (dkv == dq) and (eff_s == t)
    if stream_kv:
        k_att, v_att = k_in, f"{base}.v"
    else:
        k_att, v_att = f"{base}.k_cache", f"{base}.v_cache"
    ops.append(LinalgOpSpec(
        name=f"{base}.attention", op="attention",
        loops=(_p("t", t), _p("dq", dq), _r("s", max(1, eff_s))),
        inputs=(OperandSpec(q_in, ("t", "dq"), dtype),
                OperandSpec(k_att, ("s", "dq"), dtype),
                OperandSpec(v_att, ("s", "dq"), dtype)),
        output=OperandSpec(f"{base}.attn", ("t", "dq"), dtype),
        flops_per_point=4.2))
    ops.append(_matmul(f"{base}.o_proj", t, d, dq, f"{base}.attn",
                       f"{base}.wo", f"{base}.attn_out", dtype,
                       k_name="dq", n_name="d"))
    return ops


def _ffn_ops(cfg: ModelConfig, t: int, pre: str, base: str, dtype: str,
             d_ff: Optional[int] = None) -> List[LinalgOpSpec]:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    if cfg.gated_ffn:
        return [
            _matmul(f"{base}.gate_proj", t, f, d, pre, f"{base}.wg",
                    f"{base}.gate", dtype, n_name="f"),
            _matmul(f"{base}.up_proj", t, f, d, pre, f"{base}.wu",
                    f"{base}.up", dtype, n_name="f"),
            _elementwise(f"{base}.act_mul", "act_mul", t, f,
                         (f"{base}.gate", f"{base}.up"), f"{base}.act",
                         dtype, flops=3.0, dim_name="f"),
            _matmul(f"{base}.down_proj", t, d, f, f"{base}.act",
                    f"{base}.wd", f"{base}.ffn_out", dtype,
                    k_name="f", n_name="d"),
        ]
    return [
        _matmul(f"{base}.up_proj", t, f, d, pre, f"{base}.wu",
                f"{base}.up", dtype, n_name="f"),
        _elementwise(f"{base}.act", "act", t, f, (f"{base}.up",),
                     f"{base}.act", dtype, flops=2.0, dim_name="f"),
        _matmul(f"{base}.down_proj", t, d, f, f"{base}.act", f"{base}.wd",
                f"{base}.ffn_out", dtype, k_name="f", n_name="d"),
    ]


def _moe_ops(cfg: ModelConfig, t: int, pre: str, base: str,
             dtype: str) -> List[LinalgOpSpec]:
    d, f, e, k = cfg.d_model, cfg.d_ff, cfg.num_experts, cfg.top_k
    ops = [
        _matmul(f"{base}.router", t, e, d, pre, f"{base}.wr",
                f"{base}.route", dtype, n_name="e"),
        _elementwise(f"{base}.topk", "topk", t, e, (f"{base}.route",),
                     f"{base}.gates", dtype, flops=2.0, dim_name="e"),
    ]
    # Composite expert kernel: dispatch + top-k active expert GLU FFNs +
    # weighted combine.  Loops cover the full expert axis ``e`` (the weight
    # table's extent); flops_per_point is scaled by k/e so work counts only
    # the *active* experts (paper: T static; top-k fixes tokens per expert).
    glu_flops = (3 if cfg.gated_ffn else 2) * 2.0 * (k / e)
    ops.append(LinalgOpSpec(
        name=f"{base}.experts", op="moe_experts",
        loops=(_p("t", t), _p("d", d), _r("f", f), _r("e", e)),
        inputs=(OperandSpec(pre, ("t", "d"), dtype),
                OperandSpec(f"{base}.gates", ("t", "e"), dtype),
                OperandSpec(f"{base}.we", ("e", "f", "d"), dtype,
                            is_weight=True)),
        output=OperandSpec(f"{base}.ffn_out", ("t", "d"), dtype),
        flops_per_point=glu_flops))
    return ops


def _mamba_ops(cfg: ModelConfig, t: int, pre: str, base: str,
               dtype: str) -> List[LinalgOpSpec]:
    """Mamba2 chain, projections decomposed so every stream edge is typed
    (the fused in_proj would need ``itensor_chunk``; separate x/z/BCdt
    projections are the dataflow-native formulation)."""
    d, di = cfg.d_model, cfg.d_inner
    h, n = cfg.ssm_heads, cfg.ssm_state
    bcdt = 2 * h * n + h                  # B, C, dt widths concatenated
    # Real scan flops per (t, di) point ~ 6*n (dA, dB*x, C*h per state elem);
    # the bcdt reduction loop has extent 2hn+h, so scale per-point flops.
    scan_fpp = 6.0 * n / bcdt
    ops = [
        _matmul(f"{base}.x_proj", t, di, d, pre, f"{base}.wx",
                f"{base}.x", dtype, n_name="di"),
        _matmul(f"{base}.z_proj", t, di, d, pre, f"{base}.wz",
                f"{base}.z", dtype, n_name="di"),
        _matmul(f"{base}.bcdt_proj", t, bcdt, d, pre, f"{base}.wbcdt",
                f"{base}.bcdt", dtype, n_name="bcn"),
        _elementwise(f"{base}.conv", "conv1d", t, di, (f"{base}.x",),
                     f"{base}.xconv", dtype, flops=2.0 * cfg.conv_width,
                     dim_name="di"),
        # Composite chunked state-space scan: per head, state [n x hd]
        # updated per token (dA/dBx/Ch work folded into scan_fpp).
        LinalgOpSpec(
            name=f"{base}.ssm_scan", op="ssm_scan",
            loops=(_p("t", t), _p("di", di), _r("bcn", bcdt)),
            inputs=(OperandSpec(f"{base}.xconv", ("t", "di"), dtype),
                    OperandSpec(f"{base}.bcdt", ("t", "bcn"), dtype)),
            output=OperandSpec(f"{base}.ssm", ("t", "di"), dtype),
            flops_per_point=scan_fpp),
        _elementwise(f"{base}.gate", "act_mul", t, di,
                     (f"{base}.ssm", f"{base}.z"), f"{base}.gated",
                     dtype, flops=3.0, dim_name="di"),
        _matmul(f"{base}.out_proj", t, d, di, f"{base}.gated",
                f"{base}.wout", f"{base}.ffn_out", dtype, k_name="di",
                n_name="d"),
    ]
    return ops


def _rwkv_ops(cfg: ModelConfig, t: int, pre: str, base: str,
              dtype: str) -> List[LinalgOpSpec]:
    """RWKV6 time-mix + channel-mix, r/k/v/g/w projections decomposed so
    every stream edge is typed (no itensor_chunk needed)."""
    d, f = cfg.d_model, cfg.d_ff
    ops = [
        _matmul(f"{base}.{nm}_proj", t, d, d, pre, f"{base}.w{nm}",
                f"{base}.{nm}", dtype, n_name="dm")
        for nm in ("r", "k", "v", "w")
    ]
    ops += [
        _matmul(f"{base}.g_proj", t, d, d, pre, f"{base}.wgm",
                f"{base}.g", dtype, n_name="dm"),
        # wkv6 recurrence: per head, state [hd x hd] with data-dependent
        # decay; iteration (t, d) with hd-deep inner reduction.
        LinalgOpSpec(
            name=f"{base}.wkv", op="wkv6",
            loops=(_p("t", t), _p("d", d), _r("hd", cfg.rwkv_head_dim)),
            inputs=(OperandSpec(f"{base}.r", ("t", "d"), dtype),
                    OperandSpec(f"{base}.k", ("t", "d"), dtype),
                    OperandSpec(f"{base}.v", ("t", "d"), dtype),
                    OperandSpec(f"{base}.w", ("t", "d"), dtype)),
            output=OperandSpec(f"{base}.wkv_raw", ("t", "d"), dtype),
            flops_per_point=8.0),
        _elementwise(f"{base}.out_gate", "act_mul", t, d,
                     (f"{base}.wkv_raw", f"{base}.g"), f"{base}.wkv_out",
                     dtype, flops=3.0),
        _matmul(f"{base}.out_proj", t, d, d, f"{base}.wkv_out",
                f"{base}.wo", f"{base}.attn_out", dtype, k_name="dk",
                n_name="d"),
        # Channel mix.
        _norm(f"{base}.ln2", t, d, f"{base}.attn_out", f"{base}.cm_in",
              dtype),
        _matmul(f"{base}.cm_k", t, f, d, f"{base}.cm_in", f"{base}.wk",
                f"{base}.cm_kx", dtype, n_name="f"),
        _elementwise(f"{base}.cm_act", "act", t, f, (f"{base}.cm_kx",),
                     f"{base}.cm_act_o", dtype, flops=2.0, dim_name="f"),
        _matmul(f"{base}.cm_v", t, d, f, f"{base}.cm_act_o", f"{base}.wv",
                f"{base}.ffn_out", dtype, k_name="f", n_name="d"),
    ]
    return ops


# --------------------------------------------------------------------- #
# Public entry points
# --------------------------------------------------------------------- #

def trace_block(cfg: ModelConfig, *, tokens: int, kv_len: Optional[int] = None,
                layer_index: int = 0) -> List[LinalgOpSpec]:
    """Trace one transformer block into structured ops.

    Args:
        cfg: architecture config.
        tokens: flattened query tokens (batch * seq).
        kv_len: keys/values attended per query (defaults to ``tokens``);
            pass the cache length for decode shapes.
        layer_index: which layer of the pattern (local vs global, shared-attn
            boundary, ...).
    """
    dtype = cfg.dtype
    kv = kv_len if kv_len is not None else tokens
    kind = cfg.layer_kind(layer_index)
    base = f"L{layer_index}"
    ops: List[LinalgOpSpec] = []

    if kind == "rwkv":
        ops.append(_norm(f"{base}.ln1", tokens, cfg.d_model,
                         "x_in", f"{base}.pre", dtype))
        ops += _rwkv_ops(cfg, tokens, f"{base}.pre", base, dtype)
        ops.append(_elementwise(f"{base}.resid", "add", tokens, cfg.d_model,
                                ("x_in", f"{base}.ffn_out"), "x_out", dtype))
        return ops

    if kind.startswith("mamba"):
        ops.append(_norm(f"{base}.ln1", tokens, cfg.d_model, "x_in",
                         f"{base}.pre", dtype))
        ops += _mamba_ops(cfg, tokens, f"{base}.pre", base, dtype)
        out_src = f"{base}.ffn_out"
        if kind == "mamba+shared_attn":
            sa = f"{base}.shared"
            ops.append(_norm(f"{sa}.ln", tokens, cfg.d_model, out_src,
                             f"{sa}.pre", dtype))
            ops += _attention_ops(cfg, tokens, kv, f"{sa}.pre", sa, dtype)
            ops += _ffn_ops(cfg, tokens, f"{sa}.attn_out", sa + ".mlp", dtype)
            out_src = f"{sa}.mlp.ffn_out"
        ops.append(_elementwise(f"{base}.resid", "add", tokens, cfg.d_model,
                                ("x_in", out_src), "x_out", dtype))
        return ops

    # Attention families (dense / vlm / audio / moe / local / global).
    window = cfg.sliding_window if kind == "local_attn" else 0
    ops.append(_norm(f"{base}.ln1", tokens, cfg.d_model, "x_in",
                     f"{base}.pre1", dtype))
    ops += _attention_ops(cfg, tokens, kv, f"{base}.pre1", base, dtype,
                          sliding_window=window)
    ops.append(_elementwise(f"{base}.resid1", "add", tokens, cfg.d_model,
                            ("x_in", f"{base}.attn_out"), f"{base}.h1",
                            dtype))
    ops.append(_norm(f"{base}.ln2", tokens, cfg.d_model, f"{base}.h1",
                     f"{base}.pre2", dtype))
    if cfg.is_moe:
        ops += _moe_ops(cfg, tokens, f"{base}.pre2", base + ".moe", dtype)
        ffn_out = f"{base}.moe.ffn_out"
    else:
        ops += _ffn_ops(cfg, tokens, f"{base}.pre2", base + ".mlp", dtype)
        ffn_out = f"{base}.mlp.ffn_out"
    ops.append(_elementwise(f"{base}.resid2", "add", tokens, cfg.d_model,
                            (f"{base}.h1", ffn_out), "x_out", dtype))
    return ops


def trace_lm_head(cfg: ModelConfig, tokens: int) -> List[LinalgOpSpec]:
    """Final norm + LM head projection (streamed over vocab tiles)."""
    dtype = cfg.dtype
    return [
        _norm("final.ln", tokens, cfg.d_model, "x_in", "final.pre", dtype),
        _matmul("final.lm_head", tokens, cfg.vocab_size, cfg.d_model,
                "final.pre", "final.wemb", "logits", dtype, n_name="v"),
    ]


def block_flops(cfg: ModelConfig, tokens: int,
                kv_len: Optional[int] = None) -> float:
    return sum(op.work_flops
               for op in trace_block(cfg, tokens=tokens, kv_len=kv_len))
