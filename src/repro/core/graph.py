"""Dataflow graph IR — kernels connected by itensor-typed streams.

This is the Python twin of the paper's MLIR dataflow dialect (§3.2): nodes are
``kernel`` ops (each containing one logical task), edges carry the producer's
output itensor type and the consumer's expected input itensor type, and all
dataflow components (converters, DMAs, FIFOs) are derived from those types.

Graph storage uses ``networkx.MultiDiGraph`` so that two distinct operands
between the same kernel pair stay distinct edges — Algorithm 2 indexes
``G.edges[p, n, 0]`` for exactly this reason.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Dict, Iterable, Iterator, List, Optional, Tuple

import networkx as nx

from .converter import ConverterSpec, conversion_cost_bytes, infer_converter
from .itensor import ITensorType


@dataclass(frozen=True)
class KernelTiming:
    """Profiled/modelled kernel metrics (paper §5.3.1).

    All quantities are in cycles of the target platform clock.

    Attributes:
        initial_delay: ``D`` — cycles from kernel start to its first output
            token.
        pipeline_ii: ``II`` — cycles between consecutive output tokens.
        latency: ``L`` — total execution latency.  Defaults to the pipelined
            form ``D + (T-1) * II`` when constructed via ``from_tokens``.
    """

    initial_delay: float
    pipeline_ii: float
    latency: float

    @staticmethod
    def from_tokens(initial_delay: float, pipeline_ii: float,
                    num_tokens: int) -> "KernelTiming":
        return KernelTiming(
            initial_delay=initial_delay,
            pipeline_ii=pipeline_ii,
            latency=initial_delay + max(0, num_tokens - 1) * pipeline_ii,
        )

    def with_ii(self, ii: float, num_tokens: int) -> "KernelTiming":
        return KernelTiming.from_tokens(self.initial_delay, ii, num_tokens)


@dataclass
class KernelNode:
    """A dataflow kernel (paper Fig. 1 'Kernel').

    Attributes:
        name: unique id.
        op: operator kind ("matmul", "elementwise", "softmax", ...).
        out_type: itensor type of the (single) output stream.
        in_types: itensor types expected on each input port.
        timing: (L, D, II) model; filled by the platform model.
        work_flops: arithmetic work, for the latency model / roofline.
        weight_bytes: resident parameter bytes streamed from external memory.
        local_bytes: on-chip buffer footprint of the kernel itself
            (accumulators, line buffers), excluding converters/FIFOs.
        tags: free-form annotations (e.g. source linalg op, tiling record).
    """

    name: str
    op: str
    out_type: ITensorType
    in_types: Tuple[ITensorType, ...] = ()
    timing: Optional[KernelTiming] = None
    work_flops: float = 0.0
    weight_bytes: float = 0.0
    local_bytes: float = 0.0
    tags: Dict[str, object] = field(default_factory=dict)

    @property
    def num_out_tokens(self) -> int:
        return self.out_type.num_tokens


class DataflowGraph:
    """Kernel graph with itensor-typed edges."""

    def __init__(self) -> None:
        self.g = nx.MultiDiGraph()

    # ------------------------------------------------------------- build
    def add_kernel(self, node: KernelNode) -> KernelNode:
        if node.name in self.g:
            raise ValueError(f"duplicate kernel {node.name}")
        self.g.add_node(node.name, kernel=node)
        return node

    def connect(self, producer: str, consumer: str, *,
                src_type: Optional[ITensorType] = None,
                dst_type: Optional[ITensorType] = None,
                operand: int = 0) -> None:
        """Add a stream edge; types default to the endpoints' port types."""
        p, c = self.kernel(producer), self.kernel(consumer)
        s = src_type or p.out_type
        d = dst_type
        if d is None:
            d = c.in_types[operand] if operand < len(c.in_types) else s
        if s.data_shape != d.data_shape:
            raise ValueError(
                f"edge {producer}->{consumer}: data space {s.data_shape} vs "
                f"{d.data_shape}")
        self.g.add_edge(producer, consumer, src_type=s, dst_type=d,
                        operand=operand)

    # ------------------------------------------------------------ access
    def kernel(self, name: str) -> KernelNode:
        return self.g.nodes[name]["kernel"]

    def kernels(self) -> Iterator[KernelNode]:
        for n in self.g.nodes:
            yield self.kernel(n)

    def topo_order(self) -> List[str]:
        return list(nx.topological_sort(self.g))

    def edges(self) -> Iterator[Tuple[str, str, int, dict]]:
        yield from self.g.edges(keys=True, data=True)

    def predecessors(self, name: str) -> List[str]:
        return list(self.g.predecessors(name))

    def successors(self, name: str) -> List[str]:
        return list(self.g.successors(name))

    @property
    def num_kernels(self) -> int:
        return self.g.number_of_nodes()

    # -------------------------------------------------------- analyses
    def edge_converter(self, u: str, v: str, key: int = 0) -> Optional[ConverterSpec]:
        data = self.g.edges[u, v, key]
        return infer_converter(data["src_type"], data["dst_type"])

    def edge_memory_cost(self, u: str, v: str, key: int = 0) -> float:
        """On-chip bytes to stream-fuse across this edge.

        converter ping-pong bytes (0 on matching types) + a minimal
        depth-2 FIFO of one token (re-sized later by fifo_sizing).
        """
        data = self.g.edges[u, v, key]
        conv = conversion_cost_bytes(data["src_type"], data["dst_type"])
        fifo = 2.0 * data["src_type"].token_bytes
        return conv + fifo

    def intermediate_bytes_unfused(self) -> float:
        """External-memory intermediate footprint with *no* fusion.

        Every internal edge materializes its full tensor in memory — the
        baseline of the paper's Fig. 10a memory-reduction study.
        """
        total = 0.0
        for u, v, k, data in self.edges():
            total += data["src_type"].data_bytes
        return total

    def intermediate_bytes_fused(self, fusion_index: Dict[str, int]) -> float:
        """On-chip streaming footprint after fusion: converters + min FIFOs
        for intra-group edges; inter-group edges still hit external memory and
        are excluded (they are counted by the caller as DMA traffic)."""
        total = 0.0
        for u, v, k, data in self.edges():
            if fusion_index.get(u) == fusion_index.get(v):
                total += self.edge_memory_cost(u, v, k)
        return total

    def total_work_flops(self) -> float:
        return sum(k.work_flops for k in self.kernels())

    def total_weight_bytes(self) -> float:
        return sum(k.weight_bytes for k in self.kernels())

    def validate(self) -> None:
        if not nx.is_directed_acyclic_graph(self.g):
            raise ValueError("dataflow graph must be a DAG")
        for u, v, k, data in self.edges():
            s, d = data["src_type"], data["dst_type"]
            if s.dtype != d.dtype:
                raise ValueError(f"edge {u}->{v}: dtype {s.dtype} vs {d.dtype}")

    def __repr__(self) -> str:
        return (f"DataflowGraph({self.g.number_of_nodes()} kernels, "
                f"{self.g.number_of_edges()} streams)")
