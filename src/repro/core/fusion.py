"""Dataflow kernel fusion exploration — paper §5.2.2, Algorithm 2.

Fusion enables on-chip streaming between kernels.  The itensor type system
makes *any* producer/consumer pair fuseable by design — at the on-chip memory
cost of a layout converter when types mismatch (Algorithm 1).  Given the cost
of every edge, Algorithm 2 greedily partitions the kernel graph, in
topological order, into fusion groups whose accumulated cost stays below
``c_max`` (the single-device on-chip budget: BRAM+URAM on the paper's FPGA,
VMEM on our TPU target).

The algorithm is reproduced faithfully, including the sentinel empty group at
index 0 and the fuse-with-the-*nearest*-candidate rule (``max(cand.keys())`` —
the most recently opened group among the predecessors' groups).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple

from .graph import DataflowGraph

CostFn = Callable[[DataflowGraph, str, str, int], float]


def _default_edge_cost(graph: DataflowGraph, u: str, v: str, key: int) -> float:
    return graph.edge_memory_cost(u, v, key)


@dataclass
class FusionPlan:
    """Result of fusion exploration.

    Attributes:
        groups: list of kernel-name sets; ``groups[i]`` is fusion group ``i``.
            (The paper's sentinel empty set is removed.)
        costs: on-chip memory cost accumulated by each group.
        index: kernel name -> group index.
    """

    groups: List[Set[str]]
    costs: List[float]
    index: Dict[str, int]

    @property
    def num_groups(self) -> int:
        return len(self.groups)

    def group_of(self, name: str) -> int:
        return self.index[name]

    def intra_edges(self, graph: DataflowGraph) -> List[Tuple[str, str, int]]:
        out = []
        for u, v, k, _ in graph.edges():
            if self.index[u] == self.index[v]:
                out.append((u, v, k))
        return out

    def inter_edges(self, graph: DataflowGraph) -> List[Tuple[str, str, int]]:
        out = []
        for u, v, k, _ in graph.edges():
            if self.index[u] != self.index[v]:
                out.append((u, v, k))
        return out

    def external_bytes(self, graph: DataflowGraph) -> float:
        """External-memory traffic crossing group boundaries (DMA tensors)."""
        return sum(graph.g.edges[u, v, k]["src_type"].data_bytes
                   for u, v, k in self.inter_edges(graph))


def explore_fusion(
    graph: DataflowGraph,
    c_max: float,
    edge_cost: CostFn = _default_edge_cost,
    node_cost: Optional[Callable[[DataflowGraph, str], float]] = None,
) -> FusionPlan:
    """Algorithm 2 (paper §5.2.2), faithful reproduction.

    Args:
        graph: the kernel dataflow graph.
        c_max: maximum on-chip memory one fused kernel may use.
        edge_cost: ``compute_memory_cost`` — converter + FIFO bytes of fusing
            across an edge (defaults to the Algorithm-1-based cost).
        node_cost: optional extension beyond the paper — adds each kernel's own
            on-chip footprint to its group's budget.  ``None`` reproduces the
            paper exactly (edge costs only).
    """
    F: List[Set[str]] = [set()]   # sentinel empty fusion, as in the paper
    C: List[float] = [0.0]
    M: Dict[str, int] = {}

    for n in graph.topo_order():
        cand: Dict[int, float] = {}
        for p in graph.predecessors(n):
            # Sum cost over all parallel operand edges p -> n.
            for key in graph.g[p][n]:
                cost = edge_cost(graph, p, n, key)
                cand[M[p]] = cand.get(M[p], 0.0) + cost

        f_idx, f_cost = len(F), 0.0
        if cand:
            f_idx = max(cand.keys())          # fuse with the nearest candidate
            f_cost = cand[f_idx]
        extra = node_cost(graph, n) if node_cost else 0.0

        if f_idx == len(F) or f_cost + extra + C[f_idx] > c_max:
            F.append({n})
            C.append(extra)
            M[n] = len(F) - 1
        else:
            F[f_idx].add(n)
            C[f_idx] += f_cost + extra
            M[n] = f_idx
        graph.g.nodes[n]["fusion_index"] = M[n]

    # Drop the sentinel and renumber densely.
    keep = [i for i, s in enumerate(F) if s]
    renum = {old: new for new, old in enumerate(keep)}
    groups = [F[i] for i in keep]
    costs = [C[i] for i in keep]
    index = {n: renum[i] for n, i in M.items()}
    for n, i in index.items():
        graph.g.nodes[n]["fusion_index"] = i
    return FusionPlan(groups=groups, costs=costs, index=index)


def fusion_memory_report(graph: DataflowGraph, plan: FusionPlan) -> Dict[str, float]:
    """Before/after on-chip memory for the Fig. 10a study.

    'Before' = every intermediate result held in a full on-chip buffer (the
    only way to run fully on-chip without streaming fusion).  'After' =
    converters + FIFOs of the fused design.
    """
    before = graph.intermediate_bytes_unfused()
    after = graph.intermediate_bytes_fused(plan.index)
    return {
        "before_bytes": before,
        "after_bytes": after,
        "ratio": after / before if before else 0.0,
        "num_groups": plan.num_groups,
        "external_bytes": plan.external_bytes(graph),
    }
