"""Multi-die / multi-stage graph partitioning — paper §5.3(2).

The paper assigns dataflow tasks to FPGA dies with an ILP minimizing
inter-die communication and resource imbalance.  No ILP solver ships offline,
so we solve the identical objective with greedy topological seeding plus
Kernighan-Lin-style local search; tests check optimality against brute force
on small graphs.  On the TPU target the same partitioner assigns fusion
groups to pipeline stages / mesh slices.
"""

from __future__ import annotations

import itertools
import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from .graph import DataflowGraph


@dataclass
class PartitionResult:
    assignment: Dict[str, int]          # kernel -> die/stage index
    num_dies: int
    cut_bytes: float                    # inter-die stream traffic
    loads: List[float]                  # per-die resource load
    objective: float

    @property
    def imbalance(self) -> float:
        if not self.loads or max(self.loads) == 0:
            return 0.0
        return (max(self.loads) - min(self.loads)) / max(self.loads)


def _edge_bytes(graph: DataflowGraph, u: str, v: str, k: int) -> float:
    return graph.g.edges[u, v, k]["src_type"].total_bytes


def _node_load(graph: DataflowGraph, n: str) -> float:
    node = graph.kernel(n)
    return node.local_bytes + node.weight_bytes * 0.0 + max(1.0, node.work_flops)


def evaluate(graph: DataflowGraph, assignment: Dict[str, int], num_dies: int,
             alpha: float = 1.0, beta: float = 1.0) -> PartitionResult:
    """Objective = alpha * cut_bytes + beta * imbalance_penalty (paper's ILP
    objective: minimize inter-die communication and resource imbalance)."""
    cut = 0.0
    for u, v, k, _ in graph.edges():
        if assignment[u] != assignment[v]:
            cut += _edge_bytes(graph, u, v, k)
    loads = [0.0] * num_dies
    for n in graph.g.nodes:
        loads[assignment[n]] += _node_load(graph, n)
    mean = sum(loads) / num_dies if num_dies else 0.0
    imbalance = sum((l - mean) ** 2 for l in loads) ** 0.5
    obj = alpha * cut + beta * imbalance
    return PartitionResult(assignment=dict(assignment), num_dies=num_dies,
                           cut_bytes=cut, loads=loads, objective=obj)


def partition(graph: DataflowGraph, num_dies: int,
              alpha: float = 1.0, beta: float = 1.0,
              max_passes: int = 8) -> PartitionResult:
    """Greedy topological seeding + single-move local search."""
    order = graph.topo_order()
    if num_dies <= 1:
        return evaluate(graph, {n: 0 for n in order}, max(1, num_dies),
                        alpha, beta)
    total = sum(_node_load(graph, n) for n in order)
    target = total / num_dies
    # Seed: contiguous topological chunks of ~equal load (streams stay local).
    assignment: Dict[str, int] = {}
    die, acc = 0, 0.0
    for n in order:
        assignment[n] = die
        acc += _node_load(graph, n)
        if acc >= target and die < num_dies - 1:
            die += 1
            acc = 0.0
    best = evaluate(graph, assignment, num_dies, alpha, beta)
    # Local search: move single kernels between dies while it helps.
    for _ in range(max_passes):
        improved = False
        for n in order:
            cur = best.assignment[n]
            for d in range(num_dies):
                if d == cur:
                    continue
                trial = dict(best.assignment)
                trial[n] = d
                cand = evaluate(graph, trial, num_dies, alpha, beta)
                if cand.objective + 1e-9 < best.objective:
                    best = cand
                    improved = True
        if not improved:
            break
    return best


def brute_force(graph: DataflowGraph, num_dies: int,
                alpha: float = 1.0, beta: float = 1.0) -> PartitionResult:
    """Exact optimum by enumeration — test reference for small graphs."""
    nodes = list(graph.g.nodes)
    if len(nodes) > 10:
        raise ValueError("brute force limited to <=10 kernels")
    best: Optional[PartitionResult] = None
    for combo in itertools.product(range(num_dies), repeat=len(nodes)):
        cand = evaluate(graph, dict(zip(nodes, combo)), num_dies, alpha, beta)
        if best is None or cand.objective < best.objective:
            best = cand
    assert best is not None
    return best
