"""StreamPlan — the compiler-to-runtime bridge (DSE decisions drive execution).

Everything upstream of this module is *analysis*: ``trace.py`` turns a
``ModelConfig`` block into structured ops, ``tiling.py``/``dse.py`` explore
tile sizes and unroll with fusion feedback, ``fusion.py`` groups kernels
under the on-chip budget, and ``lowering.py`` names a Pallas implementation
per fusion group.  A ``StreamPlan`` closes the loop: it runs that pipeline
and emits, per layer *kind* (attn / local_attn / mamba / rwkv / ...), the
concrete kernel choice and block sizes the executable model should use —
``models/model.py`` consults the plan at trace time and dispatches to the
fused Pallas kernels instead of the eager jnp path.

Stage mapping (DESIGN.md §StreamPlan):

  * ``qkv``       — ln1 + Q/K/V projections.  Fused (``rmsnorm_matmul``)
    when the fusion pass put ``ln1`` and ``q_proj`` in the same group and
    the norm is RMSNorm; plain ``block_matmul`` when only the projections
    fused; eager otherwise.
  * ``attention`` — the composite attention op.  ``flash_attention`` when
    its group lowered to a Pallas-backed pattern (full-sequence; a flash
    grid is degenerate at Sq=1).
  * ``decode_attn`` — single-token attention against the paged KV cache.
    ``paged_attention`` (kernels/paged_attention.py: K/V pages streamed
    through the page-table indirection with an online softmax) whenever
    the attention group lowered to a Pallas pattern; its page size is the
    *raw* DSE tile of the attention op's KV dim — pages are HBM streaming
    granules, not MXU operands, so the 128-lane floor does not apply.
  * ``ffn``       — ln2 + MLP.  ``streamed_ffn`` (gated) / ``streamed_mlp``
    (ungated) / ``moe_experts``; the norm is folded into the kernel when
    fusion grouped it with the projections and the norm is RMSNorm.
  * ``mixer``     — the composite sequence mixer (``mamba2_scan`` /
    ``rwkv6_wkv``) for SSM families.
  * ``lm_head``   — final norm + LM head + loss.  ``streamed_xent`` streams
    vocab tiles through an online logsumexp so [T, V] logits never exist;
    chosen for training (the loss consumer is invisible to the block-level
    trace, so the choice is made here, not in the pattern registry).

Block sizes: the DSE's ``default_tile_size`` lattice is sized for the
paper's FPGA fabric (16..256); TPU Pallas kernels want MXU/lane-aligned
tiles, so plan blocks are ``max(dse_tile, 128)`` used as *targets* — every
kernel wrapper clips to the largest aligned divisor of the actual extent
(``kernels/common.pick_block``), which also keeps smoke-sized shapes legal.

Sharding dimension (DESIGN.md §9): built against a mesh, the plan also
decides, per stage, which mesh axes the kernel's block grid shards over —
derived from the same logical-axis rules the parameter shardings use
(``distributed/sharding.spec_for``), with the same quantum-aware
divisibility fallbacks to replication (never to eager).  The decision is
recorded on each ``KernelChoice`` as ``sharding`` — (grid_dim, mesh_axis)
claims the fused wrappers in ``models/layers.py`` turn into ``shard_map``
specs — and feature-dim block targets are clipped to the *post-shard*
extents so DSE tiles reflect what one shard actually streams.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, replace
from typing import Dict, Optional, Tuple

from ..configs.base import ModelConfig
from .dse import evaluate_trial
from .graph import DataflowGraph
from .lowering import CompiledDataflow, compile_model, lower_groups
from .partition import partition
from .platforms import Platform, TPU_V5E
from .trace import trace_lm_head

LANE = 128      # TPU vreg lane width: Pallas block-size floor

Blocks = Tuple[Tuple[str, int], ...]
# (grid_dim, mesh_axis_or_group) claims — the value is a single axis name
# or a tuple of names (batch over ('pod', 'data') on a multi-pod mesh).
Sharding = Tuple[Tuple[str, object], ...]


@dataclass(frozen=True)
class KernelChoice:
    """One stage's implementation + Pallas block-size targets + the mesh
    axes its block grid shards over (empty = replicate / single-device).

    ``source`` records the cost provenance of the block choice:
    ``"analytic"`` when the blocks came from the DSE's modeled objective
    (or an interpret-mode surrogate fill), ``"measured"`` when the
    autotuner picked them from wall-clock kernel timings (DESIGN.md §16).
    """
    implementation: str          # kernel name in repro.kernels, or "eager"
    blocks: Blocks = ()
    sharding: Sharding = ()
    source: str = "analytic"     # "analytic" | "measured"

    @property
    def fused(self) -> bool:
        return self.implementation != "eager"

    @property
    def kw(self) -> Dict[str, object]:
        """Block sizes (plus the sharding claim) as wrapper kwargs."""
        d: Dict[str, object] = dict(self.blocks)
        if self.sharding:
            d["shard"] = self.sharding
        return d

    def block(self, name: str, default: int = 0) -> int:
        """One named block-size target (``0``/default when absent) — the
        static-analysis accessor (analysis/ reconstructs itensor types
        from these without consulting the wrappers)."""
        return int(dict(self.blocks).get(name, default))

    def claim(self, dim: str):
        """Mesh axis (or axis group) claimed for ``dim``; None when the
        dim is unclaimed (replicated)."""
        return dict(self.sharding).get(dim)


EAGER = KernelChoice("eager")

# Stage slots every LayerPlan carries, in pipeline order — the order the
# itensor pass walks producer/consumer pairs in.
STAGES = ("qkv", "attention", "decode_attn", "verify_attn", "ffn", "mixer")


@dataclass(frozen=True)
class LayerPlan:
    """Kernel choices for one layer kind."""
    kind: str
    qkv: KernelChoice = EAGER        # ln1 + Q/K/V projections
    attention: KernelChoice = EAGER  # full-sequence attention
    decode_attn: KernelChoice = EAGER  # single-token paged attention
    verify_attn: KernelChoice = EAGER  # W-token speculative verify window
    ffn: KernelChoice = EAGER        # ln2 + MLP / MoE
    mixer: KernelChoice = EAGER      # ssm_scan / wkv composite

    @property
    def any_fused(self) -> bool:
        return any(c.fused for c in
                   (self.qkv, self.attention, self.decode_attn,
                    self.verify_attn, self.ffn, self.mixer))

    def stages(self):
        """Yield ``(stage_name, KernelChoice)`` in pipeline order."""
        for name in STAGES:
            yield name, getattr(self, name)


@dataclass(frozen=True)
class StreamPlan:
    """Concrete per-layer kernel choices for one (config, shape) pair."""
    arch: str
    tokens: int
    kv_len: int
    platform: str
    default_tile_size: int
    overall_unroll_size: int
    layers: Tuple[Tuple[str, LayerPlan], ...]   # kind -> plan
    quant: str = "none"          # the QuantMode the plan was built under
    lm_head: KernelChoice = EAGER
    modeled_latency_s: float = 0.0
    fusion_groups: int = 0
    implementations: Tuple[str, ...] = ()
    mesh_axes: Tuple[Tuple[str, int], ...] = ()   # mesh the plan targets
    # Static-verification record (analysis/verify.py): None = never
    # verified; the engine attaches the result via ``with_verification``.
    verified: Optional[bool] = None
    diagnostics: Tuple[str, ...] = ()
    # Plan-level cost provenance (DESIGN.md §16): "analytic" (pure DSE),
    # "measured" (every tuned stage scored by wall-clock measurement), or
    # "hybrid" (tuned, with analytic fills — e.g. deviceless CI).
    cost_source: str = "analytic"

    def layer(self, kind: str) -> LayerPlan:
        for k, lp in self.layers:
            if k == kind:
                return lp
        return LayerPlan(kind=kind)

    def decode_page_size(self, default: int = 16) -> int:
        """KV page size the paged decode cache should use — the DSE tile
        the plan's paged-attention choice carries (the stream granularity
        the compiler chose for the KV dim), or ``default`` when no layer
        plans a paged decode stage."""
        for _, lp in self.layers:
            if lp.decode_attn.fused:
                return lp.decode_attn.kw.get("page_size", default)
        return default

    def verify_window(self, draft_len: int) -> int:
        """Speculative verify-window rows (pending token + drafts) for a
        requested draft length — the window the ``verify_attn`` stage
        should score per dispatch.  Clamped to the decode KV page granule
        the compiler chose: a window never spans more than one page of
        fresh K/V, so a verify dispatch touches at most one page boundary
        and a rejected draft rolls back at most one freshly-opened page.
        The engine quantizes the result onto its power-of-two decode
        block ladder to cap compiled-program count."""
        return max(2, min(int(draft_len) + 1, self.decode_page_size()))

    def prefill_chunk_size(self, page_size: int, default: int = 128) -> int:
        """Chunked-prefill granule: the tile the DSE chose for the
        attention op's QUERY stream (``block_q``), rounded UP to a whole
        number of KV pages so chunk boundaries always land on page
        boundaries — the compiler's tile choice governs prefill
        granularity exactly as it governs the decode page size.  Falls
        back to ``default`` (then page-aligned) when no layer fused
        attention."""
        base = default
        for _, lp in self.layers:
            if lp.attention.fused:
                base = int(lp.attention.kw.get("block_q", default))
                break
        ps = max(1, int(page_size))
        return max(1, -(-int(base) // ps)) * ps

    def stage_choices(self):
        """Yield every stage's ``(owner, stage_name, KernelChoice)`` —
        layer stages plus the LM head — the iteration surface the
        analysis passes walk (``owner`` is the layer kind, or "final")."""
        for kind, lp in self.layers:
            for stage, choice in lp.stages():
                yield kind, stage, choice
        yield "final", "lm_head", self.lm_head

    def with_verification(self, verified: bool,
                          diagnostics: Tuple[str, ...]) -> "StreamPlan":
        """Copy of the plan carrying a verification verdict (the engine
        attaches this after running ``analysis.verify_plan``)."""
        return replace(self, verified=bool(verified),
                       diagnostics=tuple(diagnostics))

    def with_stage(self, owner: str, stage: str,
                   choice: KernelChoice) -> "StreamPlan":
        """Copy of the plan with ONE stage's choice replaced — the
        autotuner's candidate-swap primitive (``owner`` is the layer
        kind, or "final" for the LM head), addressing the same
        (owner, stage) pairs ``stage_choices`` yields."""
        if owner == "final" and stage == "lm_head":
            return replace(self, lm_head=choice)
        if stage not in STAGES:
            raise ValueError(f"unknown stage {stage!r} (have {STAGES})")
        if not any(k == owner for k, _ in self.layers):
            raise ValueError(f"plan has no layer kind {owner!r}")
        layers = tuple(
            (k, replace(lp, **{stage: choice}) if k == owner else lp)
            for k, lp in self.layers)
        return replace(self, layers=layers)

    def summary(self) -> Dict[str, object]:
        return {
            "arch": self.arch,
            "quant": self.quant,
            "tokens": self.tokens,
            "kv_len": self.kv_len,
            "tile": self.default_tile_size,
            "unroll": self.overall_unroll_size,
            "fusion_groups": self.fusion_groups,
            "modeled_latency_s": self.modeled_latency_s,
            "mesh": dict(self.mesh_axes),
            "stages": {
                kind: {"qkv": lp.qkv.implementation,
                       "attention": lp.attention.implementation,
                       "decode_attn": lp.decode_attn.implementation,
                       "verify_attn": lp.verify_attn.implementation,
                       "ffn": lp.ffn.implementation,
                       "mixer": lp.mixer.implementation}
                for kind, lp in self.layers
            },
            "sharding": {
                kind: {stage: dict(getattr(lp, stage).sharding)
                       for stage in ("qkv", "attention", "decode_attn",
                                     "verify_attn", "ffn", "mixer")
                       if getattr(lp, stage).sharding}
                for kind, lp in self.layers
            },
            "lm_head": self.lm_head.implementation,
            "lm_head_sharding": dict(self.lm_head.sharding),
            "verified": self.verified,
            "diagnostics": list(self.diagnostics),
            # Cost provenance (DESIGN.md §16): the plan-level source plus
            # every stage whose blocks came from measurements.
            "plan_source": self.cost_source,
            "stage_sources": {
                f"{kind}.{stage}": choice.source
                for kind, stage, choice in self.stage_choices()
                if choice.fused and choice.source != "analytic"
            },
        }


# --------------------------------------------------------------------- #
# Builder
# --------------------------------------------------------------------- #

def _pallas_block(tile: int) -> int:
    """DSE tile -> Pallas block-size target (lane-aligned floor)."""
    return max(int(tile), LANE)


def _tile(graph: DataflowGraph, kernel: str, dim: str,
          default: int = LANE) -> int:
    try:
        dec = graph.kernel(kernel).tags["decision"]
    except KeyError:
        return default
    return _pallas_block(dec.tile_sizes.get(dim, default))


def _raw_tile(graph: DataflowGraph, kernel: str, dim: str,
              default: int = 16) -> int:
    """DSE tile WITHOUT the 128-lane Pallas floor — for quantities that
    are streaming granules rather than MXU block operands (KV page size)."""
    try:
        dec = graph.kernel(kernel).tags["decision"]
    except KeyError:
        return default
    return int(dec.tile_sizes.get(dim, default))


def _group_impl(compiled: CompiledDataflow, kernel: str) -> str:
    """Implementation chosen for the fusion group containing ``kernel``;
    "xla_fusion" when unfused or the kernel is absent from the graph."""
    for g in compiled.lowered:
        if kernel in g.kernels:
            return g.implementation
    return "xla_fusion"


def _same_group(compiled: CompiledDataflow, a: str, b: str) -> bool:
    for g in compiled.lowered:
        if a in g.kernels:
            return b in g.kernels
    return False


def _layer_plan(cfg: ModelConfig, compiled: CompiledDataflow, kind: str,
                base: str) -> LayerPlan:
    """Map one compiled block graph onto stage-level kernel choices.

    A stage goes fused only when the fusion pass put its anchor kernel in a
    group that lowered to a Pallas-backed pattern (not ``xla_fusion``) —
    i.e. the compiler, not the runtime, decides what streams.
    """
    g = compiled.trial.graph
    assert g is not None

    def fused_at(anchor: str) -> bool:
        return _group_impl(compiled, anchor) != "xla_fusion"

    qkv = attention = decode_attn = verify_attn = ffn = mixer = EAGER

    if kind in ("attn", "local_attn", "global_attn", "mamba+shared_attn"):
        ab = f"{base}.shared" if kind == "mamba+shared_attn" else base
        if fused_at(f"{ab}.q_proj"):
            # The shared-attn block's pre-attention norm is traced as
            # "<base>.shared.ln"; regular attention blocks use "<base>.ln1".
            ln = f"{ab}.ln" if kind == "mamba+shared_attn" else f"{ab}.ln1"
            norm_fused = (cfg.norm == "rmsnorm"
                          and _same_group(compiled, ln, f"{ab}.q_proj"))
            impl = "rmsnorm_matmul" if norm_fused else "block_matmul"
            blocks: Blocks = (
                ("block_t", _tile(g, f"{ab}.q_proj", "t")),
                ("block_n", _tile(g, f"{ab}.q_proj", "dq")),
            )
            # Weight-only int8 (DESIGN.md §14): the plan flags the stage
            # and the wrapper quantizes + dispatches the w8 kernel twin.
            # Only rmsnorm_matmul has one; block_matmul (layernorm archs)
            # stays full-precision — a documented follow-on.
            if cfg.weight_quant and impl == "rmsnorm_matmul":
                blocks += (("w8", 1),)
            qkv = KernelChoice(impl, blocks)
        if fused_at(f"{ab}.attention"):
            attention = KernelChoice("flash_attention", (
                ("block_q", _tile(g, f"{ab}.attention", "t")),
                ("block_kv", _tile(g, f"{ab}.attention", "s")),
            ))
            # Decode twin of the same fusion decision: single-token
            # attention streams the paged KV cache instead of a flash
            # grid; the KV-dim DSE tile becomes the page size.
            decode_attn = KernelChoice("paged_attention", (
                ("page_size", _raw_tile(g, f"{ab}.attention", "s")),
            ))
            # Speculative-verify twin: the same paged stream scores a
            # W-row draft window per dispatch; the page granule bounds
            # how many rows one dispatch should amortize (verify_window).
            verify_attn = KernelChoice("verify_attention", (
                ("page_size", _raw_tile(g, f"{ab}.attention", "s")),
            ))
        mb = f"{ab}.moe" if cfg.is_moe else f"{ab}.mlp"
        if cfg.is_moe and cfg.gated_ffn and fused_at(f"{mb}.experts"):
            ffn = KernelChoice("moe_experts", (
                ("block_t", _tile(g, f"{mb}.experts", "t")),
            ))
        elif not cfg.is_moe and fused_at(f"{mb}.up_proj"):
            norm_fused = (cfg.norm == "rmsnorm" and _same_group(
                compiled, f"{ab}.ln2", f"{mb}.up_proj"))
            impl = "streamed_ffn" if cfg.gated_ffn else "streamed_mlp"
            fblocks: Blocks = (
                ("block_t", _tile(g, f"{mb}.up_proj", "t")),
                ("block_f", _tile(g, f"{mb}.up_proj", "f")),
                ("fuse_norm", int(norm_fused)),
            )
            if cfg.weight_quant:
                fblocks += (("w8", 1),)
            ffn = KernelChoice(impl, fblocks)

    if kind in ("mamba", "mamba+shared_attn"):
        if fused_at(f"{base}.ssm_scan"):
            mixer = KernelChoice("mamba2_scan", (
                ("chunk", _tile(g, f"{base}.ssm_scan", "t")),
            ))

    if kind == "rwkv":
        if fused_at(f"{base}.wkv"):
            mixer = KernelChoice("rwkv6_wkv", (
                ("chunk", min(64, _tile(g, f"{base}.wkv", "t"))),
            ))

    return LayerPlan(kind=kind, qkv=qkv, attention=attention,
                     decode_attn=decode_attn, verify_attn=verify_attn,
                     ffn=ffn, mixer=mixer)


# ------------------------------------------------------------- sharding

def _mesh_claims(cfg: ModelConfig, mesh) -> Dict[str, Sharding]:
    """Per-stage (grid_dim, mesh_axis) claims for one mesh.

    Feature dims go through ``distributed/sharding.spec_for`` — the SAME
    quantum-aware rules that shard the parameters, so a kernel's block
    grid never disagrees with its operands' layout (e.g. ``kv_heads``
    claims 'model' only when the head count divides; otherwise the claim
    is dropped and the stage replicates, never falls back to eager).
    Token/batch dims claim 'data' here and are divisibility-checked at
    trace time by the wrappers, where the actual batch extent is known.
    """
    # Deliberately lazy: core must stay importable without triggering the
    # distributed package (which imports models, which imports core).
    from ..distributed.sharding import spec_for

    def claim(name: str, extent: int) -> Optional[str]:
        if extent <= 0:
            return None
        ax = spec_for(cfg, (name,), (extent,), mesh)[0]
        if not isinstance(ax, str) or mesh.shape[ax] <= 1:
            return None              # size-1 axis: sharding is a no-op
        return ax

    def pairs(**dims) -> Sharding:
        return tuple((d, ax) for d, ax in dims.items() if ax)

    # Batch/token claim: the same ('pod', 'data') candidate group the
    # ``batch`` rule uses, narrowed to axes this mesh actually has — so
    # fused in_specs agree with the input placement on multi-pod meshes.
    batch_axes = tuple(a for a in ("pod", "data")
                       if a in mesh.axis_names and mesh.shape[a] > 1)
    data = (batch_axes if len(batch_axes) > 1
            else (batch_axes[0] if batch_axes else None))
    out_ax = None
    if (claim("q_dim", cfg.q_dim) == "model"
            and claim("kv_dim", cfg.kv_dim) == "model"):
        out_ax = "model"          # one choice serves wq/wk/wv: need both
    kv_heads = claim("kv_heads", cfg.num_kv_heads)
    if cfg.is_moe:
        ffn = pairs(tokens=data, experts=claim("experts", cfg.num_experts))
    else:
        ffn = pairs(tokens=data, d_ff=claim("d_ff", cfg.d_ff))
    mixer: Sharding = ()
    if cfg.is_mamba:
        mixer = pairs(batch=data, heads=claim("ssm_heads", cfg.ssm_heads))
    elif cfg.rwkv:
        mixer = pairs(batch=data, heads=claim("rwkv_heads", cfg.rwkv_heads))
    return {
        "qkv": pairs(tokens=data, out=out_ax),
        "attention": pairs(batch=data, kv_heads=kv_heads),
        "decode_attn": pairs(batch=data, kv_heads=kv_heads),
        "verify_attn": pairs(batch=data, kv_heads=kv_heads),
        "ffn": ffn,
        "mixer": mixer,
        "lm_head": pairs(tokens=data),
    }


def _axis_size(mesh, sharding: Sharding, dim: str) -> int:
    ax = dict(sharding).get(dim)
    if not ax:
        return 1
    axes = ax if isinstance(ax, tuple) else (ax,)
    size = 1
    for a in axes:
        size *= int(mesh.shape[a])
    return size


def _shard_choice(choice: KernelChoice, sharding: Sharding,
                  clips: Dict[str, int]) -> KernelChoice:
    """Attach a sharding claim; clip block targets to post-shard extents
    (``clips``: block name -> per-shard extent) so the plan's DSE tiles
    describe what ONE shard streams, not the global tensor."""
    if not choice.fused:
        return choice
    blocks = tuple(
        (name, max(1, min(int(val), clips[name]))
         if name in clips else val)
        for name, val in choice.blocks)
    return replace(choice, blocks=blocks, sharding=sharding)


def _apply_mesh(cfg: ModelConfig, lp: LayerPlan, mesh,
                claims: Dict[str, Sharding], tokens: int) -> LayerPlan:
    # Clip entries exist ONLY for dims a >1-way axis actually claims — an
    # unsharded dim keeps the DSE's global tile target untouched.  The
    # clip never drops below the LANE floor: targets stay lane-aligned
    # (the module contract) and the wrapper's ``pick_block`` handles
    # per-shard extents that are genuinely smaller at trace time — this
    # matters for the serving plan, whose ``tokens`` is the (tiny) slot
    # count, not the 128-token prefill chunk its dispatches stream.
    def clips_for(claim: Sharding, dims: Dict[str, Tuple[str, int]]
                  ) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for block, (dim, extent) in dims.items():
            n = _axis_size(mesh, claim, dim)
            if n > 1:
                out[block] = max(LANE, extent // n)
        return out

    qkv = _shard_choice(lp.qkv, claims["qkv"], clips_for(claims["qkv"], {
        "block_t": ("tokens", tokens),
        # One choice serves wq/wk/wv: the per-shard tile must fit the
        # narrowest projection's shard.
        "block_n": ("out", min(cfg.q_dim, cfg.kv_dim)),
    }))
    attention = _shard_choice(lp.attention, claims["attention"], {})
    decode_attn = _shard_choice(lp.decode_attn, claims["decode_attn"], {})
    verify_attn = _shard_choice(lp.verify_attn, claims["verify_attn"], {})
    ffn_extent = cfg.num_experts if cfg.is_moe else cfg.d_ff
    ffn_dim = "experts" if cfg.is_moe else "d_ff"
    ffn = _shard_choice(lp.ffn, claims["ffn"], clips_for(claims["ffn"], {
        "block_t": ("tokens", tokens),
        "block_f": (ffn_dim, ffn_extent),
    }))
    mixer = _shard_choice(lp.mixer, claims["mixer"], {})
    return LayerPlan(kind=lp.kind, qkv=qkv, attention=attention,
                     decode_attn=decode_attn, verify_attn=verify_attn,
                     ffn=ffn, mixer=mixer)


def build_stream_plan(cfg: ModelConfig, *, tokens: int,
                      kv_len: Optional[int] = None,
                      platform: Platform = TPU_V5E,
                      dse_budget: int = 8,
                      mesh=None, tune=None, tune_table=None,
                      cost_source=None) -> StreamPlan:
    """Run the StreamTensor pipeline over every distinct layer kind of
    ``cfg`` and collapse the result into an executable plan.

    The DSE explores the tiling space once, on the first layer kind (the
    paper's hyperparameters are global); remaining kinds and the LM head
    are compiled as single trials with the winning parameters.

    With ``mesh``, every stage additionally carries a sharding decision
    (see ``_mesh_claims``) and feature-dim block targets are clipped to
    the post-shard extents.

    Autotuning (DESIGN.md §16): ``tune=`` is a ``tuning.Tuner`` (or
    ``True`` for a fresh in-memory one) that rewrites the plan's
    block/page/chunk choices from the measured-latency table after the
    analytic build; ``tune_table=`` is a ``TuneTable`` or a path to one
    (implies tuning).  ``cost_source=`` is a ``dse.CostSource`` plumbed
    into the DSE objective itself (op-level measured makespan terms).
    """
    kinds: Dict[str, int] = {}
    for i in range(cfg.num_layers):
        kinds.setdefault(cfg.layer_kind(i), i)

    layers = []
    first = True
    tile, unroll = None, None
    latency = 0.0
    groups = 0
    impls: Tuple[str, ...] = ()
    for kind, idx in kinds.items():
        compiled = compile_model(
            cfg, tokens=tokens, kv_len=kv_len, platform=platform,
            layer_index=idx,
            dse_budget=dse_budget if first else 1,
            default_tile_size=None if first else tile,
            overall_unroll_size=None if first else unroll,
            cost_source=cost_source)
        if first:
            tile = compiled.trial.params["default_tile_size"]
            unroll = compiled.trial.params["overall_unroll_size"]
            first = False
        latency += compiled.trial.latency_s
        groups += compiled.fusion.num_groups
        impls += tuple(lg.implementation for lg in compiled.lowered)
        layers.append((kind, _layer_plan(cfg, compiled, kind,
                                         base=f"L{idx}")))

    # LM head: norm + head matmul + loss.  The loss consumer is not part of
    # the block trace, so the streamed-xent choice is made here; block sizes
    # come from the head matmul's tiling decision.
    head_trial = evaluate_trial(trace_lm_head(cfg, tokens), platform,
                                tile or LANE, unroll or 64,
                                keep_artifacts=True,
                                cost_source=cost_source)
    assert head_trial.graph is not None and head_trial.fusion is not None
    head_lowered = lower_groups(head_trial.graph, head_trial.fusion,
                                partition(head_trial.graph, 1))
    head_fused = any(lg.implementation != "xla_fusion"
                     for lg in head_lowered
                     if "final.lm_head" in lg.kernels)
    lm_head = EAGER
    if head_fused:
        lm_head = KernelChoice("streamed_xent", (
            ("block_t", _tile(head_trial.graph, "final.lm_head", "t")),
            ("block_v", max(_tile(head_trial.graph, "final.lm_head", "v"),
                            512)),
        ))
    latency += head_trial.latency_s
    groups += head_trial.fusion.num_groups
    impls += tuple(lg.implementation for lg in head_lowered)

    mesh_axes: Tuple[Tuple[str, int], ...] = ()
    if mesh is not None and len(mesh.axis_names) > 0:
        claims = _mesh_claims(cfg, mesh)
        layers = [(kind, _apply_mesh(cfg, lp, mesh, claims, tokens))
                  for kind, lp in layers]
        d = _axis_size(mesh, claims["lm_head"], "tokens")
        lm_head = _shard_choice(
            lm_head, claims["lm_head"],
            {"block_t": max(LANE, tokens // d)} if d > 1 else {})
        mesh_axes = tuple((str(a), int(mesh.shape[a]))
                          for a in mesh.axis_names)

    plan = StreamPlan(
        arch=cfg.name, tokens=tokens, kv_len=kv_len or tokens,
        platform=platform.name,
        default_tile_size=tile or LANE, overall_unroll_size=unroll or 64,
        layers=tuple(layers), quant=cfg.quant, lm_head=lm_head,
        modeled_latency_s=latency, fusion_groups=groups,
        implementations=impls, mesh_axes=mesh_axes)

    if (tune is not None and tune is not False) or tune_table is not None:
        # Deliberately lazy: core must stay importable without the tuning
        # package (which imports analysis, which imports core).
        from ..tuning.autotune import Tuner, resolve_tuner
        if isinstance(tune, Tuner):
            tuner: Optional[Tuner] = tune
        elif tune_table is not None:
            tuner = resolve_tuner(tune_table, cfg)
        else:
            tuner = Tuner()         # in-memory, hybrid-fill
        if tuner is not None:
            plan = tuner.tune_plan(cfg, plan, mesh=mesh,
                                   platform=platform)
    return plan


@functools.lru_cache(maxsize=64)
def _plan_for_base(cfg: ModelConfig, tokens: int,
                   kv_len: Optional[int] = None, mesh=None) -> StreamPlan:
    return build_stream_plan(cfg, tokens=tokens, kv_len=kv_len, mesh=mesh)


def plan_for(cfg: ModelConfig, tokens: int,
             kv_len: Optional[int] = None, mesh=None) -> StreamPlan:
    """Cached plan lookup used by the model entry points.

    Keyed on the (hashable, frozen) config plus the flattened token count,
    KV length, and mesh (``jax.sharding.Mesh`` hashes by device grid +
    axis names) — the jitted callers re-trace per shape anyway, so plan
    granularity matches jit granularity.

    When a ``tuning.Tuner`` is active (``ServingEngine(autotune=...)``
    enters ``use_tuner`` around plan resolution and dispatch tracing,
    exactly as meshes ride ``use_mesh``), the cached analytic plan is
    post-processed through the tuner OUTSIDE the lru cache — a tuned
    plan is memoized per-tuner, never served to untuned callers.
    """
    plan = _plan_for_base(cfg, tokens, kv_len, mesh)
    from ..tuning.autotune import active_tuner      # lazy: no core cycle
    tuner = active_tuner()
    if tuner is not None:
        plan = tuner.tune_plan(cfg, plan, mesh=mesh)
    return plan


# Cache management passthrough (tests clear plan caches between configs).
plan_for.cache_clear = _plan_for_base.cache_clear    # type: ignore[attr-defined]
plan_for.cache_info = _plan_for_base.cache_info      # type: ignore[attr-defined]
