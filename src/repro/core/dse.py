"""Design-space exploration driver — paper §5.1 (Optuna stand-in) + §5.

StreamTensor explores three hierarchical spaces:

  1. **Tiling space** (``tiling.py``) — hyperparameters ``default_tile_size``
     and ``overall_unroll_size``, explored here by a blackbox optimizer with
     *feedback from the kernel fusion results* (the paper uses Optuna; we ship
     an offline random + coordinate-hill-climb explorer with the same
     interface and objective).
  2. **Fusion space** (``fusion.py``) — Algorithm 2 under ``C_max``.
  3. **Resource allocation space** (``fifo_sizing.py``/``partition.py``/
     ``allocation.py``) — FIFO depths via the LP, die partitioning, tiers.

The objective evaluated per trial runs spaces 2 and 3 end-to-end and scores
the result, exactly the feedback loop of Fig. 4:

    score = modeled end-to-end latency (dataflow makespan + DMA traffic time)
            + infeasibility penalties (a kernel alone exceeding C_max feeds
              back "reduce tiling/unroll", paper §5.2.2)
"""

from __future__ import annotations

import math
import random
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from .fifo_sizing import FifoPlan, size_fifos, solve_start_times
from .fusion import FusionPlan, explore_fusion
from .graph import DataflowGraph
from .platforms import Platform
from .tiling import LinalgOpSpec, TilingSpace


@dataclass
class TrialResult:
    params: Dict[str, int]
    score: float
    latency_s: float
    onchip_bytes: float
    external_bytes: float
    num_groups: int
    feasible: bool
    graph: Optional[DataflowGraph] = None
    fusion: Optional[FusionPlan] = None
    fifo: Optional[FifoPlan] = None


@dataclass
class DSEResult:
    best: TrialResult
    trials: List[TrialResult]

    @property
    def num_trials(self) -> int:
        return len(self.trials)


def modeled_latency_s(graph: DataflowGraph, fusion: FusionPlan,
                      fifo: FifoPlan, platform: Platform) -> float:
    """Analytic end-to-end latency of the fused dataflow design.

    Dataflow makespan = max over kernels of (LP start time + kernel latency),
    in cycles; inter-group edges round-trip external memory and are charged at
    HBM bandwidth (this is exactly what stream fusion removes).
    """
    makespan_cycles = 0.0
    for k in graph.kernels():
        t = k.timing
        if t is None:
            continue
        makespan_cycles = max(makespan_cycles,
                              fifo.start_times[k.name] + t.latency)
    dma_bytes = fusion.external_bytes(graph) * 2.0   # write + read back
    dma_bytes += graph.total_weight_bytes()
    return platform.seconds(makespan_cycles) + dma_bytes / platform.hbm_bw


def evaluate_trial(ops: Sequence[LinalgOpSpec], platform: Platform,
                   default_tile_size: int, overall_unroll_size: int,
                   c_max: Optional[float] = None,
                   strategy: str = "normal",
                   keep_artifacts: bool = False) -> TrialResult:
    """One full pass through fusion + FIFO sizing (spaces 2 and 3)."""
    params = {"default_tile_size": default_tile_size,
              "overall_unroll_size": overall_unroll_size}
    c_max = c_max if c_max is not None else platform.fusion_budget()
    space = TilingSpace(ops=list(ops), default_tile_size=default_tile_size,
                        overall_unroll_size=overall_unroll_size)
    graph = space.build_graph(platform)

    def node_cost(g: DataflowGraph, name: str) -> float:
        return g.kernel(name).local_bytes

    fusion = explore_fusion(graph, c_max, node_cost=node_cost)
    timings = {k.name: k.timing for k in graph.kernels()}
    fifo = size_fifos(graph, timings, strategy=strategy)

    onchip = sum(fusion.costs) + fifo.total_bytes
    feasible = all(c <= c_max for c in fusion.costs)
    latency = modeled_latency_s(graph, fusion, fifo, platform)
    # Infeasibility: a single kernel exceeding C_max must shrink its tiling
    # (paper §5.2.2 feedback); penalize proportionally so the explorer walks
    # back toward smaller tiles/unrolls.
    penalty = 0.0
    if not feasible:
        worst = max(fusion.costs)
        penalty = latency * (worst / c_max)
    return TrialResult(
        params=params, score=latency + penalty, latency_s=latency,
        onchip_bytes=onchip, external_bytes=fusion.external_bytes(graph),
        num_groups=fusion.num_groups, feasible=feasible,
        graph=graph if keep_artifacts else None,
        fusion=fusion if keep_artifacts else None,
        fifo=fifo if keep_artifacts else None)


def explore(ops: Sequence[LinalgOpSpec], platform: Platform,
            c_max: Optional[float] = None,
            tile_candidates: Sequence[int] = (16, 32, 64, 128, 256),
            unroll_candidates: Sequence[int] = (8, 16, 32, 64, 128, 256),
            budget: int = 24, seed: int = 0,
            strategy: str = "normal") -> DSEResult:
    """Blackbox exploration (Optuna stand-in): seeded random sampling over the
    log-2 lattice followed by coordinate hill-climbing around the incumbent."""
    rng = random.Random(seed)
    seen: Dict[Tuple[int, int], TrialResult] = {}

    def run(ts: int, us: int) -> TrialResult:
        key = (ts, us)
        if key not in seen:
            seen[key] = evaluate_trial(ops, platform, ts, us, c_max=c_max,
                                       strategy=strategy)
        return seen[key]

    # Phase 1: random sampling (half the budget).
    lattice = [(t, u) for t in tile_candidates for u in unroll_candidates]
    rng.shuffle(lattice)
    for ts, us in lattice[:max(1, budget // 2)]:
        run(ts, us)

    # Phase 2: coordinate hill-climb around the incumbent.
    def neighbors(ts: int, us: int) -> List[Tuple[int, int]]:
        ti = tile_candidates.index(ts) if ts in tile_candidates else 0
        ui = unroll_candidates.index(us) if us in unroll_candidates else 0
        out = []
        for di in (-1, 1):
            if 0 <= ti + di < len(tile_candidates):
                out.append((tile_candidates[ti + di], us))
            if 0 <= ui + di < len(unroll_candidates):
                out.append((ts, unroll_candidates[ui + di]))
        return out

    while len(seen) < budget:
        inc = min(seen.values(), key=lambda r: r.score)
        moves = [n for n in neighbors(*inc.params.values()) if n not in seen]
        if not moves:
            break
        run(*moves[0])

    trials = sorted(seen.values(), key=lambda r: r.score)
    best = trials[0]
    # Re-run the winner keeping artifacts for downstream lowering.
    best = evaluate_trial(ops, platform, **best.params, c_max=c_max,
                          strategy=strategy, keep_artifacts=True)
    return DSEResult(best=best, trials=trials)
