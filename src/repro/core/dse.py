"""Design-space exploration driver — paper §5.1 (Optuna stand-in) + §5.

StreamTensor explores three hierarchical spaces:

  1. **Tiling space** (``tiling.py``) — hyperparameters ``default_tile_size``
     and ``overall_unroll_size``, explored here by a blackbox optimizer with
     *feedback from the kernel fusion results* (the paper uses Optuna; we ship
     an offline random + coordinate-hill-climb explorer with the same
     interface and objective).
  2. **Fusion space** (``fusion.py``) — Algorithm 2 under ``C_max``.
  3. **Resource allocation space** (``fifo_sizing.py``/``partition.py``/
     ``allocation.py``) — FIFO depths via the LP, die partitioning, tiers.

The objective evaluated per trial runs spaces 2 and 3 end-to-end and scores
the result, exactly the feedback loop of Fig. 4:

    score = modeled end-to-end latency (dataflow makespan + DMA traffic time)
            + infeasibility penalties (a kernel alone exceeding C_max feeds
              back "reduce tiling/unroll", paper §5.2.2)
"""

from __future__ import annotations

import math
import random
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from .fifo_sizing import FifoPlan, size_fifos, solve_start_times
from .fusion import FusionPlan, explore_fusion
from .graph import DataflowGraph
from .platforms import Platform
from .tiling import LinalgOpSpec, TilingSpace


@dataclass(frozen=True)
class CostSource:
    """Pluggable kernel-latency oracle for the DSE objective (§16).

    The analytic objective models every kernel's latency from the (L, D,
    II) platform model; a measured source overrides those terms with
    wall-clock numbers from the autotuner's table:

      * ``mode="analytic"`` — the FPGA-era model, unchanged (default).
      * ``mode="measured"`` — ``lookup(kernel_name) -> seconds | None``
        overrides where it answers; unknown kernels keep the analytic
        term (and are reported as such in the trial breakdown).
      * ``mode="hybrid"``   — like measured, but a miss is filled by
        ``fill(kernel_name, analytic_seconds) -> seconds`` (the tuning
        layer's measure-and-cache callback) instead of falling back.
    """
    mode: str = "analytic"
    lookup: Optional[Callable[[str], Optional[float]]] = None
    fill: Optional[Callable[[str, float], float]] = None

    def __post_init__(self) -> None:
        if self.mode not in ("analytic", "measured", "hybrid"):
            raise ValueError(f"unknown CostSource mode {self.mode!r} "
                             "(analytic | measured | hybrid)")

    def kernel_seconds(self, name: str,
                       analytic_s: float) -> Tuple[float, str]:
        """(latency seconds, provenance) for one kernel."""
        if self.mode == "analytic" or self.lookup is None:
            return analytic_s, "analytic"
        got = self.lookup(name)
        if got is not None:
            return float(got), "measured"
        if self.mode == "hybrid" and self.fill is not None:
            return float(self.fill(name, analytic_s)), "measured"
        return analytic_s, "analytic"


ANALYTIC = CostSource()


@dataclass
class TrialResult:
    params: Dict[str, int]
    score: float
    latency_s: float
    onchip_bytes: float
    external_bytes: float
    num_groups: int
    feasible: bool
    graph: Optional[DataflowGraph] = None
    fusion: Optional[FusionPlan] = None
    fifo: Optional[FifoPlan] = None
    # Per-kernel timing terms of the makespan objective: kernel name ->
    # {"start_s", "kernel_s", "source"} — the DSE's audit trail (§16).
    breakdown: Dict[str, Dict[str, object]] = field(default_factory=dict)
    dma_s: float = 0.0
    cost_source: str = "analytic"


@dataclass
class DSEResult:
    best: TrialResult
    trials: List[TrialResult]
    # Deterministic warm-start points evaluated before random sampling —
    # recorded so a tuned plan's provenance names the seeds it ran under.
    seed_trials: Tuple[Tuple[int, int], ...] = ()

    @property
    def num_trials(self) -> int:
        return len(self.trials)

    @property
    def breakdowns(self) -> List[Dict[str, Dict[str, object]]]:
        """Per-trial timing breakdowns, in score order."""
        return [t.breakdown for t in self.trials]


def latency_breakdown(graph: DataflowGraph, fusion: FusionPlan,
                      fifo: FifoPlan, platform: Platform,
                      cost_source: Optional[CostSource] = None,
                      ) -> Tuple[float, Dict[str, Dict[str, object]],
                                 float]:
    """End-to-end latency of the fused design plus its per-kernel terms.

    Dataflow makespan = max over kernels of (LP start time + kernel
    latency); inter-group edges round-trip external memory and are
    charged at HBM bandwidth (exactly what stream fusion removes).  The
    kernel-latency term goes through ``cost_source`` so the same LP
    machinery scores analytic, measured, and hybrid objectives.
    Returns ``(latency_s, per-kernel breakdown, dma_s)``.
    """
    cs = cost_source or ANALYTIC
    makespan_s = 0.0
    breakdown: Dict[str, Dict[str, object]] = {}
    for k in graph.kernels():
        t = k.timing
        if t is None:
            continue
        kernel_s, src = cs.kernel_seconds(k.name,
                                          platform.seconds(t.latency))
        start_s = platform.seconds(fifo.start_times[k.name])
        breakdown[k.name] = {"start_s": start_s, "kernel_s": kernel_s,
                             "source": src}
        makespan_s = max(makespan_s, start_s + kernel_s)
    dma_bytes = fusion.external_bytes(graph) * 2.0   # write + read back
    dma_bytes += graph.total_weight_bytes()
    dma_s = dma_bytes / platform.hbm_bw
    return makespan_s + dma_s, breakdown, dma_s


def modeled_latency_s(graph: DataflowGraph, fusion: FusionPlan,
                      fifo: FifoPlan, platform: Platform,
                      cost_source: Optional[CostSource] = None) -> float:
    """Analytic (or cost-source-overridden) end-to-end latency."""
    return latency_breakdown(graph, fusion, fifo, platform,
                             cost_source)[0]


def evaluate_trial(ops: Sequence[LinalgOpSpec], platform: Platform,
                   default_tile_size: int, overall_unroll_size: int,
                   c_max: Optional[float] = None,
                   strategy: str = "normal",
                   keep_artifacts: bool = False,
                   cost_source: Optional[CostSource] = None) -> TrialResult:
    """One full pass through fusion + FIFO sizing (spaces 2 and 3)."""
    params = {"default_tile_size": default_tile_size,
              "overall_unroll_size": overall_unroll_size}
    c_max = c_max if c_max is not None else platform.fusion_budget()
    space = TilingSpace(ops=list(ops), default_tile_size=default_tile_size,
                        overall_unroll_size=overall_unroll_size)
    graph = space.build_graph(platform)

    def node_cost(g: DataflowGraph, name: str) -> float:
        return g.kernel(name).local_bytes

    fusion = explore_fusion(graph, c_max, node_cost=node_cost)
    timings = {k.name: k.timing for k in graph.kernels()}
    fifo = size_fifos(graph, timings, strategy=strategy)

    onchip = sum(fusion.costs) + fifo.total_bytes
    feasible = all(c <= c_max for c in fusion.costs)
    latency, breakdown, dma_s = latency_breakdown(
        graph, fusion, fifo, platform, cost_source)
    # Infeasibility: a single kernel exceeding C_max must shrink its tiling
    # (paper §5.2.2 feedback); penalize proportionally so the explorer walks
    # back toward smaller tiles/unrolls.
    penalty = 0.0
    if not feasible:
        worst = max(fusion.costs)
        penalty = latency * (worst / c_max)
    return TrialResult(
        params=params, score=latency + penalty, latency_s=latency,
        onchip_bytes=onchip, external_bytes=fusion.external_bytes(graph),
        num_groups=fusion.num_groups, feasible=feasible,
        graph=graph if keep_artifacts else None,
        fusion=fusion if keep_artifacts else None,
        fifo=fifo if keep_artifacts else None,
        breakdown=breakdown, dma_s=dma_s,
        cost_source=(cost_source or ANALYTIC).mode)


def explore(ops: Sequence[LinalgOpSpec], platform: Platform,
            c_max: Optional[float] = None,
            tile_candidates: Sequence[int] = (16, 32, 64, 128, 256),
            unroll_candidates: Sequence[int] = (8, 16, 32, 64, 128, 256),
            budget: int = 24, seed: int = 0,
            strategy: str = "normal",
            cost_source: Optional[CostSource] = None,
            seed_trials: Optional[Sequence[Tuple[int, int]]] = None
            ) -> DSEResult:
    """Blackbox exploration (Optuna stand-in): seeded random sampling over the
    log-2 lattice followed by coordinate hill-climbing around the incumbent.

    ``seed_trials`` are (tile, unroll) points evaluated deterministically
    BEFORE random sampling — pass the winning params of a previous run to
    make a tuned plan reproducible given a frozen table: the warm starts
    are scored first, count against the budget, and on a score tie the
    earliest trial wins, so a frozen table replays to the same plan.
    """
    rng = random.Random(seed)
    seen: Dict[Tuple[int, int], TrialResult] = {}
    order: List[Tuple[int, int]] = []

    def run(ts: int, us: int) -> TrialResult:
        key = (ts, us)
        if key not in seen:
            seen[key] = evaluate_trial(ops, platform, ts, us, c_max=c_max,
                                       strategy=strategy,
                                       cost_source=cost_source)
            order.append(key)
        return seen[key]

    # Phase 0: deterministic warm starts.
    warm: Tuple[Tuple[int, int], ...] = tuple(
        (int(ts), int(us)) for ts, us in (seed_trials or ()))
    for ts, us in warm:
        run(ts, us)

    # Phase 1: random sampling (half the budget).
    lattice = [(t, u) for t in tile_candidates for u in unroll_candidates]
    rng.shuffle(lattice)
    for ts, us in lattice[:max(1, budget // 2)]:
        if len(seen) >= max(budget, len(warm)):
            break
        run(ts, us)

    # Phase 2: coordinate hill-climb around the incumbent.
    def neighbors(ts: int, us: int) -> List[Tuple[int, int]]:
        ti = tile_candidates.index(ts) if ts in tile_candidates else 0
        ui = unroll_candidates.index(us) if us in unroll_candidates else 0
        out = []
        for di in (-1, 1):
            if 0 <= ti + di < len(tile_candidates):
                out.append((tile_candidates[ti + di], us))
            if 0 <= ui + di < len(unroll_candidates):
                out.append((ts, unroll_candidates[ui + di]))
        return out

    while len(seen) < budget:
        inc = min(seen.values(), key=lambda r: r.score)
        moves = [n for n in neighbors(*inc.params.values()) if n not in seen]
        if not moves:
            break
        run(*moves[0])

    # Stable sort on score alone: ties resolve to the earliest-evaluated
    # trial, which is what makes seed_trials deterministic warm starts.
    rank = {key: i for i, key in enumerate(order)}
    trials = sorted(seen.values(),
                    key=lambda r: (r.score,
                                   rank[tuple(r.params.values())]))
    best = trials[0]
    # Re-run the winner keeping artifacts for downstream lowering.
    best = evaluate_trial(ops, platform, **best.params, c_max=c_max,
                          strategy=strategy, keep_artifacts=True,
                          cost_source=cost_source)
    return DSEResult(best=best, trials=trials, seed_trials=warm)
