"""Lowering: fusion groups -> executable kernel implementations.

The final StreamTensor stages (Fig. 4: bufferization, HLS optimization, code
generation) retarget here to TPU: every fusion group is matched against a
registry of *fused kernel patterns* — each backed by a Pallas kernel in
``repro.kernels`` (TPU target, validated in interpret mode) and a pure-XLA
reference (the form embedded in the jitted step functions).  Groups that match
no pattern lower to the XLA default; this mirrors the paper's fallback of
passing unfused kernels to the vendor compiler.

``compile_model`` is the one-call pipeline: trace -> tiling DSE -> fusion ->
FIFO sizing -> partition -> allocation -> lowering, returning a
``CompiledDataflow`` consumed by the step functions, the benchmarks (paper
tables), and EXPERIMENTS.md.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

from ..configs.base import ModelConfig
from .allocation import AllocationResult, TPU_TIERS, allocate, buffers_from_plan
from .dse import (CostSource, DSEResult, TrialResult, evaluate_trial,
                  explore, modeled_latency_s)
from .fifo_sizing import FifoPlan
from .fusion import FusionPlan, fusion_memory_report
from .graph import DataflowGraph
from .partition import PartitionResult, partition
from .platforms import Platform, TPU_V5E
from .trace import trace_block

# ---------------------------------------------------------------------- #
# Fused-kernel pattern registry
# ---------------------------------------------------------------------- #

@dataclass(frozen=True)
class KernelPattern:
    """A fused implementation available in ``repro.kernels``.

    ``ops`` is the op-kind multiset the fusion group must cover (extra
    elementwise ops are absorbed — XLA and Pallas both fuse those freely).
    """
    name: str
    ops: Tuple[str, ...]
    pallas_module: str
    priority: int = 0

    def matches(self, group_ops: Sequence[str]) -> bool:
        need = list(self.ops)
        for o in group_ops:
            if o in need:
                need.remove(o)
        return not need


PATTERNS: Tuple[KernelPattern, ...] = (
    KernelPattern("streamed_block", ("norm", "matmul", "attention", "matmul",
                                     "norm", "matmul", "matmul", "act_mul",
                                     "matmul"),
                  "repro.kernels.streamed_ffn", priority=5),
    KernelPattern("flash_attention", ("attention",),
                  "repro.kernels.flash_attention", priority=4),
    KernelPattern("streamed_ffn", ("matmul", "matmul", "act_mul", "matmul"),
                  "repro.kernels.streamed_ffn", priority=4),
    KernelPattern("mamba2_scan", ("ssm_scan",),
                  "repro.kernels.mamba2_scan", priority=4),
    KernelPattern("rwkv6_wkv", ("wkv6",),
                  "repro.kernels.rwkv6_wkv", priority=4),
    KernelPattern("moe_experts", ("moe_experts",),
                  "repro.kernels.moe_experts", priority=4),
    KernelPattern("rmsnorm_matmul", ("norm", "matmul"),
                  "repro.kernels.rmsnorm_matmul", priority=3),
    KernelPattern("matmul_chain", ("matmul", "matmul"),
                  "repro.kernels.streamed_ffn", priority=2),
    KernelPattern("matmul", ("matmul",),
                  "repro.kernels.block_matmul", priority=1),
)


@dataclass
class LoweredGroup:
    group_index: int
    kernels: List[str]
    implementation: str          # pattern name or "xla_fusion"
    pallas_module: Optional[str]
    die: int = 0


def lower_groups(graph: DataflowGraph, fusion: FusionPlan,
                 part: Optional[PartitionResult] = None) -> List[LoweredGroup]:
    out: List[LoweredGroup] = []
    for gi, group in enumerate(fusion.groups):
        names = sorted(group, key=lambda n: graph.topo_order().index(n))
        ops = [graph.kernel(n).op for n in names]
        chosen: Optional[KernelPattern] = None
        for pat in sorted(PATTERNS, key=lambda p: -p.priority):
            if pat.matches(ops):
                chosen = pat
                break
        die = part.assignment[names[0]] if part else 0
        out.append(LoweredGroup(
            group_index=gi, kernels=names,
            implementation=chosen.name if chosen else "xla_fusion",
            pallas_module=chosen.pallas_module if chosen else None,
            die=die))
    return out


# ---------------------------------------------------------------------- #
# End-to-end compile
# ---------------------------------------------------------------------- #

@dataclass
class CompiledDataflow:
    """Everything the StreamTensor pipeline decided for one block graph."""
    arch: str
    platform: str
    graph: DataflowGraph
    trial: TrialResult
    fusion: FusionPlan
    fifo: FifoPlan
    partition: PartitionResult
    allocation: AllocationResult
    lowered: List[LoweredGroup]
    memory_report: Dict[str, float]
    stage_seconds: Dict[str, float]

    @property
    def latency_s(self) -> float:
        return self.trial.latency_s

    def summary(self) -> Dict[str, object]:
        return {
            "arch": self.arch,
            "platform": self.platform,
            "kernels": self.graph.num_kernels,
            "fusion_groups": self.fusion.num_groups,
            "onchip_bytes": self.trial.onchip_bytes,
            "external_bytes": self.trial.external_bytes,
            "memory_ratio": self.memory_report["ratio"],
            "fifo_total_depth": self.fifo.total_depth,
            "modeled_latency_s": self.latency_s,
            "implementations": [g.implementation for g in self.lowered],
        }


def compile_model(cfg: ModelConfig, *, tokens: int,
                  kv_len: Optional[int] = None,
                  platform: Platform = TPU_V5E,
                  layer_index: int = 0,
                  dse_budget: int = 12,
                  num_dies: int = 1,
                  strategy: str = "normal",
                  default_tile_size: Optional[int] = None,
                  overall_unroll_size: Optional[int] = None,
                  cost_source: Optional[CostSource] = None,
                  seed_trials: Optional[Sequence[Tuple[int, int]]] = None,
                  ) -> CompiledDataflow:
    """Run the full StreamTensor pipeline on one block of ``cfg``.

    With explicit ``default_tile_size``/``overall_unroll_size`` the DSE is
    skipped (single trial) — used by tests and ablations; otherwise the
    blackbox explorer searches the tiling space with fusion feedback.
    ``cost_source`` swaps the DSE's kernel-latency oracle (analytic |
    measured | hybrid, see ``dse.CostSource``); ``seed_trials`` warm-start
    the explorer deterministically.
    """
    stages: Dict[str, float] = {}
    t0 = time.perf_counter()
    ops = trace_block(cfg, tokens=tokens, kv_len=kv_len,
                      layer_index=layer_index)
    stages["trace"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    if default_tile_size is not None:
        trial = evaluate_trial(ops, platform, default_tile_size,
                               overall_unroll_size or 64,
                               strategy=strategy, keep_artifacts=True,
                               cost_source=cost_source)
    else:
        trial = explore(ops, platform, budget=dse_budget,
                        strategy=strategy, cost_source=cost_source,
                        seed_trials=seed_trials).best
    stages["dse+fusion+fifo"] = time.perf_counter() - t0
    assert trial.graph is not None and trial.fusion is not None
    assert trial.fifo is not None

    t0 = time.perf_counter()
    part = partition(trial.graph, num_dies)
    stages["partition"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    bufs = buffers_from_plan(trial.graph, trial.fusion, trial.fifo)
    alloc = allocate(bufs, TPU_TIERS)
    stages["allocation"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    lowered = lower_groups(trial.graph, trial.fusion, part)
    stages["lowering"] = time.perf_counter() - t0

    report = fusion_memory_report(trial.graph, trial.fusion)
    return CompiledDataflow(
        arch=cfg.name, platform=platform.name, graph=trial.graph,
        trial=trial, fusion=trial.fusion, fifo=trial.fifo, partition=part,
        allocation=alloc, lowered=lowered, memory_report=report,
        stage_seconds=stages)
