"""Piecewise-linear token behavior model — paper §5.3.1–5.3.3, Fig. 8.

A kernel that produces ``T`` tokens with initial delay ``D`` and pipeline
initiation interval ``II`` has the production curve

    produced(t) = clamp( floor((t - D) / II) + 1, 0, T )

measured from the kernel's own start.  A consumer started ``delay`` cycles
after the producer consumes with its own (D=0-at-pull, II) staircase.  The
token count resident in the connecting FIFO is ``produced(t) - consumed(t)``;
its maximum over time is the FIFO depth that guarantees the producer is never
back-pressured (paper Eqs. 1 and 2).

We provide both the paper's closed forms and an exact evaluation over the
staircase breakpoints (the maximum of a difference of staircases is attained
immediately after a producer push), which the test-suite cross-checks against
cycle-accurate simulation.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Iterable, List, Tuple

from .graph import KernelTiming


def produced_tokens(timing: KernelTiming, t: float, num_tokens: int) -> int:
    """Production staircase: tokens emitted by time ``t`` (kernel starts at 0)."""
    if t < timing.initial_delay:
        return 0
    k = math.floor((t - timing.initial_delay) / timing.pipeline_ii) + 1
    return max(0, min(num_tokens, int(k)))


def consumed_tokens(timing: KernelTiming, t: float, delay: float,
                    num_tokens: int) -> int:
    """Consumption staircase of a consumer started at ``delay``.

    The consumer pulls its first token the moment it starts (Fig. 8(a):
    Target consumes token0 at its start time) and then one token per ``II``.
    """
    if t < delay:
        return 0
    k = math.floor((t - delay) / timing.pipeline_ii) + 1
    return max(0, min(num_tokens, int(k)))


# --------------------------------------------------------------------- #
# Paper closed forms (Eqs. 1 and 2)
# --------------------------------------------------------------------- #

def max_tokens_eq1(source: KernelTiming, target: KernelTiming,
                   delay: float, num_tokens: int) -> int:
    """Eq. 1 — source throughput >= target throughput (Fig. 8(c))."""
    t = num_tokens
    return int(min(t, t - math.floor((source.latency - delay) / target.pipeline_ii)))


def max_tokens_eq2(source: KernelTiming, target: KernelTiming,
                   delay: float, num_tokens: int) -> int:
    """Eq. 2 — source throughput < target throughput (Fig. 8(d)/(e))."""
    t = num_tokens
    return int(min(t, math.ceil((delay - source.initial_delay)
                                / source.pipeline_ii)))


def max_tokens_paper(source: KernelTiming, target: KernelTiming,
                     delay: float, num_tokens: int) -> int:
    """Dispatch between Eq. 1 and Eq. 2 on relative throughput."""
    if source.pipeline_ii <= target.pipeline_ii:
        return max(1, max_tokens_eq1(source, target, delay, num_tokens))
    return max(1, max_tokens_eq2(source, target, delay, num_tokens))


# --------------------------------------------------------------------- #
# Exact staircase evaluation
# --------------------------------------------------------------------- #

def max_tokens_exact(source: KernelTiming, target: KernelTiming,
                     delay: float, num_tokens: int) -> int:
    """Exact maximum of produced(t) - consumed(t) over all t.

    The maximum of the staircase difference occurs immediately after one of
    the producer's pushes; push ``k`` happens at ``D_s + k*II_s``.  The
    difference as a function of ``k`` is piecewise monotone with a single
    regime change where the consumer starts, so it suffices to probe a small
    candidate set of pushes (plus both endpoints).
    """
    t = num_tokens
    if t <= 0:
        return 0
    d_s, ii_s = source.initial_delay, source.pipeline_ii
    candidates = {0, t - 1}
    # Push index at which the consumer has just started.
    if ii_s > 0:
        k_start = math.ceil((delay - d_s) / ii_s)
        for k in (k_start - 1, k_start, k_start + 1):
            if 0 <= k < t:
                candidates.add(int(k))
    best = 0
    for k in candidates:
        push_time = d_s + k * ii_s
        fifo = (k + 1) - consumed_tokens(target, push_time, delay, t)
        best = max(best, fifo)
    return min(t, max(1, best))


def simulate_fifo_occupancy(source: KernelTiming, target: KernelTiming,
                            delay: float, num_tokens: int,
                            ) -> Tuple[int, List[Tuple[float, int]]]:
    """Cycle-accurate (event-driven) FIFO occupancy trace, for verification.

    Returns (max_occupancy, [(time, occupancy_after_event), ...]).  This is
    the Fig. 8(a)/(b) board-level behavior and is used by tests to validate
    both the closed forms and the exact evaluation.
    """
    events: List[Tuple[float, int]] = []  # (time, +1 push / -1 pop)
    for k in range(num_tokens):
        events.append((source.initial_delay + k * source.pipeline_ii, +1))
        events.append((delay + k * target.pipeline_ii, -1))
    # At equal timestamps a pop frees its slot for the simultaneous push
    # (FIFOs support same-cycle read/write; this matches the paper's curve
    # difference model).  Early pops are deferred until a token exists.
    events.sort(key=lambda e: (e[0], e[1]))
    occ, max_occ, deferred = 0, 0, 0
    trace: List[Tuple[float, int]] = []
    for time, kind in events:
        if kind == +1:
            occ += 1
            if deferred and occ > 0:
                take = min(deferred, occ)
                occ -= take
                deferred -= take
        else:
            if occ > 0:
                occ -= 1
            else:
                deferred += 1  # consumer stalls waiting for a token
        max_occ = max(max_occ, occ)
        trace.append((time, occ))
    return max_occ, trace


# --------------------------------------------------------------------- #
# Equalization strategies (paper §5.3.3)
# --------------------------------------------------------------------- #

@dataclass(frozen=True)
class EqualizationStrategy:
    """'normal' keeps profiled IIs; 'conservative' scales every kernel's II to
    the slowest kernel's throughput, shrinking FIFO depths at the cost of
    latency (area/performance trade-off, paper §5.3.3)."""

    kind: str = "normal"

    def apply(self, timings: dict, num_tokens: dict) -> dict:
        if self.kind == "normal":
            return dict(timings)
        if self.kind != "conservative":
            raise ValueError(f"unknown equalization {self.kind}")
        slowest = max(t.pipeline_ii for t in timings.values())
        return {
            name: t.with_ii(slowest, num_tokens[name])
            for name, t in timings.items()
        }
