"""On-chip memory tier allocation — paper §5.3(3).

The paper places each buffer in LUTRAM, BRAM, or URAM "prioritized by size".
We reproduce that policy generically over a platform's tier table and map it
to the TPU hierarchy (SMEM / VMEM / HBM-spill).  Inputs are the buffers the
rest of the compiler produced: converter ping-pong windows (Alg. 1), FIFO
backing stores (LP sizing), DMA staging buffers, and kernel accumulators.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple


@dataclass(frozen=True)
class MemoryTier:
    name: str
    capacity_bytes: float
    word_bytes: int = 8          # allocation granularity
    max_buffer_bytes: Optional[float] = None   # per-buffer cap (LUTRAM-like)


# Paper platform (U55C): LUTRAM ~ distributed RAM, BRAM 36Kb blocks, URAM 288Kb.
U55C_TIERS = (
    MemoryTier("LUTRAM", 2 * 2**20, word_bytes=8, max_buffer_bytes=4096),
    MemoryTier("BRAM", 9 * 2**20, word_bytes=4608),
    MemoryTier("URAM", 30 * 2**20, word_bytes=36864),
)

# TPU target: SMEM (scalar scratch), VMEM (vector memory), HBM spill.
TPU_TIERS = (
    MemoryTier("SMEM", 1 * 2**20, word_bytes=4, max_buffer_bytes=16384),
    MemoryTier("VMEM", 128 * 2**20, word_bytes=4096),
    MemoryTier("HBM", 16 * 2**30, word_bytes=4096),
)


@dataclass
class Buffer:
    name: str
    bytes: float
    kind: str = "buffer"     # converter | fifo | staging | accumulator


@dataclass
class AllocationResult:
    placement: Dict[str, str]            # buffer -> tier name
    tier_used: Dict[str, float]
    spilled: List[str]                   # buffers that fell to the last tier

    def utilization(self, tiers: Sequence[MemoryTier]) -> Dict[str, float]:
        caps = {t.name: t.capacity_bytes for t in tiers}
        return {n: self.tier_used.get(n, 0.0) / caps[n] for n in caps}


def allocate(buffers: Sequence[Buffer],
             tiers: Sequence[MemoryTier] = TPU_TIERS) -> AllocationResult:
    """Paper policy: sort by size, place each buffer in the smallest tier that
    (a) admits its size per-buffer cap and (b) still has capacity; rounded up
    to the tier's allocation word."""
    used: Dict[str, float] = {t.name: 0.0 for t in tiers}
    placement: Dict[str, str] = {}
    spilled: List[str] = []
    for buf in sorted(buffers, key=lambda b: b.bytes):
        placed = False
        for tier in tiers:
            size = math.ceil(buf.bytes / tier.word_bytes) * tier.word_bytes
            if tier.max_buffer_bytes and buf.bytes > tier.max_buffer_bytes:
                continue
            if used[tier.name] + size <= tier.capacity_bytes:
                used[tier.name] += size
                placement[buf.name] = tier.name
                placed = True
                break
        if not placed:
            last = tiers[-1]
            size = math.ceil(buf.bytes / last.word_bytes) * last.word_bytes
            used[last.name] += size
            placement[buf.name] = last.name
            spilled.append(buf.name)
    if spilled and tiers[-1].name != "HBM":
        pass  # FPGA: overflow is a fusion-feedback signal, surfaced by caller
    return AllocationResult(placement=placement, tier_used=used,
                            spilled=spilled)


def buffers_from_plan(graph, fusion, fifo) -> List[Buffer]:
    """Collect every on-chip buffer the compiler produced for allocation."""
    out: List[Buffer] = []
    for k in graph.kernels():
        if k.local_bytes:
            out.append(Buffer(f"acc:{k.name}", k.local_bytes, "accumulator"))
    for u, v, key, data in graph.edges():
        if fusion.index.get(u) != fusion.index.get(v):
            continue
        conv = graph.edge_converter(u, v, key)
        if conv is not None:
            out.append(Buffer(f"conv:{u}->{v}#{key}", conv.pingpong_bytes,
                              "converter"))
        out.append(Buffer(f"fifo:{u}->{v}#{key}",
                          fifo.fifo_bytes[(u, v, key)], "fifo"))
    return out
