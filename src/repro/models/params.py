"""Parameter & cache definitions: shapes, logical sharding axes, init.

Every parameter is declared once as a ``ParamDef`` (shape + logical axes +
init rule); from the definition tree we derive
  * ``init_params``     — materialized f32 master weights (smoke tests,
    examples; big models are never materialized on this host),
  * ``abstract_params`` — ``jax.ShapeDtypeStruct`` stand-ins (dry-run),
  * ``logical_axes``    — the logical-axis pytree the distributed layer maps
    to mesh ``PartitionSpec``s with divisibility fallbacks.

Layer stacking: layers are grouped into repeating *pattern groups* (period =
sliding/shared-attn pattern, 1 for homogeneous stacks) and stacked over the
group axis for ``lax.scan``; remainder layers (L % period) are kept unstacked.
Zamba2's shared attention block is a single unstacked copy (true parameter
sharing).

Sharding deviation (documented in DESIGN.md §13): tied input/output
embeddings are stored untied — the input table shards over d_model (local
gather) while the LM head shards over vocab (Megatron-style streamed CE) —
because one array cannot carry both layouts without a per-step all-gather.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig

Tree = Any

VOCAB_PAD = 256


def padded_vocab(v: int) -> int:
    return ((v + VOCAB_PAD - 1) // VOCAB_PAD) * VOCAB_PAD


@dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"        # normal|zeros|ones|a_log|dt_bias|decay|pos
    fan_in: Optional[int] = None
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _mat(d_in: int, d_out: int, ax_in: str, ax_out: str) -> ParamDef:
    return ParamDef((d_in, d_out), (ax_in, ax_out), "normal", fan_in=d_in)


def _vec(n: int, ax: Optional[str] = None, init: str = "zeros") -> ParamDef:
    return ParamDef((n,), (ax,), init)


def _norm_defs(cfg: ModelConfig, d: Optional[int] = None) -> Dict[str, ParamDef]:
    d = d or cfg.d_model
    out = {"scale": _vec(d)}
    if cfg.norm == "layernorm":
        out["bias"] = _vec(d)
    return out


# --------------------------------------------------------------------- #
# Block definitions
# --------------------------------------------------------------------- #

def _attn_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    d, dq, dkv, hd = cfg.d_model, cfg.q_dim, cfg.kv_dim, cfg.head_dim_
    out = {
        "wq": _mat(d, dq, "d_model", "q_dim"),
        "wk": _mat(d, dkv, "d_model", "kv_dim"),
        "wv": _mat(d, dkv, "d_model", "kv_dim"),
        "wo": _mat(dq, d, "q_dim", "d_model"),
    }
    if cfg.qkv_bias:
        out["bq"] = _vec(dq, "q_dim")
        out["bk"] = _vec(dkv, "kv_dim")
        out["bv"] = _vec(dkv, "kv_dim")
    if cfg.qk_norm:
        out["q_norm"] = _vec(hd)
        out["k_norm"] = _vec(hd)
    return out


def _ffn_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    d, f = cfg.d_model, cfg.d_ff
    if cfg.is_moe:
        e = cfg.num_experts
        out = {
            "wr": _mat(d, e, "d_model", "experts"),
            "wu": ParamDef((e, d, f), ("experts", "d_model", "d_ff"),
                           "normal", fan_in=d),
            "wd": ParamDef((e, f, d), ("experts", "d_ff", "d_model"),
                           "normal", fan_in=f),
        }
        if cfg.gated_ffn:
            out["wg"] = ParamDef((e, d, f), ("experts", "d_model", "d_ff"),
                                 "normal", fan_in=d)
        return out
    out = {"wu": _mat(d, f, "d_model", "d_ff"),
           "wd": _mat(f, d, "d_ff", "d_model")}
    if cfg.gated_ffn:
        out["wg"] = _mat(d, f, "d_model", "d_ff")
    return out


def _mamba_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    d, di = cfg.d_model, cfg.d_inner
    h, n, k = cfg.ssm_heads, cfg.ssm_state, cfg.conv_width
    return {
        "wx": _mat(d, di, "d_model", "d_inner"),
        "wz": _mat(d, di, "d_model", "d_inner"),
        "wb": _mat(d, n, "d_model", None),
        "wc": _mat(d, n, "d_model", None),
        "wdt": _mat(d, h, "d_model", "ssm_heads"),
        "dt_bias": ParamDef((h,), ("ssm_heads",), "dt_bias"),
        "a_log": ParamDef((h,), ("ssm_heads",), "a_log"),
        "d_skip": ParamDef((h,), ("ssm_heads",), "ones"),
        "conv_w": ParamDef((k, di), (None, "d_inner"), "normal", fan_in=k),
        "conv_b": _vec(di, "d_inner"),
        "wout": _mat(di, d, "d_inner", "d_model"),
    }


def _rwkv_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    d, f = cfg.d_model, cfg.d_ff
    h, n = cfg.rwkv_heads, cfg.rwkv_head_dim
    tm = {f"mix_{nm}": _vec(d, init="ones") for nm in "rkvgw"}
    tm.update({
        "wr": _mat(d, d, "d_model", "rwkv_dim"),
        "wk": _mat(d, d, "d_model", "rwkv_dim"),
        "wv": _mat(d, d, "d_model", "rwkv_dim"),
        "wg": _mat(d, d, "d_model", "rwkv_dim"),
        "ww": _mat(d, d, "d_model", "rwkv_dim"),
        "w_bias": ParamDef((h, n), ("rwkv_heads", None), "decay"),
        "u": ParamDef((h, n), ("rwkv_heads", None), "zeros"),
        "wo": _mat(d, d, "rwkv_dim", "d_model"),
    })
    cm = {
        "mix_k": _vec(d, init="ones"),
        "mix_r": _vec(d, init="ones"),
        "wk": _mat(d, f, "d_model", "d_ff"),
        "wv": _mat(f, d, "d_ff", "d_model"),
        "wr": _mat(d, d, "d_model", "rwkv_dim"),
    }
    return {"tm": tm, "cm": cm}


def block_defs(cfg: ModelConfig, kind: str) -> Dict[str, Tree]:
    """Parameter definition tree for one layer of the given kind."""
    if kind == "rwkv":
        return {"ln1": _norm_defs(cfg), "ln2": _norm_defs(cfg),
                **_rwkv_defs(cfg)}
    if kind.startswith("mamba"):
        # Shared-attn params live OUTSIDE the stack (single copy).
        return {"ln": _norm_defs(cfg), "mamba": _mamba_defs(cfg)}
    # attention kinds: attn | local_attn | global_attn
    return {"ln1": _norm_defs(cfg), "attn": _attn_defs(cfg),
            "ln2": _norm_defs(cfg), "mlp": _ffn_defs(cfg)}


def shared_block_defs(cfg: ModelConfig) -> Dict[str, Tree]:
    """Zamba2 shared attention+MLP block (one copy, applied every k layers)."""
    ffn_cfg = cfg if not cfg.is_moe else cfg
    return {"ln1": _norm_defs(cfg), "attn": _attn_defs(cfg),
            "ln2": _norm_defs(cfg), "mlp": _ffn_defs(ffn_cfg)}


def model_defs(cfg: ModelConfig) -> Dict[str, Tree]:
    vp = padded_vocab(cfg.vocab_size)
    d = cfg.d_model
    period = len(cfg.layer_pattern)
    groups, rest = divmod(cfg.num_layers, period)

    defs: Dict[str, Tree] = {}
    # Input embedding table: vocab dim deliberately UNSHARDED ("embed_vocab")
    # so the token gather stays device-local; the feature dim shards over the
    # model axis instead ("embed_dim") and the activation all-gathers.  The
    # LM head shards over vocab for Megatron-style streamed CE.  This is why
    # tied embeddings are stored untied (DESIGN.md §13).
    if cfg.frontend == "none" or not cfg.encoder_only:
        # Modality-frontend archs still embed generated tokens at decode.
        defs["embed"] = ParamDef((vp, d), ("embed_vocab", "embed_dim"),
                                 "normal", fan_in=d)
    if cfg.rope == "none" and not cfg.rwkv:
        defs["pos_embed"] = ParamDef((32_768, d), (None, "embed_dim"),
                                     "normal", fan_in=d)

    # Pattern-group stack: one subtree per position in the period, every leaf
    # stacked over the group axis.
    def stack(defs_tree: Tree) -> Tree:
        return jax.tree.map(
            lambda pd: ParamDef((groups,) + pd.shape, ("layers",) + pd.axes,
                                pd.init, pd.fan_in, pd.dtype),
            defs_tree,
            is_leaf=lambda x: isinstance(x, ParamDef))

    defs["blocks"] = tuple(
        stack(block_defs(cfg, cfg.layer_pattern[p])) for p in range(period))
    defs["rest"] = tuple(
        block_defs(cfg, cfg.layer_kind(groups * period + i))
        for i in range(rest))
    if cfg.shared_attn_every:
        defs["shared"] = shared_block_defs(cfg)

    defs["final_norm"] = _norm_defs(cfg)
    defs["lm_head"] = ParamDef((d, vp), ("d_model", "vocab"), "normal",
                               fan_in=d)
    return defs


# --------------------------------------------------------------------- #
# Materialization
# --------------------------------------------------------------------- #

def _is_def(x) -> bool:
    return isinstance(x, ParamDef)


def _init_leaf(pd: ParamDef, key: jax.Array) -> jax.Array:
    if pd.init == "zeros":
        return jnp.zeros(pd.shape, pd.dtype)
    if pd.init == "ones":
        return jnp.ones(pd.shape, pd.dtype)
    if pd.init == "a_log":
        h = pd.shape[-1]
        base = jnp.linspace(1.0, 16.0, h)
        return jnp.broadcast_to(jnp.log(base), pd.shape).astype(pd.dtype)
    if pd.init == "dt_bias":
        # inverse softplus of dt ~ logspace(1e-3, 1e-1)
        h = pd.shape[-1]
        dt = jnp.exp(jnp.linspace(math.log(1e-3), math.log(1e-1), h))
        return jnp.broadcast_to(jnp.log(jnp.expm1(dt)),
                                pd.shape).astype(pd.dtype)
    if pd.init == "decay":
        n = pd.shape[-1]
        base = jnp.linspace(-6.0, -0.5, n)
        return jnp.broadcast_to(base, pd.shape).astype(pd.dtype)
    scale = 1.0 / math.sqrt(pd.fan_in or pd.shape[0])
    return (jax.random.normal(key, pd.shape, jnp.float32)
            * scale).astype(pd.dtype)


def init_params(rng: jax.Array, cfg: ModelConfig) -> Tree:
    defs = model_defs(cfg)
    leaves, treedef = jax.tree.flatten(defs, is_leaf=_is_def)
    keys = jax.random.split(rng, len(leaves))
    vals = [_init_leaf(pd, k) for pd, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(cfg: ModelConfig) -> Tree:
    return jax.tree.map(lambda pd: jax.ShapeDtypeStruct(pd.shape, pd.dtype),
                        model_defs(cfg), is_leaf=_is_def)


def logical_axes(cfg: ModelConfig) -> Tree:
    return jax.tree.map(lambda pd: pd.axes, model_defs(cfg), is_leaf=_is_def)


def param_bytes(cfg: ModelConfig) -> int:
    total = 0
    for pd in jax.tree.leaves(model_defs(cfg), is_leaf=_is_def):
        total += math.prod(pd.shape) * jnp.dtype(pd.dtype).itemsize
    return total


# --------------------------------------------------------------------- #
# Decode caches
# --------------------------------------------------------------------- #

# Cache-leaf schema — the single source of truth for what each decode-cache
# leaf *is*.  Everything that walks a cache pytree (the serving engine's
# placement, the paged KV cache, the model's kv-length probe) classifies
# leaves through ``cache_leaf_kind`` instead of re-matching names ad hoc, so
# a new state leaf that is added here is handled everywhere — and a leaf
# that is NOT registered raises instead of being silently whole-replaced.
KV_CACHE_LEAVES = ("k", "v")                       # carry a sequence axis
STATE_CACHE_LEAVES = ("ssm", "conv", "wkv",        # slot-contiguous state
                      "tm_shift", "cm_shift")
# Per-page f32 dequant scales riding next to quantized paged K/V pools
# ([G, num_pages, Hkv]; DESIGN.md §14).  Only present in paged quantized
# cache trees — the contiguous decode cache never quantizes.
SCALE_CACHE_LEAVES = ("k_scale", "v_scale")


def cache_leaf_name(path) -> str:
    """Leaf name from a ``tree_map_with_path`` key path."""
    last = path[-1]
    return last.key if hasattr(last, "key") else str(last)


def cache_leaf_kind(name: str) -> str:
    """'kv' (paged / sequence-carrying), 'scale' (per-page dequant scales)
    or 'state' (slot-contiguous)."""
    if name in KV_CACHE_LEAVES:
        return "kv"
    if name in SCALE_CACHE_LEAVES:
        return "scale"
    if name in STATE_CACHE_LEAVES:
        return "state"
    raise ValueError(
        f"unregistered cache leaf {name!r}: add it to KV_CACHE_LEAVES, "
        "SCALE_CACHE_LEAVES or STATE_CACHE_LEAVES in models/params.py")


def kv_seq_axis(layout: str) -> int:
    """Sequence axis of a K/V cache leaf, counted from the END so the same
    value is correct at every stacking level ([G,B,...], [B,...], [...])."""
    return -2 if layout == "bhsd" else -3


@dataclass(frozen=True)
class CacheDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    dtype: Any = jnp.bfloat16


def _attn_cache(cfg: ModelConfig, groups: int, batch: int,
                max_len: int) -> Dict[str, CacheDef]:
    hkv, hd = cfg.num_kv_heads, cfg.head_dim_
    if cfg.kv_cache_layout == "bhsd":
        # Attention-native layout (§Perf I5c): the decode einsum consumes
        # the cache directly — no per-token full-cache transpose copy.
        shape = (groups, batch, hkv, max_len, hd)
        axes = ("layers", "kv_batch", "kv_heads", "kv_seq", None)
    else:
        shape = (groups, batch, max_len, hkv, hd)
        axes = ("layers", "kv_batch", "kv_seq", "kv_heads", None)
    # K/V storage follows the compute dtype: under bf16 compute the cache
    # rounds nothing the activations didn't already round, and under f32
    # compute a bf16 cache would make chunked prefill (which re-reads its
    # own chunk's K/V through the cache) diverge from whole-prompt prefill.
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    return {"k": CacheDef(shape, axes, dt), "v": CacheDef(shape, axes, dt)}


def _mamba_cache(cfg: ModelConfig, groups: int, batch: int) -> Dict[str, CacheDef]:
    h, p, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    return {
        "ssm": CacheDef((groups, batch, h, p, n),
                        ("layers", "kv_batch", "ssm_heads", None, None),
                        jnp.float32),
        "conv": CacheDef((groups, batch, cfg.conv_width - 1, cfg.d_inner),
                         ("layers", "kv_batch", None, "d_inner")),
    }


def _rwkv_cache(cfg: ModelConfig, groups: int, batch: int) -> Dict[str, CacheDef]:
    h, n, d = cfg.rwkv_heads, cfg.rwkv_head_dim, cfg.d_model
    return {
        "wkv": CacheDef((groups, batch, h, n, n),
                        ("layers", "kv_batch", "rwkv_heads", None, None),
                        jnp.float32),
        "tm_shift": CacheDef((groups, batch, d),
                             ("layers", "kv_batch", None)),
        "cm_shift": CacheDef((groups, batch, d),
                             ("layers", "kv_batch", None)),
    }


def cache_defs(cfg: ModelConfig, batch: int, max_len: int) -> Tree:
    """Decode-state definition tree, mirroring the block structure."""
    period = len(cfg.layer_pattern)
    groups, rest = divmod(cfg.num_layers, period)

    def one(kind: str, g: int) -> Dict[str, Tree]:
        if kind == "rwkv":
            return _rwkv_cache(cfg, g, batch)
        if kind == "mamba":
            return _mamba_cache(cfg, g, batch)
        if kind == "mamba+shared_attn":
            return {**_mamba_cache(cfg, g, batch),
                    **_attn_cache(cfg, g, batch, max_len)}
        return _attn_cache(cfg, g, batch, max_len)

    return {
        "blocks": tuple(one(cfg.layer_pattern[p], groups)
                        for p in range(period)),
        "rest": tuple(one(cfg.layer_kind(groups * period + i), 1)
                      for i in range(rest)),
    }


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Tree:
    return jax.tree.map(
        lambda cd: jnp.zeros(cd.shape, cd.dtype),
        cache_defs(cfg, batch, max_len),
        is_leaf=lambda x: isinstance(x, CacheDef))


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int) -> Tree:
    return jax.tree.map(
        lambda cd: jax.ShapeDtypeStruct(cd.shape, cd.dtype),
        cache_defs(cfg, batch, max_len),
        is_leaf=lambda x: isinstance(x, CacheDef))


def cache_logical_axes(cfg: ModelConfig, batch: int, max_len: int) -> Tree:
    return jax.tree.map(lambda cd: cd.axes,
                        cache_defs(cfg, batch, max_len),
                        is_leaf=lambda x: isinstance(x, CacheDef))
