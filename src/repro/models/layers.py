"""Model layers in pure JAX (functions over param pytrees).

Design notes (see DESIGN.md §7):
  * Attention is implemented in its *streaming* form — a ``lax.scan`` over KV
    chunks with a running (max, sum, acc) softmax — which is the TPU-native
    twin of the paper's stream-based dataflow: the score matrix is never
    materialized, intermediates stay in fast memory, and the same chunk loop
    is what the Pallas flash kernel implements at the BlockSpec level.
  * GQA is expressed by grouping query heads over KV heads (no KV repeat
    materialization).
  * Sliding-window layers use the two-chunk trick (chunk == window) so local
    attention is O(S * w).
  * Mamba2 uses the chunked SSD algorithm (parallel intra-chunk, scanned
    inter-chunk); RWKV6 uses a ``lax.scan`` linear recurrence with
    data-dependent diagonal decay.  Both have single-step decode forms.

All functions take/return plain jnp arrays; parameters are dicts produced by
``params.py``.  Compute dtype is the caller's; accumulation in float32.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

Params = Dict[str, Any]

NEG_INF = -1e30

# Trace-time dispatch records (mesh-aware StreamPlan, DESIGN.md §9): each
# fused wrapper bumps "shard_map" when it dispatched its kernel under
# shard_map and "single" when it ran single-device — the probe the sharded
# serving tests use to assert the fused path really went multi-device
# (counts PROGRAMS TRACED, not calls, like the engine's trace probes).
DISPATCH_RECORDS: Dict[str, int] = {"shard_map": 0, "single": 0}


def reset_dispatch_records() -> None:
    DISPATCH_RECORDS["shard_map"] = 0
    DISPATCH_RECORDS["single"] = 0


# --------------------------------------------------------------------- #
# Dispatch effect signatures (static analysis, DESIGN.md §15)
# --------------------------------------------------------------------- #
# Declarative read/write effects of the serving engine's jitted
# dispatches over their DONATED buffers — the facts the alias & donation
# checker (analysis/effects.py) verifies without tracing anything.  One
# entry per compiled dispatch; ops appear in program order.  Op fields:
#
#   reads          — buffers read wherever they currently are (in-place
#                    scatter/gather semantics; safe after earlier writes).
#   reads_initial  — buffers whose PRE-DISPATCH state the op needs; a
#                    read-after-write on a donated buffer here is a bug.
#   writes         — buffers the op updates in place (donation makes
#                    these true aliases of the caller's arrays).
#   page_indexed   — the write scatters through the page table; such
#                    ops MUST set null_routed (masked writes land on the
#                    sacrificial NULL page, kv_cache.NULL_PAGE) and,
#                    under a KV QuantMode, updates_scales (the per-page
#                    scale twin updates in lockstep with the codes).
#   cow            — copy-on-write step: duplicates pool page ``src``
#                    onto ``dst`` before any scatter.  ``fresh_dst``
#                    declares the allocator invariant that dst is a
#                    freshly-allocated private page (never aliasing src
#                    unless both are NULL) — without it a shared page
#                    could be overwritten in place.
#
# The declarations mirror serving/engine.py (_prefill / _decode /
# _verify / _prefill_chunk) and models/model.py; keep them in sync when
# a dispatch gains an operand.
DISPATCH_EFFECTS: Dict[str, Dict[str, Any]] = {
    "prefill": {
        "donated": ("slot_cache",),
        "ops": (
            {"name": "model_prefill", "reads": ("params", "tokens"),
             "writes": ("fresh",)},
            {"name": "place_prefill", "reads": ("fresh", "pages"),
             "writes": ("slot_cache",), "page_indexed": True,
             "null_routed": True, "updates_scales": True},
        ),
    },
    "prefill_chunk": {
        "donated": ("slot_cache",),
        "ops": (
            {"name": "cow_copy",
             "reads_initial": ("slot_cache",), "writes": ("slot_cache",),
             "page_indexed": True, "null_routed": True,
             "updates_scales": True,
             "cow": {"src": "cow_src", "dst": "cow_dst",
                     "fresh_dst": True}},
            {"name": "chunk_scatter",
             "reads": ("params", "tokens", "table_row", "chunk_pages",
                       "slot_cache"),
             "writes": ("slot_cache",), "page_indexed": True,
             "null_routed": True, "updates_scales": True},
        ),
    },
    "decode": {
        "donated": ("cache",),
        "ops": (
            {"name": "cow_copy",
             "reads_initial": ("cache",), "writes": ("cache",),
             "page_indexed": True, "null_routed": True,
             "updates_scales": True,
             "cow": {"src": "cow_src", "dst": "cow_dst",
                     "fresh_dst": True}},
            {"name": "decode_scan",
             "reads": ("params", "tok", "cache", "table"),
             "writes": ("cache",), "page_indexed": True,
             "null_routed": True, "updates_scales": True},
        ),
    },
    "verify": {
        "donated": ("cache",),
        "ops": (
            {"name": "cow_copy",
             "reads_initial": ("cache",), "writes": ("cache",),
             "page_indexed": True, "null_routed": True,
             "updates_scales": True,
             "cow": {"src": "cow_src", "dst": "cow_dst",
                     "fresh_dst": True}},
            {"name": "verify_window",
             "reads": ("params", "toks", "cache", "table"),
             "writes": ("cache",), "page_indexed": True,
             "null_routed": True, "updates_scales": True},
        ),
    },
}


def _shard_mesh(shard):
    """The active mesh for a plan sharding claim (None = single-device).

    The claim comes from the StreamPlan (``KernelChoice.sharding``); the
    mesh comes from the ``distributed.context`` the engine / step builder
    installed around tracing.  Either absent -> plain dispatch.
    """
    if not shard:
        return None
    from ..distributed.context import current_mesh   # lazy: no core->dist cycle
    return current_mesh()


def _claim_axis(mesh, shard, dim: str, extent: int):
    """Mesh axis (or axis group, e.g. ('pod', 'data')) the plan claimed
    for ``dim``, if the RUNTIME extent divides.  Plan-time claims check
    config-derived extents; batch/token extents are only known here.  A
    grouped claim degrades like ``spec_for``'s candidate chain — drop
    leading axes (('pod','data') -> ('data',)) before giving up — and an
    extent that divides nothing falls back to replication for that dim,
    never to eager."""
    ax = dict(shard).get(dim)
    if mesh is None or ax is None:
        return None
    axes = ax if isinstance(ax, tuple) else (ax,)
    if any(a not in mesh.axis_names for a in axes):
        return None
    for start in range(len(axes)):
        cand = axes[start:]
        size = 1
        for a in cand:
            size *= int(mesh.shape[a])
        if size > 1 and extent % size == 0:
            return cand if len(cand) > 1 else cand[0]
    return None


def _smap(fn, mesh, in_specs, out_specs):
    """shard_map a kernel dispatch (version-tolerant) and record it."""
    from ..distributed.context import shard_map
    DISPATCH_RECORDS["shard_map"] += 1
    return shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs)


# --------------------------------------------------------------------- #
# Norms
# --------------------------------------------------------------------- #

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mean = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mean) * lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(dtype)


def apply_norm(kind: str, x: jax.Array, p: Params) -> jax.Array:
    if kind == "rmsnorm":
        return rms_norm(x, p["scale"])
    return layer_norm(x, p["scale"], p["bias"])


# --------------------------------------------------------------------- #
# Weight-only int8 (DESIGN.md §14)
# --------------------------------------------------------------------- #

def quantize_channelwise(w: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-output-channel int8: w [D, N] -> (codes int8, scales
    [N] f32 with scale = amax|col| / 127).  An all-zero column encodes to
    zero codes with scale 0 (dequant stays exact)."""
    w32 = w.astype(jnp.float32)
    amax = jnp.max(jnp.abs(w32), axis=0)
    scales = amax / 127.0
    safe = jnp.where(scales > 0.0, scales, 1.0)
    codes = jnp.clip(jnp.round(w32 / safe), -127.0, 127.0).astype(jnp.int8)
    return codes, scales


def dequantize_channelwise(codes: jax.Array, scales: jax.Array,
                           dtype) -> jax.Array:
    return (codes.astype(jnp.float32) * scales[None, :]).astype(dtype)


def _w8_ste(w: jax.Array) -> jax.Array:
    """Quantize-dequantize with a straight-through gradient: the forward
    value carries the int8 rounding (matching the fused w8 kernels bit for
    bit in the eager reference), the backward passes cotangents through as
    if ``w`` were untouched."""
    codes, scales = quantize_channelwise(w)
    wq = dequantize_channelwise(codes, scales, w.dtype)
    return w + lax.stop_gradient(wq - w)


# --------------------------------------------------------------------- #
# Rotary embeddings (RoPE and M-RoPE)
# --------------------------------------------------------------------- #

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [B, S, H, D]; positions: [B, S] (int)."""
    half = x.shape[-1] // 2
    freqs = rope_freqs(x.shape[-1], theta)                      # [half]
    angles = positions[..., None].astype(jnp.float32) * freqs   # [B,S,half]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


# M-RoPE (Qwen2-VL): the rotary half-dim is split into (temporal, height,
# width) sections, each rotated by its own position stream.
MROPE_SECTIONS = (2, 1, 1)   # fractions of the half-dim: t=1/2, h=1/4, w=1/4


def apply_mrope(x: jax.Array, positions: jax.Array,
                theta: float) -> jax.Array:
    """x: [B, S, H, D]; positions: [3, B, S] (temporal, height, width)."""
    half = x.shape[-1] // 2
    freqs = rope_freqs(x.shape[-1], theta)                      # [half]
    total = sum(MROPE_SECTIONS)
    sizes = [half * s // total for s in MROPE_SECTIONS]
    sizes[-1] = half - sum(sizes[:-1])
    angle_parts = []
    start = 0
    for sec, size in enumerate(sizes):
        f = freqs[start:start + size]
        pos = positions[sec].astype(jnp.float32)                # [B,S]
        angle_parts.append(pos[..., None] * f)
        start += size
    angles = jnp.concatenate(angle_parts, axis=-1)              # [B,S,half]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


def apply_positional(kind: str, x: jax.Array, positions: jax.Array,
                     theta: float) -> jax.Array:
    if kind == "rope":
        return apply_rope(x, positions, theta)
    if kind == "mrope":
        return apply_mrope(x, positions, theta)
    return x


# --------------------------------------------------------------------- #
# Streaming (chunked / flash-style) attention
# --------------------------------------------------------------------- #

def _gqa_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """q: [B,Sq,Kh,G,D], k: [B,C,Kh,D] -> scores [B,Kh,G,Sq,C] (f32)."""
    return jnp.einsum("bqhgd,bchd->bhgqc", q, k,
                      preferred_element_type=jnp.float32)


def streaming_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, *,
    causal: bool = True,
    q_offset: int = 0,
    window: int = 0,
    chunk_size: int = 1024,
    kv_len=None,
    scale: Optional[float] = None,
    remat_chunk: bool = False,
) -> jax.Array:
    """Chunked online-softmax attention.

    Args:
        q: [B, Sq, Hq, D]; k, v: [B, Skv, Hkv, D] with Hq % Hkv == 0.
        causal: apply causal masking with query positions q_offset + i.
        q_offset: absolute position of q[0] relative to k[0] (prefill: 0 when
            Sq == Skv; decode-style calls use full-cache helpers instead).
            May be a traced scalar (chunked prefill against a cache).
        window: sliding window size (0 = unlimited); causal only.
        chunk_size: KV tile length (the stream token granularity).
        kv_len: valid KV entries (default Skv); may be a traced scalar when
            K/V come from a partially-filled cache extent.
    Returns: [B, Sq, Hq, D].
    """
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    kv_len = skv if kv_len is None else kv_len
    g = hq // hkv
    sc = scale if scale is not None else 1.0 / math.sqrt(d)
    qg = (q * sc).reshape(b, sq, hkv, g, d)

    c = min(chunk_size, skv)
    if skv % c != 0:  # pad KV up to a chunk multiple; padding masked off
        pad = c - skv % c
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = k.shape[1] // c
    kc = k.reshape(b, nc, c, hkv, d).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nc, c, hkv, d).transpose(1, 0, 2, 3, 4)

    q_pos = q_offset + jnp.arange(sq)

    def step(carry, inputs):
        m, l, acc = carry
        ci, (kb, vb) = inputs
        kv_pos = ci * c + jnp.arange(c)
        s = _gqa_scores(qg, kb)                       # [B,Kh,G,Sq,C]
        mask = kv_pos[None, :] <= q_pos[:, None] if causal else \
            jnp.ones((sq, c), dtype=bool)
        mask = jnp.logical_and(mask, kv_pos[None, :] < kv_len)
        if window:
            mask = jnp.logical_and(
                mask, kv_pos[None, :] > q_pos[:, None] - window)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        # Explicitly zero masked lanes: for a fully-masked chunk both s and
        # m_new sit at NEG_INF and exp(s - m_new) would be exp(0) = 1.
        p = jnp.where(mask[None, None, None], jnp.exp(s - m_new[..., None]),
                      0.0)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhgqc,bchd->bhgqd", p.astype(vb.dtype), vb,
                        preferred_element_type=jnp.float32)
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hkv, g, sq), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sq), dtype=jnp.float32)
    a0 = jnp.zeros((b, hkv, g, sq, d), dtype=jnp.float32)
    # remat_chunk: recompute score tiles in the backward pass instead of
    # stacking per-chunk residuals across the scan (flash-attention-style
    # O(1) residency; §Perf gemma3 hillclimb).
    body = jax.checkpoint(step) if remat_chunk else step
    (m, l, acc), _ = lax.scan(body, (m0, l0, a0),
                              (jnp.arange(nc), (kc, vc)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, hq, d).astype(q.dtype)


def local_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    window: int, q_offset: int = 0,
                    remat_chunk: bool = False) -> jax.Array:
    """Sliding-window attention via the streaming kernel with chunk=window
    (each query chunk touches at most 2 KV chunks worth of live scores)."""
    return streaming_attention(q, k, v, causal=True, q_offset=q_offset,
                               window=window,
                               chunk_size=max(128, min(window, k.shape[1])),
                               remat_chunk=remat_chunk)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cache_len: jax.Array, *,
                     window: int = 0, layout: str = "bshd") -> jax.Array:
    """One-token attention against a (possibly sequence-sharded) KV cache.

    q: [B, 1, Hq, D]; caches: [B, S, Hkv, D] ("bshd") or [B, Hkv, S, D]
    ("bhsd" — attention-native, §Perf I5c); cache_len: [] or [B] valid
    entries.  The softmax reduction over S lowers to a sharded reduce when
    S is sharded over the model axis (context-parallel decode).
    """
    b, _, hq, d = q.shape
    if layout == "bhsd":
        hkv, s = k_cache.shape[1], k_cache.shape[2]
    else:
        s, hkv = k_cache.shape[1], k_cache.shape[2]
    g = hq // hkv
    qg = (q * (1.0 / math.sqrt(d))).reshape(b, 1, hkv, g, d)
    k_eq = "bhsd" if layout == "bhsd" else "bshd"
    if layout == "bhsd":
        # Attention-native layout: the einsum consumes the cache directly
        # (no transpose copy).  Emit in the cache dtype — the MXU still
        # accumulates f32 per tile; softmax runs in f32 below.
        scores = jnp.einsum(f"bqhgd,{k_eq}->bhgqs", qg,
                            k_cache).astype(jnp.float32)
    else:
        scores = jnp.einsum(f"bqhgd,{k_eq}->bhgqs", qg, k_cache,
                            preferred_element_type=jnp.float32)
    pos = jnp.arange(s)
    valid = pos[None] < jnp.reshape(cache_len, (-1, 1))          # [B,S]
    if window:
        valid = jnp.logical_and(
            valid, pos[None] >= jnp.reshape(cache_len, (-1, 1)) - window)
    scores = jnp.where(valid[:, None, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    # PV stays in f32 (p uncast; the cache promotes): the paged decode
    # kernel folds pages through the same f32 online softmax, and the
    # plan-selectable paged path is required to match this one to 1e-5 —
    # a bf16 downcast of p here would round at a different scale than the
    # kernel's running (m, l) and break that contract.
    out = jnp.einsum(f"bhgqs,{k_eq}->bqhgd", p, v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, hq, d).astype(q.dtype)


def verify_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     q_off: jax.Array, *, window: int = 0,
                     layout: str = "bshd") -> jax.Array:
    """W-token speculative-verify attention (eager reference path).

    q: [B, W, Hq, D] — the pending token plus W-1 draft candidates;
    caches: [B, S, Hkv, D] ("bshd") or [B, Hkv, S, D] ("bhsd"); q_off:
    [B] absolute position of window row 0, so row i's causal extent is
    ``q_off + i + 1``.  The W-row twin of ``decode_attention`` under the
    same numerics contract: scores in f32, f32 softmax, f32 PV — row i
    computes exactly what ``decode_attention`` would at length
    ``q_off + i + 1`` (extra cache rows score exact NEG_INF and drop out
    of the softmax as exact zeros), which is what lets the engine accept
    draft tokens without perturbing the greedy stream.
    """
    b, w, hq, d = q.shape
    if layout == "bhsd":
        hkv, s = k_cache.shape[1], k_cache.shape[2]
    else:
        s, hkv = k_cache.shape[1], k_cache.shape[2]
    g = hq // hkv
    qg = (q * (1.0 / math.sqrt(d))).reshape(b, w, hkv, g, d)
    k_eq = "bhsd" if layout == "bhsd" else "bshd"
    if layout == "bhsd":
        scores = jnp.einsum(f"bqhgd,{k_eq}->bhgqs", qg,
                            k_cache).astype(jnp.float32)
    else:
        scores = jnp.einsum(f"bqhgd,{k_eq}->bhgqs", qg, k_cache,
                            preferred_element_type=jnp.float32)
    pos = jnp.arange(s)
    qlen = jnp.reshape(q_off, (-1, 1)) + jnp.arange(w)[None] + 1  # [B,W]
    valid = pos[None, None] < qlen[..., None]                     # [B,W,S]
    if window:
        valid = jnp.logical_and(valid, pos[None, None]
                                >= qlen[..., None] - window)
    scores = jnp.where(valid[:, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum(f"bhgqs,{k_eq}->bqhgd", p, v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, w, hq, d).astype(q.dtype)


# --------------------------------------------------------------------- #
# FFN / MoE
# --------------------------------------------------------------------- #

def _act(kind: str, x: jax.Array) -> jax.Array:
    if kind == "silu":
        return jax.nn.silu(x)
    return jax.nn.gelu(x, approximate=True)


def ffn(x: jax.Array, p: Params, *, activation: str,
        gated: bool) -> jax.Array:
    if gated:
        gate = _act(activation, x @ p["wg"])
        up = x @ p["wu"]
        return (gate * up) @ p["wd"]
    h = _act(activation, x @ p["wu"])
    return h @ p["wd"]


def moe_gates(x: jax.Array, wr: jax.Array, top_k: int) -> jax.Array:
    """Router: renormalized top-k gate weights [..., E] (zero off-top-k)."""
    logits = x @ wr
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_vals, _ = lax.top_k(probs, top_k)
    thresh = top_vals[..., -1:]
    gates = jnp.where(probs >= thresh, probs, 0.0)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return gates.astype(x.dtype)


def moe_ffn(x: jax.Array, p: Params, *, activation: str, gated: bool,
            num_experts: int, top_k: int) -> jax.Array:
    """Dense-gather MoE: every expert computes on the full token set, gated
    by the (renormalized) top-k router weights.

    This is the einsum-friendly EP formulation: experts shard over the model
    axis and each device computes only its local experts — the token
    all-to-all of dispatch-based MoE is traded for FLOPs that XLA prunes on
    the expert axis when gates are sparse.  Exact (same math as dispatch).
    """
    gates = moe_gates(x, p["wr"], top_k)
    if gated:
        gate_h = _act(activation, jnp.einsum("...d,edf->...ef", x, p["wg"]))
        up_h = jnp.einsum("...d,edf->...ef", x, p["wu"])
        h = gate_h * up_h
    else:
        h = _act(activation, jnp.einsum("...d,edf->...ef", x, p["wu"]))
    y = jnp.einsum("...ef,efd->...ed", h, p["wd"])
    return jnp.einsum("...ed,...e->...d", y, gates)


# --------------------------------------------------------------------- #
# Mamba2 (chunked SSD)
# --------------------------------------------------------------------- #

def _segsum(x: jax.Array) -> jax.Array:
    """Lower-triangular segment sums: out[..., i, j] = sum_{j<k<=i} x[..., k]."""
    q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), dtype=bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def mamba2_ssd(x: jax.Array, dt: jax.Array, a_log: jax.Array, b: jax.Array,
               c: jax.Array, d_skip: jax.Array, *, chunk: int = 128,
               init_state: Optional[jax.Array] = None,
               ) -> Tuple[jax.Array, jax.Array]:
    """Chunked state-space-dual scan (Mamba2).

    Args:
        x: [B, S, H, P] inner activations (heads x head_dim).
        dt: [B, S, H] softplus-ed step sizes.
        a_log: [H] log of -A (A = -exp(a_log)).
        b, c: [B, S, N] input/output projections (single group).
        d_skip: [H] skip connection.
        chunk: intra-chunk length Q.
        init_state: [B, H, P, N] carried SSM state.
    Returns: (y [B,S,H,P], final_state [B,H,P,N]).
    """
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    q = min(chunk, s)
    if s % q != 0:
        raise ValueError(f"seq {s} must divide by chunk {q}")
    nc = s // q
    a = -jnp.exp(a_log.astype(jnp.float32))                     # [H]
    da = dt.astype(jnp.float32) * a                             # [B,S,H]
    xdt = x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None]

    # Reshape into chunks.
    dac = da.reshape(bsz, nc, q, h)
    xc = xdt.reshape(bsz, nc, q, h, p)
    bc = b.astype(jnp.float32).reshape(bsz, nc, q, n)
    cc = c.astype(jnp.float32).reshape(bsz, nc, q, n)

    # Intra-chunk (diagonal blocks): y_ij = C_i . B_j exp(segsum) x_j.
    ss = _segsum(dac.transpose(0, 1, 3, 2))                     # [B,nc,H,Q,Q]
    l_mat = jnp.exp(ss)
    cb = jnp.einsum("bcin,bcjn->bcij", cc, bc)                  # [B,nc,Q,Q]
    y_diag = jnp.einsum("bcij,bchij,bcjhp->bcihp",
                        cb, l_mat.transpose(0, 1, 2, 3, 4), xc,
                        preferred_element_type=jnp.float32)

    # Chunk-final states: S_c = sum_j exp(sum_{k>j} da) B_j x_j.
    da_cum = jnp.cumsum(dac, axis=2)                            # [B,nc,Q,H]
    da_tot = da_cum[:, :, -1:, :]                               # [B,nc,1,H]
    decay_to_end = jnp.exp(da_tot - da_cum)                     # [B,nc,Q,H]
    states = jnp.einsum("bcqn,bcqh,bcqhp->bchpn", bc, decay_to_end, xc,
                        preferred_element_type=jnp.float32)     # [B,nc,H,P,N]

    # Inter-chunk recurrence over c.
    chunk_decay = jnp.exp(da_tot[:, :, 0, :])                   # [B,nc,H]
    s0 = (init_state.astype(jnp.float32) if init_state is not None
          else jnp.zeros((bsz, h, p, n), jnp.float32))

    def scan_fn(carry, inp):
        dec, st = inp                                           # [B,H], [B,H,P,N]
        new = carry * dec[:, :, None, None] + st
        return new, carry                                       # emit state *before* chunk

    final, prev_states = lax.scan(
        scan_fn, s0,
        (chunk_decay.transpose(1, 0, 2), states.transpose(1, 0, 2, 3, 4)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)          # [B,nc,H,P,N]

    # Inter-chunk contribution: y += C_i exp(cum da_i) S_{c-1}.
    state_decay = jnp.exp(da_cum)                               # [B,nc,Q,H]
    y_off = jnp.einsum("bcqn,bcqh,bchpn->bcqhp", cc, state_decay,
                       prev_states, preferred_element_type=jnp.float32)

    y = (y_diag + y_off).reshape(bsz, s, h, p)
    y = y + x.astype(jnp.float32) * d_skip.astype(jnp.float32)[None, None, :,
                                                               None]
    return y.astype(x.dtype), final


def mamba2_decode_step(x: jax.Array, dt: jax.Array, a_log: jax.Array,
                       b: jax.Array, c: jax.Array, d_skip: jax.Array,
                       state: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Single-token SSM update.  x: [B,H,P], dt: [B,H], b/c: [B,N],
    state: [B,H,P,N] -> (y [B,H,P], new_state)."""
    a = -jnp.exp(a_log.astype(jnp.float32))
    da = jnp.exp(dt.astype(jnp.float32) * a)                    # [B,H]
    xdt = x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None]
    upd = jnp.einsum("bhp,bn->bhpn", xdt, b.astype(jnp.float32))
    new_state = state * da[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", new_state, c.astype(jnp.float32))
    y = y + x.astype(jnp.float32) * d_skip[None, :, None]
    return y.astype(x.dtype), new_state


def causal_conv1d(x: jax.Array, w: jax.Array, bias: jax.Array,
                  init: Optional[jax.Array] = None,
                  ) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv.  x: [B,S,D], w: [K,D] -> (y, last K-1 inputs)."""
    k = w.shape[0]
    if init is None:
        init = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([init, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i][None, None, :] for i in range(k))
    tail = xp[:, xp.shape[1] - (k - 1):]
    return jax.nn.silu(y + bias[None, None, :]), tail


# --------------------------------------------------------------------- #
# RWKV6 (Finch) — data-dependent decay linear recurrence
# --------------------------------------------------------------------- #

def wkv6(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
         u: jax.Array, init_state: Optional[jax.Array] = None,
         ) -> Tuple[jax.Array, jax.Array]:
    """RWKV6 recurrence.

    r/k/v: [B, S, H, N]; w: [B, S, H, N] per-step decay in (0,1);
    u: [H, N] bonus.  State: [B, H, N, N] (keys x values).
        y_t = r_t . (S_{t-1} + u * k_t^T v_t)
        S_t = diag(w_t) S_{t-1} + k_t^T v_t
    Returns (y [B,S,H,N], final_state).
    """
    bsz, s, h, n = r.shape
    s0 = (init_state.astype(jnp.float32) if init_state is not None
          else jnp.zeros((bsz, h, n, n), jnp.float32))

    def step(state, inp):
        rt, kt, vt, wt = inp                                    # [B,H,N] each
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        y = jnp.einsum("bhk,bhkv->bhv", rt,
                       state + u[None, :, :, None] * kv)
        new = state * wt[..., None] + kv
        return new, y

    seq = (r.astype(jnp.float32).transpose(1, 0, 2, 3),
           k.astype(jnp.float32).transpose(1, 0, 2, 3),
           v.astype(jnp.float32).transpose(1, 0, 2, 3),
           w.astype(jnp.float32).transpose(1, 0, 2, 3))
    final, ys = lax.scan(step, s0, seq)
    return ys.transpose(1, 0, 2, 3).astype(r.dtype), final


def token_shift(x: jax.Array, prev: Optional[jax.Array] = None) -> jax.Array:
    """RWKV token shift: x[t-1] (zeros / carried token at t=0)."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _pallas_fwd_eager_bwd(fused_fn, eager_fn):
    """Pallas forward, eager-recompute backward.

    ``pl.pallas_call`` has no autodiff rule, so every fused wrapper pairs
    the kernel with the jnp formulation it replaces: the primal runs the
    Pallas kernel; the cotangent recomputes through the eager path's VJP
    (flash-attention-style recompute — no kernel-side residuals).  Gradients
    are therefore *exactly* the eager path's gradients; only the forward
    value carries kernel-tiling numerics.
    """
    f = jax.custom_vjp(fused_fn)

    def fwd(*args):
        return fused_fn(*args), args

    def bwd(args, g):
        return jax.vjp(eager_fn, *args)[1](g)

    f.defvjp(fwd, bwd)
    return f


def _flat_tokens(x: jax.Array) -> Tuple[jax.Array, Tuple[int, int]]:
    """[B, S, D] -> ([B*S, D], (B, S)) for the token-major kernels."""
    b, s, d = x.shape
    return x.reshape(b * s, d), (b, s)


def fused_norm_matmul(x: jax.Array, scale: jax.Array, w: jax.Array, *,
                      eps: float = 1e-6, block_t: int = 256,
                      block_n: int = 512, w8: int = 0,
                      shard=()) -> jax.Array:
    """rms_norm(x) @ w via the ``rmsnorm_matmul`` Pallas kernel.

    x: [B, S, D]; w: [D, N] -> [B, S, N].  The normalized activation lives
    only in VMEM (norm stats recomputed per token tile).  Under an active
    mesh the plan's ``shard`` claim runs the kernel column-parallel: batch
    over 'data', output columns over 'model' (no collective — each shard
    normalizes the full D row and produces its own columns).

    ``w8`` (plan block flag, DESIGN.md §14): weight-only int8 — the weight
    is quantized per output channel in-trace and the kernel dequantizes
    post-dot against the column scales.  Under a column-parallel claim the
    quantization runs per shard on its own columns (scales are
    per-output-channel, so the split is exact).  The eager reference is the
    dequantized matmul with a straight-through backward.
    """
    from ..kernels import rmsnorm_matmul as _kernel

    def fused(x, scale, w):
        xf, (b, s) = _flat_tokens(x)
        if w8:
            codes, ws = quantize_channelwise(w)
            y = _kernel(xf, scale, codes, eps=eps, block_t=block_t,
                        block_n=block_n, w_scale=ws)
        else:
            y = _kernel(xf, scale, w, eps=eps, block_t=block_t,
                        block_n=block_n)
        return y.reshape(b, s, w.shape[-1])

    def eager(x, scale, w):
        return rms_norm(x, scale, eps) @ (_w8_ste(w) if w8 else w)

    mesh = _shard_mesh(shard)
    bax = _claim_axis(mesh, shard, "tokens", x.shape[0])
    nax = _claim_axis(mesh, shard, "out", w.shape[-1])
    if bax or nax:
        fused = _smap(fused, mesh,
                      (P(bax, None, None), P(None), P(None, nax)),
                      P(bax, None, nax))
    else:
        DISPATCH_RECORDS["single"] += 1
    return _pallas_fwd_eager_bwd(fused, eager)(x, scale, w)


def fused_matmul(x: jax.Array, w: jax.Array, *, block_t: int = 256,
                 block_n: int = 256, block_k: int = 512,
                 shard=()) -> jax.Array:
    """x @ w via the tiled ``block_matmul`` Pallas kernel ([B,S,D] layout);
    same column-parallel sharding contract as ``fused_norm_matmul``."""
    from ..kernels import block_matmul as _kernel

    def fused(x, w):
        xf, (b, s) = _flat_tokens(x)
        y = _kernel(xf, w, block_m=block_t, block_n=block_n, block_k=block_k)
        return y.reshape(b, s, w.shape[-1])

    mesh = _shard_mesh(shard)
    bax = _claim_axis(mesh, shard, "tokens", x.shape[0])
    nax = _claim_axis(mesh, shard, "out", w.shape[-1])
    if bax or nax:
        fused = _smap(fused, mesh, (P(bax, None, None), P(None, nax)),
                      P(bax, None, nax))
    else:
        DISPATCH_RECORDS["single"] += 1
    return _pallas_fwd_eager_bwd(fused, lambda x, w: x @ w)(x, w)


def fused_ffn(x: jax.Array, p: Params, *, activation: str, gated: bool,
              norm_scale: Optional[jax.Array] = None,
              block_t: int = 256, block_f: int = 512, w8: int = 0,
              shard=()) -> jax.Array:
    """Stream-fused (GLU) FFN; with ``norm_scale`` the pre-FFN RMSNorm is
    folded into the kernel so the normalized stream never leaves VMEM.

    Sharded dispatch is Megatron-style row-parallel on ``d_ff``: each
    shard streams its own F columns of wg/wu and F rows of wd, and the
    partial [B, S, D] outputs are psum'd over the model axis (the gate
    activation is elementwise in F, so the split is exact math).

    ``w8``: weight-only int8 on all three projections (per-output-channel
    scales quantized in-trace; under a d_ff claim each shard scales its
    own slice).  Eager reference dequantizes with straight-through grads.
    """
    from ..kernels import streamed_ffn, streamed_mlp

    mesh = _shard_mesh(shard)
    bax = _claim_axis(mesh, shard, "tokens", x.shape[0])
    fax = _claim_axis(mesh, shard, "d_ff",
                      p["wu"].shape[-1] if "wu" in p else 0)

    if gated:
        def fused(x, wg, wu, wd, *norm):
            xf, (b, s) = _flat_tokens(x)
            qkw = {}
            if w8:
                wg, qkw["wg_scale"] = quantize_channelwise(wg)
                wu, qkw["wu_scale"] = quantize_channelwise(wu)
                wd, qkw["wd_scale"] = quantize_channelwise(wd)
            y = streamed_ffn(xf, wg, wu, wd, activation=activation,
                             norm_scale=norm[0] if norm else None,
                             block_t=block_t, block_f=block_f, **qkw)
            y = y.reshape(b, s, -1)
            return lax.psum(y, fax) if fax else y

        def eager(x, wg, wu, wd, *norm):
            h = rms_norm(x, norm[0]) if norm else x
            if w8:
                wg, wu, wd = _w8_ste(wg), _w8_ste(wu), _w8_ste(wd)
            return (_act(activation, h @ wg) * (h @ wu)) @ wd

        args = (x, p["wg"], p["wu"], p["wd"])
        w_specs = (P(None, fax), P(None, fax), P(fax, None))
    else:
        def fused(x, wu, wd, *norm):
            xf, (b, s) = _flat_tokens(x)
            qkw = {}
            if w8:
                wu, qkw["wu_scale"] = quantize_channelwise(wu)
                wd, qkw["wd_scale"] = quantize_channelwise(wd)
            y = streamed_mlp(xf, wu, wd, activation=activation,
                             norm_scale=norm[0] if norm else None,
                             block_t=block_t, block_f=block_f, **qkw)
            y = y.reshape(b, s, -1)
            return lax.psum(y, fax) if fax else y

        def eager(x, wu, wd, *norm):
            h = rms_norm(x, norm[0]) if norm else x
            if w8:
                wu, wd = _w8_ste(wu), _w8_ste(wd)
            return _act(activation, h @ wu) @ wd

        args = (x, p["wu"], p["wd"])
        w_specs = (P(None, fax), P(fax, None))
    if norm_scale is not None:
        args = args + (norm_scale,)
        w_specs = w_specs + (P(None),)
    if bax or fax:
        fused = _smap(fused, mesh, (P(bax, None, None),) + w_specs,
                      P(bax, None, None))
    else:
        DISPATCH_RECORDS["single"] += 1
    return _pallas_fwd_eager_bwd(fused, eager)(*args)


def fused_moe_ffn(x: jax.Array, p: Params, *, activation: str,
                  top_k: int, block_t: int = 256, shard=()) -> jax.Array:
    """Router eager (tiny), experts via the ``moe_experts`` Pallas kernel.

    Sharded dispatch is expert-parallel: the (globally renormalized)
    gates and the expert weight stacks split over the model axis, each
    shard computes its local experts' contributions, and the outputs are
    psum'd — same math as the dense-gather eager formulation.
    """
    from ..kernels import moe_experts_pallas

    gates = moe_gates(x, p["wr"], top_k)

    mesh = _shard_mesh(shard)
    bax = _claim_axis(mesh, shard, "tokens", x.shape[0])
    eax = _claim_axis(mesh, shard, "experts", p["wu"].shape[0])

    def fused(x, gates, wg, wu, wd):
        xf, (b, s) = _flat_tokens(x)
        gf = gates.reshape(b * s, -1)
        y = moe_experts_pallas(xf, gf, wg, wu, wd, activation=activation,
                               block_t=block_t)
        y = y.reshape(b, s, -1)
        return lax.psum(y, eax) if eax else y

    def eager(x, gates, wg, wu, wd):
        gate_h = _act(activation, jnp.einsum("...d,edf->...ef", x, wg))
        up_h = jnp.einsum("...d,edf->...ef", x, wu)
        y = jnp.einsum("...ef,efd->...ed", gate_h * up_h, wd)
        return jnp.einsum("...ed,...e->...d", y, gates)

    if bax or eax:
        fused = _smap(fused, mesh,
                      (P(bax, None, None), P(bax, None, eax),
                       P(eax, None, None), P(eax, None, None),
                       P(eax, None, None)),
                      P(bax, None, None))
    else:
        DISPATCH_RECORDS["single"] += 1
    return _pallas_fwd_eager_bwd(fused, eager)(
        x, gates, p["wg"], p["wu"], p["wd"])


def fused_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    block_q: int = 512, block_kv: int = 512,
                    shard=()) -> jax.Array:
    """Flash-attention Pallas kernel with GQA; eager backward recomputes
    through ``streaming_attention`` / ``local_attention``.

    Sharded dispatch splits the kernel grid's head dimension over the
    model axis at KV-head granularity (the G query heads sharing a KV
    head stay together, so GQA reuse survives the split) and batch over
    'data' — both embarrassingly parallel, no collectives.
    """
    from ..kernels import flash_attention

    def fused(q, k, v):
        return flash_attention(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_kv=block_kv)

    def eager(q, k, v):
        if window:
            return local_attention(q, k, v, window=window)
        return streaming_attention(q, k, v, causal=causal)

    mesh = _shard_mesh(shard)
    hax = _claim_axis(mesh, shard, "kv_heads", k.shape[2])
    bax = _claim_axis(mesh, shard, "batch", q.shape[0])
    if hax or bax:
        sp = P(bax, None, hax, None)
        fused = _smap(fused, mesh, (sp, sp, sp), sp)
    else:
        DISPATCH_RECORDS["single"] += 1
    return _pallas_fwd_eager_bwd(fused, eager)(q, k, v)


def fused_attention_chunk(q: jax.Array, k: jax.Array, v: jax.Array,
                          q_offset, kv_len, *, causal: bool = True,
                          window: int = 0, block_q: int = 512,
                          block_kv: int = 512,
                          k_scale: Optional[jax.Array] = None,
                          v_scale: Optional[jax.Array] = None,
                          shard=()) -> jax.Array:
    """Chunked-prefill twin of ``fused_attention``: the offset flash
    kernel with dynamic ``q_offset`` / ``kv_len`` scalar-prefetch
    operands, dispatched under the plan's sharding (KV heads over the
    model axis; the scalars replicate).  Serving-only — no VJP pairing
    (prefill is never differentiated).

    Quantized KV: ``k_scale``/``v_scale`` [B, Skv, Hkv] per-position f32
    scales (page-scale rows repeated over page positions) — k/v are then
    int8/fp8 codes and the kernel dequantizes in-register."""
    from ..kernels import flash_attention

    quant = k_scale is not None

    def call(q, k, v, off, kl, *scales):
        ks, vs = scales if scales else (None, None)
        return flash_attention(q, k, v, causal=causal, window=window,
                               q_offset=off, kv_len=kl,
                               block_q=block_q, block_kv=block_kv,
                               k_scale=ks, v_scale=vs)

    mesh = _shard_mesh(shard)
    hax = _claim_axis(mesh, shard, "kv_heads", k.shape[2])
    bax = _claim_axis(mesh, shard, "batch", q.shape[0])
    if hax or bax:
        sp = P(bax, None, hax, None)
        in_specs = (sp, sp, sp, P(), P())
        if quant:
            in_specs += (P(bax, None, hax), P(bax, None, hax))
        call = _smap(call, mesh, in_specs, sp)
    else:
        DISPATCH_RECORDS["single"] += 1
    extra = ((k_scale.astype(jnp.float32), v_scale.astype(jnp.float32))
             if quant else ())
    return call(q, k, v, jnp.asarray(q_offset, jnp.int32),
                jnp.asarray(kv_len, jnp.int32), *extra)


def fused_paged_attention(q: jax.Array, k_pool: jax.Array,
                          v_pool: jax.Array, page_table: jax.Array,
                          lengths: jax.Array, *, window: int = 0,
                          k_scale: Optional[jax.Array] = None,
                          v_scale: Optional[jax.Array] = None,
                          shard=()) -> jax.Array:
    """Paged decode attention under the plan's sharding: the KV page
    pools split over the model axis at the ``kv_heads`` dim (matching the
    ``PagedKVCache`` pool sharding) and slots over 'data' — with a batch
    claim the page table and lengths split by slot alongside q, so each
    data shard prefetches only its own slots' table rows (the pools stay
    whole on the page dim within a shard, so every row still resolves).
    Serving-only — no VJP pairing.

    Quantized KV: ``k_scale``/``v_scale`` [P, Hkv] per-page f32 scale
    pools (sharded with the pools at ``kv_heads``) — the pools are then
    int8/fp8 codes and the kernel dequantizes in-register per page."""
    from ..kernels import paged_decode_attention

    quant = k_scale is not None

    def call(q, kp, vp, tbl, lens, *scales):
        ks, vs = scales if scales else (None, None)
        return paged_decode_attention(q, kp, vp, tbl, lens, window=window,
                                      k_scale=ks, v_scale=vs)

    mesh = _shard_mesh(shard)
    hax = _claim_axis(mesh, shard, "kv_heads", k_pool.shape[2])
    bax = _claim_axis(mesh, shard, "batch", q.shape[0])
    if hax or bax:
        in_specs = (P(bax, None, hax, None), P(None, None, hax, None),
                    P(None, None, hax, None), P(bax, None), P(bax))
        if quant:
            in_specs += (P(None, hax), P(None, hax))
        call = _smap(call, mesh, in_specs, P(bax, None, hax, None))
    else:
        DISPATCH_RECORDS["single"] += 1
    extra = (k_scale, v_scale) if quant else ()
    return call(q, k_pool, v_pool, page_table, lengths, *extra)


def fused_verify_attention(q: jax.Array, k_pool: jax.Array,
                           v_pool: jax.Array, page_table: jax.Array,
                           q_off: jax.Array, *, window: int = 0,
                           k_scale: Optional[jax.Array] = None,
                           v_scale: Optional[jax.Array] = None,
                           shard=()) -> jax.Array:
    """Speculative-verify attention under the plan's sharding: identical
    dispatch contract to ``fused_paged_attention`` (KV pools split over
    the model axis at ``kv_heads``, slots over 'data'), with the W-row
    verify window riding in the query block — one kernel launch scores
    every draft position of every slot.  Serving-only — no VJP pairing.
    Quantized KV rides the same ``k_scale``/``v_scale`` [P, Hkv] contract
    as ``fused_paged_attention``."""
    from ..kernels import paged_verify_attention

    quant = k_scale is not None

    def call(q, kp, vp, tbl, off, *scales):
        ks, vs = scales if scales else (None, None)
        return paged_verify_attention(q, kp, vp, tbl, off, window=window,
                                      k_scale=ks, v_scale=vs)

    mesh = _shard_mesh(shard)
    hax = _claim_axis(mesh, shard, "kv_heads", k_pool.shape[2])
    bax = _claim_axis(mesh, shard, "batch", q.shape[0])
    if hax or bax:
        in_specs = (P(bax, None, hax, None), P(None, None, hax, None),
                    P(None, None, hax, None), P(bax, None), P(bax))
        if quant:
            in_specs += (P(None, hax), P(None, hax))
        call = _smap(call, mesh, in_specs, P(bax, None, hax, None))
    else:
        DISPATCH_RECORDS["single"] += 1
    extra = (k_scale, v_scale) if quant else ()
    return call(q, k_pool, v_pool, page_table, q_off, *extra)


def fused_mamba2_ssd(x: jax.Array, dt: jax.Array, a_log: jax.Array,
                     b: jax.Array, c: jax.Array, d_skip: jax.Array, *,
                     chunk: int = 128, shard=()) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan via the ``mamba2_scan`` Pallas kernel; sharded
    dispatch splits the (independent) SSM heads over the model axis and
    batch over 'data'."""
    from ..kernels import mamba2_ssd_pallas

    def fused(x, dt, a_log, b, c, d_skip):
        return mamba2_ssd_pallas(x, dt, a_log, b, c, d_skip, chunk=chunk)

    def eager(x, dt, a_log, b, c, d_skip):
        return mamba2_ssd(x, dt, a_log, b, c, d_skip, chunk=chunk)

    mesh = _shard_mesh(shard)
    hax = _claim_axis(mesh, shard, "heads", x.shape[2])
    bax = _claim_axis(mesh, shard, "batch", x.shape[0])
    if hax or bax:
        fused = _smap(fused, mesh,
                      (P(bax, None, hax, None), P(bax, None, hax), P(hax),
                       P(bax, None, None), P(bax, None, None), P(hax)),
                      (P(bax, None, hax, None), P(bax, hax, None, None)))
    else:
        DISPATCH_RECORDS["single"] += 1
    return _pallas_fwd_eager_bwd(fused, eager)(x, dt, a_log, b, c, d_skip)


def fused_wkv6(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
               u: jax.Array, *, chunk: int = 64, shard=(),
               ) -> Tuple[jax.Array, jax.Array]:
    """RWKV6 recurrence via the ``rwkv6_wkv`` Pallas kernel; sharded
    dispatch splits the (independent) RWKV heads over the model axis and
    batch over 'data'."""
    from ..kernels import wkv6_pallas

    def fused(r, k, v, w, u):
        return wkv6_pallas(r, k, v, w, u, chunk=chunk)

    def eager(r, k, v, w, u):
        return wkv6(r, k, v, w, u)

    mesh = _shard_mesh(shard)
    hax = _claim_axis(mesh, shard, "heads", r.shape[2])
    bax = _claim_axis(mesh, shard, "batch", r.shape[0])
    if hax or bax:
        sp = P(bax, None, hax, None)
        fused = _smap(fused, mesh, (sp, sp, sp, sp, P(hax, None)),
                      (sp, P(bax, hax, None, None)))
    else:
        DISPATCH_RECORDS["single"] += 1
    return _pallas_fwd_eager_bwd(fused, eager)(r, k, v, w, u)


def fused_streamed_xent(hidden: jax.Array, head: jax.Array,
                        labels: jax.Array, vocab_size: int, *,
                        block_t: int = 256, block_v: int = 2048,
                        shard=()) -> jax.Array:
    """Streamed CE loss via the ``streamed_xent`` Pallas kernel: [T, V]
    logits never materialize in the forward; the backward recomputes the
    logits from the (hidden, head) residuals through the eager formulation
    (labels ride along as an integer primal so the VJP structure is right —
    their cotangent is the symbolic zero).

    Sharded dispatch splits the token (batch) dim over 'data': each shard
    streams its own tokens' vocab tiles, and the (nll sum, valid count)
    pair is psum'd before the division so the mean weighs every token
    once regardless of the per-shard valid counts.
    """
    from ..kernels import streamed_xent_loss, streamed_xent_parts

    mesh = _shard_mesh(shard)
    bax = _claim_axis(mesh, shard, "tokens", hidden.shape[0])

    def fused(hidden, head, labels):
        hf, (b, s) = _flat_tokens(hidden)
        return streamed_xent_loss(hf, head, labels.reshape(b * s),
                                  vocab_size=vocab_size,
                                  block_t=block_t, block_v=block_v)

    if bax:
        def fused(hidden, head, labels):            # noqa: F811 — sharded twin
            hf, (b, s) = _flat_tokens(hidden)
            lf = labels.reshape(b * s)
            lse, gold = streamed_xent_parts(
                hf, head, jnp.maximum(lf, 0), vocab_size=vocab_size,
                block_t=block_t, block_v=block_v)
            valid = lf >= 0
            nll = jnp.where(valid, lse - gold, 0.0)
            tot = lax.psum(nll.sum(), bax)
            cnt = lax.psum(valid.sum(), bax)
            return tot / jnp.maximum(cnt, 1)

        fused = _smap(fused, mesh,
                      (P(bax, None, None), P(None, None), P(bax, None)),
                      P())
    else:
        DISPATCH_RECORDS["single"] += 1

    def eager(hidden, head, labels):
        hf, (b, s) = _flat_tokens(hidden)
        logits = (hf @ head).astype(jnp.float32)
        vp = logits.shape[-1]
        logits = jnp.where((jnp.arange(vp) >= vocab_size)[None], NEG_INF,
                           logits)
        lf = labels.reshape(b * s)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(lf, 0)[:, None], axis=-1)[:, 0]
        valid = lf >= 0
        nll = jnp.where(valid, lse - gold, 0.0)
        return nll.sum() / jnp.maximum(valid.sum(), 1)

    return _pallas_fwd_eager_bwd(fused, eager)(hidden, head, labels)


def wkv6_chunked(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
                 u: jax.Array, init_state: Optional[jax.Array] = None, *,
                 chunk: int = 16, min_log_w: float = -5.0,
                 ) -> Tuple[jax.Array, jax.Array]:
    """Chunk-parallel wkv6 (§Perf rwkv6 hillclimb).

    The per-token scan reads+writes the [H, N, N] f32 state every timestep —
    the dominant memory-roofline term of rwkv6 training.  This form carries
    the state once per ``chunk`` tokens (traffic / chunk) and computes the
    intra-chunk part with matmuls via the factored decay identity

        s[t,j] = sum_k (r[t,k] e^{L[t-1,k]}) * (k[j,k] e^{-L[j,k]}),  j < t

    with L the in-chunk cumulative log-decay.  ``e^{-L}`` grows with chunk
    depth, so per-step log decay is clamped at ``min_log_w``: with chunk=16
    the factor exponent is bounded by 80 < log(f32max)=88.  The clamp
    saturates decays below e^-5 per step (a token's influence after one such
    step is < 0.7%); tests verify exact equivalence against the sequential
    recurrence under the same clamp.
    """
    bsz, s, h, n = r.shape
    c = min(chunk, s)
    if s % c != 0:
        c = math.gcd(s, c)
    nc = s // c
    f32 = jnp.float32
    rr = r.astype(f32).reshape(bsz, nc, c, h, n)
    kk = k.astype(f32).reshape(bsz, nc, c, h, n)
    vv = v.astype(f32).reshape(bsz, nc, c, h, n)
    lw = jnp.clip(jnp.log(jnp.maximum(w.astype(f32), 1e-30)),
                  min_log_w, 0.0).reshape(bsz, nc, c, h, n)
    el = jnp.cumsum(lw, axis=2)          # inclusive log-decay  (<= 0)
    elm1 = el - lw                        # exclusive (L[t-1])
    a = rr * jnp.exp(elm1)                # bounded <= |r|
    bmat = kk * jnp.exp(-el)              # bounded by e^{-min_log_w * c}
    scores = jnp.einsum("bcthn,bcjhn->bchtj", a, bmat)
    tri = jnp.tril(jnp.ones((c, c), bool), k=-1)      # strictly lower: j<t
    y_intra = jnp.einsum("bchtj,bcjhn->bcthn",
                         jnp.where(tri[None, None, None], scores, 0.0), vv)
    # Diagonal bonus term: y += (sum_k r u k) * v at each t.
    coef = jnp.einsum("bcthn,hn,bcthn->bcth", rr, u.astype(f32), kk)
    y_diag = coef[..., None] * vv
    # Inter-chunk recurrence.
    s0 = (init_state.astype(f32) if init_state is not None
          else jnp.zeros((bsz, h, n, n), f32))
    chunk_decay = jnp.exp(el[:, :, -1])                   # [B,nc,H,N]
    kdec = bmat * jnp.exp(el[:, :, -1])[:, :, None]       # k * e^{L[-1]-L[j]}
    s_updates = jnp.einsum("bcjhk,bcjhv->bchkv", kdec, vv)

    def scan_fn(state, inp):
        a_c, dec, upd = inp               # [B,c,H,N], [B,H,N], [B,H,N,N]
        y_cross = jnp.einsum("bthk,bhkv->bthv", a_c, state)
        new = state * dec[..., None] + upd
        return new, y_cross

    final, y_cross = lax.scan(
        scan_fn, s0,
        (a.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2, 3),
         s_updates.transpose(1, 0, 2, 3, 4)))
    y_cross = y_cross.transpose(1, 0, 2, 3, 4)
    y = (y_intra + y_diag + y_cross).reshape(bsz, s, h, n)
    return y.astype(r.dtype), final
