"""Executable JAX models for the assigned architectures."""

from .model import (decode_step, forward_hidden, forward_train, prefill,
                    resolve_plan, streamed_xent)
from .params import (abstract_cache, abstract_params, cache_defs,
                     cache_logical_axes, init_cache, init_params,
                     logical_axes, model_defs, padded_vocab, param_bytes)

__all__ = [
    "decode_step", "forward_hidden", "forward_train", "prefill",
    "resolve_plan", "streamed_xent",
    "abstract_cache", "abstract_params", "cache_defs",
    "cache_logical_axes", "init_cache", "init_params", "logical_axes",
    "model_defs", "padded_vocab", "param_bytes",
]
