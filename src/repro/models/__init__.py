"""Executable JAX models for the assigned architectures."""

from .model import (decode_step, forward_hidden, forward_train, prefill,
                    prefill_chunk, resolve_plan, streamed_xent,
                    supports_chunked_prefill, supports_speculative,
                    verify_step)
from .params import (KV_CACHE_LEAVES, STATE_CACHE_LEAVES, abstract_cache,
                     abstract_params, cache_defs, cache_leaf_kind,
                     cache_leaf_name, cache_logical_axes, init_cache,
                     init_params, kv_seq_axis, logical_axes, model_defs,
                     padded_vocab, param_bytes)

__all__ = [
    "decode_step", "forward_hidden", "forward_train", "prefill",
    "prefill_chunk", "resolve_plan", "streamed_xent",
    "supports_chunked_prefill", "supports_speculative", "verify_step",
    "KV_CACHE_LEAVES", "STATE_CACHE_LEAVES", "abstract_cache",
    "abstract_params", "cache_defs", "cache_leaf_kind", "cache_leaf_name",
    "cache_logical_axes", "init_cache", "init_params", "kv_seq_axis",
    "logical_axes", "model_defs", "padded_vocab", "param_bytes",
]
