"""The language model: embedding -> pattern-group scan -> head.

Three entry points (DESIGN.md §7):
  * ``forward_train``  — full-sequence forward returning the streamed
    (chunked-over-sequence) cross-entropy loss; logits [B,S,V] are never
    materialized (the paper's streaming idea applied to the loss).
  * ``prefill``        — full-sequence forward returning last-position logits
    and the decode caches (KV / SSM state / RWKV state).
  * ``decode_step``    — one token against the caches.

Layers are applied as a ``lax.scan`` over *pattern groups* (stacked params
from ``params.py``), keeping the HLO small and compile times manageable at
54 layers; remainder layers run unrolled.  Zamba2's shared attention block is
closed over by the scan body (single parameter copy, per-application caches).
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..configs.base import ModelConfig
from . import layers as L
from .params import padded_vocab

Tree = Any
Plan = Any          # core.stream_plan.StreamPlan (imported lazily)
LPlan = Any         # core.stream_plan.LayerPlan


def resolve_plan(cfg: ModelConfig, tokens: int, *,
                 kv_len: Optional[int] = None,
                 plan: Optional[Plan] = None,
                 mesh=None) -> Optional[Plan]:
    """The StreamPlan driving fused-kernel dispatch, or None for eager.

    An explicit ``plan`` wins; otherwise ``cfg.use_fused_kernels`` triggers
    the (cached) compiler pipeline in ``core.stream_plan``.  Resolution
    happens at trace time — the plan is static under jit.  ``mesh``
    defaults to the active ``distributed.context`` mesh, so entry points
    traced under ``use_mesh(...)`` get mesh-aware plans (per-stage
    sharding decisions the fused wrappers turn into ``shard_map``)
    without any caller churn.
    """
    if plan is not None:
        return plan
    if not cfg.use_fused_kernels:
        return None
    if mesh is None:
        from ..distributed.context import current_mesh
        mesh = current_mesh()
    from ..core.stream_plan import plan_for
    return plan_for(cfg, tokens, kv_len, mesh)


def _lplan(plan: Optional[Plan], kind: str) -> Optional[LPlan]:
    return plan.layer(kind) if plan is not None else None


def _cache_kv_len(cfg: ModelConfig, cache: Tree,
                  page_table: Optional[jax.Array] = None) -> Optional[int]:
    """Max KV length held by a decode cache (None for pure SSM caches).

    Stacked K leaves are [G, B, S, Hkv, hd] ("bshd") or [G, B, Hkv, S, hd]
    ("bhsd"); paged K leaves are pools [G, P, page_size, Hkv, hd] and the
    extent is the page table's ``max_pages * page_size``.  Used so the
    decode plan's DSE models attention over the real cache extent rather
    than the (tiny) per-step token count.
    """
    from .params import cache_leaf_kind, cache_leaf_name, kv_seq_axis
    for path, leaf in jax.tree_util.tree_flatten_with_path(cache)[0]:
        if cache_leaf_kind(cache_leaf_name(path)) == "kv":
            if page_table is not None:
                return int(page_table.shape[1]) * int(leaf.shape[2])
            return int(leaf.shape[kv_seq_axis(cfg.kv_cache_layout)])
    return None


def _c(cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """Cast to compute dtype (bf16); norms re-promote internally."""
    return x.astype(jnp.bfloat16) if cfg.dtype == "bfloat16" else x


def _cast_tree(cfg: ModelConfig, t: Tree) -> Tree:
    return jax.tree.map(lambda a: _c(cfg, a) if a.dtype == jnp.float32 else a,
                        t)


def _chunk_of(n: int, want: int) -> int:
    c = min(want, n)
    while n % c != 0:
        c = math.gcd(n, c)
    return max(1, c)


# --------------------------------------------------------------------- #
# Block application (full-sequence mode)
# --------------------------------------------------------------------- #

def _qk_normed(cfg: ModelConfig, p: Tree, q: jax.Array,
               k: jax.Array) -> Tuple[jax.Array, jax.Array]:
    if not cfg.qk_norm:
        return q, k
    return (L.rms_norm(q, p["q_norm"]), L.rms_norm(k, p["k_norm"]))


def _project_qkv(cfg: ModelConfig, p: Tree, x: jax.Array, ln_p: Tree,
                 lplan: Optional[LPlan],
                 ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """ln + Q/K/V projections, eager or plan-fused.

    With ``rmsnorm_matmul`` the norm is folded into each projection (norm
    stats recomputed per kernel — VPU work traded for the HBM round-trip of
    the normalized stream); with ``block_matmul`` the norm stays eager and
    the projections run through the tiled Pallas matmul.
    """
    choice = lplan.qkv if lplan is not None else None
    if choice is not None and choice.fused:
        kw = choice.kw
        if choice.implementation == "rmsnorm_matmul":
            q = L.fused_norm_matmul(x, ln_p["scale"], p["wq"], **kw)
            k = L.fused_norm_matmul(x, ln_p["scale"], p["wk"], **kw)
            v = L.fused_norm_matmul(x, ln_p["scale"], p["wv"], **kw)
        else:
            h = L.apply_norm(cfg.norm, x, ln_p)
            q = L.fused_matmul(h, p["wq"], **kw)
            k = L.fused_matmul(h, p["wk"], **kw)
            v = L.fused_matmul(h, p["wv"], **kw)
    else:
        h = L.apply_norm(cfg.norm, x, ln_p)
        q = h @ p["wq"]
        k = h @ p["wk"]
        v = h @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    return q, k, v


def _attn_full(cfg: ModelConfig, p: Tree, x: jax.Array, ln_p: Tree,
               positions: jax.Array, *, window: int, collect: bool,
               lplan: Optional[LPlan] = None,
               ) -> Tuple[jax.Array, Optional[Tree]]:
    b, s, d = x.shape
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_
    q, k, v = _project_qkv(cfg, p, x, ln_p, lplan)
    q = q.reshape(b, s, hq, hd)
    k = k.reshape(b, s, hkv, hd)
    v = v.reshape(b, s, hkv, hd)
    q, k = _qk_normed(cfg, p, q, k)
    q = L.apply_positional(cfg.rope, q, positions, cfg.rope_theta)
    k = L.apply_positional(cfg.rope, k, positions, cfg.rope_theta)
    attn_c = lplan.attention if lplan is not None else None
    if attn_c is not None and attn_c.fused:
        o = L.fused_attention(q, k, v, causal=cfg.causal, window=window,
                              **attn_c.kw)
    elif window:
        o = L.local_attention(q, k, v, window=window,
                              remat_chunk=cfg.remat_attn_chunk)
    else:
        o = L.streaming_attention(q, k, v, causal=cfg.causal,
                                  remat_chunk=cfg.remat_attn_chunk)
    out = o.reshape(b, s, hq * hd) @ p["wo"]
    if collect:
        if cfg.kv_cache_layout == "bhsd":
            return out, {"k": k.transpose(0, 2, 1, 3),
                         "v": v.transpose(0, 2, 1, 3)}
        return out, {"k": k, "v": v}
    return out, None


def _ffn_apply(cfg: ModelConfig, p: Tree, x: jax.Array) -> jax.Array:
    if cfg.is_moe:
        return L.moe_ffn(x, p, activation=cfg.activation,
                         gated=cfg.gated_ffn, num_experts=cfg.num_experts,
                         top_k=cfg.top_k)
    return L.ffn(x, p, activation=cfg.activation, gated=cfg.gated_ffn)


def _ffn_block(cfg: ModelConfig, p: Tree, x: jax.Array, ln_p: Tree,
               lplan: Optional[LPlan]) -> jax.Array:
    """ln2 + FFN/MoE, eager or plan-fused.  ``fuse_norm`` in the choice
    folds the RMSNorm into the streamed FFN kernel itself."""
    choice = lplan.ffn if lplan is not None else None
    if choice is not None and choice.fused:
        kw = choice.kw
        if choice.implementation == "moe_experts":
            h2 = L.apply_norm(cfg.norm, x, ln_p)
            return L.fused_moe_ffn(h2, p, activation=cfg.activation,
                                   top_k=cfg.top_k, **kw)
        fuse_norm = bool(kw.pop("fuse_norm", 0))
        if fuse_norm:
            return L.fused_ffn(x, p, activation=cfg.activation,
                               gated=cfg.gated_ffn,
                               norm_scale=ln_p["scale"], **kw)
        h2 = L.apply_norm(cfg.norm, x, ln_p)
        return L.fused_ffn(h2, p, activation=cfg.activation,
                           gated=cfg.gated_ffn, **kw)
    h2 = L.apply_norm(cfg.norm, x, ln_p)
    return _ffn_apply(cfg, p, h2)


def _attn_block_full(cfg: ModelConfig, p: Tree, x: jax.Array,
                     positions: jax.Array, *, window: int = 0,
                     collect: bool = False,
                     lplan: Optional[LPlan] = None,
                     ) -> Tuple[jax.Array, Optional[Tree]]:
    attn_out, kv = _attn_full(cfg, p["attn"], x, p["ln1"], positions,
                              window=window, collect=collect, lplan=lplan)
    x = x + attn_out
    x = x + _ffn_block(cfg, p["mlp"], x, p["ln2"], lplan)
    return x, kv


def _mamba_block_full(cfg: ModelConfig, p: Tree, x: jax.Array, *,
                      collect: bool = False,
                      lplan: Optional[LPlan] = None,
                      ) -> Tuple[jax.Array, Optional[Tree]]:
    b, s, d = x.shape
    m = p["mamba"]
    h = L.apply_norm(cfg.norm, x, p["ln"])
    xin = h @ m["wx"]                                      # [B,S,di]
    z = h @ m["wz"]
    bmat = h @ m["wb"]                                     # [B,S,N]
    cmat = h @ m["wc"]
    dt = jax.nn.softplus(h @ m["wdt"]
                         + m["dt_bias"].astype(h.dtype))   # [B,S,H]
    xconv, conv_tail = L.causal_conv1d(xin, m["conv_w"], m["conv_b"])
    hps = xconv.reshape(b, s, cfg.ssm_heads, cfg.ssm_head_dim)
    mixer = lplan.mixer if lplan is not None else None
    if mixer is not None and mixer.fused:
        chunk = _chunk_of(s, mixer.kw.get("chunk", 128))
        y, state = L.fused_mamba2_ssd(hps, dt, m["a_log"], bmat, cmat,
                                      m["d_skip"], chunk=chunk,
                                      shard=mixer.sharding)
    else:
        chunk = _chunk_of(s, 128)
        y, state = L.mamba2_ssd(hps, dt, m["a_log"], bmat, cmat,
                                m["d_skip"], chunk=chunk)
    y = y.reshape(b, s, cfg.d_inner) * jax.nn.silu(z)
    x = x + y @ m["wout"]
    aux = {"ssm": state.astype(jnp.float32),
           "conv": conv_tail} if collect else None
    return x, aux


def _rwkv_block_full(cfg: ModelConfig, p: Tree, x: jax.Array, *,
                     collect: bool = False,
                     lplan: Optional[LPlan] = None,
                     ) -> Tuple[jax.Array, Optional[Tree]]:
    b, s, d = x.shape
    h, n = cfg.rwkv_heads, cfg.rwkv_head_dim
    tm, cm = p["tm"], p["cm"]
    # Time mix.
    xa = L.apply_norm(cfg.norm, x, p["ln1"])
    xs = L.token_shift(xa)

    def mix(name):
        mu = tm[f"mix_{name}"].astype(xa.dtype)
        return xa * mu + xs * (1.0 - mu)

    r = (mix("r") @ tm["wr"]).reshape(b, s, h, n)
    k = (mix("k") @ tm["wk"]).reshape(b, s, h, n)
    v = (mix("v") @ tm["wv"]).reshape(b, s, h, n)
    g = jax.nn.silu(mix("g") @ tm["wg"])
    wdec = jnp.exp(-jnp.exp(
        (mix("w") @ tm["ww"]).astype(jnp.float32)
        + tm["w_bias"].reshape(1, 1, h * n))).reshape(b, s, h, n)
    mixer = lplan.mixer if lplan is not None else None
    if mixer is not None and mixer.fused:
        y, state = L.fused_wkv6(r, k, v, wdec, tm["u"],
                                chunk=_chunk_of(s, mixer.kw.get("chunk", 64)),
                                shard=mixer.sharding)
    elif cfg.rwkv_chunk > 0:
        y, state = L.wkv6_chunked(r, k, v, wdec, tm["u"],
                                  chunk=cfg.rwkv_chunk)
    else:
        y, state = L.wkv6(r, k, v, wdec, tm["u"])
    y = (y.reshape(b, s, d) * g) @ tm["wo"]
    x = x + y
    # Channel mix.
    xc = L.apply_norm(cfg.norm, x, p["ln2"])
    xcs = L.token_shift(xc)

    def cmix(name):
        mu = cm[f"mix_{name}"].astype(xc.dtype)
        return xc * mu + xcs * (1.0 - mu)

    kk = jnp.square(jax.nn.relu(cmix("k") @ cm["wk"]))
    rr = jax.nn.sigmoid(cmix("r") @ cm["wr"])
    x = x + rr * (kk @ cm["wv"])
    aux = None
    if collect:
        aux = {"wkv": state, "tm_shift": xa[:, -1], "cm_shift": xc[:, -1]}
    return x, aux


def _apply_block_full(cfg: ModelConfig, kind: str, p: Tree, shared: Tree,
                      x: jax.Array, positions: jax.Array,
                      collect: bool,
                      lplan: Optional[LPlan] = None) -> Tuple[jax.Array, Tree]:
    if kind == "rwkv":
        return _rwkv_block_full(cfg, p, x, collect=collect, lplan=lplan)
    if kind == "mamba":
        return _mamba_block_full(cfg, p, x, collect=collect, lplan=lplan)
    if kind == "mamba+shared_attn":
        x, aux = _mamba_block_full(cfg, p, x, collect=collect, lplan=lplan)
        x, kv = _attn_block_full(cfg, shared, x, positions, collect=collect,
                                 lplan=lplan)
        if collect:
            aux = {**aux, **kv}
        return x, aux
    window = cfg.sliding_window if kind == "local_attn" else 0
    return _attn_block_full(cfg, p, x, positions, window=window,
                            collect=collect, lplan=lplan)


# --------------------------------------------------------------------- #
# Full-sequence backbone
# --------------------------------------------------------------------- #

def _embed_in(cfg: ModelConfig, params: Tree, batch: Dict[str, jax.Array],
              ) -> Tuple[jax.Array, jax.Array]:
    """Returns (x [B,S,D], positions)."""
    if "embeds" in batch:
        x = _c(cfg, batch["embeds"])
        b, s = x.shape[:2]
    else:
        tokens = batch["tokens"]
        b, s = tokens.shape
        x = _c(cfg, jnp.take(params["embed"], tokens, axis=0))
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    if cfg.rope == "mrope":
        positions = batch.get("positions")
        if positions is None:
            base = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
            positions = jnp.broadcast_to(base[None], (3, b, s))
    else:
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    if cfg.rope == "none" and "pos_embed" in params:
        x = x + _c(cfg, params["pos_embed"][:s][None])
    return x, positions


def forward_hidden(params: Tree, cfg: ModelConfig,
                   batch: Dict[str, jax.Array], *,
                   remat: bool = True,
                   act_sharding=None,
                   act_pin_scope: str = "all",
                   plan: Optional[Plan] = None) -> jax.Array:
    """Embedding + all blocks + final norm -> hidden states [B,S,D].

    ``act_sharding``: optional NamedSharding pinning the residual stream
    (§Perf: without a pin, GSPMD is free to shuttle the f32 norm
    intermediates across the model axis — measured as f32 activation
    all-gathers/all-reduces per layer on llama3-8b).  ``act_pin_scope``:
    'all' pins every block boundary, 'embed' only the scan entry.

    ``plan``: a ``core.stream_plan.StreamPlan`` (or None).  When set (or
    when ``cfg.use_fused_kernels`` resolves one), blocks dispatch to the
    fused Pallas kernels the compiler pipeline selected.
    """
    pin_all = act_sharding is not None and act_pin_scope == "all"
    pin = ((lambda a: jax.lax.with_sharding_constraint(a, act_sharding))
           if act_sharding is not None else (lambda a: a))
    pin_block = pin if pin_all else (lambda a: a)
    params = _cast_tree(cfg, params)
    x, positions = _embed_in(cfg, params, batch)
    plan = resolve_plan(cfg, x.shape[0] * x.shape[1], plan=plan)
    x = pin(x)
    period = len(cfg.layer_pattern)
    groups = cfg.num_layers // period
    shared = params.get("shared")

    def group_body(x, block_params: Tuple[Tree, ...]) -> Tuple[jax.Array, None]:
        for pidx in range(period):
            kind = cfg.layer_pattern[pidx]
            x, _ = _apply_block_full(cfg, kind, block_params[pidx], shared,
                                     x, positions, collect=False,
                                     lplan=_lplan(plan, kind))
            x = pin_block(x)
        return x, None

    body = jax.checkpoint(group_body) if remat else group_body
    if groups > 0:
        x, _ = lax.scan(body, x, params["blocks"])
    for i, bp in enumerate(params["rest"]):
        kind = cfg.layer_kind(groups * period + i)
        x, _ = _apply_block_full(cfg, kind, bp, shared, x, positions,
                                 collect=False, lplan=_lplan(plan, kind))
        x = pin_block(x)
    return L.apply_norm(cfg.norm, x, params["final_norm"])


# --------------------------------------------------------------------- #
# Streamed cross-entropy (chunked over sequence)
# --------------------------------------------------------------------- #

def streamed_xent(hidden: jax.Array, head: jax.Array, labels: jax.Array,
                  vocab_size: int, chunk: int = 256) -> jax.Array:
    """Mean CE without materializing [B,S,V] logits.

    hidden: [B,S,D]; head: [D,Vp] (vocab possibly padded); labels: [B,S]
    with -100 = ignore.  Sequence is processed in chunks via ``lax.scan`` —
    the paper's streaming applied to the loss layer.
    """
    b, s, d = hidden.shape
    vp = head.shape[-1]
    c = _chunk_of(s, chunk)
    nc = s // c
    hc = hidden.reshape(b, nc, c, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, nc, c).transpose(1, 0, 2)
    pad_mask = (jnp.arange(vp) >= vocab_size)[None, None]

    def step(carry, inp):
        tot, cnt = carry
        h, y = inp                                    # [B,c,D], [B,c]
        logits = (h @ head).astype(jnp.float32)       # [B,c,Vp]
        logits = jnp.where(pad_mask, -1e30, logits)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(y, 0)[..., None], axis=-1)[..., 0]
        valid = y >= 0
        nll = jnp.where(valid, lse - gold, 0.0)
        return (tot + nll.sum(), cnt + valid.sum()), None

    (tot, cnt), _ = lax.scan(step, (jnp.float32(0.0), jnp.int32(0)), (hc, lc))
    return tot / jnp.maximum(cnt, 1)


def forward_train(params: Tree, cfg: ModelConfig,
                  batch: Dict[str, jax.Array], *,
                  remat: bool = True, act_sharding=None,
                  act_pin_scope: str = "all",
                  plan: Optional[Plan] = None) -> jax.Array:
    """Streamed-CE training loss."""
    labels = batch["labels"]
    plan = resolve_plan(cfg, labels.shape[0] * labels.shape[1], plan=plan)
    hidden = forward_hidden(params, cfg, batch, remat=remat,
                            act_sharding=act_sharding,
                            act_pin_scope=act_pin_scope, plan=plan)
    head = _c(cfg, params["lm_head"])
    if plan is not None and plan.lm_head.fused:
        return L.fused_streamed_xent(hidden, head, labels, cfg.vocab_size,
                                     **plan.lm_head.kw)
    return streamed_xent(hidden, head, labels, cfg.vocab_size)


# --------------------------------------------------------------------- #
# Prefill
# --------------------------------------------------------------------- #

def prefill(params: Tree, cfg: ModelConfig, batch: Dict[str, jax.Array], *,
            plan: Optional[Plan] = None) -> Tuple[jax.Array, Tree]:
    """Forward pass that also returns decode caches (sized at the prompt
    length; the serving layer places them into max-length buffers)."""
    params = _cast_tree(cfg, params)
    x, positions = _embed_in(cfg, params, batch)
    plan = resolve_plan(cfg, x.shape[0] * x.shape[1], plan=plan)
    period = len(cfg.layer_pattern)
    groups = cfg.num_layers // period
    shared = params.get("shared")

    def group_body(x, block_params):
        auxes = []
        for pidx in range(period):
            kind = cfg.layer_pattern[pidx]
            x, aux = _apply_block_full(cfg, kind, block_params[pidx], shared,
                                       x, positions, collect=True,
                                       lplan=_lplan(plan, kind))
            auxes.append(aux)
        return x, tuple(auxes)

    caches_rest = []
    if groups > 0:
        x, caches_blocks = lax.scan(group_body, x, params["blocks"])
    else:
        caches_blocks = ()
    for i, bp in enumerate(params["rest"]):
        kind = cfg.layer_kind(groups * period + i)
        x, aux = _apply_block_full(cfg, kind, bp, shared, x, positions,
                                   collect=True, lplan=_lplan(plan, kind))
        caches_rest.append(jax.tree.map(lambda a: a[None], aux))
    x = L.apply_norm(cfg.norm, x, params["final_norm"])
    logits = (x[:, -1:] @ _c(cfg, params["lm_head"])).astype(jnp.float32)
    vp = logits.shape[-1]
    logits = jnp.where((jnp.arange(vp) >= cfg.vocab_size)[None, None],
                       -1e30, logits)
    return logits, {"blocks": caches_blocks, "rest": tuple(caches_rest)}


# --------------------------------------------------------------------- #
# Chunked prefill (fixed-shape tiles against the paged decode cache)
# --------------------------------------------------------------------- #

def supports_chunked_prefill(cfg: ModelConfig) -> bool:
    """Whether ``prefill_chunk`` can serve this config.

    Chunked prefill carries per-request state between chunks through the
    paged KV pools — which only exists for attention K/V.  SSM / RWKV /
    hybrid stacks carry recurrent state (ssm/conv/wkv/token-shift) that
    the full-sequence mixers cannot yet resume mid-prompt, and mrope's
    3-axis positions are not expressible as a scalar chunk offset; those
    configs prefill whole-prompt (the engine falls back automatically).
    """
    kinds = {cfg.layer_kind(i) for i in range(cfg.num_layers)}
    # cfg.causal is load-bearing: causal masking is what hides the final
    # chunk's zero-pad K/V (kv_len counts pad positions as valid).
    return (cfg.causal and cfg.rope != "mrope"
            and kinds <= {"attn", "local_attn", "global_attn"})


def _attn_block_chunk(cfg: ModelConfig, p: Tree, x: jax.Array, cache: Tree,
                      table_row: jax.Array, chunk_pages: jax.Array,
                      offset: jax.Array, kv_len: jax.Array, *,
                      window: int = 0,
                      lplan: Optional[LPlan] = None,
                      cow_src: Optional[jax.Array] = None,
                      cow_dst: Optional[jax.Array] = None,
                      ) -> Tuple[jax.Array, Tree]:
    """One attention block over a prompt CHUNK, against the paged cache.

    x: [1, C, D]; cache: {"k","v"} pools [P, page_size, Hkv, hd];
    table_row: [max_pages] the slot's logical->physical page map;
    chunk_pages: [C // page_size] physical pages of THIS chunk;
    offset: dynamic chunk start position; kv_len: dynamic valid KV extent
    (= offset + C: earlier chunks plus this one).

    The chunk's K/V are written into their pages FIRST, then attention
    gathers the slot's full page extent and masks by (causal @ absolute
    positions, kv_len) — so queries see chunks 0..k-1 AND their own chunk
    through the same pools the decode step will keep appending to.  Pad
    tokens of a final partial chunk sit at positions past every real
    query, so causal masking excludes them for free.

    ``cow_src``/``cow_dst`` (traced int32 scalars, ``NULL_PAGE`` when
    idle) drive the copy-on-write path: when this chunk's span includes
    a page the slot shares through the prefix cache, the shared page is
    copied onto the private ``cow_dst`` inside both pools before the
    scatter — a shared page is never a write target (DESIGN.md §10).
    ``table_row`` / ``chunk_pages`` already carry ``cow_dst``.
    """
    # Function-local for the same circular-import reason as the decode
    # path: serving imports models at module load.
    from ..serving.kv_cache import (gather_pages, gather_pages_dequant,
                                    live_page_table, place_chunk_pages,
                                    place_chunk_pages_q)
    b, c, d = x.shape
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_
    layout = cfg.kv_cache_layout
    ap = p["attn"]
    q, k, v = _project_qkv(cfg, ap, x, p["ln1"], lplan)
    q = q.reshape(b, c, hq, hd)
    k = k.reshape(b, c, hkv, hd)
    v = v.reshape(b, c, hkv, hd)
    q, k = _qk_normed(cfg, ap, q, k)
    positions = offset + jnp.arange(c)[None]               # [1, C]
    q = L.apply_positional(cfg.rope, q, positions, cfg.rope_theta)
    k = L.apply_positional(cfg.rope, k, positions, cfg.rope_theta)
    k_new = k.transpose(0, 2, 1, 3) if layout == "bhsd" else k
    v_new = v.transpose(0, 2, 1, 3) if layout == "bhsd" else v
    quant = "k_scale" in cache
    if quant:
        kc, ks = place_chunk_pages_q(cache["k"], cache["k_scale"], k_new,
                                     chunk_pages, layout=layout,
                                     cow_src=cow_src, cow_dst=cow_dst)
        vc, vs = place_chunk_pages_q(cache["v"], cache["v_scale"], v_new,
                                     chunk_pages, layout=layout,
                                     cow_src=cow_src, cow_dst=cow_dst)
    else:
        kc = place_chunk_pages(cache["k"], k_new, chunk_pages, layout=layout,
                               cow_src=cow_src, cow_dst=cow_dst)
        vc = place_chunk_pages(cache["v"], v_new, chunk_pages, layout=layout,
                               cow_src=cow_src, cow_dst=cow_dst)
    # Bound KV traffic by the live prefix: the gather touches O(prefix)
    # distinct pages instead of the slot's full table extent (masking at
    # kv_len already discards the dead rows' scores).
    row_live = live_page_table(table_row, kv_len, cache["k"].shape[1])
    choice = lplan.attention if lplan is not None else None
    fused = choice is not None and choice.fused
    if quant and not fused:
        # Eager reference: dense dequantized K/V through the same
        # streaming-attention path the f32 cache takes.
        kseq = gather_pages_dequant(kc, ks, row_live[None], layout=layout)
        vseq = gather_pages_dequant(vc, vs, row_live[None], layout=layout)
    else:
        kseq = gather_pages(kc, row_live[None], layout=layout)
        vseq = gather_pages(vc, row_live[None], layout=layout)
    if layout == "bhsd":
        kseq = kseq.transpose(0, 2, 1, 3)
        vseq = vseq.transpose(0, 2, 1, 3)
    if fused:
        # The plan's flash kernel, offset twin: q_offset/kv_len ride in as
        # scalar-prefetch operands so one compiled program covers every
        # chunk index over any cache fill; the sharded dispatch (and the
        # shard_map it builds) comes from the plan's sharding claim.
        # Quantized: K/V stay codes and the per-page scale rows expand to
        # per-position scale lanes the kernel consumes next to each tile.
        scl = {}
        if quant:
            ps_ = cache["k"].shape[1]
            scl = {"k_scale": jnp.repeat(ks[row_live], ps_, axis=0)[None],
                   "v_scale": jnp.repeat(vs[row_live], ps_, axis=0)[None]}
        o = L.fused_attention_chunk(q, kseq, vseq, offset, kv_len,
                                    causal=cfg.causal, window=window,
                                    **scl, **choice.kw)
    else:
        o = L.streaming_attention(q, kseq, vseq, causal=cfg.causal,
                                  q_offset=offset, window=window,
                                  kv_len=kv_len)
    x = x + o.reshape(b, c, hq * hd) @ ap["wo"]
    x = x + _ffn_block(cfg, p["mlp"], x, p["ln2"], lplan)
    new_kv = {"k": kc, "v": vc}
    if quant:
        new_kv.update(k_scale=ks, v_scale=vs)
    return x, new_kv


def _apply_block_chunk(cfg: ModelConfig, kind: str, p: Tree, x: jax.Array,
                       cache: Tree, table_row: jax.Array,
                       chunk_pages: jax.Array, offset: jax.Array,
                       kv_len: jax.Array,
                       lplan: Optional[LPlan] = None,
                       cow_src: Optional[jax.Array] = None,
                       cow_dst: Optional[jax.Array] = None,
                       ) -> Tuple[jax.Array, Tree]:
    if kind not in ("attn", "local_attn", "global_attn"):
        raise NotImplementedError(
            f"chunked prefill does not support layer kind {kind!r} "
            "(gate on supports_chunked_prefill)")
    window = cfg.sliding_window if kind == "local_attn" else 0
    return _attn_block_chunk(cfg, p, x, cache, table_row, chunk_pages,
                             offset, kv_len, window=window, lplan=lplan,
                             cow_src=cow_src, cow_dst=cow_dst)


def prefill_chunk(params: Tree, cfg: ModelConfig, tokens: jax.Array,
                  cache: Tree, table_row: jax.Array, chunk_pages: jax.Array,
                  offset: jax.Array, last_idx: jax.Array,
                  cow_src: Optional[jax.Array] = None,
                  cow_dst: Optional[jax.Array] = None, *,
                  plan: Optional[Plan] = None,
                  ) -> Tuple[jax.Array, jax.Array, Tree]:
    """Process ONE fixed-size prompt chunk against the paged decode cache.

    tokens: [1, C] int32, the chunk (zero-padded past the prompt's end on
    the final chunk); cache: paged pools from ``serving.kv_cache``
    (donated by the engine — K/V scatters update in place); table_row:
    [max_pages] int32 slot page map; chunk_pages: [C // page_size] int32
    physical pages for this chunk; offset: dynamic chunk start position;
    last_idx: within-chunk index of the prompt's last real token (only
    meaningful on the final chunk — earlier dispatches discard the token).

    ``offset`` may be any page-aligned position, including a NONZERO
    first-dispatch offset against table rows the prefix cache
    pre-populated with shared pages (DESIGN.md §10): the gather walks the
    whole live row, so queries attend to the claimed prefix exactly as
    they would to self-computed chunks.  ``cow_src``/``cow_dst`` (traced
    int32 scalars, ``NULL_PAGE`` when idle) copy one shared page onto a
    private one in every layer's K and V pool before the chunk scatter —
    the copy-on-write step for a chunk whose span overlaps a shared page.

    Every dynamic quantity (offset, last_idx, page ids, the COW pair) is
    a traced operand, so ONE compiled program serves every chunk of every
    prompt — the compile count is independent of the prompt-length mix.
    Returns (next_token [1, 1], logits [1, 1, Vp] at ``last_idx``,
    new_cache).
    """
    if not supports_chunked_prefill(cfg):
        raise NotImplementedError(
            f"chunked prefill unsupported for config {cfg.name!r}")
    params = _cast_tree(cfg, params)
    b, c = tokens.shape
    offset = jnp.asarray(offset, jnp.int32)
    x = _c(cfg, jnp.take(params["embed"], tokens, axis=0))
    x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    if cfg.rope == "none" and "pos_embed" in params:
        positions = jnp.broadcast_to(offset + jnp.arange(c)[None], (b, c))
        x = x + jnp.take(_c(cfg, params["pos_embed"]), positions, axis=0)
    # Plan keyed on the chunk token count and the gathered cache extent —
    # both static, so the plan (like the program) is one per engine.
    kv_extent = int(table_row.shape[0]) * _cache_page_size(cache)
    plan = resolve_plan(cfg, b * c, kv_len=kv_extent, plan=plan)
    kv_len = offset + c
    period = len(cfg.layer_pattern)
    groups = cfg.num_layers // period

    def group_body(x, inp):
        block_params, cache_g = inp
        new_caches = []
        for pidx in range(period):
            kind = cfg.layer_pattern[pidx]
            x, nc = _apply_block_chunk(cfg, kind, block_params[pidx], x,
                                       cache_g[pidx], table_row,
                                       chunk_pages, offset, kv_len,
                                       lplan=_lplan(plan, kind),
                                       cow_src=cow_src, cow_dst=cow_dst)
            new_caches.append(nc)
        return x, tuple(new_caches)

    if groups > 0:
        x, new_blocks = lax.scan(group_body, x,
                                 (params["blocks"], cache["blocks"]))
    else:
        new_blocks = ()
    new_rest = []
    for i, bp in enumerate(params["rest"]):
        kind = cfg.layer_kind(groups * period + i)
        c_i = jax.tree.map(lambda a: a[0], cache["rest"][i])
        x, nc = _apply_block_chunk(cfg, kind, bp, x, c_i, table_row,
                                   chunk_pages, offset, kv_len,
                                   lplan=_lplan(plan, kind),
                                   cow_src=cow_src, cow_dst=cow_dst)
        new_rest.append(jax.tree.map(lambda a: a[None], nc))
    x = L.apply_norm(cfg.norm, x, params["final_norm"])
    h_last = lax.dynamic_slice_in_dim(x, jnp.asarray(last_idx, jnp.int32),
                                      1, axis=1)            # [1, 1, D]
    logits = (h_last @ _c(cfg, params["lm_head"])).astype(jnp.float32)
    vp = logits.shape[-1]
    logits = jnp.where((jnp.arange(vp) >= cfg.vocab_size)[None, None],
                       -1e30, logits)
    next_tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return next_tokens, logits, {"blocks": new_blocks,
                                 "rest": tuple(new_rest)}


def _cache_page_size(cache: Tree) -> int:
    """Page size of a paged cache tree (shape[2] of any K/V pool leaf)."""
    from .params import cache_leaf_kind, cache_leaf_name
    for path, leaf in jax.tree_util.tree_flatten_with_path(cache)[0]:
        if cache_leaf_kind(cache_leaf_name(path)) == "kv":
            return int(leaf.shape[2])
    raise ValueError("cache tree holds no K/V pool leaves")


# --------------------------------------------------------------------- #
# Decode
# --------------------------------------------------------------------- #

def _decode_positions(cache_pos: jax.Array, b: int) -> jax.Array:
    """Normalize a decode write position (scalar or [B]) to a [B] vector —
    per-slot positions are what continuous batching runs on; the scalar
    form is the degenerate all-slots-aligned case."""
    return jnp.broadcast_to(
        jnp.reshape(jnp.asarray(cache_pos, jnp.int32), (-1,)), (b,))


def _attn_block_decode(cfg: ModelConfig, p: Tree, x: jax.Array,
                       cache: Tree, cache_pos: jax.Array,
                       lengths: jax.Array, *, window: int = 0,
                       lplan: Optional[LPlan] = None,
                       page_table: Optional[jax.Array] = None,
                       ) -> Tuple[jax.Array, Tree]:
    """x: [B,1,D]; cache: {"k","v"} [B,Smax,Hkv,hd] contiguous, or paged
    pools [P,page_size,Hkv,hd] when ``page_table`` ([B,max_pages]) is set.

    ``cache_pos`` may be a scalar or a per-slot [B] vector.  With a page
    table the token is scattered through the slot's page indirection and
    attention runs either through the ``paged_attention`` Pallas kernel
    (when the plan selected it) or the gather-pages reference path; the
    contiguous path scatters per slot at its own offset.  The plan's
    flash kernel is never used here — its grid is degenerate at Sq=1.
    """
    b = x.shape[0]
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_
    layout = cfg.kv_cache_layout
    ap = p["attn"]
    q, k, v = _project_qkv(cfg, ap, x, p["ln1"], lplan)
    q = q.reshape(b, 1, hq, hd)
    k = k.reshape(b, 1, hkv, hd)
    v = v.reshape(b, 1, hkv, hd)
    q, k = _qk_normed(cfg, ap, q, k)
    pos = _decode_positions(cache_pos, b)[:, None]          # [B, 1]
    if cfg.rope == "mrope":
        pos3 = jnp.broadcast_to(pos[None], (3, b, 1))
        q = L.apply_positional(cfg.rope, q, pos3, cfg.rope_theta)
        k = L.apply_positional(cfg.rope, k, pos3, cfg.rope_theta)
    else:
        q = L.apply_positional(cfg.rope, q, pos, cfg.rope_theta)
        k = L.apply_positional(cfg.rope, k, pos, cfg.rope_theta)
    k_new = k.transpose(0, 2, 1, 3) if layout == "bhsd" else k
    v_new = v.transpose(0, 2, 1, 3) if layout == "bhsd" else v
    if page_table is not None:
        # Deliberately deferred: serving imports models at module load, so
        # this back edge to the paged-cache primitives must stay
        # function-local (hoisting it is a circular import).  The
        # primitives are pure array ops; they live in serving because
        # that's where the page allocator that owns their layout lives.
        from ..serving.kv_cache import (gather_pages, gather_pages_dequant,
                                        live_page_table, paged_append,
                                        paged_append_q)
        pos_v = pos[:, 0]
        quant = "k_scale" in cache
        ks = vs = None
        if quant:
            kc, ks = paged_append_q(cache["k"], cache["k_scale"],
                                    page_table, pos_v, k_new, layout=layout)
            vc, vs = paged_append_q(cache["v"], cache["v_scale"],
                                    page_table, pos_v, v_new, layout=layout)
        else:
            kc = paged_append(cache["k"], page_table, pos_v, k_new,
                              layout=layout)
            vc = paged_append(cache["v"], page_table, pos_v, v_new,
                              layout=layout)
        choice = lplan.decode_attn if lplan is not None else None
        if choice is not None and choice.fused:
            o = L.fused_paged_attention(q, kc, vc, page_table, lengths + 1,
                                        window=window, k_scale=ks,
                                        v_scale=vs, shard=choice.sharding)
        else:
            # Bound the gather by each slot's live prefix, mirroring the
            # chunk path (the length mask already discards dead rows).
            tbl_live = live_page_table(page_table, lengths + 1,
                                       cache["k"].shape[1])
            if quant:
                kd = gather_pages_dequant(kc, ks, tbl_live, layout=layout)
                vd = gather_pages_dequant(vc, vs, tbl_live, layout=layout)
            else:
                kd = gather_pages(kc, tbl_live, layout=layout)
                vd = gather_pages(vc, tbl_live, layout=layout)
            o = L.decode_attention(q, kd, vd, lengths + 1, window=window,
                                   layout=layout)
    else:
        from .params import kv_seq_axis
        ax = kv_seq_axis(layout)
        seq_len = cache["k"].shape[ax]
        # Per-slot scatter (a slot at capacity rewrites its final row; the
        # engine retires it there), vmapped so each slot writes its own
        # offset — the wave-shared scalar position is just the aligned case.
        pos_w = jnp.minimum(pos[:, 0], seq_len - 1)

        def upd(c, new, p_):
            return lax.dynamic_update_slice_in_dim(
                c, new.astype(c.dtype), p_, axis=ax)

        kc = jax.vmap(upd)(cache["k"], k_new, pos_w)
        vc = jax.vmap(upd)(cache["v"], v_new, pos_w)
        o = L.decode_attention(q, kc, vc, lengths + 1, window=window,
                               layout=layout)
    x = x + o.reshape(b, 1, hq * hd) @ ap["wo"]
    x = x + _ffn_block(cfg, p["mlp"], x, p["ln2"], lplan)
    new_kv = {"k": kc, "v": vc}
    if page_table is not None and "k_scale" in cache:
        new_kv.update(k_scale=ks, v_scale=vs)
    return x, new_kv


def _mamba_block_decode(cfg: ModelConfig, p: Tree, x: jax.Array,
                        cache: Tree) -> Tuple[jax.Array, Tree]:
    b = x.shape[0]
    m = p["mamba"]
    h = L.apply_norm(cfg.norm, x, p["ln"])[:, 0]           # [B,D]
    xin = h @ m["wx"]
    z = h @ m["wz"]
    bmat = h @ m["wb"]
    cmat = h @ m["wc"]
    dt = jax.nn.softplus(h @ m["wdt"] + m["dt_bias"].astype(h.dtype))
    # Conv state update: cache["conv"] holds the previous K-1 inputs.
    conv_in = jnp.concatenate([cache["conv"],
                               xin[:, None].astype(cache["conv"].dtype)],
                              axis=1)                      # [B,K,di]
    w = m["conv_w"]
    y = jnp.einsum("bkd,kd->bd", conv_in.astype(jnp.float32),
                   w.astype(jnp.float32))
    xconv = jax.nn.silu(y + m["conv_b"].astype(jnp.float32)).astype(x.dtype)
    hps = xconv.reshape(b, cfg.ssm_heads, cfg.ssm_head_dim)
    yssm, state = L.mamba2_decode_step(hps, dt, m["a_log"], bmat, cmat,
                                       m["d_skip"], cache["ssm"])
    yin = yssm.reshape(b, cfg.d_inner) * jax.nn.silu(z)
    x = x + (yin @ m["wout"])[:, None]
    return x, {"ssm": state, "conv": conv_in[:, 1:]}


def _rwkv_block_decode(cfg: ModelConfig, p: Tree, x: jax.Array,
                       cache: Tree) -> Tuple[jax.Array, Tree]:
    b = x.shape[0]
    h, n, d = cfg.rwkv_heads, cfg.rwkv_head_dim, cfg.d_model
    tm, cm = p["tm"], p["cm"]
    xa = L.apply_norm(cfg.norm, x, p["ln1"])[:, 0]
    xs = cache["tm_shift"].astype(xa.dtype)

    def mix(name):
        mu = tm[f"mix_{name}"].astype(xa.dtype)
        return xa * mu + xs * (1.0 - mu)

    r = (mix("r") @ tm["wr"]).reshape(b, 1, h, n)
    k = (mix("k") @ tm["wk"]).reshape(b, 1, h, n)
    v = (mix("v") @ tm["wv"]).reshape(b, 1, h, n)
    g = jax.nn.silu(mix("g") @ tm["wg"])
    wdec = jnp.exp(-jnp.exp(
        (mix("w") @ tm["ww"]).astype(jnp.float32)
        + tm["w_bias"].reshape(1, h * n))).reshape(b, 1, h, n)
    y, state = L.wkv6(r, k, v, wdec, tm["u"],
                      init_state=cache["wkv"])
    y = (y.reshape(b, d) * g) @ tm["wo"]
    x = x + y[:, None]
    xc = L.apply_norm(cfg.norm, x, p["ln2"])[:, 0]
    xcs = cache["cm_shift"].astype(xc.dtype)

    def cmix(name):
        mu = cm[f"mix_{name}"].astype(xc.dtype)
        return xc * mu + xcs * (1.0 - mu)

    kk = jnp.square(jax.nn.relu(cmix("k") @ cm["wk"]))
    rr = jax.nn.sigmoid(cmix("r") @ cm["wr"])
    x = x + (rr * (kk @ cm["wv"]))[:, None]
    new = {"wkv": state, "tm_shift": xa.astype(cache["tm_shift"].dtype),
           "cm_shift": xc.astype(cache["cm_shift"].dtype)}
    return x, new


def _apply_block_decode(cfg: ModelConfig, kind: str, p: Tree, shared: Tree,
                        x: jax.Array, cache: Tree, cache_pos: jax.Array,
                        lengths: jax.Array,
                        lplan: Optional[LPlan] = None,
                        page_table: Optional[jax.Array] = None,
                        ) -> Tuple[jax.Array, Tree]:
    if kind == "rwkv":
        return _rwkv_block_decode(cfg, p, x, cache)
    if kind == "mamba":
        return _mamba_block_decode(cfg, p, x, cache)
    if kind == "mamba+shared_attn":
        mamba_cache = {"ssm": cache["ssm"], "conv": cache["conv"]}
        attn_cache = {n: cache[n] for n in ("k", "v", "k_scale", "v_scale")
                      if n in cache}
        x, nm = _mamba_block_decode(cfg, p, x, mamba_cache)
        x, na = _attn_block_decode(cfg, shared, x, attn_cache, cache_pos,
                                   lengths, lplan=lplan,
                                   page_table=page_table)
        return x, {**nm, **na}
    window = cfg.sliding_window if kind == "local_attn" else 0
    return _attn_block_decode(cfg, p, x, cache, cache_pos, lengths,
                              window=window, lplan=lplan,
                              page_table=page_table)


def decode_step(params: Tree, cfg: ModelConfig, tokens: jax.Array,
                cache: Tree, cache_pos: jax.Array, lengths: jax.Array, *,
                page_table: Optional[jax.Array] = None,
                plan: Optional[Plan] = None,
                ) -> Tuple[jax.Array, jax.Array, Tree]:
    """One decoding step.

    tokens: [B,1] int32; cache: pytree from ``init_cache``/``prefill`` (or
    paged pools from ``serving.kv_cache`` when ``page_table`` is given);
    cache_pos: int32 write position, scalar or per-slot [B]; lengths: [B]
    valid lengths; page_table: [B, max_pages] int32 page indirection.
    Returns (next_tokens [B,1], logits [B,1,Vp], new_cache).
    """
    params = _cast_tree(cfg, params)
    b = tokens.shape[0]
    pos_v = _decode_positions(cache_pos, b)
    x = _c(cfg, jnp.take(params["embed"], tokens, axis=0))
    x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    if cfg.rope == "none" and "pos_embed" in params:
        x = x + jnp.take(_c(cfg, params["pos_embed"]), pos_v,
                         axis=0)[:, None]
    plan = resolve_plan(cfg, b,
                        kv_len=_cache_kv_len(cfg, cache, page_table),
                        plan=plan)
    period = len(cfg.layer_pattern)
    groups = cfg.num_layers // period
    shared = params.get("shared")

    def group_body(x, inp):
        block_params, cache_g = inp
        new_caches = []
        for pidx in range(period):
            kind = cfg.layer_pattern[pidx]
            x, nc = _apply_block_decode(cfg, kind, block_params[pidx],
                                        shared, x, cache_g[pidx], pos_v,
                                        lengths, lplan=_lplan(plan, kind),
                                        page_table=page_table)
            new_caches.append(nc)
        return x, tuple(new_caches)

    if groups > 0:
        x, new_blocks = lax.scan(group_body, x,
                                 (params["blocks"], cache["blocks"]))
    else:
        new_blocks = ()
    new_rest = []
    for i, bp in enumerate(params["rest"]):
        kind = cfg.layer_kind(groups * period + i)
        c_i = jax.tree.map(lambda a: a[0], cache["rest"][i])
        x, nc = _apply_block_decode(cfg, kind, bp, shared, x, c_i,
                                    pos_v, lengths,
                                    lplan=_lplan(plan, kind),
                                    page_table=page_table)
        new_rest.append(jax.tree.map(lambda a: a[None], nc))
    x = L.apply_norm(cfg.norm, x, params["final_norm"])
    logits = (x @ _c(cfg, params["lm_head"])).astype(jnp.float32)
    vp = logits.shape[-1]
    logits = jnp.where((jnp.arange(vp) >= cfg.vocab_size)[None, None],
                       -1e30, logits)
    next_tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    new_cache = {"blocks": new_blocks, "rest": tuple(new_rest)}
    return next_tokens, logits, new_cache


# --------------------------------------------------------------------- #
# Speculative verify (draft-then-verify decode, DESIGN.md §11)
# --------------------------------------------------------------------- #

def supports_speculative(cfg: ModelConfig) -> bool:
    """Whether ``verify_step`` can serve this config.

    Speculative decode needs a rejected draft to be UNDOABLE: for paged
    attention K/V that is a page-table edit (``rollback_extent``), but
    SSM / conv / RWKV recurrent state folds every consumed token into a
    dense carry that cannot be truncated, so hybrid stacks are out.  The
    remaining constraints are the chunked-prefill ones: causal masking is
    what scopes each window row to its own prefix, and mrope's 3-axis
    positions don't extend along a scalar window offset.
    """
    return supports_chunked_prefill(cfg)


def _attn_block_verify(cfg: ModelConfig, p: Tree, x: jax.Array,
                       cache: Tree, cache_pos: jax.Array,
                       lengths: jax.Array, *, window: int = 0,
                       lplan: Optional[LPlan] = None,
                       page_table: Optional[jax.Array] = None,
                       ) -> Tuple[jax.Array, Tree]:
    """One attention block over a W-token verify window, paged cache only.

    x: [B, W, D] — the pending token plus W-1 draft candidates per slot;
    ``cache_pos`` ([B] or scalar) is the window's first write position,
    so K/V rows land at ``pos .. pos + W - 1`` and window row i attends
    through position ``pos + i`` (its own token included), exactly the
    extent single-token decode would see after consuming i accepted
    tokens.  Rows past the accepted prefix leave stale K/V behind; the
    engine truncates them via ``rollback_extent`` and the NEXT dispatch
    overwrites them — in between they sit beyond every slot's length and
    are therefore invisible to the masks.
    """
    if page_table is None:
        raise NotImplementedError(
            "verify_step requires the paged KV cache (rollback is a "
            "page-table edit; the contiguous cache has no equivalent)")
    from ..serving.kv_cache import (gather_pages, gather_pages_dequant,
                                    live_page_table, paged_append_window,
                                    paged_append_window_q)
    b, w, _ = x.shape
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_
    layout = cfg.kv_cache_layout
    ap = p["attn"]
    q, k, v = _project_qkv(cfg, ap, x, p["ln1"], lplan)
    q = q.reshape(b, w, hq, hd)
    k = k.reshape(b, w, hkv, hd)
    v = v.reshape(b, w, hkv, hd)
    q, k = _qk_normed(cfg, ap, q, k)
    pos0 = _decode_positions(cache_pos, b)
    pos = pos0[:, None] + jnp.arange(w)[None]               # [B, W]
    q = L.apply_positional(cfg.rope, q, pos, cfg.rope_theta)
    k = L.apply_positional(cfg.rope, k, pos, cfg.rope_theta)
    k_new = k.transpose(0, 2, 1, 3) if layout == "bhsd" else k
    v_new = v.transpose(0, 2, 1, 3) if layout == "bhsd" else v
    quant = "k_scale" in cache
    ks = vs = None
    if quant:
        kc, ks = paged_append_window_q(cache["k"], cache["k_scale"],
                                       page_table, pos0, k_new,
                                       layout=layout)
        vc, vs = paged_append_window_q(cache["v"], cache["v_scale"],
                                       page_table, pos0, v_new,
                                       layout=layout)
    else:
        kc = paged_append_window(cache["k"], page_table, pos0, k_new,
                                 layout=layout)
        vc = paged_append_window(cache["v"], page_table, pos0, v_new,
                                 layout=layout)
    choice = lplan.verify_attn if lplan is not None else None
    if choice is not None and choice.fused:
        o = L.fused_verify_attention(q, kc, vc, page_table, lengths,
                                     window=window, k_scale=ks, v_scale=vs,
                                     shard=choice.sharding)
    else:
        tbl_live = live_page_table(page_table, lengths + w,
                                   cache["k"].shape[1])
        if quant:
            kd = gather_pages_dequant(kc, ks, tbl_live, layout=layout)
            vd = gather_pages_dequant(vc, vs, tbl_live, layout=layout)
        else:
            kd = gather_pages(kc, tbl_live, layout=layout)
            vd = gather_pages(vc, tbl_live, layout=layout)
        o = L.verify_attention(q, kd, vd, lengths, window=window,
                               layout=layout)
    x = x + o.reshape(b, w, hq * hd) @ ap["wo"]
    x = x + _ffn_block(cfg, p["mlp"], x, p["ln2"], lplan)
    new_kv = {"k": kc, "v": vc}
    if quant:
        new_kv.update(k_scale=ks, v_scale=vs)
    return x, new_kv


def _apply_block_verify(cfg: ModelConfig, kind: str, p: Tree, x: jax.Array,
                        cache: Tree, cache_pos: jax.Array,
                        lengths: jax.Array,
                        lplan: Optional[LPlan] = None,
                        page_table: Optional[jax.Array] = None,
                        ) -> Tuple[jax.Array, Tree]:
    if kind not in ("attn", "local_attn", "global_attn"):
        raise NotImplementedError(
            f"speculative verify does not support layer kind {kind!r} "
            "(gate on supports_speculative)")
    window = cfg.sliding_window if kind == "local_attn" else 0
    return _attn_block_verify(cfg, p, x, cache, cache_pos, lengths,
                              window=window, lplan=lplan,
                              page_table=page_table)


def verify_step(params: Tree, cfg: ModelConfig, tokens: jax.Array,
                cache: Tree, cache_pos: jax.Array, lengths: jax.Array, *,
                page_table: jax.Array,
                plan: Optional[Plan] = None,
                ) -> Tuple[jax.Array, jax.Array, Tree]:
    """Score a W-token speculative window in ONE dispatch.

    tokens: [B, W] int32 — column 0 the pending (already-committed) input
    token, columns 1..W-1 the draft candidates; cache: paged pools;
    cache_pos: window start write position ([B] or scalar); lengths: [B]
    tokens already in the cache (== cache_pos on the serving path);
    page_table: [B, max_pages].  Returns (greedy [B, W], logits
    [B, W, Vp], new_cache): ``greedy[:, i]`` is the model's next token
    after consuming ``tokens[:, :i+1]`` — the engine accepts draft
    ``tokens[:, i]`` while it equals ``greedy[:, i-1]``, and every
    accepted row's logits are the ones non-speculative decode would have
    produced (the verify attention scopes row i to its own causal
    prefix).  Sits between ``prefill_chunk`` and ``decode_step``: same
    paged cache, same dynamic per-slot operands, one compiled program
    per window size W.
    """
    if not supports_speculative(cfg):
        raise NotImplementedError(
            f"speculative verify unsupported for config {cfg.name!r}")
    params = _cast_tree(cfg, params)
    b, w = tokens.shape
    pos_v = _decode_positions(cache_pos, b)
    x = _c(cfg, jnp.take(params["embed"], tokens, axis=0))
    x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    if cfg.rope == "none" and "pos_embed" in params:
        pos = pos_v[:, None] + jnp.arange(w)[None]
        x = x + jnp.take(_c(cfg, params["pos_embed"]), pos, axis=0)
    plan = resolve_plan(cfg, b * w,
                        kv_len=_cache_kv_len(cfg, cache, page_table),
                        plan=plan)
    period = len(cfg.layer_pattern)
    groups = cfg.num_layers // period

    def group_body(x, inp):
        block_params, cache_g = inp
        new_caches = []
        for pidx in range(period):
            kind = cfg.layer_pattern[pidx]
            x, nc = _apply_block_verify(cfg, kind, block_params[pidx], x,
                                        cache_g[pidx], pos_v, lengths,
                                        lplan=_lplan(plan, kind),
                                        page_table=page_table)
            new_caches.append(nc)
        return x, tuple(new_caches)

    if groups > 0:
        x, new_blocks = lax.scan(group_body, x,
                                 (params["blocks"], cache["blocks"]))
    else:
        new_blocks = ()
    new_rest = []
    for i, bp in enumerate(params["rest"]):
        kind = cfg.layer_kind(groups * period + i)
        c_i = jax.tree.map(lambda a: a[0], cache["rest"][i])
        x, nc = _apply_block_verify(cfg, kind, bp, x, c_i, pos_v, lengths,
                                    lplan=_lplan(plan, kind),
                                    page_table=page_table)
        new_rest.append(jax.tree.map(lambda a: a[None], nc))
    x = L.apply_norm(cfg.norm, x, params["final_norm"])
    logits = (x @ _c(cfg, params["lm_head"])).astype(jnp.float32)
    vp = logits.shape[-1]
    logits = jnp.where((jnp.arange(vp) >= cfg.vocab_size)[None, None],
                       -1e30, logits)
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    new_cache = {"blocks": new_blocks, "rest": tuple(new_rest)}
    return greedy, logits, new_cache
