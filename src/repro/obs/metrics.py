"""Typed metrics: Counter / Gauge / Info / Histogram + the registry.

Replaces the serving engine's ad-hoc ``self.metrics`` dict (PR 1-9 grew
it to ~50 untyped keys with MIXED lifetimes — some accumulated across
``generate()`` calls, some were refreshed per call, and the derived
rates silently conflated the two).  The registry makes the lifetime of
every number explicit:

  * **Counter** — monotone, accumulates across the engine's whole life
    (``generated``, ``prefills``, ``rejected``, ``prefill_traces``, ...).
  * **Gauge** — point-in-time value, last write wins (``sched_budget``,
    ``decode_block_last``, ``kv_bytes_peak``, derived rates).
  * **Info** — configuration constants and provenance strings
    (``quant``, ``plan_source``, ``tune_table``); excluded from numeric
    aggregation, exported as a single labeled info sample.
  * **Histogram** — log-spaced buckets with p50/p90/p99 read-out
    (``ttft_s``, ``tpot_s``, ``queue_wait_s``, ``chunk_latency_s``, ...).

Two snapshot views resolve the lifetime ambiguity (DESIGN.md §17):
``"lifetime"`` reports totals since construction; ``"last_generate"``
reports the window since the most recent ``Registry.mark()`` (the engine
marks at the top of every ``generate()``).  Counters subtract their
marked value; histograms subtract their marked bucket counts, so
percentiles are computable PER WINDOW from the same storage; gauges and
infos are point-in-time in both views.

``MetricsView`` is a live read-only ``Mapping`` over the lifetime view —
the backwards-compatible ``engine.metrics``: every pre-existing key
resolves to the same number as before, ``dict(engine.metrics)`` still
snapshots, and histogram families additionally expand to
``<name>_count`` / ``<name>_mean`` / ``<name>_p50`` / ``_p90`` / ``_p99``.
"""

from __future__ import annotations

import math
from bisect import bisect_left
from typing import Any, Dict, Iterator, List, Mapping, Optional, Tuple

VIEWS = ("lifetime", "last_generate")

_PCTS = (("p50", 0.50), ("p90", 0.90), ("p99", 0.99))


def _check_view(view: str) -> None:
    if view not in VIEWS:
        raise ValueError(f"unknown view {view!r} (lifetime | last_generate)")


class Counter:
    """Monotone accumulator.  ``lifetime`` = total since construction;
    ``last_generate`` = delta since the registry's last ``mark()``."""

    kind = "counter"
    __slots__ = ("name", "help", "_value", "_marked")

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._value = 0.0
        self._marked = 0.0

    def inc(self, v: float = 1.0) -> None:
        if v < 0:
            raise ValueError(f"counter {self.name}: negative inc {v}")
        self._value += v

    def mark(self) -> None:
        self._marked = self._value

    def value(self, view: str = "lifetime") -> float:
        return (self._value if view == "lifetime"
                else self._value - self._marked)


class Gauge:
    """Point-in-time value; identical in both views."""

    kind = "gauge"
    __slots__ = ("name", "help", "_value")

    def __init__(self, name: str, help: str = "", value: float = 0.0):
        self.name = name
        self.help = help
        self._value = value

    def set(self, v: float) -> None:
        self._value = v

    def max(self, v: float) -> None:
        """Monotone-max update (peak trackers)."""
        if v > self._value:
            self._value = v

    def mark(self) -> None:
        pass

    def value(self, view: str = "lifetime") -> float:
        return self._value


class Info:
    """Configuration / provenance value of any scalar type (str, int,
    float).  Settable (plan provenance changes after tuning) but outside
    the numeric aggregation paths."""

    kind = "info"
    __slots__ = ("name", "help", "_value")

    def __init__(self, name: str, help: str = "", value: Any = None):
        self.name = name
        self.help = help
        self._value = value

    def set(self, v: Any) -> None:
        self._value = v

    def mark(self) -> None:
        pass

    def value(self, view: str = "lifetime") -> Any:
        return self._value


def log_buckets(lo: float, hi: float, per_decade: int) -> Tuple[float, ...]:
    """Log-spaced upper bounds ``lo * 10**(i/per_decade)`` covering
    ``[lo, hi]`` inclusive (the last bound is >= hi)."""
    if not (lo > 0 and hi > lo and per_decade > 0):
        raise ValueError(f"bad bucket spec lo={lo} hi={hi} "
                         f"per_decade={per_decade}")
    n = math.ceil(per_decade * math.log10(hi / lo))
    return tuple(lo * 10.0 ** (i / per_decade) for i in range(n + 1))


class Histogram:
    """Log-spaced-bucket histogram with percentile read-out.

    Buckets are upper bounds ``le``: observation ``v`` lands in the
    first bucket whose bound is >= v; values above the last bound land
    in the overflow bucket.  Percentiles interpolate GEOMETRICALLY
    inside the selected bucket (log-spaced grid, so the log-linear
    assumption matches the bucket shape) and clamp to the observed
    min/max — p50 <= p90 <= p99 by construction (one cumulative scan,
    monotone ranks).  Marked bucket counts make window percentiles as
    cheap as lifetime ones.
    """

    kind = "histogram"
    __slots__ = ("name", "help", "unit", "bounds", "_counts", "_marked",
                 "_count", "_sum", "_min", "_max",
                 "_m_count", "_m_sum")

    def __init__(self, name: str, help: str = "", *, lo: float = 1e-5,
                 hi: float = 100.0, per_decade: int = 4, unit: str = "s"):
        self.name = name
        self.help = help
        self.unit = unit
        self.bounds = log_buckets(lo, hi, per_decade)
        self._counts = [0] * (len(self.bounds) + 1)
        self._marked = [0] * (len(self.bounds) + 1)
        self._count = 0
        self._sum = 0.0
        self._min = math.inf
        self._max = -math.inf
        self._m_count = 0
        self._m_sum = 0.0

    def observe(self, v: float) -> None:
        if not math.isfinite(v):
            return                       # nan ttft (rejected) never lands
        self._counts[bisect_left(self.bounds, v)] += 1
        self._count += 1
        self._sum += v
        if v < self._min:
            self._min = v
        if v > self._max:
            self._max = v

    def mark(self) -> None:
        self._marked = list(self._counts)
        self._m_count = self._count
        self._m_sum = self._sum

    # ------------------------------------------------------------ reads
    def counts(self, view: str = "lifetime") -> List[int]:
        if view == "lifetime":
            return list(self._counts)
        return [c - m for c, m in zip(self._counts, self._marked)]

    def count(self, view: str = "lifetime") -> int:
        return (self._count if view == "lifetime"
                else self._count - self._m_count)

    def sum(self, view: str = "lifetime") -> float:
        return (self._sum if view == "lifetime"
                else self._sum - self._m_sum)

    def mean(self, view: str = "lifetime") -> float:
        n = self.count(view)
        return self.sum(view) / n if n else math.nan

    def percentile(self, q: float, view: str = "lifetime") -> float:
        """Rank-``q`` estimate from bucket counts (nan when empty)."""
        if not (0.0 < q <= 1.0):
            raise ValueError(f"quantile {q} outside (0, 1]")
        counts = self.counts(view)
        total = sum(counts)
        if total == 0:
            return math.nan
        target = q * total
        cum = 0.0
        for i, c in enumerate(counts):
            if c == 0:
                continue
            if cum + c >= target:
                frac = (target - cum) / c
                if i >= len(self.bounds):          # overflow bucket
                    est = self._max
                else:
                    upper = self.bounds[i]
                    lower = (self.bounds[i - 1] if i > 0
                             else upper / (self.bounds[1] / self.bounds[0]))
                    est = lower * (upper / lower) ** frac
                return min(max(est, self._min), self._max)
            cum += c
        return self._max                            # q == 1.0 fallthrough


class Registry:
    """Ordered collection of typed metrics with get-or-create accessors
    and the two snapshot views.  Re-declaring a name with a different
    type raises — the registry is the single source of truth for what
    each number IS."""

    def __init__(self):
        self._metrics: Dict[str, Any] = {}

    # ----------------------------------------------------- declarations
    def _declare(self, cls, name: str, help: str, **kw):
        got = self._metrics.get(name)
        if got is not None:
            if not isinstance(got, cls):
                raise TypeError(f"metric {name!r} already declared as "
                                f"{got.kind}, not {cls.kind}")
            return got
        m = cls(name, help, **kw)
        self._metrics[name] = m
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._declare(Counter, name, help)

    def gauge(self, name: str, help: str = "",
              value: float = 0.0) -> Gauge:
        return self._declare(Gauge, name, help, value=value)

    def info(self, name: str, help: str = "", value: Any = None) -> Info:
        return self._declare(Info, name, help, value=value)

    def histogram(self, name: str, help: str = "", *, lo: float = 1e-5,
                  hi: float = 100.0, per_decade: int = 4,
                  unit: str = "s") -> Histogram:
        return self._declare(Histogram, name, help, lo=lo, hi=hi,
                             per_decade=per_decade, unit=unit)

    # ----------------------------------------------------------- access
    def __getitem__(self, name: str):
        return self._metrics[name]

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def metrics(self) -> List[Any]:
        return list(self._metrics.values())

    # ------------------------------------------------------------ views
    def mark(self) -> None:
        """Open a new ``last_generate`` window (the engine calls this at
        the top of every ``generate()``)."""
        for m in self._metrics.values():
            m.mark()

    def snapshot(self, view: str = "lifetime") -> Dict[str, Any]:
        """Flat materialized dict: counters/gauges/infos by name,
        histograms expanded to ``_count``/``_mean``/``_p50``/``_p90``/
        ``_p99``."""
        _check_view(view)
        out: Dict[str, Any] = {}
        for m in self._metrics.values():
            if isinstance(m, Histogram):
                out[m.name + "_count"] = m.count(view)
                out[m.name + "_mean"] = m.mean(view)
                for tag, q in _PCTS:
                    out[f"{m.name}_{tag}"] = m.percentile(q, view)
            else:
                out[m.name] = m.value(view)
        return out


class MetricsView(Mapping):
    """Live read-only ``Mapping`` over a registry view — the engine's
    backwards-compatible ``metrics`` attribute.  ``dict(view)``,
    ``view["generated"]``, iteration, and ``len`` all work; writes go
    through the registry's typed handles, never through this view."""

    __slots__ = ("_reg", "_view")

    def __init__(self, registry: Registry, view: str = "lifetime"):
        _check_view(view)
        self._reg = registry
        self._view = view

    def _keys(self) -> List[str]:
        out: List[str] = []
        for m in self._reg.metrics():
            if isinstance(m, Histogram):
                out.extend(f"{m.name}_{suffix}" for suffix in
                           ("count", "mean", "p50", "p90", "p99"))
            else:
                out.append(m.name)
        return out

    def __getitem__(self, key: str) -> Any:
        m = self._reg._metrics.get(key)
        if m is not None and not isinstance(m, Histogram):
            return m.value(self._view)
        base, _, suffix = key.rpartition("_")
        h = self._reg._metrics.get(base)
        if isinstance(h, Histogram):
            if suffix == "count":
                return h.count(self._view)
            if suffix == "mean":
                return h.mean(self._view)
            for tag, q in _PCTS:
                if suffix == tag:
                    return h.percentile(q, self._view)
        raise KeyError(key)

    def __iter__(self) -> Iterator[str]:
        return iter(self._keys())

    def __len__(self) -> int:
        return len(self._keys())

    def __repr__(self) -> str:
        return f"MetricsView({self._view}, {dict(self)!r})"
