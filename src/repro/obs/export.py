"""Exporters: Chrome trace-event JSON, JSONL event log, Prometheus text.

Three read-only views over the same two data sources (the recorder's
event list and the metrics registry):

  * ``chrome_trace(events)`` — Chrome trace-event JSON, loadable in
    Perfetto (https://ui.perfetto.dev) or ``chrome://tracing``.  One
    pid for the engine process, one tid per *track* (``engine``,
    ``sched``, ``kv``, ``prefix``, ``tune``, ``slot0..N``), with
    ``thread_name`` metadata so the UI labels lanes.  Spans become
    ``ph:"X"`` complete events, instants become ``ph:"i"`` with
    thread scope; timestamps are microseconds as the format requires.
  * ``events_jsonl(events)`` — one JSON object per line, stable key
    order, for ad-hoc ``jq``/pandas analysis.
  * ``prometheus_text(registry)`` — Prometheus text exposition 0.0.4.
    Counters/gauges map 1:1; histograms emit the standard cumulative
    ``_bucket{le="..."}`` / ``_sum`` / ``_count`` series PLUS
    ``_p50``/``_p90``/``_p99`` gauges (precomputed quantiles must be
    their own families — mixing them into the histogram type is
    invalid exposition).  Info metrics fold into one
    ``<ns>_build_info``-style sample with the values as labels.

All output is deterministic given deterministic inputs (sorted label
sets, insertion-ordered tracks/metrics, fixed float formatting) so the
golden-file tests compare byte-exact.
"""

from __future__ import annotations

import json
import math
from typing import Any, Dict, Iterable, List

from .events import Event
from .metrics import Counter, Gauge, Histogram, Info, Registry, _PCTS

PID = 1  # single engine process; tracks map to tids


def _track_tids(events: Iterable[Event]) -> Dict[str, int]:
    """Assign tids by first appearance, slots sorted after named tracks
    so the Perfetto lane order is stable regardless of admission order."""
    seen: List[str] = []
    for ev in events:
        if ev.track not in seen:
            seen.append(ev.track)
    named = [t for t in seen if not t.startswith("slot")]
    slots = sorted((t for t in seen if t.startswith("slot")),
                   key=lambda t: int(t[4:]))
    return {t: i + 1 for i, t in enumerate(named + slots)}


def _us(t: float) -> float:
    return round(t * 1e6, 3)


def chrome_trace(events: Iterable[Event]) -> Dict[str, Any]:
    """Build the Chrome trace-event JSON object (dump with
    ``json.dump``; Perfetto loads the file as-is)."""
    events = list(events)
    tids = _track_tids(events)
    trace: List[Dict[str, Any]] = []
    for track, tid in tids.items():
        trace.append({"ph": "M", "pid": PID, "tid": tid,
                      "name": "thread_name", "args": {"name": track}})
    for ev in events:
        rec: Dict[str, Any] = {
            "name": ev.name, "pid": PID, "tid": tids[ev.track],
            "ts": _us(ev.ts), "cat": ev.name.split(".", 1)[0],
        }
        if ev.kind == "span":
            rec["ph"] = "X"
            rec["dur"] = _us(ev.dur)
        else:
            rec["ph"] = "i"
            rec["s"] = "t"  # thread-scoped instant
        if ev.args:
            rec["args"] = dict(ev.args)
        trace.append(rec)
    return {"traceEvents": trace, "displayTimeUnit": "ms"}


def events_jsonl(events: Iterable[Event]) -> str:
    """One compact JSON object per event, one per line."""
    lines = []
    for ev in events:
        lines.append(json.dumps(
            {"name": ev.name, "kind": ev.kind, "ts": ev.ts,
             "dur": ev.dur, "track": ev.track, "args": dict(ev.args)},
            separators=(",", ":"), sort_keys=False))
    return "\n".join(lines) + ("\n" if lines else "")


# ------------------------------------------------------------- prometheus
def _sanitize(name: str) -> str:
    return "".join(c if (c.isalnum() or c == "_") else "_" for c in name)


def _fmt(v: float) -> str:
    if isinstance(v, bool):
        return "1" if v else "0"
    if v != v:                       # nan
        return "NaN"
    if v in (math.inf, -math.inf):
        return "+Inf" if v > 0 else "-Inf"
    if float(v).is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


def _label_escape(v: Any) -> str:
    return str(v).replace("\\", "\\\\").replace('"', '\\"').replace(
        "\n", "\\n")


def prometheus_text(registry: Registry, *, namespace: str = "repro",
                    view: str = "lifetime") -> str:
    """Prometheus text exposition of the registry's ``view``."""
    out: List[str] = []
    infos: List[Info] = []
    for m in registry.metrics():
        full = f"{namespace}_{_sanitize(m.name)}"
        if isinstance(m, Info):
            infos.append(m)
            continue
        if isinstance(m, Counter):
            name = full + "_total"
            out.append(f"# HELP {name} {m.help or m.name}")
            out.append(f"# TYPE {name} counter")
            out.append(f"{name} {_fmt(m.value(view))}")
        elif isinstance(m, Gauge):
            out.append(f"# HELP {full} {m.help or m.name}")
            out.append(f"# TYPE {full} gauge")
            out.append(f"{full} {_fmt(m.value(view))}")
        elif isinstance(m, Histogram):
            if m.unit and not full.endswith("_" + m.unit):
                full = f"{full}_{m.unit}"
            out.append(f"# HELP {full} {m.help or m.name}")
            out.append(f"# TYPE {full} histogram")
            counts = m.counts(view)
            cum = 0
            for bound, c in zip(m.bounds, counts):
                cum += c
                out.append(f'{full}_bucket{{le="{_fmt(bound)}"}} {cum}')
            cum += counts[-1]
            out.append(f'{full}_bucket{{le="+Inf"}} {cum}')
            out.append(f"{full}_sum {_fmt(m.sum(view))}")
            out.append(f"{full}_count {m.count(view)}")
            for tag, q in _PCTS:
                qn = f"{full}_{tag}"
                out.append(f"# HELP {qn} {q:g} quantile of {m.name}")
                out.append(f"# TYPE {qn} gauge")
                out.append(f"{qn} {_fmt(m.percentile(q, view))}")
    if infos:
        name = f"{namespace}_info"
        labels = ",".join(
            f'{_sanitize(i.name)}="{_label_escape(i.value())}"'
            for i in infos)
        out.append(f"# HELP {name} engine configuration / provenance")
        out.append(f"# TYPE {name} gauge")
        out.append(f"{name}{{{labels}}} 1")
    return "\n".join(out) + "\n"


def validate_chrome_trace(trace: Dict[str, Any]) -> List[str]:
    """Self-contained schema check (no jsonschema dependency): returns a
    list of problems, empty when the object is a loadable trace."""
    errs: List[str] = []
    evs = trace.get("traceEvents")
    if not isinstance(evs, list) or not evs:
        return ["traceEvents missing or empty"]
    tids_named = set()
    for i, e in enumerate(evs):
        if not isinstance(e, dict):
            errs.append(f"[{i}] not an object")
            continue
        ph = e.get("ph")
        if ph not in ("X", "i", "M"):
            errs.append(f"[{i}] bad ph {ph!r}")
            continue
        if not isinstance(e.get("pid"), int) or not isinstance(
                e.get("tid"), int):
            errs.append(f"[{i}] pid/tid not int")
        if ph == "M":
            if e.get("name") == "thread_name":
                tids_named.add((e.get("pid"), e.get("tid")))
            continue
        if not isinstance(e.get("name"), str) or not e["name"]:
            errs.append(f"[{i}] missing name")
        ts = e.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            errs.append(f"[{i}] bad ts {ts!r}")
        if ph == "X":
            dur = e.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errs.append(f"[{i}] bad dur {dur!r}")
        if (e.get("pid"), e.get("tid")) not in tids_named:
            errs.append(f"[{i}] tid {e.get('tid')} has no thread_name")
    return errs
