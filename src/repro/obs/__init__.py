"""Observability for the serving runtime: events, metrics, exporters.

Usage from the engine side::

    from repro.obs import Recorder, ManualClock
    eng = ServingEngine(..., telemetry=True)          # fresh Recorder
    eng = ServingEngine(..., telemetry=Recorder(), clock=ManualClock(tick=1e-4))
    eng.generate(prompts)
    trace = chrome_trace(eng.obs.events)              # Perfetto-loadable
    text = prometheus_text(eng.registry)              # exposition
    win = eng.snapshot("last_generate")               # windowed metrics

``python -m repro.obs --demo`` bursts a small engine and writes all
three artifacts; DESIGN.md §17 documents the taxonomy and formats.
"""

from .events import (
    Clock,
    Event,
    ManualClock,
    NULL_RECORDER,
    NullRecorder,
    Recorder,
    resolve_recorder,
    slot_track,
)
from .events import (  # noqa: F401  (event-name vocabulary)
    DISPATCH_DECODE,
    DISPATCH_PREFILL,
    DISPATCH_PREFILL_CHUNK,
    DISPATCH_VERIFY,
    PAGE_ALLOC,
    PAGE_COW,
    PAGE_EVICT,
    PAGE_FREE,
    PAGE_ROLLBACK,
    PREFIX_CLAIM,
    PREFIX_EVICT,
    PREFIX_INSERT,
    REQ_ADMITTED,
    REQ_FINISHED,
    REQ_FIRST_TOKEN,
    REQ_PREFILL_CHUNK,
    REQ_QUEUED,
    REQ_REJECTED,
    SCHED_BUDGET,
    TRACE_DECODE,
    TRACE_PREFILL,
    TRACE_VERIFY,
    TRACK_ENGINE,
    TRACK_KV,
    TRACK_PREFIX,
    TRACK_SCHED,
    TRACK_TUNE,
    TUNE_MEASURE,
    TUNE_PRUNE,
)
from .export import (
    chrome_trace,
    events_jsonl,
    prometheus_text,
    validate_chrome_trace,
)
from .metrics import (
    Counter,
    Gauge,
    Histogram,
    Info,
    MetricsView,
    Registry,
    log_buckets,
)

__all__ = [
    "Clock", "Event", "ManualClock", "NullRecorder", "NULL_RECORDER",
    "Recorder", "resolve_recorder", "slot_track",
    "Counter", "Gauge", "Histogram", "Info", "MetricsView", "Registry",
    "log_buckets",
    "chrome_trace", "events_jsonl", "prometheus_text",
    "validate_chrome_trace",
]
