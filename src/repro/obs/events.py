"""Event bus: typed span/instant records for the serving runtime.

StreamTensor's argument is that performance lives in *where time and
bytes go*; this module makes the runtime schedule itself an inspectable
artifact.  Every interesting moment in the serving engine — a request
moving through its lifecycle, a dispatch occupying a slot, a page
changing hands, the tuner measuring a candidate — is recorded as a typed
``Event`` on a named *track*, and the exporters (``obs/export.py``) turn
the event list into a Perfetto-loadable Chrome trace, a JSONL log, or
feed the registry's Prometheus exposition.

Design constraints, in order:

  1. **Zero hot-path cost when disabled.**  ``NULL_RECORDER`` is a
     singleton whose ``instant``/``complete`` are no-ops and whose
     ``span`` returns one shared no-op context manager — no ``Event``
     (or any other) allocation ever happens, which the disabled-overhead
     test asserts through the event-count probe.  Emission sites on the
     engine's per-dispatch path additionally guard with
     ``recorder.enabled`` so even argument tuples are never built.
  2. **Deterministic under test.**  The clock is injectable: a
     ``ManualClock`` (optionally auto-ticking) makes span starts,
     durations, and orderings reproducible, so the export golden tests
     compare byte-exact output.
  3. **One timebase.**  The engine stamps ``Request`` lifecycle times
     with the SAME clock the recorder uses, so lifecycle instants and
     dispatch spans line up on the trace.

Event taxonomy (the names below are the vocabulary; DESIGN.md §17 has
the full table):

  * request lifecycle — ``req.queued`` → ``req.admitted`` →
    ``req.prefill_chunk`` (per chunk) → ``req.first_token`` →
    ``req.finished`` / ``req.rejected``
  * dispatch spans — ``dispatch.prefill`` / ``dispatch.prefill_chunk``
    / ``dispatch.decode`` / ``dispatch.verify`` on the engine track,
    mirrored per participating slot as ``prefill`` / ``prefill_chunk``
    / ``decode`` / ``verify`` on ``slot<i>`` tracks
  * compile probes — ``trace.prefill`` / ``trace.decode`` /
    ``trace.verify``: emitted from inside the traced Python bodies, so
    their event count EQUALS the engine's retrace counters
  * paged memory — ``page.alloc`` / ``page.free`` / ``page.cow`` /
    ``page.rollback`` / ``page.evict``
  * prefix cache — ``prefix.claim`` / ``prefix.insert`` /
    ``prefix.evict``
  * tuner — ``tune.measure`` / ``tune.prune``
  * scheduler — ``sched.budget`` / ``sched.admit_wave``
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

Clock = Callable[[], float]

# ----------------------------------------------------------------- names
# Request lifecycle (tracks: "sched" while queued, "slot<i>" once bound).
REQ_QUEUED = "req.queued"
REQ_ADMITTED = "req.admitted"
REQ_PREFILL_CHUNK = "req.prefill_chunk"
REQ_FIRST_TOKEN = "req.first_token"
REQ_FINISHED = "req.finished"
REQ_REJECTED = "req.rejected"

# Dispatch spans (engine track + per-slot mirrors).
DISPATCH_PREFILL = "dispatch.prefill"
DISPATCH_PREFILL_CHUNK = "dispatch.prefill_chunk"
DISPATCH_DECODE = "dispatch.decode"
DISPATCH_VERIFY = "dispatch.verify"

# Compile probes: emitted while jit TRACES the dispatch body, so the
# event count equals the engine's programs-built counters.
TRACE_PREFILL = "trace.prefill"
TRACE_DECODE = "trace.decode"
TRACE_VERIFY = "trace.verify"

# Paged-memory events (track "kv").
PAGE_ALLOC = "page.alloc"
PAGE_FREE = "page.free"
PAGE_COW = "page.cow"
PAGE_ROLLBACK = "page.rollback"
PAGE_EVICT = "page.evict"

# Prefix-cache events (track "prefix").
PREFIX_CLAIM = "prefix.claim"
PREFIX_INSERT = "prefix.insert"
PREFIX_EVICT = "prefix.evict"

# Tuner events (track "tune").
TUNE_MEASURE = "tune.measure"
TUNE_PRUNE = "tune.prune"

# Scheduler decisions (track "sched").
SCHED_BUDGET = "sched.budget"

# Canonical track names (slots add "slot0", "slot1", ...).
TRACK_ENGINE = "engine"
TRACK_SCHED = "sched"
TRACK_KV = "kv"
TRACK_PREFIX = "prefix"
TRACK_TUNE = "tune"


def slot_track(slot: int) -> str:
    return f"slot{slot}"


# ---------------------------------------------------------------- events
@dataclass(frozen=True)
class Event:
    """One record on the bus.

    ``kind`` is ``"span"`` (has a duration) or ``"instant"``.  ``ts`` /
    ``dur`` are SECONDS on the recorder's clock (exporters convert to
    trace-viewer microseconds).  ``track`` names the horizontal lane the
    event belongs to (one Perfetto thread per track); ``args`` carries
    the typed payload (slot, rid, page ids, ...)."""

    name: str
    kind: str                   # "span" | "instant"
    ts: float
    dur: float = 0.0
    track: str = TRACK_ENGINE
    args: Dict[str, Any] = field(default_factory=dict)

    @property
    def end(self) -> float:
        return self.ts + self.dur


# ---------------------------------------------------------------- clocks
class ManualClock:
    """Injectable deterministic clock for tests and golden exports.

    Every call advances the time by ``tick`` and returns the NEW value
    (so consecutive stamps are distinct, spans get nonzero durations
    without any explicit ``advance``, and — because engine request
    stamps use 0.0 as the "unset" sentinel — the default ``start=0.0``
    never leaks a zero stamp).  ``advance`` moves the clock by an
    arbitrary delta for scripted scenarios."""

    def __init__(self, start: float = 0.0, tick: float = 1e-6):
        self.t = float(start)
        self.tick = float(tick)

    def __call__(self) -> float:
        self.t += self.tick
        return self.t

    def advance(self, dt: float) -> float:
        self.t += float(dt)
        return self.t


# ------------------------------------------------------------- recorders
class _SpanCtx:
    """Re-entrant-free lightweight span context: stamps on enter, emits
    one span ``Event`` on exit.  Created per ``Recorder.span`` call."""

    __slots__ = ("_rec", "_name", "_track", "_args", "_t0")

    def __init__(self, rec: "Recorder", name: str, track: str,
                 args: Dict[str, Any]):
        self._rec = rec
        self._name = name
        self._track = track
        self._args = args
        self._t0 = 0.0

    def __enter__(self) -> "_SpanCtx":
        self._t0 = self._rec.clock()
        return self

    def __exit__(self, *exc) -> None:
        rec = self._rec
        rec._emit(Event(self._name, "span", self._t0,
                        rec.clock() - self._t0, self._track, self._args))


class Recorder:
    """Append-only event recorder with an injectable clock.

    ``max_events`` bounds memory on long-lived engines: past the cap new
    events are counted in ``dropped`` instead of stored (the metrics
    registry keeps aggregating regardless — only the timeline truncates).
    """

    enabled = True

    def __init__(self, clock: Optional[Clock] = None, *,
                 max_events: int = 1_000_000):
        self.clock: Clock = clock if clock is not None else time.perf_counter
        self.events: List[Event] = []
        self.max_events = max_events
        self.dropped = 0

    def _emit(self, ev: Event) -> None:
        if len(self.events) >= self.max_events:
            self.dropped += 1
            return
        self.events.append(ev)

    def now(self) -> float:
        return self.clock()

    def instant(self, name: str, *, track: str = TRACK_ENGINE,
                ts: Optional[float] = None, **args: Any) -> None:
        self._emit(Event(name, "instant",
                         self.clock() if ts is None else ts,
                         0.0, track, args))

    def complete(self, name: str, t0: float, dur: float, *,
                 track: str = TRACK_ENGINE, **args: Any) -> None:
        """Record an already-measured span (the engine times its own
        dispatches with the shared clock and reports start + duration —
        this also lets one measurement fan out to several tracks)."""
        self._emit(Event(name, "span", t0, dur, track, args))

    def span(self, name: str, *, track: str = TRACK_ENGINE,
             **args: Any) -> _SpanCtx:
        return _SpanCtx(self, name, track, args)

    def count(self, name: str) -> int:
        """Event-count probe: how many events carry ``name``."""
        return sum(1 for e in self.events if e.name == name)

    def clear(self) -> None:
        self.events.clear()
        self.dropped = 0


class _NullSpan:
    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> None:
        return None


_NULL_SPAN = _NullSpan()


class NullRecorder:
    """Disabled recorder: every method is a no-op and ``span`` returns
    one shared context object, so the hot path allocates NOTHING.  The
    ``events`` attribute is a shared empty tuple — the event-count probe
    reads zero, and appending is impossible by construction."""

    enabled = False
    events = ()
    dropped = 0
    clock: Clock = staticmethod(time.perf_counter)

    def now(self) -> float:
        return 0.0

    def instant(self, name: str, **kw: Any) -> None:
        return None

    def complete(self, name: str, t0: float, dur: float, **kw: Any) -> None:
        return None

    def span(self, name: str, **kw: Any) -> _NullSpan:
        return _NULL_SPAN

    def count(self, name: str) -> int:
        return 0

    def clear(self) -> None:
        return None


NULL_RECORDER = NullRecorder()


def resolve_recorder(spec, *, clock: Optional[Clock] = None):
    """Engine-facing resolution for ``ServingEngine(telemetry=...)``:

      * ``None`` / ``False`` -> ``NULL_RECORDER`` (zero-overhead)
      * ``True``             -> fresh ``Recorder`` (on ``clock`` when
                                given, so lifecycle stamps and spans
                                share a timebase)
      * ``Recorder``         -> used as given; an explicit ``clock``
                                rebinds it so the engine and recorder
                                can never disagree on the timebase
    """
    if spec is None or spec is False:
        return NULL_RECORDER
    if spec is True:
        return Recorder(clock)
    if isinstance(spec, (Recorder, NullRecorder)):
        if clock is not None and isinstance(spec, Recorder):
            spec.clock = clock
        return spec
    raise TypeError(f"telemetry= accepts bool or Recorder; "
                    f"got {type(spec).__name__}")
