"""``python -m repro.obs`` — telemetry demo / self-check CLI.

``--demo`` bursts a reduced gpt2 engine (chunked prefill + speculative
decode, the same mixed traffic the benchmarks use), with telemetry ON
and OFF, then:

  * asserts the greedy tokens are bit-identical (telemetry is a pure
    observer) and the OFF engine recorded zero events,
  * asserts the trace-probe counters equal the matching TRACE_* event
    counts (both bump at the same traced-body sites),
  * validates the Chrome trace against the schema checker and the
    TTFT/TPOT percentile ordering (p50 <= p90 <= p99),
  * writes three artifacts to ``--out`` (default ``obs_demo/``):
    ``trace.json`` (load in https://ui.perfetto.dev or
    chrome://tracing), ``events.jsonl`` and ``metrics.prom``.

Exit status is nonzero on any failed check, so CI can run it as a
smoke test.  ``--tokens`` / ``--prompts`` scale the burst.
"""

from __future__ import annotations

import argparse
import dataclasses
import os
import sys
import time
from typing import List

import numpy as np

from . import (
    TRACE_DECODE,
    TRACE_PREFILL,
    TRACE_VERIFY,
    chrome_trace,
    events_jsonl,
    prometheus_text,
    validate_chrome_trace,
)


def _demo_engine(telemetry: bool, *, max_len: int):
    import jax

    from ..configs import get_config
    from ..models import init_params
    from ..serving import ServingEngine

    cfg = dataclasses.replace(get_config("gpt2").reduced(),
                              dtype="float32", use_fused_kernels=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(cfg, params, batch_slots=2, max_len=max_len,
                        decode_block=4, chunked=True,
                        prefill_chunk=max(8, max_len // 8),
                        speculative=True, draft_len=4,
                        telemetry=telemetry)
    return cfg, eng


def _prompts(cfg, n: int, max_len: int) -> List[np.ndarray]:
    periods = ((1, 2, 3, 4), (7, 8, 9), (5, 6), (2, 9), (3, 1, 4))
    lens = (max_len // 3, max_len // 6, max_len // 2, max_len // 4,
            max_len // 5)
    v = cfg.vocab_size
    return [np.array((periods[i % len(periods)] * max_len)[:max(2, lens[i % len(lens)])],
                     np.int32) % v for i in range(n)]


def run_demo(out_dir: str, *, n_prompts: int, new_tokens: int,
             max_len: int) -> int:
    failures: List[str] = []

    def check(ok: bool, what: str) -> None:
        print(("  ok   " if ok else "  FAIL ") + what)
        if not ok:
            failures.append(what)

    print("building engines (telemetry on / off) ...")
    cfg, eng = _demo_engine(True, max_len=max_len)
    _, eng_off = _demo_engine(False, max_len=max_len)
    prompts = _prompts(cfg, n_prompts, max_len)

    t0 = time.perf_counter()
    reqs = eng.generate([p.copy() for p in prompts],
                        max_new_tokens=new_tokens)
    wall_on = time.perf_counter() - t0
    t0 = time.perf_counter()
    reqs_off = eng_off.generate([p.copy() for p in prompts],
                                max_new_tokens=new_tokens)
    wall_off = time.perf_counter() - t0
    print(f"burst: {n_prompts} prompts x {new_tokens} tokens, "
          f"{wall_on * 1e3:.0f}ms on / {wall_off * 1e3:.0f}ms off")

    check([r.out_tokens for r in reqs] == [r.out_tokens for r in reqs_off],
          "greedy tokens identical with telemetry on vs off")
    check(eng_off.obs.events == () and not eng_off.obs.enabled,
          "telemetry-off recorder captured zero events")
    check(len(eng.obs.events) > 0, "telemetry-on recorder captured events")
    for name, probe in ((TRACE_PREFILL, "prefill"), (TRACE_DECODE, "decode"),
                        (TRACE_VERIFY, "verify")):
        check(eng.obs.count(name) == eng._traces[probe],
              f"{name} events == {probe} trace probe "
              f"({eng._traces[probe]})")

    trace = chrome_trace(eng.obs.events)
    errs = validate_chrome_trace(trace)
    check(not errs, "chrome trace passes schema validation"
          + ("" if not errs else f": {errs[:3]}"))

    snap = eng.snapshot("last_generate")
    for h in ("ttft_s", "tpot_s"):
        p50, p90, p99 = (snap[f"{h}_p50"], snap[f"{h}_p90"],
                         snap[f"{h}_p99"])
        check(p50 <= p90 <= p99,
              f"{h} percentiles ordered: p50={p50:.4g} <= p90={p90:.4g}"
              f" <= p99={p99:.4g}")
        check(snap[f"{h}_count"] == len(reqs),
              f"{h} observed once per request")

    prom = prometheus_text(eng.registry)
    check("repro_ttft_s_bucket{" in prom and "repro_generated_total" in prom,
          "prometheus exposition has histograms and counters")

    os.makedirs(out_dir, exist_ok=True)
    import json
    with open(os.path.join(out_dir, "trace.json"), "w") as fh:
        json.dump(trace, fh)
    with open(os.path.join(out_dir, "events.jsonl"), "w") as fh:
        fh.write(events_jsonl(eng.obs.events))
    with open(os.path.join(out_dir, "metrics.prom"), "w") as fh:
        fh.write(prom)
    print(f"wrote {out_dir}/trace.json ({len(trace['traceEvents'])} rows, "
          f"load in ui.perfetto.dev), events.jsonl "
          f"({len(eng.obs.events)} events), metrics.prom")

    if failures:
        print(f"{len(failures)} check(s) FAILED", file=sys.stderr)
        return 1
    print("all checks passed")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.obs",
                                 description=__doc__)
    ap.add_argument("--demo", action="store_true",
                    help="run the burst demo + self-checks")
    ap.add_argument("--out", default="obs_demo",
                    help="artifact directory (default: obs_demo/)")
    ap.add_argument("--prompts", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=96)
    args = ap.parse_args(argv)
    if not args.demo:
        ap.print_help()
        return 2
    return run_demo(args.out, n_prompts=args.prompts,
                    new_tokens=args.tokens, max_len=args.max_len)


if __name__ == "__main__":
    raise SystemExit(main())
