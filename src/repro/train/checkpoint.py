"""Fault-tolerant, mesh-agnostic checkpointing.

Design for thousands of nodes (DESIGN.md §9):
  * every array saved under its tree path with a content sha256 in a
    manifest; a restore verifies integrity before any weight is installed;
  * writes go to ``<dir>/tmp-<step>`` then ``os.replace`` to ``step-N`` —
    a crash mid-save never corrupts the latest checkpoint;
  * checkpoints are **mesh-agnostic**: arrays are stored unsharded with
    their logical-axis names; restore re-shards onto whatever mesh the job
    restarts with (elastic rescale = restore on a different mesh);
  * async save: the step's arrays are snapshotted to host memory and
    written by a background thread so the train loop keeps stepping;
  * retention: keep the newest ``keep`` checkpoints.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

Tree = Any

MANIFEST = "manifest.json"


def _key_str(p: Any) -> str:
    for attr in ("key", "idx", "name"):
        if hasattr(p, attr):
            return str(getattr(p, attr))
    return str(p)


def _flatten(tree: Tree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [("/".join(_key_str(p) for p in path), leaf)
            for path, leaf in flat]


def _sha256(arr: np.ndarray) -> str:
    return hashlib.sha256(np.ascontiguousarray(arr).tobytes()).hexdigest()


def save_checkpoint(directory: str | Path, step: int, params: Tree, *,
                    opt_state: Optional[Tree] = None,
                    extra: Optional[Dict[str, Any]] = None,
                    keep: int = 3) -> Path:
    """Atomic synchronous save.  Returns the final checkpoint path."""
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    tmp = directory / f"tmp-{step}-{os.getpid()}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    manifest: Dict[str, Any] = {"step": step, "arrays": {},
                                "extra": extra or {},
                                "time": time.time()}
    trees = {"params": params}
    if opt_state is not None:
        trees["opt"] = opt_state
    for prefix, tree in trees.items():
        for name, leaf in _flatten(tree):
            arr = np.asarray(jax.device_get(leaf))
            fname = f"{prefix}__{name.replace('/', '__')}.npy"
            np.save(tmp / fname, arr)
            manifest["arrays"][f"{prefix}/{name}"] = {
                "file": fname, "shape": list(arr.shape),
                "dtype": str(arr.dtype), "sha256": _sha256(arr)}
    (tmp / MANIFEST).write_text(json.dumps(manifest, indent=1))
    final = directory / f"step-{step:08d}"
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)
    _retain(directory, keep)
    return final


def _retain(directory: Path, keep: int) -> None:
    ckpts = sorted(d for d in directory.iterdir()
                   if d.is_dir() and d.name.startswith("step-"))
    for old in ckpts[:-keep]:
        shutil.rmtree(old, ignore_errors=True)


def latest_checkpoint(directory: str | Path) -> Optional[Path]:
    directory = Path(directory)
    if not directory.exists():
        return None
    ckpts = sorted(d for d in directory.iterdir()
                   if d.is_dir() and d.name.startswith("step-")
                   and (d / MANIFEST).exists())
    return ckpts[-1] if ckpts else None


def restore_checkpoint(path: str | Path, params_template: Tree, *,
                       opt_template: Optional[Tree] = None,
                       shardings: Optional[Tree] = None,
                       opt_shardings: Optional[Tree] = None,
                       verify: bool = True,
                       ) -> Tuple[int, Tree, Optional[Tree], Dict[str, Any]]:
    """Restore onto the current mesh (elastic: templates/shardings may come
    from a different mesh than the checkpoint was written on)."""
    path = Path(path)
    manifest = json.loads((path / MANIFEST).read_text())

    def load_tree(template: Tree, prefix: str, shard_tree: Optional[Tree]):
        names = [n for n, _ in _flatten(template)]
        shards = ([s for _, s in _flatten(shard_tree)]
                  if shard_tree is not None else [None] * len(names))
        leaves = []
        for name, shard in zip(names, shards):
            meta = manifest["arrays"][f"{prefix}/{name}"]
            arr = np.load(path / meta["file"])
            if verify and _sha256(arr) != meta["sha256"]:
                raise IOError(f"checksum mismatch for {prefix}/{name}")
            leaves.append(jax.device_put(arr, shard) if shard is not None
                          else arr)
        flat, treedef = jax.tree_util.tree_flatten(template)
        return jax.tree_util.tree_unflatten(treedef, leaves)

    params = load_tree(params_template, "params", shardings)
    opt = None
    if opt_template is not None and any(
            k.startswith("opt/") for k in manifest["arrays"]):
        opt = load_tree(opt_template, "opt", opt_shardings)
    return int(manifest["step"]), params, opt, manifest.get("extra", {})


class AsyncCheckpointer:
    """Snapshot-to-host then write in a background thread."""

    def __init__(self, directory: str | Path, keep: int = 3):
        self.directory = Path(directory)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_error: Optional[BaseException] = None

    def save(self, step: int, params: Tree,
             opt_state: Optional[Tree] = None,
             extra: Optional[Dict[str, Any]] = None) -> None:
        self.wait()
        # Snapshot on the caller thread (device -> host) so the train loop
        # can donate/overwrite device buffers immediately after.
        params_host = jax.tree.map(lambda a: np.asarray(jax.device_get(a)),
                                   params)
        opt_host = (jax.tree.map(lambda a: np.asarray(jax.device_get(a)),
                                 opt_state) if opt_state is not None
                    else None)

        def work():
            try:
                save_checkpoint(self.directory, step, params_host,
                                opt_state=opt_host, extra=extra,
                                keep=self.keep)
            except BaseException as e:   # surfaced on next wait()
                self.last_error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err
