"""Fault-tolerant training driver.

The loop a real fleet runs (DESIGN.md §9):
  * checkpoint every N steps (async), resume from the latest on start;
  * per-step deadline watchdog — a straggling/hung step raises, the step is
    retried from the last good state, and after ``max_retries`` the job
    exits nonzero for the scheduler to reschedule (on TPU the static XLA
    schedule means stragglers come from hosts/input, not the chips);
  * failure injection hook for tests (simulates preemption mid-run);
  * elastic rescale: ``resume()`` re-shards the mesh-agnostic checkpoint
    onto whatever mesh the restarted job constructs.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig, ShapeConfig
from ..data.pipeline import TokenPipeline
from ..distributed.optimizer import AdamWConfig, init_opt_state
from ..distributed.sharding import optimizer_specs, tree_specs
from ..distributed.steps import make_train_step
from ..models import abstract_params, init_params, logical_axes
from .checkpoint import (AsyncCheckpointer, latest_checkpoint,
                         restore_checkpoint)

Tree = Any


@dataclass
class TrainerConfig:
    total_steps: int = 100
    checkpoint_every: int = 20
    checkpoint_dir: str = "checkpoints"
    keep_checkpoints: int = 3
    step_deadline_s: float = 0.0        # 0 = no watchdog
    max_retries: int = 2
    log_every: int = 10
    seed: int = 0


class StepDeadlineExceeded(RuntimeError):
    pass


class Trainer:
    def __init__(self, cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                 tcfg: Optional[TrainerConfig] = None,
                 opt_cfg: Optional[AdamWConfig] = None,
                 failure_hook: Optional[Callable[[int], None]] = None):
        self.cfg = cfg
        self.shape = shape
        self.mesh = mesh
        self.tcfg = tcfg or TrainerConfig()
        self.failure_hook = failure_hook
        self.step_fn, self.p_specs, self.o_specs, self.b_spec_fn = \
            make_train_step(cfg, mesh, opt_cfg)
        ax = logical_axes(cfg)
        ab = abstract_params(cfg)
        self.p_shardings = jax.tree.map(
            lambda s: NamedSharding(mesh, s), self.p_specs,
            is_leaf=lambda x: isinstance(x, P))
        o_moments = optimizer_specs(cfg, ax, ab, mesh)
        self.o_shardings = jax.tree.map(
            lambda s: NamedSharding(mesh, s), self.o_specs,
            is_leaf=lambda x: isinstance(x, P))
        self.pipeline = TokenPipeline(cfg, shape, seed=self.tcfg.seed)
        self.ckpt = AsyncCheckpointer(self.tcfg.checkpoint_dir,
                                      keep=self.tcfg.keep_checkpoints)
        self.step = 0
        self.params: Optional[Tree] = None
        self.opt_state: Optional[Tree] = None
        self.history: list = []

    # ------------------------------------------------------------ setup
    def init(self) -> None:
        rng = jax.random.PRNGKey(self.tcfg.seed)
        params = init_params(rng, self.cfg)
        self.params = jax.device_put(params, self.p_shardings)
        self.opt_state = jax.device_put(init_opt_state(self.params),
                                        self.o_shardings)
        self.step = 0

    def resume(self) -> bool:
        """Restore latest checkpoint (onto THIS mesh — elastic)."""
        path = latest_checkpoint(self.tcfg.checkpoint_dir)
        if path is None:
            return False
        from ..distributed.optimizer import abstract_opt_state
        ab = abstract_params(self.cfg)
        step, params, opt, extra = restore_checkpoint(
            path, ab, opt_template=abstract_opt_state(ab),
            shardings=self.p_shardings, opt_shardings=self.o_shardings)
        self.params = params
        self.opt_state = (opt if opt is not None else
                          jax.device_put(init_opt_state(params),
                                         self.o_shardings))
        self.step = step
        self.pipeline.load_state_dict(extra.get("pipeline", {"step": step}))
        return True

    # ------------------------------------------------------------- loop
    def _put_batch(self, batch: Dict[str, np.ndarray]) -> Dict[str, Any]:
        specs = self.b_spec_fn(batch)
        return {k: jax.device_put(v, NamedSharding(self.mesh, specs[k]))
                for k, v in batch.items()}

    def _one_step(self) -> Dict[str, float]:
        if self.failure_hook is not None:
            self.failure_hook(self.step)
        batch = self._put_batch(next(self.pipeline))
        t0 = time.perf_counter()
        self.params, self.opt_state, metrics = self.step_fn(
            self.params, self.opt_state, batch)
        metrics = {k: float(v) for k, v in metrics.items()}
        dt = time.perf_counter() - t0
        if self.tcfg.step_deadline_s and dt > self.tcfg.step_deadline_s:
            raise StepDeadlineExceeded(
                f"step {self.step} took {dt:.2f}s "
                f"(deadline {self.tcfg.step_deadline_s}s)")
        metrics["step_s"] = dt
        return metrics

    def run(self) -> Dict[str, float]:
        if self.params is None and not self.resume():
            self.init()
        metrics: Dict[str, float] = {}
        while self.step < self.tcfg.total_steps:
            retries = 0
            while True:
                try:
                    metrics = self._one_step()
                    break
                except StepDeadlineExceeded:
                    retries += 1
                    if retries > self.tcfg.max_retries:
                        raise
                    # Straggler mitigation: replay the step (input is
                    # deterministic at this step index; params unchanged
                    # only if the failure happened before dispatch — we
                    # conservatively restore from the last checkpoint).
                    if not self.resume():
                        self.init()
            self.step += 1
            self.pipeline.state.step = self.step
            self.history.append((self.step, metrics.get("loss", 0.0)))
            if self.step % self.tcfg.log_every == 0:
                print(f"[train] step={self.step} "
                      f"loss={metrics.get('loss', float('nan')):.4f} "
                      f"({metrics.get('step_s', 0):.2f}s)", flush=True)
            if self.step % self.tcfg.checkpoint_every == 0:
                self.ckpt.save(self.step, self.params, self.opt_state,
                               extra={"pipeline":
                                      self.pipeline.state_dict()})
        self.ckpt.wait()
        return metrics
