"""Training substrate: checkpointing, fault-tolerant trainer."""
from .checkpoint import (AsyncCheckpointer, latest_checkpoint,
                         restore_checkpoint, save_checkpoint)
from .trainer import StepDeadlineExceeded, Trainer, TrainerConfig
__all__ = ["AsyncCheckpointer", "latest_checkpoint", "restore_checkpoint",
           "save_checkpoint", "StepDeadlineExceeded", "Trainer",
           "TrainerConfig"]
