"""Discrete-event simulator for self-timed dataflow graphs.

Plays the role of the paper's on-board measurement: kernels fire when their
input FIFOs hold tokens and their output FIFOs have space (back-pressure), so
undersized FIFOs manifest as stall cascades — and, for window-consuming
kernels such as layout converters, as outright deadlock (paper Pitfall 4).
The test-suite uses this to validate that LP-sized FIFO plans complete
stall-free and that deliberately undersized ones deadlock.

Model (multi-rate synchronous dataflow):
  * A kernel with timing (D, II) fires its first token D cycles after its
    inputs for that firing are present, and subsequent tokens II cycles
    apart (or later, if starved or back-pressured).
  * Rates: the tokens on an edge are the PRODUCER's tokens.  A consumer
    making ``T_c`` firings against a producer stream of ``T_p`` tokens
    consumes ``floor((f+1)*T_p/T_c) - floor(f*T_p/T_c)`` tokens on its f-th
    firing (rational-rate SDF) — this is how kernels with different tile
    granularities compose, mirroring the itensor reassociation at stream
    boundaries.
  * ``consume_window[k] = w`` marks kernel ``k`` as a window consumer: its
    first firing additionally requires ``w`` tokens resident in each input
    FIFO — the behavior of a stream layout converter that must fill its
    ping buffer before emitting (paper §3.2.1 itensor_converter).
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from ..core.fifo_sizing import FifoPlan
from ..core.graph import DataflowGraph, KernelTiming

EdgeKey = Tuple[str, str, int]


@dataclass
class SimResult:
    completed: bool
    makespan: float
    fired: Dict[str, int]
    peak_occupancy: Dict[EdgeKey, int]
    deadlock_kernels: List[str] = field(default_factory=list)

    def throughput(self, tokens: int) -> float:
        return tokens / self.makespan if self.makespan > 0 else 0.0


def simulate_dataflow(
    graph: DataflowGraph,
    timings: Dict[str, KernelTiming],
    plan: Optional[FifoPlan] = None,
    depths: Optional[Dict[EdgeKey, int]] = None,
    consume_window: Optional[Dict[str, int]] = None,
    max_steps: int = 1_000_000,
) -> SimResult:
    """Run the graph to completion or deadlock.

    Args:
        graph: dataflow graph; each kernel fires ``out_type.num_tokens`` times.
        timings: per-kernel (L, D, II).
        plan: FIFO plan providing per-edge depths (preferred).
        depths: explicit per-edge depth override (used to provoke deadlock).
        consume_window: first-firing window requirement per kernel.
    """
    cap: Dict[EdgeKey, int] = {}
    for u, v, k, _ in graph.edges():
        key = (u, v, k)
        if depths and key in depths:
            cap[key] = depths[key]
        elif plan is not None:
            cap[key] = plan.depths[key]
        else:
            cap[key] = 2
    window = consume_window or {}

    in_edges: Dict[str, List[EdgeKey]] = {n: [] for n in graph.g.nodes}
    out_edges: Dict[str, List[EdgeKey]] = {n: [] for n in graph.g.nodes}
    for u, v, k, _ in graph.edges():
        in_edges[v].append((u, v, k))
        out_edges[u].append((u, v, k))

    fifo: Dict[EdgeKey, deque] = {e: deque() for e in cap}
    peak: Dict[EdgeKey, int] = {e: 0 for e in cap}
    target = {n: graph.kernel(n).num_out_tokens for n in graph.g.nodes}
    fired = {n: 0 for n in graph.g.nodes}
    last_fire = {n: -float("inf") for n in graph.g.nodes}
    makespan = 0.0

    # Rational-rate consumption: tokens the consumer of edge e pops on its
    # f-th firing (producer stream length vs consumer firing count).
    def edge_need(e: EdgeKey, f: int) -> int:
        u, v, _ = e
        tp, tc = target[u], target[v]
        return (f + 1) * tp // tc - f * tp // tc

    def fire_time(n: str) -> Optional[float]:
        """Earliest time kernel n can fire its next token, or None."""
        if fired[n] >= target[n]:
            return None
        f = fired[n]
        arrivals = []
        for e in in_edges[n]:
            need = edge_need(e, f)
            if f == 0:
                need = max(need, window.get(n, 1) if need else 0)
            if len(fifo[e]) < need:
                return None  # starved
            if need:
                arrivals.append(fifo[e][need - 1])
        for e in out_edges[n]:
            if len(fifo[e]) >= cap[e]:
                return None  # back-pressured
        t = timings[n]
        pipeline = (t.initial_delay if f == 0 else
                    last_fire[n] + t.pipeline_ii)
        base = max(arrivals) if arrivals else 0.0
        if f == 0:
            return max(base + t.initial_delay,
                       pipeline if not in_edges[n] else 0.0)
        return max(base, pipeline)

    steps = 0
    while steps < max_steps:
        steps += 1
        best_n, best_t = None, None
        for n in graph.g.nodes:
            ft = fire_time(n)
            if ft is not None and (best_t is None or ft < best_t):
                best_n, best_t = n, ft
        if best_n is None:
            break
        # Fire best_n at best_t: pop its rate per input, push per output.
        f = fired[best_n]
        for e in in_edges[best_n]:
            for _ in range(edge_need(e, f)):
                fifo[e].popleft()
        for e in out_edges[best_n]:
            fifo[e].append(best_t)
            peak[e] = max(peak[e], len(fifo[e]))
        fired[best_n] += 1
        last_fire[best_n] = best_t
        makespan = max(makespan, best_t)

    incomplete = [n for n in graph.g.nodes if fired[n] < target[n]]
    return SimResult(
        completed=not incomplete,
        makespan=makespan,
        fired=fired,
        peak_occupancy=peak,
        deadlock_kernels=incomplete,
    )
