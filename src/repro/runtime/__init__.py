"""Runtime layer: dataflow simulator, serving loop, fault-tolerant runner."""

from .simulator import SimResult, simulate_dataflow

__all__ = ["SimResult", "simulate_dataflow"]
