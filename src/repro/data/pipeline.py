"""Deterministic, shardable, checkpointable synthetic data pipeline.

Counter-based generation (numpy Philox keyed on (seed, step, shard)) gives
the three properties a 1000-node training fleet needs from its input
pipeline, without any files on disk:

  * **determinism** — any (step, host) pair regenerates identical data, so a
    restarted/reshuffled job replays exactly;
  * **sharding** — each host draws only its ``global_batch / num_hosts``
    rows, keyed by shard id (no cross-host coordination);
  * **checkpointability** — pipeline state is ONE integer (the step),
    stored in the training checkpoint manifest.

The stream models packed LM documents: variable-length 'documents' (Zipf
token distribution) packed back-to-back with EOS separators, labels = next
token, -100 at padding.  Frontend (VLM/audio) archs get synthetic embedding
batches with the same determinism guarantees.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

from ..configs.base import ModelConfig, ShapeConfig

EOS = 0
IGNORE = -100


@dataclass
class PipelineState:
    step: int = 0

    def to_dict(self) -> Dict[str, int]:
        return {"step": self.step}

    @staticmethod
    def from_dict(d: Dict[str, int]) -> "PipelineState":
        return PipelineState(step=int(d.get("step", 0)))


class TokenPipeline:
    """Packed-document LM batches for one host shard."""

    def __init__(self, cfg: ModelConfig, shape: ShapeConfig, *,
                 seed: int = 0, num_shards: int = 1, shard_id: int = 0,
                 mean_doc_len: int = 512):
        if shape.global_batch % num_shards:
            raise ValueError(
                f"global batch {shape.global_batch} % shards {num_shards}")
        self.cfg = cfg
        self.shape = shape
        self.seed = seed
        self.num_shards = num_shards
        self.shard_id = shard_id
        self.local_batch = shape.global_batch // num_shards
        self.mean_doc_len = mean_doc_len
        self.state = PipelineState()

    # ------------------------------------------------------------------ #
    @staticmethod
    def _splitmix64(x: int) -> int:
        """Diffuse a counter into 64 well-mixed bits (numpy's Philox keying
        is insensitive to low-bit differences in the raw key words)."""
        x = (x + 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF
        z = x
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & 0xFFFFFFFFFFFFFFFF
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & 0xFFFFFFFFFFFFFFFF
        return z ^ (z >> 31)

    def _rng(self, step: int) -> np.random.Generator:
        base = (self.seed << 40) ^ (step << 16) ^ self.shard_id
        key = [self._splitmix64(base), self._splitmix64(base ^ 0xda7a)]
        return np.random.Generator(np.random.Philox(key=key))

    def _pack_row(self, rng: np.random.Generator, seq: int) -> np.ndarray:
        row = np.empty(seq + 1, dtype=np.int32)
        pos = 0
        v = self.cfg.vocab_size
        while pos <= seq:
            n = max(8, int(rng.exponential(self.mean_doc_len)))
            n = min(n, seq + 1 - pos)
            # Zipf-ish marginal over the vocab, offset past EOS.
            doc = rng.zipf(1.3, size=n).astype(np.int64)
            row[pos:pos + n] = (doc % (v - 1)) + 1
            pos += n
            if pos <= seq:
                row[pos - 1] = EOS
        return row

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Materialize this shard's batch for an absolute step (pure)."""
        rng = self._rng(step)
        seq = self.shape.seq_len
        rows = np.stack([self._pack_row(rng, seq)
                         for _ in range(self.local_batch)])
        batch = {"tokens": rows[:, :-1].astype(np.int32),
                 "labels": rows[:, 1:].astype(np.int32)}
        if self.cfg.frontend != "none":
            emb = self._rng(step ^ 0x5eed).standard_normal(
                (self.local_batch, seq, self.cfg.d_model),
                dtype=np.float32) * 0.1
            batch = {"embeds": emb, "labels": batch["labels"]}
        if self.cfg.rope == "mrope":
            base = np.broadcast_to(np.arange(seq, dtype=np.int32),
                                   (self.local_batch, seq))
            batch["positions"] = np.broadcast_to(
                base[None], (3, self.local_batch, seq)).copy()
        return batch

    def __next__(self) -> Dict[str, np.ndarray]:
        b = self.batch_at(self.state.step)
        self.state.step += 1
        return b

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    # --------------------------------------------------------- checkpoint
    def state_dict(self) -> Dict[str, int]:
        return self.state.to_dict()

    def load_state_dict(self, d: Dict[str, int]) -> None:
        self.state = PipelineState.from_dict(d)
