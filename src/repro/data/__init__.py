"""Deterministic sharded data pipeline."""
from .pipeline import IGNORE, PipelineState, TokenPipeline
__all__ = ["IGNORE", "PipelineState", "TokenPipeline"]
