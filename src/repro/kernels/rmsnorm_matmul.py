"""Fused RMSNorm -> matmul kernel (norm streamed into the projection).

The normalized activation never round-trips HBM: per token tile the kernel
computes the row rsqrt statistics in VMEM and immediately feeds the
normalized tile into the MXU against a [D, bn] weight tile.  Grid
(t_blocks, n_blocks); the full D row is kept resident (D <= ~8k fits VMEM
comfortably at bt=256: 256*8192*2B = 4 MiB).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import interpret_default, pick_block


def _kernel(x_ref, scale_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    normed = x * jax.lax.rsqrt(var + eps)
    normed = normed * (1.0 + scale_ref[...].astype(jnp.float32))
    o_ref[...] = jnp.dot(normed.astype(x_ref.dtype), w_ref[...],
                         preferred_element_type=jnp.float32
                         ).astype(o_ref.dtype)


def rmsnorm_matmul(x: jax.Array, scale: jax.Array, w: jax.Array, *,
                   eps: float = 1e-6, block_t: int = 256,
                   block_n: int = 512,
                   interpret: Optional[bool] = None) -> jax.Array:
    """x: [T, D]; scale: [D]; w: [D, N] -> rms_norm(x) @ w  [T, N]."""
    t, d = x.shape
    d2, n = w.shape
    assert d == d2 and scale.shape == (d,)
    bt = pick_block(t, block_t)
    bn = pick_block(n, block_n)
    grid = (t // bt, n // bn)
    interpret = interpret_default() if interpret is None else interpret
    return pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, d), lambda i, j: (i, 0)),
            pl.BlockSpec((d,), lambda i, j: (0,)),
            pl.BlockSpec((d, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bt, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((t, n), x.dtype),
        interpret=interpret,
    )(x, scale, w)
