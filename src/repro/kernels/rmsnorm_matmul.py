"""Fused RMSNorm -> matmul kernel (norm streamed into the projection).

The normalized activation never round-trips HBM: per token tile the kernel
computes the row rsqrt statistics in VMEM and immediately feeds the
normalized tile into the MXU against a [D, bn] weight tile.  Grid
(t_blocks, n_blocks); the full D row is kept resident (D <= ~8k fits VMEM
comfortably at bt=256: 256*8192*2B = 4 MiB).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import interpret_default, pick_block

# Autotune candidate lattice (tuning/autotune.py): block-target grids
# the measured-latency tuner scores for this kernel family.  Points
# the kernel lint rejects (lane floor, VMEM budget) are pruned before
# anything is compiled or timed.
TUNE_SPACE = {"block_t": (128, 256, 512), "block_n": (128, 256, 512)}


def _kernel(x_ref, scale_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    normed = x * jax.lax.rsqrt(var + eps)
    normed = normed * (1.0 + scale_ref[...].astype(jnp.float32))
    o_ref[...] = jnp.dot(normed.astype(x_ref.dtype), w_ref[...],
                         preferred_element_type=jnp.float32
                         ).astype(o_ref.dtype)


def _kernel_w8(x_ref, scale_ref, w_ref, ws_ref, o_ref, *, eps: float):
    """Weight-only int8 body (DESIGN.md §14): ``w`` holds int8 codes with
    per-output-channel f32 scales.  The dot runs codes-against-f32 and the
    column scale is applied POST-dot — mathematically identical to
    dequantizing the tile first (``x @ (codes * s) == (x @ codes) * s``
    column by column), but streaming 1 byte/weight from HBM."""
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    normed = x * jax.lax.rsqrt(var + eps)
    normed = normed * (1.0 + scale_ref[...].astype(jnp.float32))
    acc = jnp.dot(normed, w_ref[...].astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    o_ref[...] = (acc * ws_ref[...][None, :]).astype(o_ref.dtype)


def rmsnorm_matmul(x: jax.Array, scale: jax.Array, w: jax.Array, *,
                   eps: float = 1e-6, block_t: int = 256,
                   block_n: int = 512,
                   w_scale: Optional[jax.Array] = None,
                   interpret: Optional[bool] = None) -> jax.Array:
    """x: [T, D]; scale: [D]; w: [D, N] -> rms_norm(x) @ w  [T, N].

    ``w_scale`` [N]: weight-only int8 — ``w`` is int8 codes, dequantized
    against the per-output-channel scales inside the kernel.
    """
    t, d = x.shape
    d2, n = w.shape
    assert d == d2 and scale.shape == (d,)
    bt = pick_block(t, block_t)
    bn = pick_block(n, block_n)
    grid = (t // bt, n // bn)
    interpret = interpret_default() if interpret is None else interpret
    in_specs = [
        pl.BlockSpec((bt, d), lambda i, j: (i, 0)),
        pl.BlockSpec((d,), lambda i, j: (0,)),
        pl.BlockSpec((d, bn), lambda i, j: (0, j)),
    ]
    operands = [x, scale, w]
    kernel = _kernel
    if w_scale is not None:
        in_specs.append(pl.BlockSpec((bn,), lambda i, j: (j,)))
        operands.append(w_scale.astype(jnp.float32))
        kernel = _kernel_w8
    return pl.pallas_call(
        functools.partial(kernel, eps=eps),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bt, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((t, n), x.dtype),
        interpret=interpret,
    )(*operands)
