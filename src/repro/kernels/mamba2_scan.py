"""Mamba2 chunked SSD Pallas kernel.

Grid (batch*heads, chunks); the chunk dimension is sequential and carries the
[P, N] SSM state in VMEM scratch — the inter-chunk recurrence IS a stream:
each chunk consumes the previous state token, produces the next, and the
state never leaves VMEM (the FPGA version would hold it in BRAM between
pipeline iterations).

Per chunk (intra-chunk work, all MXU-friendly):
    L        = exp(segsum(dA))                  [Q, Q] lower-triangular
    y_diag   = ((C B^T) * L) (x*dt)             [Q, P]
    y_off    = (C h_prev) * exp(cumsum dA)      [Q, P]
    h_next   = h_prev * exp(sum dA) + B^T ((x*dt) * decay_to_end)
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .common import interpret_default

# Autotune candidate lattice (tuning/autotune.py): SSD chunk lengths
# (the sequential-scan granule; larger chunks amortize the state
# carry, smaller ones shrink the in-VMEM chunk working set).
TUNE_SPACE = {"chunk": (64, 128, 256)}


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, y_ref, hout_ref,
                state_ref, *, n_chunks: int, chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32)            # [Q, P]
    dt = dt_ref[0].astype(jnp.float32)          # [Q, 1]
    a = a_ref[0].astype(jnp.float32)            # [1, 1] (per head)
    b = b_ref[0].astype(jnp.float32)            # [Q, N]
    c = c_ref[0].astype(jnp.float32)            # [Q, N]
    d_skip = d_ref[0].astype(jnp.float32)       # [1, 1]

    da = dt * a                                  # [Q, 1]
    xdt = x * dt                                 # [Q, P]
    cum = jnp.cumsum(da, axis=0)                 # [Q, 1]
    # Intra-chunk decay matrix L[i, j] = exp(sum_{j<k<=i} da_k), j <= i.
    diff = cum - cum.T                           # [Q, Q]
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    l_mat = jnp.where(tri, jnp.exp(diff), 0.0)
    cb = jnp.dot(c, b.T, preferred_element_type=jnp.float32)  # [Q, Q]
    y = jnp.dot(cb * l_mat, xdt,
                preferred_element_type=jnp.float32)           # [Q, P]
    # Inter-chunk: contribution of the carried state.
    state = state_ref[...]                                    # [P, N]
    y += jnp.exp(cum) * jnp.dot(c, state.T,
                                preferred_element_type=jnp.float32)
    # State update.
    total = cum[-1:, :]                                       # [1, 1]
    decay_to_end = jnp.exp(total - cum)                       # [Q, 1]
    state_ref[...] = state * jnp.exp(total) + \
        jnp.dot((xdt * decay_to_end).T, b,
                preferred_element_type=jnp.float32)           # [P, N]
    y_ref[0] = (y + x * d_skip).astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _done():
        hout_ref[0] = state_ref[...]


def mamba2_ssd_pallas(x: jax.Array, dt: jax.Array, a_log: jax.Array,
                      b: jax.Array, c: jax.Array, d_skip: jax.Array, *,
                      chunk: int = 128,
                      interpret: Optional[bool] = None,
                      ) -> Tuple[jax.Array, jax.Array]:
    """Shapes as layers.mamba2_ssd: x [B,S,H,P], dt [B,S,H], a_log [H],
    b/c [B,S,N], d_skip [H] -> (y [B,S,H,P], state [B,H,P,N])."""
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    q = min(chunk, s)
    assert s % q == 0, (s, q)
    nc = s // q
    bh = bsz * h

    # Flatten to the (batch*head, chunks, ...) kernel layout.
    xk = x.transpose(0, 2, 1, 3).reshape(bh, s, p)
    dtk = dt.transpose(0, 2, 1).reshape(bh, s, 1)
    ak = -jnp.exp(a_log.astype(jnp.float32))
    ak = jnp.tile(ak.reshape(1, h), (bsz, 1)).reshape(bh, 1, 1)
    dk = jnp.tile(d_skip.reshape(1, h).astype(jnp.float32),
                  (bsz, 1)).reshape(bh, 1, 1)
    bk = jnp.repeat(b, h, axis=0).reshape(bsz, h, s, n) \
        .reshape(bh, s, n)
    ck = jnp.repeat(c, h, axis=0).reshape(bsz, h, s, n) \
        .reshape(bh, s, n)

    interpret = interpret_default() if interpret is None else interpret
    y, hfinal = pl.pallas_call(
        functools.partial(_ssd_kernel, n_chunks=nc, chunk=q),
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, q, p), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, q, 1), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1, 1), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, q, n), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, q, n), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1, 1), lambda i, j: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, q, p), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, p, n), lambda i, j: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, p), x.dtype),
            jax.ShapeDtypeStruct((bh, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(xk, dtk.reshape(bh, s, 1), ak, bk, ck, dk)

    y = y.reshape(bsz, h, s, p).transpose(0, 2, 1, 3)
    state = hfinal.reshape(bsz, h, p, n)
    return y, state
