"""Paged decode attention Pallas kernel — K/V pages streamed by indirection.

Single-token decode attention over the paged KV cache
(``serving/kv_cache.py``): one query token per slot attends to that slot's
pages through the page table.  This closes the last eager stage in the
decode hot loop (ROADMAP "Fused decode attention") with the paper's
streaming pattern: each K/V page is DMA'd into VMEM, its score tile is
produced, folded into the online-softmax running (m, l, acc), and
discarded — the per-slot score row never materializes in HBM.

Grid: ``(slots, kv_heads, n_pages)`` with the page dimension as the
sequential inner loop carrying the accumulators in VMEM scratch.  The page
table and per-slot lengths ride in as *scalar-prefetch* operands
(``PrefetchScalarGridSpec``) so the K/V BlockSpec index maps are
data-dependent: program (b, h, j) fetches physical page ``table[b, j]`` —
the explicit data-movement-by-indirection that PowerFusion's IR spells out
and that a dense BlockSpec cannot express.  GQA falls out of the grid: the
``G = Hq // Hkv`` query heads sharing a KV head live in one block, so K/V
pages are fetched once per kv head (the head dim is a reuse dim of the
page stream).

Pages fully past a slot's length are skipped with ``pl.when`` (no MXU
work, though the page DMA itself is still issued by the pipeline);
unallocated table entries point at the NULL page so the indirection is
always in bounds.  Per-slot length (and optional sliding-window) masking
is applied per element inside the page.  Interpret-mode fallback on CPU,
same as every kernel in this package.

Quantized pools (DESIGN.md §14): when ``k_scale``/``v_scale`` pools are
passed, the K/V pools hold int8 / fp8-e4m3 codes and the kernels
dequantize each page IN-REGISTER inside the online-softmax loop —
``k = codes.astype(f32) * scale[page, head]``.  The per-(page, kv-head)
f32 scale pools ride in as scalar-prefetch operands next to the page
table, fetched through the same ``tbl[b, j]`` indirection, so the page
stream's HBM traffic drops to the code itemsize while the math stays f32.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .common import LANE, interpret_default, round_up

# Autotune candidate lattice (tuning/autotune.py): KV page sizes the
# tuner scores for the paged decode stream.  Pages are HBM streaming
# granules, not MXU operands, so sub-lane sizes are legal; the tuned
# winner becomes the PagedKVCache page size AND the verify-window
# granule (verify_attention inherits it — the pool is shared).
TUNE_SPACE = {"page_size": (8, 16, 32, 64)}

NEG_INF = -1e30


def _paged_decode_kernel(len_ref, tbl_ref, q_ref, k_ref, v_ref, o_ref,
                         m_ref, l_ref, acc_ref, *, page_size: int,
                         n_pages: int, scale: float, window: int):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[b]
    page_start = j * page_size
    # Page-level skip: pages at/after the slot's length hold no valid
    # entries; with a sliding window, pages wholly before the window are
    # dead too.  Skipped pages issue no MXU work.
    run = page_start < length
    if window:
        run = jnp.logical_and(run, page_start + page_size > length - window)

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale        # [G, D]
        k = k_ref[0, :, 0, :].astype(jnp.float32)          # [ps, D]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)            # [G, ps]
        g = s.shape[0]
        kv_pos = page_start + jax.lax.broadcasted_iota(
            jnp.int32, (g, page_size), 1)
        mask = kv_pos < length
        if window:
            mask = jnp.logical_and(mask, kv_pos >= length - window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        v = v_ref[0, :, 0, :].astype(jnp.float32)          # [ps, D]
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == n_pages - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def _paged_decode_kernel_q(len_ref, tbl_ref, ks_ref, vs_ref, q_ref, k_ref,
                           v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                           page_size: int, n_pages: int, scale: float,
                           window: int):
    """Quantized decode body: identical online softmax, with each K/V
    page dequantized in-register at its per-(page, head) scale.  The
    scale pools are scalar-prefetch operands (SMEM), indexed through the
    same page-table indirection as the page fetch itself."""
    b = pl.program_id(0)
    h = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[b]
    page_start = j * page_size
    run = page_start < length
    if window:
        run = jnp.logical_and(run, page_start + page_size > length - window)

    @pl.when(run)
    def _body():
        phys = tbl_ref[b, j]
        q = q_ref[0, 0].astype(jnp.float32) * scale        # [G, D]
        k = k_ref[0, :, 0, :].astype(jnp.float32) * ks_ref[phys, h]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)            # [G, ps]
        g = s.shape[0]
        kv_pos = page_start + jax.lax.broadcasted_iota(
            jnp.int32, (g, page_size), 1)
        mask = kv_pos < length
        if window:
            mask = jnp.logical_and(mask, kv_pos >= length - window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        v = v_ref[0, :, 0, :].astype(jnp.float32) * vs_ref[phys, h]
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == n_pages - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def _paged_verify_kernel(off_ref, tbl_ref, q_ref, k_ref, v_ref, o_ref,
                         m_ref, l_ref, acc_ref, *, page_size: int,
                         n_pages: int, scale: float, window: int,
                         win: int, g: int):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_off = off_ref[b]
    page_start = j * page_size
    # Page-level skip across the whole window: the deepest row (win-1)
    # attends through q_off + win, the shallowest (row 0) starts its
    # sliding window at q_off + 1 - window; pages outside that union are
    # dead for every row.  Pages live for only SOME rows still run — the
    # per-row mask turns them into exact no-ops for the others (p == 0,
    # corr == 1), which is what keeps each row bit-identical to the
    # single-token decode kernel at its own length.
    run = page_start < q_off + win
    if window:
        run = jnp.logical_and(
            run, page_start + page_size > q_off + 1 - window)

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale        # [win*G, D]
        k = k_ref[0, :, 0, :].astype(jnp.float32)          # [ps, D]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)            # [win*G, ps]
        rows = win * g
        # Row i of the q block is query head (i % g) of window slot
        # (i // g): its causal extent is q_off + (i // g) + 1.
        q_idx = jax.lax.broadcasted_iota(
            jnp.int32, (rows, page_size), 0) // g
        kv_pos = page_start + jax.lax.broadcasted_iota(
            jnp.int32, (rows, page_size), 1)
        qlen = q_off + q_idx + 1
        mask = kv_pos < qlen
        if window:
            mask = jnp.logical_and(mask, kv_pos >= qlen - window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        v = v_ref[0, :, 0, :].astype(jnp.float32)          # [ps, D]
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == n_pages - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def _paged_verify_kernel_q(off_ref, tbl_ref, ks_ref, vs_ref, q_ref, k_ref,
                           v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                           page_size: int, n_pages: int, scale: float,
                           window: int, win: int, g: int):
    """Quantized verify body: per-row causal masking as the f32 kernel,
    pages dequantized in-register (see ``_paged_decode_kernel_q``)."""
    b = pl.program_id(0)
    h = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_off = off_ref[b]
    page_start = j * page_size
    run = page_start < q_off + win
    if window:
        run = jnp.logical_and(
            run, page_start + page_size > q_off + 1 - window)

    @pl.when(run)
    def _body():
        phys = tbl_ref[b, j]
        q = q_ref[0, 0].astype(jnp.float32) * scale        # [win*G, D]
        k = k_ref[0, :, 0, :].astype(jnp.float32) * ks_ref[phys, h]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)            # [win*G, ps]
        rows = win * g
        q_idx = jax.lax.broadcasted_iota(
            jnp.int32, (rows, page_size), 0) // g
        kv_pos = page_start + jax.lax.broadcasted_iota(
            jnp.int32, (rows, page_size), 1)
        qlen = q_off + q_idx + 1
        mask = kv_pos < qlen
        if window:
            mask = jnp.logical_and(mask, kv_pos >= qlen - window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        v = v_ref[0, :, 0, :].astype(jnp.float32) * vs_ref[phys, h]
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == n_pages - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def paged_verify_attention(q: jax.Array, k_pool: jax.Array,
                           v_pool: jax.Array, page_table: jax.Array,
                           q_off: jax.Array, *, window: int = 0,
                           scale: Optional[float] = None,
                           k_scale: Optional[jax.Array] = None,
                           v_scale: Optional[jax.Array] = None,
                           interpret: Optional[bool] = None) -> jax.Array:
    """W-token speculative-verify attention against paged K/V pools.

    q: [B, W, Hq, D] — the pending token plus W-1 draft candidates per
    slot; k_pool/v_pool: [P, page_size, Hkv, D]; page_table:
    [B, max_pages]; q_off: [B] absolute position of window row 0 (the
    pending token's write position — row i attends causally through
    ``q_off + i``, i.e. length ``q_off + i + 1``).  Returns
    [B, W, Hq, D].

    One dispatch scores all W positions: the decode kernel's grid and
    online-softmax body, with the window's rows stacked into the query
    block (kv-head-major, so K/V pages are still fetched once per kv
    head for the whole window) and a per-row causal extent replacing the
    shared length.  Each row's accumulator sequence is the one the
    single-token kernel would produce at that row's length — pages a row
    cannot see fold in as exact no-ops — so accepted tokens bit-match
    non-speculative decode.

    Quantized pools: pass ``k_scale``/``v_scale`` [P, Hkv] f32 (both or
    neither) — the pools are then int8/fp8 codes, dequantized in-register.
    """
    b, w, hq, d = q.shape
    _, page_size, hkv, _ = k_pool.shape
    n_pages = page_table.shape[1]
    g = hq // hkv
    quant = k_scale is not None
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    interpret = interpret_default() if interpret is None else interpret
    dp = d if interpret else round_up(d, LANE)
    if dp != d:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, 0), (0, dp - d)))
        k_pool = jnp.pad(k_pool, ((0, 0), (0, 0), (0, 0), (0, dp - d)))
        v_pool = jnp.pad(v_pool, ((0, 0), (0, 0), (0, 0), (0, dp - d)))
    # [B, W, Hq, D] -> [B, Hkv, W*G, D]: kv-head-major with the window
    # rows interleaved (row = w_idx * G + g_idx), so program (b, h) holds
    # every (window slot, query head) pair sharing KV head h.
    qk = q.reshape(b, w, hkv, g, dp).transpose(0, 2, 1, 3, 4) \
          .reshape(b, hkv, w * g, dp)

    n_scalars = 4 if quant else 2    # q_off, page_table (, k/v scales)

    def qmap(bi, hi, ji, *scalars):
        return (bi, hi, 0, 0)

    def kvmap(bi, hi, ji, off, tbl, *scalars):
        return (tbl[bi, ji], 0, hi, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=n_scalars,
        grid=(b, hkv, n_pages),
        in_specs=[
            pl.BlockSpec((1, 1, w * g, dp), qmap),
            pl.BlockSpec((1, page_size, 1, dp), kvmap),
            pl.BlockSpec((1, page_size, 1, dp), kvmap),
        ],
        out_specs=pl.BlockSpec((1, 1, w * g, dp), qmap),
        scratch_shapes=[
            pltpu.VMEM((w * g, 1), jnp.float32),
            pltpu.VMEM((w * g, 1), jnp.float32),
            pltpu.VMEM((w * g, dp), jnp.float32),
        ],
    )
    kernel = _paged_verify_kernel_q if quant else _paged_verify_kernel
    scalars = (q_off.astype(jnp.int32), page_table.astype(jnp.int32))
    if quant:
        scalars += (k_scale.astype(jnp.float32),
                    v_scale.astype(jnp.float32))
    out = pl.pallas_call(
        functools.partial(
            kernel, page_size=page_size, n_pages=n_pages,
            scale=scale, window=window, win=w, g=g),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, w * g, dp), q.dtype),
        interpret=interpret,
    )(*scalars, qk, k_pool, v_pool)
    return out.reshape(b, hkv, w, g, dp).transpose(0, 2, 1, 3, 4) \
              .reshape(b, w, hq, dp)[..., :d]


def paged_decode_attention(q: jax.Array, k_pool: jax.Array,
                           v_pool: jax.Array, page_table: jax.Array,
                           lengths: jax.Array, *, window: int = 0,
                           scale: Optional[float] = None,
                           k_scale: Optional[jax.Array] = None,
                           v_scale: Optional[jax.Array] = None,
                           interpret: Optional[bool] = None) -> jax.Array:
    """One-token attention against paged K/V pools.

    q: [B, 1, Hq, D]; k_pool/v_pool: [P, page_size, Hkv, D] (page-major
    canonical layout from ``serving/kv_cache.py``); page_table:
    [B, max_pages] int32 physical page ids (NULL page for unallocated
    entries); lengths: [B] valid entries per slot (including the token
    appended this step).  Returns [B, 1, Hq, D].

    A slot with length 0 (inactive) produces zeros — its output is
    discarded by the engine.

    Quantized pools: pass ``k_scale``/``v_scale`` [P, Hkv] f32 (both or
    neither) — the pools are then int8/fp8 codes, dequantized in-register.
    """
    b, _, hq, d = q.shape
    _, page_size, hkv, _ = k_pool.shape
    n_pages = page_table.shape[1]
    g = hq // hkv
    quant = k_scale is not None
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    interpret = interpret_default() if interpret is None else interpret
    dp = d if interpret else round_up(d, LANE)
    if dp != d:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, 0), (0, dp - d)))
        k_pool = jnp.pad(k_pool, ((0, 0), (0, 0), (0, 0), (0, dp - d)))
        v_pool = jnp.pad(v_pool, ((0, 0), (0, 0), (0, 0), (0, dp - d)))
    # [B, 1, Hq, D] -> [B, Hkv, G, D]: kv-head-major so program (b, h)
    # holds the G query heads that share KV head h.
    qk = q.reshape(b, hkv, g, dp)

    n_scalars = 4 if quant else 2    # lengths, page_table (, k/v scales)

    def qmap(bi, hi, ji, *scalars):
        return (bi, hi, 0, 0)

    def kvmap(bi, hi, ji, lens, tbl, *scalars):
        return (tbl[bi, ji], 0, hi, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=n_scalars,
        grid=(b, hkv, n_pages),
        in_specs=[
            pl.BlockSpec((1, 1, g, dp), qmap),
            pl.BlockSpec((1, page_size, 1, dp), kvmap),
            pl.BlockSpec((1, page_size, 1, dp), kvmap),
        ],
        out_specs=pl.BlockSpec((1, 1, g, dp), qmap),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, dp), jnp.float32),
        ],
    )
    kernel = _paged_decode_kernel_q if quant else _paged_decode_kernel
    scalars = (lengths.astype(jnp.int32), page_table.astype(jnp.int32))
    if quant:
        scalars += (k_scale.astype(jnp.float32),
                    v_scale.astype(jnp.float32))
    out = pl.pallas_call(
        functools.partial(
            kernel, page_size=page_size, n_pages=n_pages,
            scale=scale, window=window),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, dp), q.dtype),
        interpret=interpret,
    )(*scalars, qk, k_pool, v_pool)
    return out.reshape(b, 1, hq, dp)[..., :d]
