"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..core.itensor import ITensorType


def matmul_ref(x, w, out_dtype=None):
    out = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32))
    return out.astype(out_dtype or x.dtype)


def _act(kind, x):
    if kind == "silu":
        return jax.nn.silu(x)
    return jax.nn.gelu(x, approximate=True)


def ffn_ref(x, wg, wu, wd, activation="silu"):
    x32 = x.astype(jnp.float32)
    h = _act(activation, x32 @ wg.astype(jnp.float32)) * \
        (x32 @ wu.astype(jnp.float32))
    return (h @ wd.astype(jnp.float32)).astype(x.dtype)


def mlp_ref(x, wu, wd, activation="gelu"):
    x32 = x.astype(jnp.float32)
    h = _act(activation, x32 @ wu.astype(jnp.float32))
    return (h @ wd.astype(jnp.float32)).astype(x.dtype)


def rmsnorm_matmul_ref(x, scale, w, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    normed = x32 * jax.lax.rsqrt(var + eps) * \
        (1.0 + scale.astype(jnp.float32))
    return (normed.astype(x.dtype).astype(jnp.float32)
            @ w.astype(jnp.float32)).astype(x.dtype)


def attention_ref(q, k, v, *, causal=True, window=0, kv_len=None,
                  scale=None):
    """q: [B,Sq,Hq,D]; k/v: [B,Skv,Hkv,D] (GQA repeat)."""
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    g = hq // hkv
    kr = jnp.repeat(k, g, axis=2)
    vr = jnp.repeat(v, g, axis=2)
    sc = scale if scale is not None else 1.0 / math.sqrt(d)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) * sc
    qp = jnp.arange(sq)[:, None]
    kp = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask = kp <= qp
    if window:
        mask = jnp.logical_and(mask, kp > qp - window)
    if kv_len is not None:
        mask = jnp.logical_and(mask, kp < kv_len)
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vr.astype(jnp.float32))
    return out.astype(q.dtype)


def xent_parts_ref(hidden, head, labels, vocab_size):
    logits = (hidden.astype(jnp.float32) @ head.astype(jnp.float32))
    vp = logits.shape[-1]
    logits = jnp.where((jnp.arange(vp) >= vocab_size)[None], -1e30, logits)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return lse, gold


def xent_loss_ref(hidden, head, labels, vocab_size):
    lse, gold = xent_parts_ref(hidden, head, jnp.maximum(labels, 0),
                               vocab_size)
    valid = labels >= 0
    nll = jnp.where(valid, lse - gold, 0.0)
    return nll.sum() / jnp.maximum(valid.sum(), 1)


def mamba2_ref(x, dt, a_log, b, c, d_skip, init_state=None):
    """Sequential recurrence oracle; shapes as layers.mamba2_ssd."""
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    a = -jnp.exp(a_log.astype(jnp.float32))
    state = (init_state.astype(jnp.float32) if init_state is not None
             else jnp.zeros((bsz, h, p, n), jnp.float32))
    x32, dt32 = x.astype(jnp.float32), dt.astype(jnp.float32)
    b32, c32 = b.astype(jnp.float32), c.astype(jnp.float32)

    def step(state, t):
        da = jnp.exp(dt32[:, t] * a)
        upd = jnp.einsum("bhp,bn->bhpn", x32[:, t] * dt32[:, t][..., None],
                         b32[:, t])
        state = state * da[..., None, None] + upd
        y = jnp.einsum("bhpn,bn->bhp", state, c32[:, t])
        return state, y + x32[:, t] * d_skip.astype(jnp.float32)[None, :,
                                                                 None]

    state, ys = jax.lax.scan(step, state, jnp.arange(s))
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), state


def wkv6_ref(r, k, v, w, u, init_state=None):
    bsz, s, h, n = r.shape
    state = (init_state.astype(jnp.float32) if init_state is not None
             else jnp.zeros((bsz, h, n, n), jnp.float32))
    r32, k32 = r.astype(jnp.float32), k.astype(jnp.float32)
    v32, w32 = v.astype(jnp.float32), w.astype(jnp.float32)
    u32 = u.astype(jnp.float32)

    def step(state, t):
        kv = jnp.einsum("bhk,bhv->bhkv", k32[:, t], v32[:, t])
        y = jnp.einsum("bhk,bhkv->bhv", r32[:, t],
                       state + u32[None, :, :, None] * kv)
        return state * w32[:, t][..., None] + kv, y

    state, ys = jax.lax.scan(step, state, jnp.arange(s))
    return ys.transpose(1, 0, 2, 3).astype(r.dtype), state


def moe_experts_ref(x, gates, wg, wu, wd, activation="silu"):
    x32 = x.astype(jnp.float32)
    gh = _act(activation, jnp.einsum("td,edf->tef", x32,
                                     wg.astype(jnp.float32)))
    uh = jnp.einsum("td,edf->tef", x32, wu.astype(jnp.float32))
    y = jnp.einsum("tef,efd->ted", gh * uh, wd.astype(jnp.float32))
    return jnp.einsum("ted,te->td", y,
                      gates.astype(jnp.float32)).astype(x.dtype)


def convert_layout_ref(data, src: ITensorType, dst: ITensorType):
    """Consumer-order tile stream by direct slicing."""
    tiles = []
    for off in dst.stream_offsets():
        idx = tuple(slice(o, o + e) for o, e in zip(off, dst.elem_shape))
        tiles.append(data[idx])
    return jnp.stack(tiles)
