"""MoE expert-FFN Pallas kernel (dense-gather EP formulation).

Grid (t_blocks, experts); the expert dimension is the sequential inner loop
accumulating the gated expert outputs in VMEM.  Each step computes one
expert's GLU on the resident token tile and folds it in weighted by that
expert's gate column — the router->dispatch->expert->combine chain of the
dataflow graph collapsed into one streaming kernel (gates with zero weight
still compute: the dense-gather trade that makes experts shardable over the
model axis without all-to-alls; see DESIGN.md §6).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .common import interpret_default, pick_block

# Autotune candidate lattice (tuning/autotune.py): the expert grid is
# fixed by the config, so only the token tile is searched.
TUNE_SPACE = {"block_t": (128, 256, 512)}


def _act(kind: str, x):
    if kind == "silu":
        return jax.nn.silu(x)
    return jax.nn.gelu(x, approximate=True)


def _moe_kernel(x_ref, g_ref, wg_ref, wu_ref, wd_ref, o_ref, acc_ref, *,
                n_e: int, activation: str):
    ei = pl.program_id(1)

    @pl.when(ei == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]
    gate = jnp.dot(x, wg_ref[0], preferred_element_type=jnp.float32)
    up = jnp.dot(x, wu_ref[0], preferred_element_type=jnp.float32)
    h = (_act(activation, gate) * up).astype(x.dtype)
    y = jnp.dot(h, wd_ref[0], preferred_element_type=jnp.float32)
    g = g_ref[...][:, 0:1].astype(jnp.float32)       # [bt, 1] this expert
    acc_ref[...] += y * g

    @pl.when(ei == n_e - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def moe_experts_pallas(x: jax.Array, gates: jax.Array, wg: jax.Array,
                       wu: jax.Array, wd: jax.Array, *,
                       activation: str = "silu", block_t: int = 256,
                       interpret: Optional[bool] = None) -> jax.Array:
    """x: [T, D]; gates: [T, E] (zero off the top-k); wg/wu: [E, D, F];
    wd: [E, F, D] -> [T, D]."""
    t, d = x.shape
    e, d2, f = wg.shape
    assert d == d2 and gates.shape == (t, e)
    bt = pick_block(t, block_t)
    grid = (t // bt, e)
    interpret = interpret_default() if interpret is None else interpret
    return pl.pallas_call(
        functools.partial(_moe_kernel, n_e=e, activation=activation),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bt, 1), lambda i, j: (i, j)),
            pl.BlockSpec((1, d, f), lambda i, j: (j, 0, 0)),
            pl.BlockSpec((1, d, f), lambda i, j: (j, 0, 0)),
            pl.BlockSpec((1, f, d), lambda i, j: (j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bt, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, d), x.dtype),
        scratch_shapes=[pltpu.VMEM((bt, d), jnp.float32)],
        interpret=interpret,
    )(x, gates, wg, wu, wd)
