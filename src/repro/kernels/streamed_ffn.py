"""Stream-fused GLU FFN — the canonical StreamTensor kernel fusion.

Computes ``down( act(x @ Wg) * (x @ Wu) )`` with the [T, d_ff] intermediate
living ONLY in VMEM: grid (t_blocks, f_blocks) where the f dimension is the
sequential inner loop.  Per (t, f) step the kernel produces one intermediate
tile, immediately consumes it against the matching Wd tile, and accumulates
the [bt, d_model] output in a VMEM scratch — producer (gate/up matmuls) and
consumer (down matmul) are *stream-fused* exactly as the paper fuses Kernel0
into Kernel1 through an on-chip buffer instead of external memory.

The itensor view: the intermediate's type is
    itensor<bt x bf, [T/bt, F/bf] * [bt, bf], (d0,d1)->(d0,d1)>
for both producer and consumer — types match, so fusion needs no layout
converter and the FIFO collapses to a single VMEM tile (itensor folding,
paper §4.3.2).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .common import interpret_default, pick_block


def _act(kind: str, x):
    if kind == "silu":
        return jax.nn.silu(x)
    return jax.nn.gelu(x, approximate=True)


def _ffn_kernel(x_ref, wg_ref, wu_ref, wd_ref, o_ref, acc_ref, *,
                n_f: int, activation: str):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]
    gate = jnp.dot(x, wg_ref[...], preferred_element_type=jnp.float32)
    up = jnp.dot(x, wu_ref[...], preferred_element_type=jnp.float32)
    h = (_act(activation, gate) * up).astype(x.dtype)   # stays in VMEM
    acc_ref[...] += jnp.dot(h, wd_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(1) == n_f - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def streamed_ffn(x: jax.Array, wg: jax.Array, wu: jax.Array, wd: jax.Array,
                 *, activation: str = "silu",
                 block_t: int = 256, block_f: int = 512,
                 interpret: Optional[bool] = None) -> jax.Array:
    """x: [T, D]; wg/wu: [D, F]; wd: [F, D] -> [T, D]."""
    t, d = x.shape
    d2, f = wg.shape
    assert d == d2 and wu.shape == (d, f) and wd.shape == (f, d)
    bt = pick_block(t, block_t)
    bf = pick_block(f, block_f)
    grid = (t // bt, f // bf)
    interpret = interpret_default() if interpret is None else interpret

    return pl.pallas_call(
        functools.partial(_ffn_kernel, n_f=grid[1], activation=activation),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, d), lambda i, j: (i, 0)),
            pl.BlockSpec((d, bf), lambda i, j: (0, j)),
            pl.BlockSpec((d, bf), lambda i, j: (0, j)),
            pl.BlockSpec((bf, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bt, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, d), x.dtype),
        scratch_shapes=[pltpu.VMEM((bt, d), jnp.float32)],
        interpret=interpret,
    )(x, wg, wu, wd)


def streamed_mlp(x: jax.Array, wu: jax.Array, wd: jax.Array, *,
                 activation: str = "gelu",
                 block_t: int = 256, block_f: int = 512,
                 interpret: Optional[bool] = None) -> jax.Array:
    """Ungated variant (GPT-2 / HuBERT): down(act(x @ Wu))."""
    t, d = x.shape
    _, f = wu.shape

    def kernel(x_ref, wu_ref, wd_ref, o_ref, acc_ref, *, n_f: int):
        @pl.when(pl.program_id(1) == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        h = _act(activation,
                 jnp.dot(x_ref[...], wu_ref[...],
                         preferred_element_type=jnp.float32)).astype(x.dtype)
        acc_ref[...] += jnp.dot(h, wd_ref[...],
                                preferred_element_type=jnp.float32)

        @pl.when(pl.program_id(1) == n_f - 1)
        def _done():
            o_ref[...] = acc_ref[...].astype(o_ref.dtype)

    bt = pick_block(t, block_t)
    bf = pick_block(f, block_f)
    grid = (t // bt, f // bf)
    interpret = interpret_default() if interpret is None else interpret
    return pl.pallas_call(
        functools.partial(kernel, n_f=grid[1]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, d), lambda i, j: (i, 0)),
            pl.BlockSpec((d, bf), lambda i, j: (0, j)),
            pl.BlockSpec((bf, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bt, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, d), x.dtype),
        scratch_shapes=[pltpu.VMEM((bt, d), jnp.float32)],
        interpret=interpret,
    )(x, wu, wd)
