"""Stream-fused GLU FFN — the canonical StreamTensor kernel fusion.

Computes ``down( act(x @ Wg) * (x @ Wu) )`` with the [T, d_ff] intermediate
living ONLY in VMEM: grid (t_blocks, f_blocks) where the f dimension is the
sequential inner loop.  Per (t, f) step the kernel produces one intermediate
tile, immediately consumes it against the matching Wd tile, and accumulates
the [bt, d_model] output in a VMEM scratch — producer (gate/up matmuls) and
consumer (down matmul) are *stream-fused* exactly as the paper fuses Kernel0
into Kernel1 through an on-chip buffer instead of external memory.

With ``norm_scale`` the pre-FFN RMSNorm is folded in as well (the StreamPlan
path when the fusion pass grouped ln2 with the projections): each x tile is
normalized in VMEM right before hitting the MXU, so the normalized
activation never round-trips HBM either.  The norm is recomputed per f-step
on the resident x tile — pure VPU work traded for an HBM stream, the same
trade ``rmsnorm_matmul`` makes.

The itensor view: the intermediate's type is
    itensor<bt x bf, [T/bt, F/bf] * [bt, bf], (d0,d1)->(d0,d1)>
for both producer and consumer — types match, so fusion needs no layout
converter and the FIFO collapses to a single VMEM tile (itensor folding,
paper §4.3.2).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .common import interpret_default, pick_block

# Autotune candidate lattice (tuning/autotune.py) shared by
# streamed_ffn and streamed_mlp; lint-pruned before timing.
TUNE_SPACE = {"block_t": (128, 256, 512), "block_f": (128, 256, 512)}


def _act(kind: str, x):
    if kind == "silu":
        return jax.nn.silu(x)
    return jax.nn.gelu(x, approximate=True)


def _rms_tile(x, scale_ref, eps: float):
    """RMS-normalize one [bt, D] tile in VMEM (matches layers.rms_norm)."""
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    y = y * (1.0 + scale_ref[...].astype(jnp.float32))
    return y.astype(x.dtype)


def _ffn_kernel(*refs, n_f: int, activation: str, norm_eps: Optional[float]):
    if norm_eps is not None:
        x_ref, scale_ref, wg_ref, wu_ref, wd_ref, o_ref, acc_ref = refs
    else:
        x_ref, wg_ref, wu_ref, wd_ref, o_ref, acc_ref = refs

    @pl.when(pl.program_id(1) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]
    if norm_eps is not None:
        x = _rms_tile(x, scale_ref, norm_eps)
    gate = jnp.dot(x, wg_ref[...], preferred_element_type=jnp.float32)
    up = jnp.dot(x, wu_ref[...], preferred_element_type=jnp.float32)
    h = (_act(activation, gate) * up).astype(x.dtype)   # stays in VMEM
    acc_ref[...] += jnp.dot(h, wd_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(1) == n_f - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _ffn_kernel_w8(*refs, n_f: int, activation: str,
                   norm_eps: Optional[float]):
    """Weight-only int8 body (DESIGN.md §14): wg/wu/wd are int8 codes with
    per-output-channel f32 scales.  Gate/up scales apply pre-activation
    (the nonlinearity needs real values); the down scale applies post-dot
    per accumulation step — both exact per-column dequantizations, with
    every weight streamed from HBM at 1 byte."""
    if norm_eps is not None:
        (x_ref, scale_ref, wg_ref, wgs_ref, wu_ref, wus_ref, wd_ref,
         wds_ref, o_ref, acc_ref) = refs
    else:
        (x_ref, wg_ref, wgs_ref, wu_ref, wus_ref, wd_ref, wds_ref,
         o_ref, acc_ref) = refs

    @pl.when(pl.program_id(1) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]
    if norm_eps is not None:
        x = _rms_tile(x, scale_ref, norm_eps)
    x32 = x.astype(jnp.float32)
    gate = jnp.dot(x32, wg_ref[...].astype(jnp.float32),
                   preferred_element_type=jnp.float32) * wgs_ref[...][None]
    up = jnp.dot(x32, wu_ref[...].astype(jnp.float32),
                 preferred_element_type=jnp.float32) * wus_ref[...][None]
    h = _act(activation, gate) * up                     # stays in VMEM
    acc_ref[...] += jnp.dot(h, wd_ref[...].astype(jnp.float32),
                            preferred_element_type=jnp.float32
                            ) * wds_ref[...][None]

    @pl.when(pl.program_id(1) == n_f - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def streamed_ffn(x: jax.Array, wg: jax.Array, wu: jax.Array, wd: jax.Array,
                 *, activation: str = "silu",
                 norm_scale: Optional[jax.Array] = None,
                 norm_eps: float = 1e-6,
                 block_t: int = 256, block_f: int = 512,
                 wg_scale: Optional[jax.Array] = None,
                 wu_scale: Optional[jax.Array] = None,
                 wd_scale: Optional[jax.Array] = None,
                 interpret: Optional[bool] = None) -> jax.Array:
    """x: [T, D]; wg/wu: [D, F]; wd: [F, D] -> [T, D].

    ``norm_scale`` [D]: fold ``rms_norm(x, norm_scale)`` into the kernel.
    ``wg_scale``/``wu_scale`` [F] + ``wd_scale`` [D]: weight-only int8 —
    the weights are int8 codes dequantized in-kernel per output channel.
    """
    t, d = x.shape
    d2, f = wg.shape
    assert d == d2 and wu.shape == (d, f) and wd.shape == (f, d)
    bt = pick_block(t, block_t)
    bf = pick_block(f, block_f)
    grid = (t // bt, f // bf)
    interpret = interpret_default() if interpret is None else interpret
    w8 = wg_scale is not None

    in_specs = [pl.BlockSpec((bt, d), lambda i, j: (i, 0))]
    operands = [x]
    if norm_scale is not None:
        in_specs.append(pl.BlockSpec((d,), lambda i, j: (0,)))
        operands.append(norm_scale)
    if w8:
        in_specs += [
            pl.BlockSpec((d, bf), lambda i, j: (0, j)),
            pl.BlockSpec((bf,), lambda i, j: (j,)),
            pl.BlockSpec((d, bf), lambda i, j: (0, j)),
            pl.BlockSpec((bf,), lambda i, j: (j,)),
            pl.BlockSpec((bf, d), lambda i, j: (j, 0)),
            pl.BlockSpec((d,), lambda i, j: (0,)),
        ]
        operands += [wg, wg_scale.astype(jnp.float32),
                     wu, wu_scale.astype(jnp.float32),
                     wd, wd_scale.astype(jnp.float32)]
        kernel = _ffn_kernel_w8
    else:
        in_specs += [
            pl.BlockSpec((d, bf), lambda i, j: (0, j)),
            pl.BlockSpec((d, bf), lambda i, j: (0, j)),
            pl.BlockSpec((bf, d), lambda i, j: (j, 0)),
        ]
        operands += [wg, wu, wd]
        kernel = _ffn_kernel

    return pl.pallas_call(
        functools.partial(kernel, n_f=grid[1], activation=activation,
                          norm_eps=norm_eps if norm_scale is not None
                          else None),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bt, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, d), x.dtype),
        scratch_shapes=[pltpu.VMEM((bt, d), jnp.float32)],
        interpret=interpret,
    )(*operands)


def _mlp_kernel(*refs, n_f: int, activation: str, norm_eps: Optional[float]):
    if norm_eps is not None:
        x_ref, scale_ref, wu_ref, wd_ref, o_ref, acc_ref = refs
    else:
        x_ref, wu_ref, wd_ref, o_ref, acc_ref = refs

    @pl.when(pl.program_id(1) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]
    if norm_eps is not None:
        x = _rms_tile(x, scale_ref, norm_eps)
    h = _act(activation,
             jnp.dot(x, wu_ref[...],
                     preferred_element_type=jnp.float32)).astype(x.dtype)
    acc_ref[...] += jnp.dot(h, wd_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(1) == n_f - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _mlp_kernel_w8(*refs, n_f: int, activation: str,
                   norm_eps: Optional[float]):
    """Weight-only int8 ungated body (see ``_ffn_kernel_w8``)."""
    if norm_eps is not None:
        (x_ref, scale_ref, wu_ref, wus_ref, wd_ref, wds_ref,
         o_ref, acc_ref) = refs
    else:
        x_ref, wu_ref, wus_ref, wd_ref, wds_ref, o_ref, acc_ref = refs

    @pl.when(pl.program_id(1) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]
    if norm_eps is not None:
        x = _rms_tile(x, scale_ref, norm_eps)
    x32 = x.astype(jnp.float32)
    up = jnp.dot(x32, wu_ref[...].astype(jnp.float32),
                 preferred_element_type=jnp.float32) * wus_ref[...][None]
    h = _act(activation, up)
    acc_ref[...] += jnp.dot(h, wd_ref[...].astype(jnp.float32),
                            preferred_element_type=jnp.float32
                            ) * wds_ref[...][None]

    @pl.when(pl.program_id(1) == n_f - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def streamed_mlp(x: jax.Array, wu: jax.Array, wd: jax.Array, *,
                 activation: str = "gelu",
                 norm_scale: Optional[jax.Array] = None,
                 norm_eps: float = 1e-6,
                 block_t: int = 256, block_f: int = 512,
                 wu_scale: Optional[jax.Array] = None,
                 wd_scale: Optional[jax.Array] = None,
                 interpret: Optional[bool] = None) -> jax.Array:
    """Ungated variant (GPT-2 / HuBERT): down(act(x @ Wu)).

    ``wu_scale`` [F] + ``wd_scale`` [D]: weight-only int8 codes.
    """
    t, d = x.shape
    _, f = wu.shape
    bt = pick_block(t, block_t)
    bf = pick_block(f, block_f)
    grid = (t // bt, f // bf)
    interpret = interpret_default() if interpret is None else interpret
    w8 = wu_scale is not None

    in_specs = [pl.BlockSpec((bt, d), lambda i, j: (i, 0))]
    operands = [x]
    if norm_scale is not None:
        in_specs.append(pl.BlockSpec((d,), lambda i, j: (0,)))
        operands.append(norm_scale)
    if w8:
        in_specs += [
            pl.BlockSpec((d, bf), lambda i, j: (0, j)),
            pl.BlockSpec((bf,), lambda i, j: (j,)),
            pl.BlockSpec((bf, d), lambda i, j: (j, 0)),
            pl.BlockSpec((d,), lambda i, j: (0,)),
        ]
        operands += [wu, wu_scale.astype(jnp.float32),
                     wd, wd_scale.astype(jnp.float32)]
        kernel = _mlp_kernel_w8
    else:
        in_specs += [
            pl.BlockSpec((d, bf), lambda i, j: (0, j)),
            pl.BlockSpec((bf, d), lambda i, j: (j, 0)),
        ]
        operands += [wu, wd]
        kernel = _mlp_kernel

    return pl.pallas_call(
        functools.partial(kernel, n_f=grid[1], activation=activation,
                          norm_eps=norm_eps if norm_scale is not None
                          else None),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bt, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, d), x.dtype),
        scratch_shapes=[pltpu.VMEM((bt, d), jnp.float32)],
        interpret=interpret,
    )(*operands)
