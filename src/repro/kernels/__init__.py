"""Pallas TPU kernels for the performance-critical compute layers.

Every kernel: ``<name>.py`` holds the ``pl.pallas_call`` + BlockSpec body,
``ops.py`` the model-layout jitted wrappers, ``ref.py`` the pure-jnp oracle.
Validated in interpret mode on CPU; TPU is the target (MXU-aligned blocks,
VMEM scratch accumulators).
"""

from . import ref
from .ops import (block_matmul, convert_layout, flash_attention,
                  flash_attention_2d, mamba2_ssd_pallas, moe_experts_pallas,
                  rmsnorm_matmul, streamed_ffn, streamed_mlp,
                  streamed_xent_loss, streamed_xent_parts, wkv6_pallas)
from .paged_attention import paged_decode_attention, paged_verify_attention

__all__ = [
    "ref", "block_matmul", "convert_layout", "flash_attention",
    "flash_attention_2d", "mamba2_ssd_pallas", "moe_experts_pallas",
    "paged_decode_attention", "paged_verify_attention", "rmsnorm_matmul",
    "streamed_ffn", "streamed_mlp", "streamed_xent_loss",
    "streamed_xent_parts", "wkv6_pallas",
]
