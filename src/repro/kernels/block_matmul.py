"""Tiled matmul Pallas kernel — the baseline dataflow 'Kernel' (paper Fig.1).

Grid (m_blocks, n_blocks, k_blocks); K is the innermost (sequential) grid dim
so the f32 VMEM accumulator persists across K steps — the itensor iteration
space [M/bm, N/bn, K/bk] with map (d0,d1,d2)->(d0,d1) on the output (K is a
reuse dim), exactly the Fig. 5(c) pattern.  Block shapes are MXU-aligned
(multiples of 128) for the production path; test shapes fall back to exact
divisors.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .common import interpret_default, pick_block

# Autotune candidate lattice (tuning/autotune.py) in KernelChoice
# block names (block_t/block_n map onto this wrapper's block_m/
# block_n); lint-illegal points are pruned before timing.
TUNE_SPACE = {"block_t": (128, 256, 512), "block_n": (128, 256, 512)}


def _matmul_kernel(x_ref, w_ref, o_ref, acc_ref, *, n_k: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def block_matmul(x: jax.Array, w: jax.Array, *,
                 block_m: int = 256, block_n: int = 256, block_k: int = 512,
                 out_dtype: Optional[jnp.dtype] = None,
                 interpret: Optional[bool] = None) -> jax.Array:
    """x: [M, K] @ w: [K, N] -> [M, N] with VMEM-tiled accumulation."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    out_dtype = out_dtype or x.dtype
    bm = pick_block(m, block_m)
    bn = pick_block(n, block_n)
    bk = pick_block(k, block_k)
    grid = (m // bm, n // bn, k // bk)
    interpret = interpret_default() if interpret is None else interpret

    return pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w)
