"""Streamed cross-entropy kernel — the loss as a dataflow consumer.

Grid (t_blocks, v_blocks): per step the kernel computes one [bt, bv] logits
tile (hidden @ head tile on the MXU), folds it into a running online
logsumexp, and extracts the gold logit where the label falls in this vocab
tile.  The [T, V] logits tensor never exists — in itensor terms the logits
stream has type itensor<bt x bv, [T/bt, V/bv]*[bt, bv], (d0,d1)->(d0,d1)>
and its only consumer (the reduction) is fused, so the stream collapses
in-VMEM (paper §4.3.2 itensor folding).

Emits (lse [T], gold [T]); loss = mean(lse - gold) over valid labels,
computed by the wrapper.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .common import interpret_default, pick_block

# Autotune candidate lattice (tuning/autotune.py): vocab tiles are
# the dominant stream (the [T, V] logits never materialize), token
# tiles bound the online-logsumexp state resident per step.
TUNE_SPACE = {"block_t": (128, 256), "block_v": (512, 1024, 2048)}

NEG_INF = -1e30


def _xent_kernel(h_ref, w_ref, y_ref, lse_ref, gold_ref, m_ref, s_ref,
                 g_ref, *, n_v: int, block_v: int, vocab_size: int):
    vi = pl.program_id(1)

    @pl.when(vi == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        s_ref[...] = jnp.zeros_like(s_ref)
        g_ref[...] = jnp.full_like(g_ref, NEG_INF)

    logits = jnp.dot(h_ref[...], w_ref[...],
                     preferred_element_type=jnp.float32)     # [bt, bv]
    v_start = vi * block_v
    v_pos = v_start + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
    valid = v_pos < vocab_size
    logits = jnp.where(valid, logits, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(logits, axis=-1, keepdims=True))
    p = jnp.where(valid, jnp.exp(logits - m_new), 0.0)
    s_ref[...] = s_ref[...] * jnp.exp(m_prev - m_new) + \
        jnp.sum(p, axis=-1, keepdims=True)
    m_ref[...] = m_new

    # Gold logit: the label's column may fall inside this vocab tile.
    y = y_ref[...]                                            # [bt]
    hit = (v_pos == y[:, None])
    tile_gold = jnp.max(jnp.where(hit, logits, NEG_INF), axis=-1,
                        keepdims=True)
    g_ref[...] = jnp.maximum(g_ref[...], tile_gold)

    @pl.when(vi == n_v - 1)
    def _done():
        lse_ref[...] = (m_ref[...] + jnp.log(
            jnp.maximum(s_ref[...], 1e-30)))[:, 0]
        gold_ref[...] = g_ref[...][:, 0]


def streamed_xent_parts(hidden: jax.Array, head: jax.Array,
                        labels: jax.Array, *, vocab_size: int,
                        block_t: int = 256, block_v: int = 2048,
                        interpret: Optional[bool] = None,
                        ) -> Tuple[jax.Array, jax.Array]:
    """hidden: [T, D]; head: [D, Vp]; labels: [T] -> (lse [T], gold [T])."""
    t, d = hidden.shape
    _, vp = head.shape
    bt = pick_block(t, block_t)
    bv = pick_block(vp, block_v)
    grid = (t // bt, vp // bv)
    interpret = interpret_default() if interpret is None else interpret
    lse, gold = pl.pallas_call(
        functools.partial(_xent_kernel, n_v=grid[1], block_v=bv,
                          vocab_size=vocab_size),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, d), lambda i, j: (i, 0)),
            pl.BlockSpec((d, bv), lambda i, j: (0, j)),
            pl.BlockSpec((bt,), lambda i, j: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((bt,), lambda i, j: (i,)),
            pl.BlockSpec((bt,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t,), jnp.float32),
            jax.ShapeDtypeStruct((t,), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bt, 1), jnp.float32),
            pltpu.VMEM((bt, 1), jnp.float32),
            pltpu.VMEM((bt, 1), jnp.float32),
        ],
        interpret=interpret,
    )(hidden, head, labels)
    return lse, gold


def streamed_xent_loss(hidden: jax.Array, head: jax.Array,
                       labels: jax.Array, *, vocab_size: int,
                       interpret: Optional[bool] = None, **kw) -> jax.Array:
    """Mean CE over labels >= 0 (ignore index < 0), flat token axis."""
    lse, gold = streamed_xent_parts(hidden, head, jnp.maximum(labels, 0),
                                    vocab_size=vocab_size,
                                    interpret=interpret, **kw)
    valid = labels >= 0
    nll = jnp.where(valid, lse - gold, 0.0)
    return nll.sum() / jnp.maximum(valid.sum(), 1)
