"""Materialized stream layout converter — Algorithm 1 on real data.

Re-tiles a 2D tensor from a producer itensor layout to a consumer layout
through a window buffer of the Algorithm-1-inferred shape: the direct TPU
twin of the paper's ``itensor_converter`` (Fig. 7(a)).  The shared loop
prefix becomes the Pallas grid (the window is re-used once per shared
iteration — the paper's ping-pong reuse, realized by Pallas' automatic
cross-iteration double buffering); the non-reducible dims become the window
extents.

The wrapper derives grid/BlockSpecs straight from the two ``ITensorType``s,
so core/converter.py decisions are *executable* — tests stream data through
and compare against slicing the tensor in consumer order.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core.converter import infer_converter
from ..core.itensor import ITensorType
from .common import interpret_default


def _copy_kernel(src_ref, dst_ref):
    dst_ref[0] = src_ref[...]


def convert_layout(data: jax.Array, src: ITensorType, dst: ITensorType, *,
                   interpret: Optional[bool] = None) -> jax.Array:
    """Stream ``data`` (producer layout ``src``) out in consumer layout
    ``dst``; returns the tile stream stacked in consumer order
    [num_tokens, *dst.elem_shape].

    The window BlockSpec is the Algorithm-1 buffer: grid = the shared loop
    prefix; each grid step loads one window (ping) while the previous
    window's tiles drain (pong) — Pallas pipelines this automatically.
    """
    if tuple(data.shape) != src.data_shape:
        raise ValueError(f"{data.shape} != {src.data_shape}")
    spec = infer_converter(src, dst)
    interpret = interpret_default() if interpret is None else interpret

    grid_out = dst.grid_shape
    n_tokens = dst.num_tokens
    eh, ew = dst.elem_shape

    if spec is None:
        # Types match: the 'converter' is a FIFO — emit tiles directly.
        def index_map(t):
            offs = _nth_offset(dst, t)
            return offs

        return pl.pallas_call(
            _copy_kernel,
            grid=(n_tokens,),
            in_specs=[pl.BlockSpec((eh, ew), lambda t: index_map(t))],
            out_specs=pl.BlockSpec((1, eh, ew), lambda t: (t, 0, 0)),
            out_shape=jax.ShapeDtypeStruct((n_tokens, eh, ew), data.dtype),
            interpret=interpret,
        )(data)

    # Window buffer path: grid over the consumer stream; every tile read
    # comes from the window, whose block index is the shared-prefix part of
    # the tile coordinate.  Window extents from Algorithm 1.
    wh, ww = spec.buf_shape
    gh = src.data_shape[0] // wh
    gw = src.data_shape[1] // ww

    def in_map(t):
        oh, ow = _nth_offset_traced(dst, t)   # element-unit offsets
        return (oh // wh, ow // ww)           # window-block units

    def kernel(win_ref, out_ref, *, spec_shapes):
        t = pl.program_id(0)
        oh, ow = _nth_offset_traced(dst, t)
        local_h = oh % wh
        local_w = ow % ww
        tile = jax.lax.dynamic_slice(win_ref[...], (local_h, local_w),
                                     (eh, ew))
        out_ref[0] = tile

    return pl.pallas_call(
        functools.partial(kernel, spec_shapes=(wh, ww)),
        grid=(n_tokens,),
        in_specs=[pl.BlockSpec((wh, ww), in_map)],
        out_specs=pl.BlockSpec((1, eh, ew), lambda t: (t, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_tokens, eh, ew), data.dtype),
        interpret=interpret,
    )(data)


def _nth_offset(t_type: ITensorType, n):
    """Data offset of the n-th stream token (trace-time arithmetic)."""
    trips = t_type.tripcounts
    idx = []
    rem = n
    for tc in reversed(trips):
        idx.append(rem % tc)
        rem = rem // tc
    idx = list(reversed(idx))
    offs = tuple(idx[k] * t_type.steps[k] for k in t_type.iter_map.results)
    # BlockSpec index maps are in units of blocks.
    return tuple(o // e for o, e in zip(offs, t_type.elem_shape))


def _nth_offset_traced(t_type: ITensorType, n):
    """Same as _nth_offset but in data elements (for in-window slicing)."""
    trips = t_type.tripcounts
    idx = []
    rem = n
    for tc in reversed(trips):
        idx.append(rem % tc)
        rem = rem // tc
    idx = list(reversed(idx))
    return tuple(idx[k] * t_type.steps[k] for k in t_type.iter_map.results)
