"""Public jitted wrappers for the Pallas kernels.

These are the entry points the lowered fusion groups map to
(core/lowering.py pattern registry).  Each wrapper reshapes model-layout
tensors into the kernel layouts, pads head dims to the 128-lane width where
needed, and dispatches to interpret mode off-TPU.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .block_matmul import block_matmul
from .common import LANE, interpret_default, round_up
from .flash_attention import flash_attention_2d
from .mamba2_scan import mamba2_ssd_pallas
from .moe_experts import moe_experts_pallas
from .rmsnorm_matmul import rmsnorm_matmul
from .rwkv6_wkv import wkv6_pallas
from .stream_converter import convert_layout
from .streamed_ffn import streamed_ffn, streamed_mlp
from .streamed_xent import streamed_xent_loss, streamed_xent_parts

__all__ = [
    "block_matmul", "streamed_ffn", "streamed_mlp", "rmsnorm_matmul",
    "flash_attention", "flash_attention_2d", "streamed_xent_loss",
    "streamed_xent_parts", "mamba2_ssd_pallas", "wkv6_pallas",
    "moe_experts_pallas", "convert_layout",
]


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    kv_len=None,
                    block_q: int = 512, block_kv: int = 512,
                    q_offset=None,
                    k_scale: Optional[jax.Array] = None,
                    v_scale: Optional[jax.Array] = None,
                    interpret: Optional[bool] = None) -> jax.Array:
    """Model-layout flash attention with GQA.

    q: [B, Sq, Hq, D]; k/v: [B, Skv, Hkv, D] -> [B, Sq, Hq, D].
    Query heads are grouped over their KV head so one kernel instance
    serves a (kv-head, group) pair without materializing repeated K/V.

    ``q_offset`` (chunked prefill) shifts query positions by a dynamic
    scalar so a chunk's queries attend the already-cached prefix; with it
    set, ``kv_len`` may be a traced scalar (the cache's valid fill).

    Quantized K/V (offset path): ``k_scale``/``v_scale`` [B, Skv, Hkv]
    per-position f32 scales — k/v are then int8/fp8 codes gathered from
    quantized pools, dequantized in-register by the kernel.
    """
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    g = hq // hkv
    dp = round_up(d, LANE) if not interpret_default() else d
    if dp != d:
        pad = ((0, 0), (0, 0), (0, 0), (0, dp - d))
        q = jnp.pad(q, pad)
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
    scale = 1.0 / math.sqrt(d)
    # Flatten heads: q -> [B*Hkv*G, Sq, D] grouped kv-head-major so that
    # program b's KV head is b // g — no repeated K/V in memory.
    qk = q.reshape(b, sq, hkv, g, dp).transpose(0, 2, 3, 1, 4) \
        .reshape(b * hkv * g, sq, dp)
    kk = k.transpose(0, 2, 1, 3).reshape(b * hkv, skv, dp)
    vk = v.transpose(0, 2, 1, 3).reshape(b * hkv, skv, dp)
    if k_scale is not None:
        k_scale = k_scale.transpose(0, 2, 1).reshape(b * hkv, skv)
        v_scale = v_scale.transpose(0, 2, 1).reshape(b * hkv, skv)
    out = flash_attention_2d(qk, kk, vk, causal=causal, window=window,
                             kv_len=kv_len, scale=scale, kv_group=g,
                             block_q=block_q, block_kv=block_kv,
                             q_offset=q_offset, k_scale=k_scale,
                             v_scale=v_scale, interpret=interpret)
    out = out.reshape(b, hkv, g, sq, dp).transpose(0, 3, 1, 2, 4) \
        .reshape(b, sq, hq, dp)
    return out[..., :d]
