"""Flash attention Pallas kernel — attention as a streaming dataflow.

Grid (batch*kv_heads*group, q_blocks, kv_blocks); the kv dimension is the
sequential inner loop carrying (m, l, acc) in VMEM scratch — the online
softmax IS the paper's streaming pattern: score tiles are produced, consumed,
and discarded without ever visiting HBM.  Causal masking skips fully-masked
kv blocks with ``pl.when`` (no MXU work issued).

Supports GQA (q heads grouped over kv heads), causal and sliding-window
masks.  Head dim padded to the 128-lane width by the wrapper in ops.py.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .common import interpret_default, pick_block

# Autotune candidate lattice (tuning/autotune.py): query/KV stream
# tile grid for the measured-latency tuner; lint-pruned pre-compile.
TUNE_SPACE = {"block_q": (128, 256, 512), "block_kv": (128, 256, 512)}

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  n_kv: int, block_q: int, block_kv: int, scale: float,
                  causal: bool, window: int, kv_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = ki * block_kv
    # Block-level skip: a kv block strictly after every query position of
    # this q block contributes nothing under causal masking — no MXU work is
    # issued for it.  This is where flash attention earns its O(S*w) local
    # cost (window lower-bound masking is per-element below).
    run = (k_start <= q_start + block_q - 1) if causal else (ki >= 0)

    @pl.when(run)
    def _body():
        q = q_ref[0]
        k = k_ref[0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale      # [bq, bkv]
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_kv), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_kv), 1)
        mask = k_pos < kv_len
        if causal:
            mask = jnp.logical_and(mask, k_pos <= q_pos)
        if window:
            mask = jnp.logical_and(mask, k_pos > q_pos - window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[0] = l_ref[0] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[0] = acc_ref[0] * corr + jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[0] = m_new

    @pl.when(ki == n_kv - 1)
    def _done():
        l = jnp.maximum(l_ref[0], 1e-30)
        o_ref[0] = (acc_ref[0] / l).astype(o_ref.dtype)


def _flash_kernel_offset(meta_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                         acc_ref, *, n_kv: int, block_q: int, block_kv: int,
                         scale: float, causal: bool, window: int):
    """Offset twin of ``_flash_kernel`` for chunked prefill: query
    positions are ``q_offset + i`` and the valid KV length is dynamic,
    both carried in the scalar-prefetch ``meta_ref = [q_offset, kv_len]``
    — one compiled program serves any chunk index over any cache fill.
    """
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    q_off = meta_ref[0]
    kv_len = meta_ref[1]

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q + q_off          # absolute query positions
    k_start = ki * block_kv
    # Block-level skips mirror the static kernel, but against the DYNAMIC
    # offset/length: kv blocks past the valid cache fill, or strictly
    # after every (absolute) query position of this q block, issue no MXU
    # work.  With a sliding window, blocks wholly before the earliest
    # query's window are dead too.
    run = k_start < kv_len
    if causal:
        run = jnp.logical_and(run, k_start <= q_start + block_q - 1)
    if window:
        run = jnp.logical_and(run, k_start + block_kv > q_start - window)

    @pl.when(run)
    def _body():
        q = q_ref[0]
        k = k_ref[0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale      # [bq, bkv]
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_kv), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_kv), 1)
        mask = k_pos < kv_len
        if causal:
            mask = jnp.logical_and(mask, k_pos <= q_pos)
        if window:
            mask = jnp.logical_and(mask, k_pos > q_pos - window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[0] = l_ref[0] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[0] = acc_ref[0] * corr + jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[0] = m_new

    @pl.when(ki == n_kv - 1)
    def _done():
        l = jnp.maximum(l_ref[0], 1e-30)
        o_ref[0] = (acc_ref[0] / l).astype(o_ref.dtype)


def _flash_kernel_offset_q(meta_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref,
                           o_ref, m_ref, l_ref, acc_ref, *, n_kv: int,
                           block_q: int, block_kv: int, scale: float,
                           causal: bool, window: int):
    """Quantized twin of ``_flash_kernel_offset`` (DESIGN.md §14): K/V
    blocks are int8/fp8 codes dequantized in-register against per-POSITION
    f32 scales (``[Hkv_, Skv]`` operands blocked alongside K/V — each KV
    position inherits its page's per-(page, head) scale, expanded by the
    gather wrapper).  Math stays f32; masking/skips are unchanged."""
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    q_off = meta_ref[0]
    kv_len = meta_ref[1]

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q + q_off          # absolute query positions
    k_start = ki * block_kv
    run = k_start < kv_len
    if causal:
        run = jnp.logical_and(run, k_start <= q_start + block_q - 1)
    if window:
        run = jnp.logical_and(run, k_start + block_kv > q_start - window)

    @pl.when(run)
    def _body():
        q = q_ref[0]
        k = k_ref[0].astype(jnp.float32) * ks_ref[0][:, None]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale      # [bq, bkv]
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_kv), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_kv), 1)
        mask = k_pos < kv_len
        if causal:
            mask = jnp.logical_and(mask, k_pos <= q_pos)
        if window:
            mask = jnp.logical_and(mask, k_pos > q_pos - window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[0] = l_ref[0] * corr + jnp.sum(p, axis=-1, keepdims=True)
        v = v_ref[0].astype(jnp.float32) * vs_ref[0][:, None]
        acc_ref[0] = acc_ref[0] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[0] = m_new

    @pl.when(ki == n_kv - 1)
    def _done():
        l = jnp.maximum(l_ref[0], 1e-30)
        o_ref[0] = (acc_ref[0] / l).astype(o_ref.dtype)


def flash_attention_2d(q: jax.Array, k: jax.Array, v: jax.Array, *,
                       causal: bool = True, window: int = 0,
                       kv_len=None,
                       scale: Optional[float] = None,
                       kv_group: int = 1,
                       block_q: int = 512, block_kv: int = 512,
                       q_offset=None,
                       k_scale: Optional[jax.Array] = None,
                       v_scale: Optional[jax.Array] = None,
                       interpret: Optional[bool] = None) -> jax.Array:
    """Flattened-head core: q [Hq_, Sq, D], k/v [Hkv_, Skv, D] where
    ``Hq_ == Hkv_ * kv_group`` -> [Hq_, Sq, D].

    GQA without K/V materialization: the KV BlockSpec index map sends the
    ``kv_group`` query-head programs sharing a KV head to the SAME K/V
    blocks (itensor view: the head dim is a *reuse* dim of the K/V stream —
    Fig. 5(c) again).

    ``q_offset`` (None = 0, static) shifts query positions for chunked
    prefill: query i masks as absolute position ``q_offset + i`` against
    a KV extent that already holds earlier chunks.  When it is given (an
    int or a traced scalar), it and ``kv_len`` ride in as scalar-prefetch
    operands so ONE compiled program serves every chunk of every prompt;
    ``kv_len`` may then be dynamic too (the valid fill of the cache).

    Quantized K/V (offset path only): pass ``k_scale``/``v_scale``
    [Hkv_, Skv] f32 per-position scales — k/v are then int8/fp8 codes,
    dequantized block-by-block in-register.
    """
    h, sq, d = q.shape
    _, skv, _ = k.shape
    kv_len = kv_len if kv_len is not None else skv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    bq = pick_block(sq, block_q)
    bkv = pick_block(skv, block_kv)
    grid = (h, sq // bq, skv // bkv)
    interpret = interpret_default() if interpret is None else interpret
    g = kv_group
    quant = k_scale is not None
    if quant and q_offset is None:
        raise NotImplementedError(
            "quantized flash attention only supports the offset "
            "(chunked-prefill) path")

    if q_offset is not None:
        meta = jnp.stack([jnp.asarray(q_offset, jnp.int32).reshape(()),
                          jnp.asarray(kv_len, jnp.int32).reshape(())])

        def kv_block(b, i, j, meta):
            # Bound KV traffic by the live prefix: a kv block wholly past
            # the dynamic kv_len (= meta[1]) contributes nothing (its
            # ``run`` predicate is false), so clamp its index to the LAST
            # LIVE block — the pipeline re-fetches an already-resident
            # block instead of DMA'ing dead pages, and ``pl.when``
            # discards the (never-issued) compute.  Chunked prefill reads
            # O(prefix) K/V per chunk instead of O(table extent).
            last_live = jnp.maximum(meta[1] - 1, 0) // bkv
            return (b // g, jnp.minimum(j, last_live), 0)

        def sc_block(b, i, j, meta):
            last_live = jnp.maximum(meta[1] - 1, 0) // bkv
            return (b // g, jnp.minimum(j, last_live))

        in_specs = [
            pl.BlockSpec((1, bq, d), lambda b, i, j, meta: (b, i, 0)),
            pl.BlockSpec((1, bkv, d), kv_block),
            pl.BlockSpec((1, bkv, d), kv_block),
        ]
        operands = (q, k, v)
        if quant:
            in_specs += [pl.BlockSpec((1, bkv), sc_block),
                         pl.BlockSpec((1, bkv), sc_block)]
            operands += (k_scale.astype(jnp.float32),
                         v_scale.astype(jnp.float32))
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,           # [q_offset, kv_len]
            grid=grid,
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, bq, d),
                                   lambda b, i, j, meta: (b, i, 0)),
            scratch_shapes=[
                pltpu.VMEM((1, bq, 1), jnp.float32),
                pltpu.VMEM((1, bq, 1), jnp.float32),
                pltpu.VMEM((1, bq, d), jnp.float32),
            ],
        )
        kernel = _flash_kernel_offset_q if quant else _flash_kernel_offset
        return pl.pallas_call(
            functools.partial(
                kernel, n_kv=grid[2], block_q=bq,
                block_kv=bkv, scale=scale, causal=causal, window=window),
            grid_spec=grid_spec,
            out_shape=jax.ShapeDtypeStruct((h, sq, d), q.dtype),
            interpret=interpret,
        )(meta, *operands)

    return pl.pallas_call(
        functools.partial(
            _flash_kernel, n_kv=grid[2], block_q=bq, block_kv=bkv,
            scale=scale, causal=causal, window=window, kv_len=kv_len),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bkv, d), lambda b, i, j: (b // g, j, 0)),
            pl.BlockSpec((1, bkv, d), lambda b, i, j: (b // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, bq, 1), jnp.float32),
            pltpu.VMEM((1, bq, 1), jnp.float32),
            pltpu.VMEM((1, bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
