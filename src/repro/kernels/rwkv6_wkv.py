"""RWKV6 wkv recurrence Pallas kernel.

Grid (batch*heads, time_chunks); the [N, N] per-head state is carried in
VMEM scratch across the sequential chunk dimension.  Inside a chunk the
recurrence runs as a ``fori_loop`` over timesteps — the time axis is a
stream, each token's (r, k, v, w) is consumed once, and the only persistent
object is the state token (the FPGA analogue keeps it in a BRAM ping-pong).

A matmul-factored intra-chunk form exists (r~ = r * Wcum, k~ = k / Wcum)
but divides by cumulative decays and underflows in bf16 for long chunks; the
sequential form is numerically exact, and the chunk dimension still provides
the coarse-grained pipelining (documented trade-off, EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .common import interpret_default

# Autotune candidate lattice (tuning/autotune.py): WKV chunk lengths.
# The N x N state outer products grow quadratically with the chunk,
# so the grid stays small (the planner also caps at 64).
TUNE_SPACE = {"chunk": (16, 32, 64)}


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, sout_ref,
                state_ref, *, n_chunks: int, chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    u = u_ref[0].astype(jnp.float32)             # [1, N] (key bonus)

    def step(t, state):
        rt = r_ref[0, t].astype(jnp.float32)[None, :]   # [1, N]
        kt = k_ref[0, t].astype(jnp.float32)[None, :]
        vt = v_ref[0, t].astype(jnp.float32)[None, :]
        wt = w_ref[0, t].astype(jnp.float32)[None, :]
        kv = kt.T @ vt                                  # [N, N]
        y = rt @ (state + u.T * kv)                     # [1, N]
        y_ref[0, t] = y[0].astype(y_ref.dtype)
        return state * wt.T + kv

    state_ref[...] = jax.lax.fori_loop(0, chunk, step, state_ref[...])

    @pl.when(ci == n_chunks - 1)
    def _done():
        sout_ref[0] = state_ref[...]


def wkv6_pallas(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
                u: jax.Array, *, chunk: int = 64,
                interpret: Optional[bool] = None,
                ) -> Tuple[jax.Array, jax.Array]:
    """Shapes as layers.wkv6: r/k/v/w [B,S,H,N], u [H,N]
    -> (y [B,S,H,N], state [B,H,N,N])."""
    bsz, s, h, n = r.shape
    q = min(chunk, s)
    assert s % q == 0
    nc = s // q
    bh = bsz * h

    def flat(x):
        return x.transpose(0, 2, 1, 3).reshape(bh, s, n)

    uk = jnp.tile(u.astype(jnp.float32)[None], (bsz, 1, 1)) \
        .reshape(bh, 1, n)
    interpret = interpret_default() if interpret is None else interpret
    y, state = pl.pallas_call(
        functools.partial(_wkv_kernel, n_chunks=nc, chunk=q),
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, q, n), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, q, n), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, q, n), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, q, n), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1, n), lambda i, j: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, q, n), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, n, n), lambda i, j: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, n), r.dtype),
            jax.ShapeDtypeStruct((bh, n, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, n), jnp.float32)],
        interpret=interpret,
    )(flat(r), flat(k), flat(v), flat(w), uk)
    return (y.reshape(bsz, h, s, n).transpose(0, 2, 1, 3),
            state.reshape(bsz, h, n, n))
