"""Shared Pallas kernel utilities.

All kernels in this package target TPU (pl.pallas_call + BlockSpec VMEM
tiling, MXU-aligned block shapes) and are *validated* on CPU with
``interpret=True`` — the kernel body executes in Python against the
``ref.py`` oracles.  ``on_tpu()`` picks the execution mode.
"""

from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

MXU = 128          # systolic array edge: align matmul dims to multiples
LANE = 128         # vreg lanes (last dim)
SUBLANE = 8        # vreg sublanes (2nd-to-last dim, f32)


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def interpret_default() -> bool:
    """Interpret mode everywhere except a real TPU."""
    return not on_tpu()


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def pick_block(extent: int, target: int, align: int = MXU) -> int:
    """Largest aligned block <= target that divides extent; falls back to the
    largest divisor <= target when alignment is impossible (small test
    shapes), mirroring the tiling-space policy in core/tiling.py."""
    cap = min(extent, target)
    best = None
    for b in range(cap, 0, -1):
        if extent % b:
            continue
        if b % align == 0:
            return b
        if best is None:
            best = b
    return best or extent


def vmem_bytes(*shapes_dtypes: Tuple[Tuple[int, ...], jnp.dtype]) -> int:
    total = 0
    for shape, dtype in shapes_dtypes:
        total += math.prod(shape) * jnp.dtype(dtype).itemsize
    return total
