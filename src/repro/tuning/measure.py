"""Measurement harness — wall-clock candidate timing with analytic fallback.

One candidate = one fused ``KernelChoice`` (implementation + block
targets) at one op-shape context.  ``measure_candidate`` returns the
latency the tuner should score it with, plus the PROVENANCE of that
number:

  * On a real TPU the candidate's kernel family is compiled and timed in
    isolation — wall-clock median-of-k after a warmup dispatch, through
    a per-family driver that builds representative operands from the
    config's own dimensions (``source="measured"``).
  * In interpret mode (deviceless CI) wall-clock would time the Python
    Pallas interpreter, which says nothing about the MXU — so the
    harness falls back to ``analytic_estimate``, a block-sensitive
    surrogate (``source="analytic"``) that keeps the tuner's argmin
    meaningful and deterministic without a device.

The surrogate models what block sizes actually change on a weight-
streaming dataflow kernel: every token-block restreams the stage's
weights once (so bigger token tiles amortize HBM traffic) and every
grid step pays a fixed pipeline-fill overhead (so bigger feature tiles
mean fewer steps), on top of the compute/memory roofline.  Candidates
the kernel lint rejects never reach this module — legality pruning
happens in ``autotune.py`` BEFORE anything is compiled or scored.

Families without an isolation driver (the paged/verify decode kernels,
whose operands are pool + page-table state, and the MoE/SSM/RWKV
mixers) fall back to the surrogate even on device — a documented
follow-on, not a silent gap: ``measure_candidate`` reports the source.
"""

from __future__ import annotations

import math
import statistics
import time
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..core.itensor import dtype_bytes
from ..core.platforms import Platform
from ..core.stream_plan import KernelChoice, StreamPlan
from ..kernels.common import LANE, interpret_default, pick_block, round_up

# Median-of-k protocol: one warmup dispatch absorbs compilation, then k
# timed dispatches; the median is robust to a stray scheduling hiccup.
WARMUP = 1
REPS = 5

# Pipeline-fill overhead charged per grid step by the surrogate — the
# same fixed stage-fill depth ``Platform.kernel_timing`` models.
_PIPELINE_DEPTH = 32.0


def measure(fn: Callable[[], object], *, reps: int = REPS,
            warmup: int = WARMUP) -> float:
    """Wall-clock median-of-``reps`` of ``fn`` after ``warmup`` calls."""
    for _ in range(max(0, warmup)):
        jax.block_until_ready(fn())
    samples = []
    for _ in range(max(1, reps)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        samples.append(time.perf_counter() - t0)
    return float(statistics.median(samples))


def _eff(extent: int, target: int) -> int:
    """Effective block after the wrapper's ``pick_block`` clip."""
    return pick_block(max(1, int(extent)), max(1, int(target)))


def _cdiv(a: int, b: int) -> int:
    return -(-int(a) // max(1, int(b)))


def analytic_estimate(cfg: ModelConfig, plan: StreamPlan, stage: str,
                      choice: KernelChoice, platform: Platform) -> float:
    """Block-sensitive latency surrogate for one candidate (seconds).

    roofline(flops, streamed bytes) + grid_steps * pipeline fill.  The
    streamed-bytes term restreams the stage's weights once per token
    block — the dominant effect a token tile has on a weight-streaming
    kernel — so the argmin over a candidate lattice is meaningful even
    though the absolute number is a model, not a measurement.
    """
    impl = choice.implementation
    dt = dtype_bytes(cfg.dtype)
    t = max(1, plan.tokens)
    s = max(1, plan.kv_len)
    d = cfg.d_model
    flops = 0.0
    stream = 0.0
    steps = 1

    if impl in ("rmsnorm_matmul", "block_matmul"):
        n = max(1, min(cfg.q_dim, cfg.kv_dim))
        bt = _eff(t, choice.block("block_t", t))
        bn = _eff(n, choice.block("block_n", n))
        restreams = _cdiv(t, bt)
        steps = restreams * _cdiv(n, bn)
        flops = 2.0 * t * d * n
        stream = restreams * d * n * dt + t * d * dt
    elif impl in ("streamed_ffn", "streamed_mlp"):
        f = max(1, cfg.d_ff)
        mats = 3 if impl == "streamed_ffn" else 2
        bt = _eff(t, choice.block("block_t", t))
        bf = _eff(f, choice.block("block_f", f))
        restreams = _cdiv(t, bt)
        steps = restreams * _cdiv(f, bf)
        flops = 2.0 * mats * t * d * f
        stream = restreams * mats * d * f * dt + t * d * dt
    elif impl == "moe_experts":
        f = max(1, cfg.d_ff)
        e = max(1, cfg.num_experts)
        bt = _eff(t, choice.block("block_t", t))
        restreams = _cdiv(t, bt)
        steps = restreams * e
        flops = 2.0 * 3 * t * d * f
        stream = restreams * 3 * d * f * e * dt + t * d * dt
    elif impl == "flash_attention":
        dp = round_up(max(1, cfg.head_dim_), LANE)
        h = max(1, cfg.num_heads)
        bq = _eff(t, choice.block("block_q", t))
        bkv = _eff(s, choice.block("block_kv", s))
        qb = _cdiv(t, bq)
        steps = h * qb * _cdiv(s, bkv)
        flops = 4.0 * h * t * s * dp
        stream = qb * 2.0 * h * s * dp * dt + h * t * dp * dt
    elif impl in ("paged_attention", "verify_attention"):
        dp = round_up(max(1, cfg.head_dim_), LANE)
        hkv = max(1, cfg.num_kv_heads)
        ps = max(1, choice.block("page_size", 16))
        steps = hkv * _cdiv(s, ps)
        flops = 4.0 * max(1, cfg.num_heads) * s * dp
        stream = 2.0 * hkv * s * dp * dt
    elif impl in ("mamba2_scan", "rwkv6_wkv"):
        # Chunked recurrences: within-chunk work is quadratic in the
        # chunk length while the sequential state carry costs one
        # pipeline fill per chunk — the lattice has a real interior
        # tradeoff, unlike the monotone matmul tiles.
        width = max(1, cfg.d_inner if impl == "mamba2_scan" else d)
        q = _eff(t, choice.block("chunk", t))
        steps = _cdiv(t, q)
        flops = 4.0 * t * q * width
        stream = 2.0 * t * width * dt
    elif impl == "streamed_xent":
        v = max(1, cfg.vocab_size)
        bt = _eff(t, choice.block("block_t", t))
        bv = _eff(v, choice.block("block_v", v))
        restreams = _cdiv(t, bt)
        steps = restreams * _cdiv(v, bv)
        flops = 2.0 * t * d * v
        stream = restreams * d * v * dt + t * d * dt
    else:
        # Unknown family: a flat (block-insensitive) floor — the tuner
        # keeps the original choice on ties.
        flops = 2.0 * t * d * d
        stream = t * d * dt

    roofline = max(flops / platform.peak_flops, stream / platform.hbm_bw)
    return roofline + steps * (_PIPELINE_DEPTH / platform.freq_hz)


# --------------------------------------------------------------------- #
# Isolation drivers: build representative operands from the config's own
# dimensions and dispatch the candidate's kernel family with its blocks.
# --------------------------------------------------------------------- #

def _np_dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, dtype=jnp.float32).astype(dtype)


def _driver(cfg: ModelConfig, plan: StreamPlan, stage: str,
            choice: KernelChoice) -> Optional[Callable[[], object]]:
    """A zero-arg jitted dispatch of this candidate, or None when the
    family has no isolation driver (caller falls back to the surrogate)."""
    impl = choice.implementation
    dtype = _np_dtype(cfg)
    t = max(1, plan.tokens)
    s = max(1, plan.kv_len)
    d = cfg.d_model
    k0, k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 4)

    if impl in ("rmsnorm_matmul", "block_matmul"):
        n = max(1, min(cfg.q_dim, cfg.kv_dim))
        x = _rand(k0, (t, d), dtype)
        w = _rand(k1, (d, n), dtype)
        if impl == "rmsnorm_matmul":
            from ..kernels.rmsnorm_matmul import rmsnorm_matmul
            scale = jnp.ones((d,), dtype)
            bt, bn = choice.block("block_t", 256), choice.block("block_n", 512)
            return jax.jit(lambda: rmsnorm_matmul(
                x, scale, w, block_t=bt, block_n=bn))
        from ..kernels.block_matmul import block_matmul
        bm, bn = choice.block("block_t", 256), choice.block("block_n", 256)
        return jax.jit(lambda: block_matmul(x, w, block_m=bm, block_n=bn))

    if impl == "flash_attention":
        from ..kernels.flash_attention import flash_attention_2d
        hq = max(1, cfg.num_heads)
        hkv = max(1, cfg.num_kv_heads)
        dp = max(1, cfg.head_dim_)
        q = _rand(k0, (hq, t, dp), dtype)
        kk = _rand(k1, (hkv, s, dp), dtype)
        v = _rand(k2, (hkv, s, dp), dtype)
        bq, bkv = choice.block("block_q", 512), choice.block("block_kv", 512)
        return jax.jit(lambda: flash_attention_2d(
            q, kk, v, causal=True, kv_group=hq // hkv,
            block_q=bq, block_kv=bkv))

    if impl in ("streamed_ffn", "streamed_mlp"):
        f = max(1, cfg.d_ff)
        x = _rand(k0, (t, d), dtype)
        wu = _rand(k1, (d, f), dtype)
        wd = _rand(k2, (f, d), dtype)
        bt, bf = choice.block("block_t", 256), choice.block("block_f", 512)
        if impl == "streamed_ffn":
            from ..kernels.streamed_ffn import streamed_ffn
            wg = _rand(k3, (d, f), dtype)
            return jax.jit(lambda: streamed_ffn(
                x, wg, wu, wd, block_t=bt, block_f=bf))
        from ..kernels.streamed_ffn import streamed_mlp
        return jax.jit(lambda: streamed_mlp(
            x, wu, wd, block_t=bt, block_f=bf))

    if impl == "streamed_xent":
        from ..kernels.streamed_xent import streamed_xent_loss
        v = max(1, cfg.vocab_size)
        hid = _rand(k0, (t, d), dtype)
        head = _rand(k1, (d, v), dtype)
        labels = jax.random.randint(k2, (t,), 0, v)
        bt, bv = choice.block("block_t", 256), choice.block("block_v", 2048)
        return jax.jit(lambda: streamed_xent_loss(
            hid, head, labels, vocab_size=v, block_t=bt, block_v=bv))

    return None     # paged/verify/moe/ssm/rwkv: surrogate-only for now


def measure_candidate(cfg: ModelConfig, plan: StreamPlan, kind: str,
                      stage: str, choice: KernelChoice, *,
                      platform: Platform, force: bool = False,
                      reps: int = REPS, warmup: int = WARMUP
                      ) -> Tuple[float, str]:
    """Latency for one lint-legal candidate: ``(seconds, source)``.

    Interpret mode (no TPU) falls back to the analytic surrogate unless
    ``force=True`` — forcing in interpret mode times the Python Pallas
    interpreter, which is only useful to exercise the wall-clock path in
    tests.  A driver failure (OOM, unsupported shape) also degrades to
    the surrogate rather than killing the tuning pass.
    """
    if interpret_default() and not force:
        return analytic_estimate(cfg, plan, stage, choice, platform), \
            "analytic"
    fn = _driver(cfg, plan, stage, choice)
    if fn is None:
        return analytic_estimate(cfg, plan, stage, choice, platform), \
            "analytic"
    try:
        return measure(fn, reps=reps, warmup=warmup), "measured"
    except Exception:
        return analytic_estimate(cfg, plan, stage, choice, platform), \
            "analytic"
