"""Persistent measured-latency table — the autotuner's build-once cache.

One JSON file holds every latency the tuner has ever established for one
backend: entries are keyed by the full candidate identity — kernel
implementation, op shape context, dtype, QuantMode, mesh axes, candidate
blocks — and the FILE is stamped with a schema version plus a backend
fingerprint (platform + interpret/compiled mode), so a table measured on
one machine is never silently trusted on another.

Contract (DESIGN.md §16):

  * **build-once / reuse**: the first engine start measures (or, without
    a device, analytically scores) every lint-legal candidate and writes
    the table; every later start resolves its plan from the file with
    zero measurement dispatches.
  * **atomic writes**: ``save`` writes a temp file in the same directory
    and ``os.replace``s it over the target — a concurrent reader (or a
    crash mid-write) sees either the old table or the new one, never a
    torn file.
  * **graceful fallback**: a missing, corrupt, schema-mismatched, or
    wrong-backend file degrades to an EMPTY table plus a warning
    ``Diagnostic`` (pass ``tuning``) — the tuner then scores candidates
    analytically; it never raises out of the serving path.
  * **frozen mode**: ``frozen=True`` forbids fills and saves — the
    reproducibility mode: a frozen table must yield bit-identical plans
    on every resolution.
"""

from __future__ import annotations

import json
import os
import tempfile
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Tuple

from ..analysis.diagnostics import Diagnostic

SCHEMA_VERSION = 1


def backend_fingerprint() -> str:
    """Identity of the machine the measurements describe: the JAX backend
    plus whether Pallas kernels compile or interpret — an interpret-mode
    (analytic-source) table must never be trusted as TPU wall-clock."""
    import jax

    from ..kernels.common import interpret_default
    backend = jax.default_backend()
    mode = "interpret" if interpret_default() else "compiled"
    return f"{backend}:{mode}"


@dataclass(frozen=True)
class TuneEntry:
    """One cached candidate latency."""
    latency_s: float
    source: str = "analytic"    # "measured" | "analytic"
    samples: int = 1


def make_key(kernel: str, *, shape: Iterable[Tuple[str, int]],
             dtype: str, quant: str,
             mesh_axes: Iterable[Tuple[str, int]],
             blocks: Iterable[Tuple[str, int]]) -> str:
    """Canonical entry key.  Every field that changes the measured kernel
    program is part of the key; field ORDER inside each group is sorted
    so logically-equal candidates collide."""
    def fmt(pairs) -> str:
        return ",".join(f"{k}={int(v)}" for k, v in sorted(pairs))

    return (f"{kernel}|shape[{fmt(shape)}]|dtype={dtype}|quant={quant}"
            f"|mesh[{fmt(mesh_axes)}]|blocks[{fmt(blocks)}]")


@dataclass
class TuneTable:
    """In-memory view of one on-disk measured-latency table."""

    path: Optional[str] = None
    backend: str = field(default_factory=backend_fingerprint)
    entries: Dict[str, TuneEntry] = field(default_factory=dict)
    frozen: bool = False
    # Load-time problems (corrupt file, version/backend mismatch) — the
    # tuner forwards these as plan diagnostics so the fallback is visible.
    diagnostics: List[Diagnostic] = field(default_factory=list)
    hits: int = 0
    misses: int = 0
    dirty: bool = False

    # ----------------------------------------------------------- access
    def get(self, key: str) -> Optional[TuneEntry]:
        e = self.entries.get(key)
        if e is None:
            self.misses += 1
        else:
            self.hits += 1
        return e

    def put(self, key: str, entry: TuneEntry) -> None:
        if self.frozen:
            raise RuntimeError("frozen TuneTable refuses writes "
                               "(reproducibility mode)")
        self.entries[key] = entry
        self.dirty = True

    def __len__(self) -> int:
        return len(self.entries)

    # ------------------------------------------------------ persistence
    @classmethod
    def load(cls, path: str, *, frozen: bool = False) -> "TuneTable":
        """Read a table file; any defect degrades to an empty table with
        a warning diagnostic instead of raising (the serving path must
        never die on a stale cache)."""
        table = cls(path=path, frozen=frozen)
        if not os.path.exists(path):
            return table
        try:
            with open(path) as fh:
                raw = json.load(fh)
        except (json.JSONDecodeError, OSError, UnicodeDecodeError) as e:
            table.diagnostics.append(Diagnostic(
                "warning", "tuning", "table", "table-corrupt",
                f"tune table {path!r} is unreadable ({e}); falling back "
                "to the analytic cost model",
                "delete the file (it will be rebuilt on the next "
                "autotuned start)"))
            return table
        if not isinstance(raw, dict) or raw.get("version") != SCHEMA_VERSION:
            table.diagnostics.append(Diagnostic(
                "warning", "tuning", "table", "table-version",
                f"tune table {path!r} has schema version "
                f"{raw.get('version') if isinstance(raw, dict) else '?'} "
                f"(expected {SCHEMA_VERSION}); falling back to the "
                "analytic cost model",
                "delete the file or re-tune to regenerate it"))
            return table
        if raw.get("backend") != table.backend:
            table.diagnostics.append(Diagnostic(
                "warning", "tuning", "table", "table-backend",
                f"tune table {path!r} was measured on backend "
                f"{raw.get('backend')!r} but this process runs "
                f"{table.backend!r}; its latencies do not transfer",
                "re-tune on this backend (the file will be replaced)"))
            return table
        try:
            for key, e in raw.get("entries", {}).items():
                table.entries[str(key)] = TuneEntry(
                    latency_s=float(e["latency_s"]),
                    source=str(e["source"]),
                    samples=int(e.get("samples", 1)))
        except (KeyError, TypeError, ValueError) as e:
            table.entries.clear()
            table.diagnostics.append(Diagnostic(
                "warning", "tuning", "table", "table-corrupt",
                f"tune table {path!r} carries malformed entries ({e}); "
                "falling back to the analytic cost model",
                "delete the file and re-tune"))
        return table

    def save(self, path: Optional[str] = None) -> str:
        """Atomic write: temp file in the destination directory, then
        ``os.replace`` — concurrent writers last-write-win, and a reader
        never observes a torn file."""
        if self.frozen:
            raise RuntimeError("frozen TuneTable refuses saves")
        path = path or self.path
        if path is None:
            raise ValueError("TuneTable has no path to save to")
        payload = {
            "version": SCHEMA_VERSION,
            "backend": self.backend,
            "entries": {k: {"latency_s": e.latency_s, "source": e.source,
                            "samples": e.samples}
                        for k, e in sorted(self.entries.items())},
        }
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(prefix=".tune-", suffix=".json", dir=d)
        try:
            with os.fdopen(fd, "w") as fh:
                json.dump(payload, fh, indent=1, sort_keys=True)
            os.replace(tmp, path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
        self.path = path
        self.dirty = False
        return path
