"""Autotune round-trip check: ``python -m repro.tuning --arch gpt2``.

The executable form of DESIGN.md §16's build-once/reuse contract, run by
the CI ``tune`` job (deviceless: candidates are scored by the analytic
surrogate, which exercises every code path except the wall-clock timer):

  1. First engine start with ``autotune=<table path>`` must tune — only
     lint-legal candidates are scored — and persist the table to disk.
  2. Second engine start against the same path must perform ZERO
     measurement dispatches (every candidate served from the table) and
     resolve a bit-identical StreamPlan (frozen-dataclass equality).
  3. Both engines must greedy-decode identical tokens for identical
     prompts — tuning changes stream granularity, never kernel math.

Exits nonzero on any violation; prints a stats JSON on success.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import tempfile


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="repro.tuning")
    ap.add_argument("--arch", default="gpt2")
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--table", default=None,
                    help="table path (default: a fresh temp dir)")
    args = ap.parse_args(argv)

    import jax
    import numpy as np

    from ..configs import get_config
    from ..core.stream_plan import plan_for
    from ..models import init_params
    from ..serving.engine import ServingEngine

    cfg = dataclasses.replace(get_config(args.arch).reduced(),
                              use_fused_kernels=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompts = [np.arange(1, 9, dtype=np.int32),
               np.arange(3, 17, dtype=np.int32)]

    tmp = None
    if args.table is None:
        tmp = tempfile.TemporaryDirectory(prefix="repro_tune_")
        path = os.path.join(tmp.name, f"{cfg.name}.json")
    else:
        path = args.table

    failures = []

    def check(ok: bool, what: str) -> None:
        if not ok:
            failures.append(what)
            print(f"FAIL  {what}", file=sys.stderr)

    try:
        eng1 = ServingEngine(cfg, params, batch_slots=args.slots,
                             max_len=args.max_len, autotune=path)
        out1 = eng1.generate([p.copy() for p in prompts],
                             max_new_tokens=args.new_tokens)
        check(os.path.exists(path), "first start persisted the table")
        check(eng1.tuner.stats.measured > 0,
              "first start scored candidates not in the table")
        check(eng1.metrics["tune_entries"] > 0,
              "first start filled table entries")
        check(eng1.tuner.stats.candidates
              >= eng1.tuner.stats.pruned + eng1.tuner.stats.measured,
              "candidate accounting (considered >= pruned + scored)")

        # Fresh process stand-in: drop the plan cache so the second
        # engine re-resolves everything through its own (disk) table.
        plan_for.cache_clear()
        measured_before = eng1.tuner.stats.measured

        eng2 = ServingEngine(cfg, params, batch_slots=args.slots,
                             max_len=args.max_len, autotune=path)
        out2 = eng2.generate([p.copy() for p in prompts],
                             max_new_tokens=args.new_tokens)
        check(eng2.tuner.stats.measured == 0,
              "second start performed zero measurements "
              f"(got {eng2.tuner.stats.measured})")
        check(eng2.metrics["tune_hits"] > 0,
              "second start served candidates from the table")
        check(eng1.plan == eng2.plan,
              "second start resolved a bit-identical plan")
        check(eng1.tuner.stats.measured == measured_before,
              "second start did not dirty the first tuner")
        for a, b in zip(out1, out2):
            check(a.out_tokens == b.out_tokens,
                  f"greedy tokens identical for request {a.rid}")

        stats = {
            "arch": cfg.name,
            "table": path,
            "entries": eng2.metrics["tune_entries"],
            "candidates": eng1.tuner.stats.candidates,
            "pruned_by_lint": eng1.tuner.stats.pruned,
            "measured_first_start": measured_before,
            "measured_second_start": eng2.tuner.stats.measured,
            "table_hits_second_start": eng2.tuner.table.hits,
            "plan_source": eng2.metrics["plan_source"],
            "stages_tuned": eng1.tuner.stats.stages,
            "ok": not failures,
        }
        print(json.dumps(stats, indent=2))
    finally:
        if tmp is not None:
            tmp.cleanup()

    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
