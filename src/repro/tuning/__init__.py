"""Measured-latency autotuner (DESIGN.md §16).

Three parts: a measurement harness (``measure``) that compiles and times
lint-legal candidate kernel configs in isolation (analytic surrogate in
interpret mode, so CI stays deviceless); a persistent, versioned,
backend-fingerprinted latency table (``table``) with build-once/reuse
semantics and atomic writes; and the plumbed objective (``autotune``)
that rewrites a StreamPlan's block/page/chunk choices from measurements
and stamps every ``KernelChoice`` with its cost provenance.  Entry
points: ``ServingEngine(autotune=...)``, ``build_stream_plan(tune=...)``,
and the ``python -m repro.tuning`` round-trip check CI runs.
"""

from .autotune import (Tuner, TunerStats, active_tuner,
                       default_table_path, enumerate_candidates,
                       resolve_tuner, use_tuner)
from .measure import analytic_estimate, measure, measure_candidate
from .table import (SCHEMA_VERSION, TuneEntry, TuneTable,
                    backend_fingerprint, make_key)

__all__ = [
    "SCHEMA_VERSION", "TuneEntry", "TuneTable", "Tuner", "TunerStats",
    "active_tuner", "analytic_estimate", "backend_fingerprint",
    "default_table_path", "enumerate_candidates", "make_key", "measure",
    "measure_candidate", "resolve_tuner", "use_tuner",
]
