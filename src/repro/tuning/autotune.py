"""Autotuner: lint-pruned candidate search over per-family block lattices.

The measured-latency replacement for the FPGA-era analytic DSE objective
(DESIGN.md §16).  For every fused stage of a ``StreamPlan`` the tuner

  1. enumerates the kernel family's ``TUNE_SPACE`` lattice (declared next
     to each kernel in ``repro.kernels.*``), always keeping the plan's
     original analytic choice as a candidate and deduplicating points
     that clip to the same effective blocks;
  2. prunes the grid BEFORE anything is compiled or timed by running the
     PR 8 kernel lint (``analysis.kernel_lint.check_kernels``) on a
     stage-swapped copy of the plan — a candidate that draws any error
     OR warning at its own stage (lane floor, VMEM budget, non-dividing
     block) is discarded, so the tuned table can never select a plan the
     static verifier rejects;
  3. scores the survivors through the persistent ``TuneTable`` — a hit
     reuses the stored latency, a miss measures (or, deviceless,
     analytically estimates) the candidate and fills the table — and
     stamps the winning ``KernelChoice`` with its cost provenance.

``verify_attention`` is never tuned independently: it inherits the tuned
``paged_attention`` page size per layer, because both stream the SAME
paged KV pool and a divergent granule would split the pool geometry.

The tuner reaches plan resolution the same way the mesh does: a context
variable.  ``ServingEngine(autotune=...)`` enters ``use_tuner`` around
every plan resolution and dispatch trace, and ``core.stream_plan
.plan_for`` consults ``active_tuner()`` after the cached base build — so
the model entry points (which re-resolve plans at their own token
counts) pick up tuned plans without any signature churn.  ``tune_plan``
memoizes per (config, shape, mesh), and candidate evaluation is
deterministic (sorted lattice order, strict-min ties keep the first
candidate), so a warm table yields bit-identical plans on every start.
"""

from __future__ import annotations

import itertools
import os
from contextlib import contextmanager
from contextvars import ContextVar
from dataclasses import dataclass, replace
from typing import Dict, Iterator, List, Optional, Tuple

from ..configs.base import ModelConfig
from ..core.platforms import PLATFORMS, TPU_V5E, Platform
from ..core.stream_plan import KernelChoice, StreamPlan
from ..kernels.common import pick_block
from ..obs import NULL_RECORDER, TRACK_TUNE, TUNE_MEASURE, TUNE_PRUNE
from .measure import analytic_estimate, measure_candidate
from .table import TuneEntry, TuneTable, make_key

# Environment override for where ``autotune=True`` engines keep their
# tables; one JSON file per arch (keys inside carry quant/mesh/shape).
TUNE_DIR_ENV = "REPRO_TUNE_DIR"
DEFAULT_TUNE_DIR = ".repro_tune"


def default_table_path(cfg: ModelConfig) -> str:
    d = os.environ.get(TUNE_DIR_ENV, DEFAULT_TUNE_DIR)
    return os.path.join(d, f"{cfg.name}.json")


def _tune_spaces() -> Dict[str, Dict[str, Tuple[int, ...]]]:
    """implementation name -> candidate lattice, from the family modules.

    Imported via ``importlib`` submodule paths — the package re-exports
    shadow the module names with the wrapper functions."""
    import importlib

    def space(mod: str) -> Dict[str, Tuple[int, ...]]:
        return importlib.import_module(f"repro.kernels.{mod}").TUNE_SPACE

    ffn = space("streamed_ffn")
    return {
        "rmsnorm_matmul": space("rmsnorm_matmul"),
        "block_matmul": space("block_matmul"),
        "flash_attention": space("flash_attention"),
        "paged_attention": space("paged_attention"),
        # verify_attention inherits decode_attn's tuned page size (shared
        # pool geometry) — see _sync_verify_pages.
        "verify_attention": {},
        "streamed_ffn": ffn,
        "streamed_mlp": ffn,
        "moe_experts": space("moe_experts"),
        "mamba2_scan": space("mamba2_scan"),
        "rwkv6_wkv": space("rwkv6_wkv"),
        "streamed_xent": space("streamed_xent"),
    }


def _platform_for(plan: StreamPlan) -> Platform:
    for p in PLATFORMS.values():
        if p.name == plan.platform:
            return p
    return PLATFORMS.get(str(plan.platform).lower().replace("-", "_"),
                         TPU_V5E)


def _block_extents(cfg: ModelConfig, plan: StreamPlan, stage: str,
                   choice: KernelChoice) -> Dict[str, int]:
    """Extent each tunable block clips against — for candidate dedup."""
    t = max(1, plan.tokens)
    s = max(1, plan.kv_len)
    if stage == "qkv":
        return {"block_t": t, "block_n": min(cfg.q_dim, cfg.kv_dim)}
    if stage == "attention":
        return {"block_q": t, "block_kv": s}
    if stage == "ffn":
        if choice.implementation == "moe_experts":
            return {"block_t": t}
        return {"block_t": t, "block_f": cfg.d_ff}
    if stage == "mixer":
        return {"chunk": t}
    if stage == "lm_head":
        return {"block_t": t, "block_v": cfg.vocab_size}
    return {}       # page_size is a raw streaming granule, no clip


def _signature(cfg: ModelConfig, plan: StreamPlan, stage: str,
               cand: KernelChoice) -> Tuple[Tuple[str, int], ...]:
    """Effective-block identity: two lattice points that clip to the same
    kernel program collapse to one candidate."""
    ext = _block_extents(cfg, plan, stage, cand)
    return tuple(
        (name, pick_block(max(1, ext[name]), max(1, int(val)))
         if name in ext else int(val))
        for name, val in cand.blocks)


def _shape_ctx(cfg: ModelConfig, plan: StreamPlan
               ) -> Tuple[Tuple[str, int], ...]:
    """Op-shape context baked into every table key: all dims a candidate
    kernel's program can depend on."""
    return (("t", max(1, plan.tokens)), ("s", max(1, plan.kv_len)),
            ("d", cfg.d_model), ("n", min(cfg.q_dim, cfg.kv_dim)),
            ("f", cfg.d_ff), ("v", cfg.vocab_size),
            ("h", cfg.num_heads), ("hkv", cfg.num_kv_heads))


def enumerate_candidates(cfg: ModelConfig, plan: StreamPlan, stage: str,
                         choice: KernelChoice) -> List[KernelChoice]:
    """Deduped candidate list for one stage, the original choice first.

    Candidates vary only the block names the family's ``TUNE_SPACE``
    declares; flags (``fuse_norm``, ``w8``) and the sharding claim are
    carried through unchanged — tuning never changes kernel math, only
    stream granularity, which is why tuned greedy tokens stay
    bit-identical.
    """
    space = _tune_spaces().get(choice.implementation, {})
    have = dict(choice.blocks)
    names = sorted(n for n in space if n in have)
    out: List[KernelChoice] = [choice]
    seen = {_signature(cfg, plan, stage, choice)}
    for combo in itertools.product(*(sorted(space[n]) for n in names)):
        override = dict(zip(names, combo))
        blocks = tuple((n, override.get(n, v)) for n, v in choice.blocks)
        cand = replace(choice, blocks=blocks)
        sig = _signature(cfg, plan, stage, cand)
        if sig in seen:
            continue
        seen.add(sig)
        out.append(cand)
    return out


def _sync_verify_pages(plan: StreamPlan) -> StreamPlan:
    """verify_attn inherits decode_attn's (tuned) page size per layer —
    the speculative verify window streams the SAME paged pool."""
    for kind, lp in plan.layers:
        if not (lp.verify_attn.fused and lp.decode_attn.fused):
            continue
        ps = lp.decode_attn.block("page_size", 16)
        if lp.verify_attn.block("page_size") == ps:
            continue
        blocks = tuple((n, ps if n == "page_size" else v)
                       for n, v in lp.verify_attn.blocks)
        plan = plan.with_stage(kind, "verify_attn", replace(
            lp.verify_attn, blocks=blocks, source=lp.decode_attn.source))
    return plan


@dataclass
class TunerStats:
    """Per-tuner counters (the table itself counts hits/misses)."""
    measured: int = 0       # candidate evaluations NOT served from table
    pruned: int = 0         # lattice points rejected by the kernel lint
    candidates: int = 0     # deduped lattice points considered
    stages: int = 0         # fused stages tuned


class Tuner:
    """Stage-level autotuner over one ``TuneTable``.

    ``mode``:
      * ``"hybrid"``   (default) — table hits are reused, misses are
        measured (or analytically estimated, deviceless) and filled in.
      * ``"measured"`` — only table entries are trusted; a candidate the
        table has never seen is skipped, and a stage with no scored
        candidate keeps its analytic choice.
      * ``"analytic"`` — score everything with the surrogate, touch the
        table not at all (A/B baseline).
    """

    def __init__(self, table: Optional[TuneTable] = None, *,
                 mode: str = "hybrid", force_measure: bool = False,
                 autosave: bool = True):
        if mode not in ("hybrid", "measured", "analytic"):
            raise ValueError(f"unknown tuner mode {mode!r} "
                             "(hybrid | measured | analytic)")
        if table is None:
            table = TuneTable()
        elif isinstance(table, str):
            table = TuneTable.load(table)
        self.table = table
        self.mode = mode
        self.force_measure = force_measure
        self.autosave = autosave
        self.stats = TunerStats()
        self._memo: Dict[object, StreamPlan] = {}
        # Telemetry recorder (obs/events.py): measure/prune instants on
        # the "tune" track.  The engine rebinds this to its own recorder
        # when telemetry is enabled.
        self.obs = NULL_RECORDER

    # ------------------------------------------------------------ plans
    def tune_plan(self, cfg: ModelConfig, plan: StreamPlan, *,
                  mesh=None, platform: Optional[Platform] = None
                  ) -> StreamPlan:
        """Tuned copy of ``plan`` (memoized per config + shape + mesh)."""
        key = (cfg, plan.tokens, plan.kv_len, plan.mesh_axes)
        got = self._memo.get(key)
        if got is not None:
            return got
        plat = platform or _platform_for(plan)
        tuned = plan
        sources: List[str] = []
        for kind, stage, choice in list(plan.stage_choices()):
            if not choice.fused or stage == "verify_attn":
                continue
            best = self._tune_stage(cfg, tuned, kind, stage, choice, plat)
            if best is None:
                continue
            tuned = tuned.with_stage(kind, stage, best)
            sources.append(best.source)
            self.stats.stages += 1
        tuned = _sync_verify_pages(tuned)
        # Provenance is about where the NUMBERS came from, not whether the
        # tuner ran: all-surrogate tuning (deviceless CI) stays "analytic";
        # any measured stage makes the plan "hybrid"; all-measured makes it
        # "measured".  Tuned-ness itself is reported via TunerStats.
        if sources and any(s == "measured" for s in sources):
            cost = ("measured" if all(s == "measured" for s in sources)
                    else "hybrid")
            tuned = replace(tuned, cost_source=cost)
        if (self.autosave and self.table.path and self.table.dirty
                and not self.table.frozen):
            self.table.save()
        self._memo[key] = tuned
        return tuned

    # ----------------------------------------------------------- stages
    def _legal(self, cfg: ModelConfig, plan: StreamPlan, kind: str,
               stage: str, cand: KernelChoice,
               platform: Platform) -> bool:
        """PR 8 lint as the pruning oracle: the candidate must draw ZERO
        error/warning diagnostics at its own stage (the registry sweep
        requires clean plans, so a warning is a rejection too)."""
        from ..analysis.kernel_lint import check_kernels
        swapped = plan.with_stage(kind, stage, cand)
        where = f"{kind}.{stage}"
        return not any(d.severity in ("error", "warning")
                       and d.stage == where
                       for d in check_kernels(swapped, cfg, platform))

    def _score(self, cfg: ModelConfig, plan: StreamPlan, kind: str,
               stage: str, cand: KernelChoice, platform: Platform
               ) -> Optional[Tuple[float, str]]:
        if self.mode == "analytic":
            return analytic_estimate(cfg, plan, stage, cand, platform), \
                "analytic"
        key = make_key(cand.implementation, shape=_shape_ctx(cfg, plan),
                       dtype=cfg.dtype, quant=cfg.quant,
                       mesh_axes=plan.mesh_axes, blocks=cand.blocks)
        entry = self.table.get(key)
        if entry is not None:
            return entry.latency_s, entry.source
        if self.mode == "measured":
            return None         # trust the table only: unseen = skipped
        latency, source = measure_candidate(
            cfg, plan, kind, stage, cand, platform=platform,
            force=self.force_measure)
        self.stats.measured += 1
        if self.obs.enabled:
            self.obs.instant(TUNE_MEASURE, track=TRACK_TUNE,
                             impl=cand.implementation, stage=stage,
                             latency_s=latency, source=source)
        if not self.table.frozen:
            self.table.put(key, TuneEntry(latency_s=latency,
                                          source=source))
        return latency, source

    def _tune_stage(self, cfg: ModelConfig, plan: StreamPlan, kind: str,
                    stage: str, choice: KernelChoice,
                    platform: Platform) -> Optional[KernelChoice]:
        cands = enumerate_candidates(cfg, plan, stage, choice)
        self.stats.candidates += len(cands)
        best: Optional[KernelChoice] = None
        best_lat = float("inf")
        best_src = "analytic"
        for i, cand in enumerate(cands):
            # The original analytic choice (i == 0) is never pruned — it
            # is the fallback the plan already committed to.
            if i > 0 and not self._legal(cfg, plan, kind, stage, cand,
                                         platform):
                self.stats.pruned += 1
                if self.obs.enabled:
                    self.obs.instant(TUNE_PRUNE, track=TRACK_TUNE,
                                     impl=cand.implementation, stage=stage)
                continue
            scored = self._score(cfg, plan, kind, stage, cand, platform)
            if scored is None:
                continue
            lat, src = scored
            if lat < best_lat:      # strict: ties keep the earlier point
                best, best_lat, best_src = cand, lat, src
        if best is None:
            return None
        return replace(best, source=best_src)


# --------------------------------------------------------------------- #
# Context plumbing (mirrors distributed.context.use_mesh)
# --------------------------------------------------------------------- #

_ACTIVE_TUNER: ContextVar[Optional[Tuner]] = ContextVar(
    "repro_active_tuner", default=None)


def active_tuner() -> Optional[Tuner]:
    """The tuner the enclosing ``use_tuner`` installed, or None."""
    return _ACTIVE_TUNER.get()


@contextmanager
def use_tuner(tuner: Optional[Tuner]) -> Iterator[Optional[Tuner]]:
    """Install ``tuner`` for plan resolution within the dynamic extent
    (None is a no-op, so callers need not branch)."""
    token = _ACTIVE_TUNER.set(tuner)
    try:
        yield tuner
    finally:
        _ACTIVE_TUNER.reset(token)


def resolve_tuner(spec, cfg: ModelConfig) -> Optional[Tuner]:
    """Engine-facing spec resolution for ``ServingEngine(autotune=...)``:

      * ``None`` / ``False``   -> no tuner
      * ``True``               -> persistent table at the default path
                                  (``$REPRO_TUNE_DIR`` or ``.repro_tune``)
      * ``str``                -> table file (``*.json``) or directory
      * ``TuneTable`` / ``Tuner`` -> used as given
    """
    if spec is None or spec is False:
        return None
    if isinstance(spec, Tuner):
        return spec
    if isinstance(spec, TuneTable):
        return Tuner(spec)
    if spec is True:
        path = default_table_path(cfg)
    elif isinstance(spec, (str, os.PathLike)):
        path = os.fspath(spec)
        if not path.endswith(".json"):
            path = os.path.join(path, f"{cfg.name}.json")
    else:
        raise TypeError(f"autotune= accepts bool, path, TuneTable, or "
                        f"Tuner; got {type(spec).__name__}")
    return Tuner(TuneTable.load(path))
