"""Jitted step functions: train / prefill / decode, with mesh shardings.

``make_*`` builds the jitted function together with its in/out shardings from
the logical-axis trees — the same entry points serve the smoke tests (1
device), the multi-pod dry-run (512 placeholder devices, abstract inputs),
and a real cluster launch.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig, ShapeConfig
from ..models import (abstract_cache, abstract_params, cache_logical_axes,
                      decode_step, forward_train, logical_axes, padded_vocab,
                      prefill)
from .context import use_mesh
from .optimizer import AdamWConfig, OptState, abstract_opt_state, adamw_update
from .sharding import (activation_spec, batch_spec, optimizer_specs,
                       spec_for, tree_specs)

Tree = Any


# --------------------------------------------------------------------- #
# Abstract inputs (the dry-run's ShapeDtypeStruct stand-ins)
# --------------------------------------------------------------------- #

def train_batch_abstract(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    out: Dict[str, Any] = {}
    if cfg.frontend != "none":
        out["embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                             jnp.bfloat16)
    else:
        out["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    out["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    if cfg.rope == "mrope":
        out["positions"] = jax.ShapeDtypeStruct((3, b, s), jnp.int32)
    return out


def decode_inputs_abstract(cfg: ModelConfig, shape: ShapeConfig
                           ) -> Dict[str, Any]:
    b = shape.global_batch
    return {
        "tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32),
        "cache": abstract_cache(cfg, b, shape.seq_len),
        "cache_pos": jax.ShapeDtypeStruct((), jnp.int32),
        "lengths": jax.ShapeDtypeStruct((b,), jnp.int32),
    }


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Every model input for one dry-run cell, as ShapeDtypeStructs."""
    if shape.kind == "decode":
        return decode_inputs_abstract(cfg, shape)
    return train_batch_abstract(cfg, shape)


# --------------------------------------------------------------------- #
# Train step
# --------------------------------------------------------------------- #

def make_train_step(cfg: ModelConfig, mesh: Mesh,
                    opt_cfg: Optional[AdamWConfig] = None,
                    remat: bool = True,
                    pin_activations: object = False):
    """Returns (jitted_fn, params_specs, opt_specs, batch_spec_fn).

    fn(params, opt_state, batch) -> (params, opt_state, metrics)

    ``pin_activations``: False (baseline), True/'all' (pin every block
    boundary batch-sharded), 'embed' (scan entry only), or 'sp'
    (Megatron-style sequence parallelism: residual stream additionally
    sharded over the model axis on the sequence dim).
    """
    opt_cfg = opt_cfg or AdamWConfig()
    ax = logical_axes(cfg)
    ab = abstract_params(cfg)
    p_specs = tree_specs(cfg, ax, ab, mesh)
    o_moment_specs = optimizer_specs(cfg, ax, ab, mesh)
    o_specs = OptState(step=P(), mu=o_moment_specs, nu=o_moment_specs)
    mode = ("all" if pin_activations is True else pin_activations) or None
    act = None
    scope = "all"
    if mode:
        spec = activation_spec(mesh)
        if mode == "sp":
            spec = P(spec[0], "model", None)     # sequence-parallel stream
        act = NamedSharding(mesh, spec)
        scope = "embed" if mode == "embed" else "all"

    p_shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs,
                               is_leaf=lambda x: isinstance(x, P))

    def step_fn(params, opt_state, batch):
        def loss_fn(p):
            # use_mesh (trace-time): with ``use_fused_kernels`` the plan
            # resolves mesh-aware and the fused wrappers dispatch their
            # Pallas kernels under shard_map instead of ignoring the mesh.
            with use_mesh(mesh):
                return forward_train(p, cfg, batch, remat=remat,
                                     act_sharding=act, act_pin_scope=scope)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        # Keep gradients in the parameter layout before the update.
        grads = jax.lax.with_sharding_constraint(grads, p_shardings)
        new_params, new_opt, metrics = adamw_update(opt_cfg, params, grads,
                                                    opt_state)
        new_params = jax.lax.with_sharding_constraint(new_params, p_shardings)
        metrics = {"loss": loss, **metrics}
        return new_params, new_opt, metrics

    def b_specs(batch_abstract):
        return batch_spec(cfg, batch_abstract, mesh)

    jitted = jax.jit(
        step_fn,
        donate_argnums=(0, 1),
    )
    return jitted, p_specs, o_specs, b_specs


# --------------------------------------------------------------------- #
# Prefill / decode steps
# --------------------------------------------------------------------- #

def make_prefill_step(cfg: ModelConfig, mesh: Mesh):
    ax = logical_axes(cfg)
    ab = abstract_params(cfg)
    p_specs = tree_specs(cfg, ax, ab, mesh)

    def fn(params, batch):
        # Routed through the fused path: under the mesh context the plan
        # resolves mesh-aware, so one code path serves 1-device smoke
        # tests, the forced host-device mesh, and a real cluster.
        with use_mesh(mesh):
            return prefill(params, cfg, batch)

    def b_specs(batch_abstract):
        return batch_spec(cfg, batch_abstract, mesh)

    return jax.jit(fn), p_specs, b_specs


def make_decode_step(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig):
    """serve_step: one new token against the KV/state caches."""
    ax = logical_axes(cfg)
    ab = abstract_params(cfg)
    p_specs = tree_specs(cfg, ax, ab, mesh)
    c_ax = cache_logical_axes(cfg, shape.global_batch, shape.seq_len)
    c_ab = abstract_cache(cfg, shape.global_batch, shape.seq_len)
    c_specs = tree_specs(cfg, c_ax, c_ab, mesh)
    tok_spec = spec_for(cfg, ("batch", None), (shape.global_batch, 1), mesh)
    len_spec = spec_for(cfg, ("batch",), (shape.global_batch,), mesh)

    def fn(params, tokens, cache, cache_pos, lengths):
        with use_mesh(mesh):
            nt, logits, new_cache = decode_step(params, cfg, tokens, cache,
                                                cache_pos, lengths)
        return nt, new_cache

    jitted = jax.jit(fn, donate_argnums=(2,))
    in_specs = {"params": p_specs, "tokens": tok_spec, "cache": c_specs,
                "cache_pos": P(), "lengths": len_spec}
    return jitted, in_specs


# --------------------------------------------------------------------- #
# Lowering helpers used by the dry-run
# --------------------------------------------------------------------- #

def lower_cell(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
               remat: bool = True, perf: object = False):
    """Lower the right step function for one (arch x shape) cell with fully
    abstract inputs.  Returns the ``jax.stages.Lowered``.

    ``perf``: False = paper-faithful baseline; True/'all'/'embed'/'sp'
    applies the §Perf optimization set (pin mode per make_train_step) plus
    chunked wkv6 and per-chunk attention remat.
    """
    if perf:
        from dataclasses import replace
        cfg = replace(cfg, rwkv_chunk=16, remat_attn_chunk=True,
                      kv_cache_layout="bhsd")

    def shard(t, s):
        return jax.tree.map(
            lambda a, sp: jax.ShapeDtypeStruct(
                a.shape, a.dtype, sharding=NamedSharding(mesh, sp)),
            t, s, is_leaf=lambda x: isinstance(x, P))

    if shape.kind == "train":
        fn, p_specs, o_specs, b_spec_fn = make_train_step(
            cfg, mesh, remat=remat, pin_activations=perf)
        ab = abstract_params(cfg)
        batch = train_batch_abstract(cfg, shape)
        bspecs = b_spec_fn(batch)
        params = shard(ab, p_specs)
        opt = shard(abstract_opt_state(ab), o_specs)
        batch = shard(batch, bspecs)
        return fn.lower(params, opt, batch)
    if shape.kind == "prefill":
        fn, p_specs, b_spec_fn = make_prefill_step(cfg, mesh)
        ab = abstract_params(cfg)
        batch = train_batch_abstract(cfg, shape)
        batch.pop("labels", None)
        bspecs = b_spec_fn(batch)
        return fn.lower(shard(ab, p_specs), shard(batch, bspecs))
    # decode
    fn, in_specs = make_decode_step(cfg, mesh, shape)
    inputs = decode_inputs_abstract(cfg, shape)
    return fn.lower(shard(abstract_params(cfg), in_specs["params"]),
                    shard(inputs["tokens"], in_specs["tokens"]),
                    shard(inputs["cache"], in_specs["cache"]),
                    jax.ShapeDtypeStruct((), jnp.int32),
                    shard(inputs["lengths"], in_specs["lengths"]))
