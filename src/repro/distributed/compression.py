"""Gradient compression: int8 quantized all-reduce with error feedback.

A distributed-optimization trick for slow cross-pod links: gradients are
quantized to int8 with a per-tensor scale before the data-parallel
all-reduce (4x fewer DCI bytes than f32), and the quantization error is
carried in an error-feedback buffer added to the next step's gradient —
convergence-neutral in expectation (Karimireddy et al., 2019).

Implemented with ``shard_map`` so the quantize -> psum -> dequantize
pipeline is explicit (a jit-level all-reduce cannot be intercepted).  Used
by the trainer when ``compress_grads=True``; exact path remains default.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from .context import shard_map

Tree = Any


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum_mean(x: jax.Array, axis_name: str
                         ) -> Tuple[jax.Array, jax.Array]:
    """Quantized mean-reduce over ``axis_name``; returns (mean, error)."""
    q, scale = quantize_int8(x)
    deq = dequantize_int8(q, scale)
    err = x - deq                                   # stays local (feedback)
    # int8 payload all-reduce: sum int32 accumulations of the int8 grid.
    summed = jax.lax.psum(q.astype(jnp.int32), axis_name)
    scale_sum = jax.lax.psum(scale, axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    # Per-shard scales differ; use the mean scale (standard approximation).
    mean = summed.astype(jnp.float32) * (scale_sum / n) / n
    return mean, err


def make_compressed_allreduce(mesh: Mesh, axis: str = "data"):
    """Returns f(grads_tree, error_tree) -> (mean_grads, new_error).

    Gradients must be replicated over every mesh axis except ``axis`` and
    sharded (or replicated) identically on entry and exit; each leaf is
    reduced independently.
    """
    other = tuple(a for a in mesh.axis_names if a != axis)

    def one(g, e):
        def body(g_local, e_local):
            mean, err = compressed_psum_mean(g_local + e_local, axis)
            return mean, err
        return shard_map(
            body, mesh=mesh,
            in_specs=(P(*[None] * g.ndim), P(*[None] * g.ndim)),
            out_specs=(P(*[None] * g.ndim), P(*[None] * g.ndim)),
        )(g, e)

    def reduce_tree(grads: Tree, errors: Optional[Tree] = None
                    ) -> Tuple[Tree, Tree]:
        if errors is None:
            errors = jax.tree.map(jnp.zeros_like, grads)
        pairs = jax.tree.map(one, grads, errors)
        means = jax.tree.map(lambda p: p[0], pairs,
                             is_leaf=lambda x: isinstance(x, tuple))
        errs = jax.tree.map(lambda p: p[1], pairs,
                            is_leaf=lambda x: isinstance(x, tuple))
        return means, errs

    return reduce_tree
