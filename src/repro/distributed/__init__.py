"""Distribution layer: sharding rules, optimizer, step functions."""

from .optimizer import (AdamWConfig, OptState, abstract_opt_state,
                        adamw_update, init_opt_state, lr_schedule)
from .sharding import (activation_spec, batch_spec, optimizer_specs,
                       spec_for, tree_shardings, tree_specs)
from .steps import (decode_inputs_abstract, input_specs, lower_cell,
                    make_decode_step, make_prefill_step, make_train_step,
                    train_batch_abstract)

__all__ = [
    "AdamWConfig", "OptState", "abstract_opt_state", "adamw_update",
    "init_opt_state", "lr_schedule", "activation_spec", "batch_spec",
    "optimizer_specs", "spec_for", "tree_shardings", "tree_specs",
    "decode_inputs_abstract", "input_specs", "lower_cell",
    "make_decode_step", "make_prefill_step", "make_train_step",
    "train_batch_abstract",
]
