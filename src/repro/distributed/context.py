"""Active-mesh context + version-tolerant ``shard_map``.

The mesh-aware StreamPlan (core/stream_plan.py) decides *which* mesh axes
each fused kernel's block grid shards over; the fused wrappers in
``models/layers.py`` need the actual ``Mesh`` object at trace time to
build the ``shard_map``.  Threading a mesh argument through every model
entry point would churn the whole call graph, so the mesh rides in a
context variable instead: the serving engine and the jitted step builders
enter ``use_mesh(mesh)`` around plan resolution and dispatch tracing, and
``current_mesh()`` is what the wrappers (and ``resolve_plan``) read.

This module deliberately imports nothing from ``repro`` so it can be
imported lazily from ``models/layers.py`` and ``core/stream_plan.py``
without creating an import cycle through ``distributed/__init__``.
"""

from __future__ import annotations

from contextlib import contextmanager
from contextvars import ContextVar
from typing import Iterator, Optional

try:                                    # jax >= 0.6 top-level export
    from jax import shard_map as _shard_map
except ImportError:                     # older jax: experimental module
    from jax.experimental.shard_map import shard_map as _shard_map
from jax.sharding import Mesh

_ACTIVE_MESH: ContextVar[Optional[Mesh]] = ContextVar(
    "repro_active_mesh", default=None)


def current_mesh() -> Optional[Mesh]:
    """The mesh the enclosing ``use_mesh`` installed, or None (1-device)."""
    return _ACTIVE_MESH.get()


@contextmanager
def use_mesh(mesh: Optional[Mesh]) -> Iterator[Optional[Mesh]]:
    """Install ``mesh`` as the active mesh for plan resolution and fused
    dispatch within the dynamic extent (None is a no-op single-device
    context, so callers need not branch)."""
    token = _ACTIVE_MESH.set(mesh)
    try:
        yield mesh
    finally:
        _ACTIVE_MESH.reset(token)


def shard_map(body, *, mesh, in_specs, out_specs):
    """Version-tolerant shard_map: replication checking is named
    ``check_vma`` on new jax and ``check_rep`` before the rename."""
    try:
        return _shard_map(body, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=False)
    except TypeError:
        return _shard_map(body, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=False)
