"""Logical-axis sharding rules with divisibility fallbacks.

Parameters/caches/activations carry *logical* axis names (``params.py``);
this module maps them to mesh ``PartitionSpec``s.  Rules are ordered by
priority; each rule claims a mesh axis for the first matching logical dim
whose extent passes the **quantum-aware divisibility check** (e.g. ``q_dim``
shards over 'model' only when the *head count* divides the axis, so heads are
never split mid-head).  Unclaimed dims replicate.

Notable fallback chains (DESIGN.md §6):
  * ``kv_heads`` -> 'model' when divisible, else the KV-cache ``kv_seq`` dim
    takes the model axis (context-parallel decode);
  * ``experts`` -> 'model' (EP) when the expert count divides, else the
    per-expert ``d_ff`` dim shards (TP within experts) — granite-moe-3b's 40
    experts on a 16-way axis take this path;
  * ``batch`` -> ('pod','data') when divisible, else ('data',), else
    replicated (long_500k's batch=1).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig

Tree = Any


@dataclass(frozen=True)
class Rule:
    name: str
    candidates: Tuple[Tuple[str, ...], ...]   # mesh-axis groups, in order
    quantum: str = ""                          # cfg attr giving the quantum


def _quantum(cfg: ModelConfig, rule: Rule) -> int:
    if not rule.quantum:
        return 1
    q = getattr(cfg, rule.quantum)
    return int(q) if q else 1


RULES: Tuple[Rule, ...] = (
    Rule("batch", (("pod", "data"), ("data",))),
    Rule("kv_batch", (("pod", "data"), ("data",))),
    Rule("vocab", (("model",),)),
    Rule("embed_dim", (("model",),)),
    Rule("q_dim", (("model",),), "head_dim_"),
    Rule("kv_dim", (("model",),), "head_dim_"),
    Rule("experts", (("model",),)),
    Rule("d_ff", (("model",),)),
    Rule("d_inner", (("model",),), "ssm_head_dim"),
    Rule("ssm_heads", (("model",),)),
    Rule("rwkv_dim", (("model",),), "rwkv_head_dim"),
    Rule("rwkv_heads", (("model",),)),
    Rule("kv_heads", (("model",),)),
    Rule("kv_seq", (("model",),)),            # context-parallel fallback
    Rule("opt_shard", (("data",),)),          # ZeRO-1 optimizer sharding
)


def spec_for(cfg: ModelConfig, axes: Sequence[Optional[str]],
             shape: Sequence[int], mesh: Mesh) -> P:
    """Resolve one tensor's logical axes to a PartitionSpec."""
    parts: List[Optional[Any]] = [None] * len(axes)
    used: set = set()
    mesh_axes = set(mesh.axis_names)
    for rule in RULES:
        for i, name in enumerate(axes):
            if name != rule.name or parts[i] is not None:
                continue
            quantum = _quantum(cfg, rule)
            if shape[i] % quantum != 0:
                continue
            units = shape[i] // quantum
            for cand in rule.candidates:
                # Every axis of the candidate group must exist in this mesh
                # (('pod','data') falls through to ('data',) on single-pod).
                if not cand or any(a not in mesh_axes for a in cand):
                    continue
                cand_avail = cand
                if any(a in used for a in cand_avail):
                    continue
                size = math.prod(mesh.shape[a] for a in cand_avail)
                if units % size != 0:
                    continue
                parts[i] = (cand_avail if len(cand_avail) > 1
                            else cand_avail[0])
                used.update(cand_avail)
                break
            if parts[i] is not None:
                break   # rule consumed; move to next rule
    return P(*parts)


def tree_specs(cfg: ModelConfig, axes_tree: Tree, abstract_tree: Tree,
               mesh: Mesh) -> Tree:
    """PartitionSpec tree from (logical axes tree, ShapeDtypeStruct tree)."""
    return jax.tree.map(
        lambda axes, ab: spec_for(cfg, axes, ab.shape, mesh),
        axes_tree, abstract_tree,
        is_leaf=lambda x: isinstance(x, tuple) and len(x) > 0 and all(
            isinstance(e, (str, type(None))) for e in x))


def tree_shardings(cfg: ModelConfig, axes_tree: Tree, abstract_tree: Tree,
                   mesh: Mesh) -> Tree:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        tree_specs(cfg, axes_tree, abstract_tree, mesh),
                        is_leaf=lambda x: isinstance(x, P))


def batch_spec(cfg: ModelConfig, batch_abstract: Dict[str, Any],
               mesh: Mesh) -> Dict[str, P]:
    """Input-batch PartitionSpecs: batch dim over (pod, data)."""
    out = {}
    for k, v in batch_abstract.items():
        if k == "positions":          # M-RoPE [3, B, S]
            out[k] = spec_for(cfg, (None, "batch", None), v.shape, mesh)
        elif v.ndim >= 2:
            axes = ("batch",) + (None,) * (v.ndim - 1)
            out[k] = spec_for(cfg, axes, v.shape, mesh)
        else:
            out[k] = P()
    return out


def activation_spec(mesh: Mesh) -> P:
    """[B, S, D] activations: batch over (pod, data), rest replicated."""
    names = [a for a in ("pod", "data") if a in mesh.axis_names]
    return P(tuple(names) if len(names) > 1 else names[0], None, None)


# ---------------------------------------------------------------- ZeRO-1

def optimizer_axes(cfg: ModelConfig, axes: Sequence[Optional[str]],
                   shape: Sequence[int], mesh: Mesh) -> Tuple:
    """Optimizer-state logical axes: like the parameter, plus the 'data'
    axis claimed by the largest still-unsharded divisible dim (ZeRO-1 —
    Adam moments are sharded over data parallelism and the update is
    followed by a parameter all-gather that XLA schedules itself)."""
    base = spec_for(cfg, axes, shape, mesh)
    parts = list(base) + [None] * (len(shape) - len(base))
    if "data" not in mesh.axis_names:
        return tuple(parts)
    dsize = mesh.shape["data"]
    used = {a for p in parts if p for a in
            (p if isinstance(p, tuple) else (p,))}
    if "data" in used:
        return tuple(parts)
    order = sorted(range(len(shape)), key=lambda i: -shape[i])
    for i in order:
        if parts[i] is None and shape[i] % dsize == 0 and shape[i] >= dsize:
            parts[i] = "data"
            break
    return tuple(parts)


def optimizer_specs(cfg: ModelConfig, axes_tree: Tree, abstract_tree: Tree,
                    mesh: Mesh) -> Tree:
    return jax.tree.map(
        lambda axes, ab: P(*optimizer_axes(cfg, axes, ab.shape, mesh)),
        axes_tree, abstract_tree,
        is_leaf=lambda x: isinstance(x, tuple) and len(x) > 0 and all(
            isinstance(e, (str, type(None))) for e in x))
