"""AdamW in plain JAX, with ZeRO-1-style state sharding.

Master parameters are float32; compute casts to bf16 inside the model.
Moments are float32 and carry the *optimizer* sharding spec — the parameter's
model-parallel layout plus the data axis claimed on the largest free dim
(``sharding.optimizer_specs``), so optimizer memory scales down with data
parallelism (ZeRO-1).  XLA inserts the post-update all-gather automatically.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Tree = Any


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class OptState(NamedTuple):
    step: jax.Array
    mu: Tree
    nu: Tree


def init_opt_state(params: Tree) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(step=jnp.zeros((), jnp.int32), mu=zeros,
                    nu=jax.tree.map(jnp.copy, zeros))


def abstract_opt_state(abstract_params: Tree) -> OptState:
    z = jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32),
                     abstract_params)
    return OptState(step=jax.ShapeDtypeStruct((), jnp.int32), mu=z, nu=z)


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_ratio."""
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree: Tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(cfg: AdamWConfig, params: Tree, grads: Tree,
                 state: OptState) -> Tuple[Tree, OptState, Dict[str, jax.Array]]:
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state.step + 1
    lr = lr_schedule(cfg, state.step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * jnp.square(g)
        mh = m_new / bc1
        vh = v_new / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p
        return p - lr * delta, m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        a, b, c = upd(p, g, m, v)
        new_p.append(a)
        new_m.append(b)
        new_v.append(c)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return (jax.tree.unflatten(treedef, new_p),
            OptState(step, jax.tree.unflatten(treedef, new_m),
                     jax.tree.unflatten(treedef, new_v)),
            metrics)
