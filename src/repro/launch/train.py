"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \
        [--smoke] [--steps 50] [--data N --model M] [--ckpt DIR] [--resume]

``--smoke`` uses the reduced config (CPU-runnable end-to-end driver: ~100M-
class models train in minutes).  The full configs target the production
mesh and are exercised by the dry-run; on a real cluster this same
entrypoint runs them (mesh axes sized by --data/--model).
"""

from __future__ import annotations

import argparse
import sys

import jax

from ..configs import ARCHS, get_config
from ..configs.base import ShapeConfig
from ..distributed.optimizer import AdamWConfig
from ..train.trainer import Trainer, TrainerConfig
from .mesh import make_host_mesh


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=sorted(ARCHS), required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default="checkpoints")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    shape = ShapeConfig("cli", args.seq_len, args.batch, "train")
    mesh = make_host_mesh(args.data, args.model)
    tcfg = TrainerConfig(total_steps=args.steps,
                         checkpoint_every=args.ckpt_every,
                         checkpoint_dir=args.ckpt, log_every=5)
    opt = AdamWConfig(lr=args.lr, warmup_steps=max(1, args.steps // 10),
                      total_steps=args.steps)
    trainer = Trainer(cfg, shape, mesh, tcfg, opt)
    if args.resume and trainer.resume():
        print(f"[train] resumed at step {trainer.step}")
    metrics = trainer.run()
    first = trainer.history[0][1] if trainer.history else float("nan")
    print(f"[train] done: loss {first:.4f} -> "
          f"{metrics.get('loss', float('nan')):.4f} "
          f"in {trainer.step} steps")
    return 0


if __name__ == "__main__":
    sys.exit(main())
