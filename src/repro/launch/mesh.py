"""Production mesh construction.

Kept as functions (never module-level constants) so importing this module
never touches jax device state.  The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
import; smoke tests and benchmarks see the real single device.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax


def _auto(n: int):
    return (jax.sharding.AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False):
    """The grading mesh: 16x16 = 256 chips per pod; 2 pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Arbitrary mesh (tests use small host-device meshes, e.g. (2,4))."""
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))


def make_host_mesh(data: int = 1, model: int = 1):
    """Mesh over however many (possibly forced) host devices exist."""
    n = len(jax.devices())
    if data * model > n:
        raise ValueError(f"mesh {data}x{model} needs {data*model} devices, "
                         f"have {n}")
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=_auto(2))
