"""Production mesh construction.

Kept as functions (never module-level constants) so importing this module
never touches jax device state.  The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
import; smoke tests and benchmarks see the real single device.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax


def _make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Version-tolerant ``jax.make_mesh``: ``axis_types`` (with Auto axes)
    only exists on newer jax; older releases default every axis to Auto."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """The grading mesh: 16x16 = 256 chips per pod; 2 pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Arbitrary mesh (tests use small host-device meshes, e.g. (2,4))."""
    return _make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Mesh over however many (possibly forced) host devices exist."""
    n = len(jax.devices())
    if data * model > n:
        raise ValueError(f"mesh {data}x{model} needs {data*model} devices, "
                         f"have {n}")
    return _make_mesh((data, model), ("data", "model"))
