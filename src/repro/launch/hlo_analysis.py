"""Loop-aware cost analysis over compiled HLO text.

``compiled.cost_analysis()`` counts every ``while`` body ONCE (verified in
tests/test_hlo_analysis.py), so a scanned 24-layer model under-reports flops
by ~the layer count.  Post-optimization HLO carries
``backend_config={"known_trip_count":{"n":...}}`` on while ops, which lets us
do it right: parse the module into computations, cost each one (dot flops
from contracting dims, ~1 flop/element for elementwise/reduce, fusion
boundary bytes, collective payloads), and multiply nested computation costs
through while trip counts.

Collective link-traffic model (per device, ring algorithms):
    all-gather:         result_bytes - operand_bytes
    reduce-scatter:     operand_bytes - result_bytes
    all-reduce:         2 * operand_bytes * (n-1)/n
    all-to-all:         operand_bytes * (n-1)/n
    collective-permute: operand_bytes
The brief's plain "sum of operand sizes" is also reported (``operand_bytes``).
"""

from __future__ import annotations

import json
import math
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 0.5, "u4": 0.5,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*?)\s([\w\-]+)\((.*)$")
_COMP_HDR_RE = re.compile(r"^\s*(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"?(\d+)"?\}')
_CALL_RE = re.compile(r"(?:calls|to_apply|body|condition)=%([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_GROUP_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUP_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "exponential-minus-one", "log", "log-plus-one",
    "tanh", "rsqrt", "sqrt", "power", "sign", "floor", "ceil", "round",
    "cosine", "sine", "logistic", "atan2", "remainder", "select", "clamp",
    "compare", "and", "or", "xor", "not", "shift-left",
    "shift-right-logical", "shift-right-arithmetic",
}
FREE = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "bitcast-convert", "reshape", "transpose", "copy", "copy-start",
    "copy-done", "broadcast", "iota", "convert", "slice", "dynamic-slice",
    "dynamic-update-slice", "concatenate", "pad", "reverse", "gather",
    "scatter", "after-all", "custom-call", "rng-bit-generator", "domain",
    "partition-id", "replica-id", "optimization-barrier",
}
COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_elems_bytes(type_str: str) -> Tuple[float, float]:
    elems, total = 0.0, 0.0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1.0
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        elems += n
        total += n * _DTYPE_BYTES[dt]
    return elems, total


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_link: Dict[str, float] = field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVES})
    coll_operand: Dict[str, float] = field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVES})
    coll_count: int = 0

    def add(self, other: "Cost", factor: float = 1.0) -> None:
        self.flops += factor * other.flops
        self.bytes += factor * other.bytes
        for k in COLLECTIVES:
            self.coll_link[k] += factor * other.coll_link[k]
            self.coll_operand[k] += factor * other.coll_operand[k]
        self.coll_count += int(factor * other.coll_count)


@dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    rest: str          # operands + attributes (raw tail of the line)

    def operands(self) -> List[str]:
        # Operand list = %names up to the closing paren of the op call.
        depth, out, cur = 0, [], []
        for ch in self.rest:
            if ch == "(":
                depth += 1
            elif ch == ")":
                if depth == 0:
                    break
                depth -= 1
            cur.append(ch)
        arglist = "".join(cur)
        return re.findall(r"%([\w.\-]+)", arglist)


class HloModule:
    def __init__(self, text: str):
        self.computations: Dict[str, List[Instr]] = {}
        self.entry: Optional[str] = None
        self._parse(text)
        self._memo: Dict[str, Cost] = {}

    def _parse(self, text: str) -> None:
        cur: Optional[str] = None
        for raw in text.splitlines():
            line = raw.rstrip()
            if not line:
                continue
            hdr = _COMP_HDR_RE.match(line)
            if hdr and line.rstrip().endswith("{"):
                cur = hdr.group(2)
                self.computations[cur] = []
                if hdr.group(1):
                    self.entry = cur
                continue
            if line.strip() == "}":
                cur = None
                continue
            if cur is None:
                continue
            m = _INSTR_RE.match(line)
            if m:
                self.computations[cur].append(
                    Instr(m.group(1), m.group(2), m.group(3), m.group(4)))

    # ------------------------------------------------------------------ #
    def _sym(self, comp: str) -> Dict[str, str]:
        return {i.name: i.type_str for i in self.computations[comp]}

    def _dot_flops(self, instr: Instr, sym: Dict[str, str]) -> float:
        out_elems, _ = _shape_elems_bytes(instr.type_str)
        ops = instr.operands()
        contracted = 1.0
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.rest)
        if m and ops:
            lhs_type = sym.get(ops[0], "")
            sm = _SHAPE_RE.search(lhs_type)
            if sm:
                dims = [int(d) for d in sm.group(2).split(",") if d]
                for ci in m.group(1).split(","):
                    if ci:
                        contracted *= dims[int(ci)]
        return 2.0 * out_elems * contracted

    def _root_opcode(self, comp: str) -> str:
        for instr in reversed(self.computations.get(comp, [])):
            return instr.opcode
        return ""

    def _sliced_param_bytes(self, callee: str) -> Dict[int, float]:
        """Fusion parameters consumed ONLY through (dynamic-)slice ops ->
        bytes actually read (sum of slice results).  This is the scan-xs
        pattern: the fused body slices one step's window out of the stacked
        input; counting the full stacked array per loop iteration inflates
        the memory term by the trip count."""
        instrs = self.computations.get(callee, [])
        param_of: Dict[str, int] = {}
        for i in instrs:
            if i.opcode == "parameter":
                m = re.match(r"(\d+)", i.rest)
                if m:
                    param_of[i.name] = int(m.group(1))
        sliced: Dict[int, float] = {}
        disqualified: set = set()
        for i in instrs:
            if i.opcode == "parameter":
                continue
            ops = i.operands()
            for pos, o in enumerate(ops):
                if o not in param_of:
                    continue
                idx = param_of[o]
                if i.opcode in ("dynamic-slice", "slice") and pos == 0:
                    _, rb = _shape_elems_bytes(i.type_str)
                    sliced[idx] = sliced.get(idx, 0.0) + rb
                else:
                    disqualified.add(idx)
        return {k: v for k, v in sliced.items() if k not in disqualified}

    def _fusion_bytes(self, instr: Instr, sym: Dict[str, str],
                      callees: List[str]) -> float:
        """Boundary bytes of a fusion, aware of in-place slice updates.

        A fusion rooted at ``dynamic-update-slice`` aliases its big operand
        with its output and touches only the updated window — counting the
        full buffer on both sides (XLA's own convention) inflates KV-cache
        writes by seq_len/1.  Similarly (dynamic-)slice-consumed operands
        (scan xs) only read their window.
        """
        _, rb = _shape_elems_bytes(instr.type_str)
        op_names = instr.operands()
        op_bytes = [(_shape_elems_bytes(sym[o])[1] if o in sym else 0.0)
                    for o in op_names]
        sliced = self._sliced_param_bytes(callees[0]) if callees else {}
        for idx, b in sliced.items():
            if idx < len(op_bytes):
                op_bytes[idx] = min(op_bytes[idx], b)
        root = self._root_opcode(callees[0]) if callees else ""
        if root == "dynamic-update-slice" or "dynamic-update-slice" in \
                instr.name:
            # Exclude the aliased full buffer (one operand ~= result bytes);
            # the written window ~= the largest remaining operand.
            rest = sorted(op_bytes)
            for i, b in enumerate(rest):
                if abs(b - rb) <= 0.01 * max(rb, 1.0):
                    rest.pop(i)
                    break
            else:
                if rest:
                    rest.pop()          # fall back: drop the largest
            win = max(rest) if rest else 0.0
            return sum(rest) + win
        if root in ("dynamic-slice", "slice", "gather") or \
                instr.name.startswith(("dynamic-slice", "slice", "gather")):
            small = [b for b in op_bytes if b <= 4.0 * max(rb, 1.0)]
            return sum(small) + 2.0 * rb
        return sum(op_bytes) + rb

    def _group_size(self, instr: Instr) -> int:
        m = _GROUP_LIST_RE.search(instr.rest)
        if m:
            return len(m.group(1).split(","))
        m = _GROUP_IOTA_RE.search(instr.rest)
        if m:
            return int(m.group(2))
        return 2

    def comp_cost(self, name: str) -> Cost:
        if name in self._memo:
            return self._memo[name]
        cost = Cost()
        self._memo[name] = cost           # break accidental cycles
        sym = self._sym(name)

        def operand_bytes(instr: Instr) -> float:
            total = 0.0
            for op in instr.operands():
                if op in sym:
                    total += _shape_elems_bytes(sym[op])[1]
            return total

        for instr in self.computations.get(name, []):
            opc = instr.opcode
            base = opc[:-6] if opc.endswith("-start") else opc
            base = base[:-5] if base.endswith("-done") else base
            if opc.endswith("-done"):
                continue
            if base in COLLECTIVES:
                ob = operand_bytes(instr)
                _, rb = _shape_elems_bytes(instr.type_str)
                if opc.endswith("-start"):
                    rb = max(0.0, rb - ob)   # start result = (operand, out)
                n = self._group_size(instr)
                frac = (n - 1) / n if n > 1 else 0.0
                if base == "all-gather":
                    link = max(0.0, rb - ob)
                elif base == "reduce-scatter":
                    link = max(0.0, ob - rb)
                elif base == "all-reduce":
                    link = 2.0 * ob * frac
                elif base == "all-to-all":
                    link = ob * frac
                else:                        # collective-permute
                    link = ob
                cost.coll_link[base] += link
                cost.coll_operand[base] += ob
                cost.coll_count += 1
                cost.bytes += ob + rb
                continue
            if opc == "while":
                trip = 1
                m = _TRIP_RE.search(instr.rest)
                if m:
                    trip = int(m.group(1))
                sub = Cost()
                for cm in _CALL_RE.finditer(instr.rest):
                    sub.add(self.comp_cost(cm.group(1)))
                cost.add(sub, factor=trip)
                continue
            if opc == "conditional":
                m = _BRANCH_RE.search(instr.rest)
                branches = (re.findall(r"%([\w.\-]+)", m.group(1))
                            if m else [c.group(1) for c in
                                       _CALL_RE.finditer(instr.rest)])
                subs = [self.comp_cost(b) for b in branches]
                if subs:
                    worst = max(subs, key=lambda c: c.flops + c.bytes)
                    cost.add(worst)
                continue
            if opc in ("fusion", "call", "async-start", "map"):
                callees = []
                for cm in _CALL_RE.finditer(instr.rest):
                    callees.append(cm.group(1))
                    sub = self.comp_cost(cm.group(1))
                    # Fusion internals contribute flops but only boundary
                    # bytes (internals live in registers).
                    cost.flops += sub.flops
                    for k in COLLECTIVES:
                        cost.coll_link[k] += sub.coll_link[k]
                        cost.coll_operand[k] += sub.coll_operand[k]
                    cost.coll_count += sub.coll_count
                cost.bytes += self._fusion_bytes(instr, sym, callees)
                continue
            if opc == "dot":
                cost.flops += self._dot_flops(instr, sym)
                _, rb = _shape_elems_bytes(instr.type_str)
                cost.bytes += operand_bytes(instr) + rb
                continue
            if opc == "convolution":
                out_elems, rb = _shape_elems_bytes(instr.type_str)
                kb = operand_bytes(instr)
                cost.flops += 2.0 * out_elems  # lower bound; convs unused
                cost.bytes += kb + rb
                continue
            if opc in ("reduce", "reduce-window", "sort", "select-and-scatter"):
                ob = operand_bytes(instr)
                _, rb = _shape_elems_bytes(instr.type_str)
                elems = sum(_shape_elems_bytes(sym[o])[0]
                            for o in instr.operands() if o in sym)
                cost.flops += elems
                cost.bytes += ob + rb
                continue
            if opc in ELEMENTWISE:
                elems, rb = _shape_elems_bytes(instr.type_str)
                cost.flops += elems
                # Inside fusions this is register traffic; at top level the
                # op reads/writes memory.  Count it — top-level elementwise
                # ops are rare post-fusion.
                cost.bytes += operand_bytes(instr) + rb
                continue
            # FREE and anything unrecognized: no flops; no bytes.
        return cost

    def entry_cost(self) -> Cost:
        assert self.entry is not None, "no ENTRY computation found"
        return self.comp_cost(self.entry)


def analyze_hlo(text: str) -> Dict[str, object]:
    """Loop-aware per-device totals for a compiled SPMD module."""
    mod = HloModule(text)
    c = mod.entry_cost()
    return {
        "flops": c.flops,
        "bytes_accessed": c.bytes,
        "collective_link_bytes": dict(c.coll_link),
        "collective_operand_bytes": dict(c.coll_operand),
        "collective_link_total": sum(c.coll_link.values()),
        "collective_operand_total": sum(c.coll_operand.values()),
        "collective_count": c.coll_count,
        "num_computations": len(mod.computations),
    }


def top_items(text: str, n: int = 20, kind: str = "bytes"
              ) -> List[Tuple[float, str, str]]:
    """Trip-scaled heaviest instructions — the §Perf profiling view.

    Returns [(cost, 'op @ trip_factor', metadata-op-name)] sorted desc.
    ``kind``: 'bytes' | 'flops' | 'collective'.
    """
    mod = HloModule(text)
    items: List[Tuple[float, str, str]] = []

    def walk(comp: str, factor: float) -> None:
        sym = mod._sym(comp)
        for instr in mod.computations.get(comp, []):
            opc = instr.opcode
            if opc.endswith("-done"):
                continue
            base = opc[:-6] if opc.endswith("-start") else opc
            if opc == "while":
                trip = 1
                m = _TRIP_RE.search(instr.rest)
                if m:
                    trip = int(m.group(1))
                for cm in _CALL_RE.finditer(instr.rest):
                    walk(cm.group(1), factor * trip)
                continue
            if opc in ("fusion", "call", "async-start", "conditional", "map"):
                callees = [cm.group(1)
                           for cm in _CALL_RE.finditer(instr.rest)]
                for callee in callees:
                    sub = mod.comp_cost(callee)
                    if kind == "flops" and sub.flops:
                        items.append((factor * sub.flops,
                                      f"{instr.name} [{opc}] x{factor:g}",
                                      instr.type_str[:60]))
                if kind == "bytes":
                    b = mod._fusion_bytes(instr, sym, callees)
                    items.append((factor * b,
                                  f"{instr.name} [{opc}] x{factor:g}",
                                  instr.type_str[:60]))
                continue
            single = Cost()
            tmp = HloModule.__new__(HloModule)  # reuse costing of one instr
            # Simplest: cost a synthetic one-instruction computation.
            tmp.computations = {"_one": [instr]}
            tmp.entry = "_one"
            tmp._memo = {}
            # Patch symbol lookup to the real computation's table.
            tmp._sym = lambda name, _sym_tbl=sym: _sym_tbl  # type: ignore
            one = tmp.comp_cost("_one")
            val = {"bytes": one.bytes, "flops": one.flops,
                   "collective": sum(one.coll_link.values())}[kind]
            if val:
                items.append((factor * val,
                              f"{instr.name} [{opc}] x{factor:g}",
                              instr.type_str[:60]))

    if mod.entry:
        walk(mod.entry, 1.0)
    items.sort(key=lambda t: -t[0])
    return items[:n]
