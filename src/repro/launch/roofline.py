"""Roofline-term derivation from compiled dry-run artifacts.

Per the brief:
    compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory term     = HLO_bytes / (chips * HBM_bw)
    collective term = collective_bytes / (chips * link_bw)

``cost_analysis`` supplies FLOPs/bytes; collective bytes are parsed from the
compiled HLO text by summing operand sizes of all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute ops.  MODEL_FLOPS uses
6*N*D (dense) or 6*N_active*D (MoE) for train, 2*N*D for inference steps.
"""

from __future__ import annotations

import math
import re
from typing import Dict, Optional

from ..configs.base import ModelConfig, ShapeConfig

# TPU v5e constants from the brief.
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
LINK_BW = 50e9               # bytes/s / link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 0.5, "u4": 0.5,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
          "collective-permute")


def _shape_bytes(shape_str: str) -> float:
    """Bytes of every 'dtype[dims]' occurrence in the string."""
    total = 0.0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, float]:
    """Sum result-shape bytes per collective kind over the whole module.

    Shapes in SPMD-partitioned HLO are *per-device*, so the totals are bytes
    held per device per collective — with the brief's
    ``collective_bytes / (chips * link_bw)`` convention, total collective
    bytes = per-device sum x chips, and the division by chips recovers the
    per-device value computed here.  '-start'/'-done' pairs are counted once.
    """
    out: Dict[str, float] = {k: 0.0 for k in _KINDS}
    counts: Dict[str, int] = {k: 0 for k in _KINDS}
    for line in hlo_text.splitlines():
        line = line.strip()
        if "=" not in line:
            continue
        head, _, rest = line.partition("=")
        for kind in _KINDS:
            # Result shape sits between '=' and the op name.
            idx = rest.find(f" {kind}(")
            sidx = rest.find(f" {kind}-start(")
            if idx < 0 and sidx < 0:
                continue
            cut = idx if idx >= 0 else sidx
            shape_str = rest[:cut]
            b = _shape_bytes(shape_str)
            if sidx >= 0:
                # start op result is (operand, result[, scratch]) tuple:
                # halve to count the transferred payload once.
                b *= 0.5
            out[kind] += b
            counts[kind] += 1
            break
    out["total"] = sum(out[k] for k in _KINDS)
    out["counts"] = counts  # type: ignore
    return out


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """6*N*D for train, 2*N*D per generated/prefilled token otherwise."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        d = shape.global_batch * shape.seq_len
        return 6.0 * n * d
    if shape.kind == "prefill":
        d = shape.global_batch * shape.seq_len
        return 2.0 * n * d
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def roofline_from_compiled(cfg: ModelConfig, shape: ShapeConfig,
                           rec: Dict, *, chips: int) -> Dict[str, float]:
    flops = rec["cost"]["flops"]
    bytes_accessed = rec["cost"]["bytes_accessed"]
    coll = rec["collectives"]["total"]
    # cost_analysis on an SPMD module reports per-partition numbers.
    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_accessed / HBM_BW
    collective_s = coll / LINK_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    bound = max(terms, key=terms.get).replace("_s", "")
    mf = model_flops(cfg, shape)
    hlo_total_flops = flops * chips
    return {
        **terms,
        "bound": bound,
        "step_s_lower_bound": max(terms.values()),
        "model_flops": mf,
        "hlo_flops_per_chip": flops,
        "useful_flops_ratio": (mf / hlo_total_flops
                               if hlo_total_flops else 0.0),
        "mfu_upper_bound": (mf / (chips * PEAK_FLOPS)
                            / max(terms.values())
                            if max(terms.values()) > 0 else 0.0),
    }
