"""Serving launcher: batched greedy generation with the serving engine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
        [--requests 8] [--prompt-len 32] [--new-tokens 16]
"""

from __future__ import annotations

import argparse
import sys
import time

import jax
import numpy as np

from ..configs import ARCHS, get_config
from ..models import init_params
from ..serving.engine import ServingEngine


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=sorted(ARCHS), required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--contiguous", action="store_true",
                    help="contiguous slots*max_len KV cache instead of "
                         "the paged default")
    ap.add_argument("--page-size", type=int, default=None,
                    help="KV page size (default: StreamPlan tile / 16)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    if cfg.encoder_only:
        ap.error(f"{args.arch} is encoder-only: no decode step")
    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    engine = ServingEngine(cfg, params, batch_slots=args.slots,
                           max_len=args.prompt_len + args.new_tokens + 8,
                           paged=not args.contiguous,
                           page_size=args.page_size)
    rng = np.random.default_rng(args.seed)
    prompts = [rng.integers(1, cfg.vocab_size, args.prompt_len,
                            dtype=np.int32)
               for _ in range(args.requests)]
    t0 = time.perf_counter()
    reqs = engine.generate(prompts, max_new_tokens=args.new_tokens)
    dt = time.perf_counter() - t0
    total = sum(len(r.out_tokens) for r in reqs)
    ttft = np.mean([r.ttft_s for r in reqs])
    m = engine.metrics
    print(f"[serve] {len(reqs)} requests, {total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s), mean TTFT {ttft*1e3:.1f}ms")
    print(f"[serve] kv cache: {'paged' if m['paged'] else 'contiguous'}, "
          f"peak {m['kv_bytes_peak']} / reserved {m['kv_bytes_reserved']} "
          f"bytes, block efficiency {m['ticks']}/{m['scan_ticks']} ticks")
    for r in reqs[:2]:
        print(f"  req {r.rid}: {r.out_tokens[:8]}...")
    return 0


if __name__ == "__main__":
    sys.exit(main())
