import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: ``jax.jit(step).lower(**abstract_inputs).compile()`` must succeed
on the 16x16 single-pod mesh and the 2x16x16 multi-pod mesh for every cell,
and the compiled artifact yields the roofline terms
(``cost_analysis``/``memory_analysis`` + collective bytes parsed from the
HLO) recorded in EXPERIMENTS.md.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b \
        --shape train_4k [--multi-pod] [--out results.json]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--out dir/]
"""

import argparse
import json
import re
import sys
import time
import traceback
from pathlib import Path

import jax

from ..configs import ALL_SHAPES, ARCHS, ASSIGNED_ARCHS, cells, get_config, \
    get_shape, skipped_cells
from .hlo_analysis import analyze_hlo
from .mesh import make_production_mesh
from .roofline import roofline_from_compiled


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             perf: bool = False, verbose: bool = True) -> dict:
    """Lower + compile one cell; return the dry-run record."""
    from ..distributed.steps import lower_cell   # jax initialized by now

    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(map(str, mesh.devices.shape)),
        "chips": chips, "kind": shape.kind, "perf": perf,
    }
    t0 = time.perf_counter()
    lowered = lower_cell(cfg, shape, mesh, perf=perf)
    rec["lower_s"] = round(time.perf_counter() - t0, 2)

    t0 = time.perf_counter()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.perf_counter() - t0, 2)

    mem = compiled.memory_analysis()
    def _m(attr):
        return int(getattr(mem, attr, 0) or 0) if mem is not None else 0
    rec["memory"] = {
        "argument_bytes": _m("argument_size_in_bytes"),
        "output_bytes": _m("output_size_in_bytes"),
        "temp_bytes": _m("temp_size_in_bytes"),
        "alias_bytes": _m("alias_size_in_bytes"),
    }
    rec["memory"]["peak_bytes"] = (rec["memory"]["argument_bytes"]
                                   + rec["memory"]["output_bytes"]
                                   + rec["memory"]["temp_bytes"]
                                   - rec["memory"]["alias_bytes"])
    cost = compiled.cost_analysis() or {}
    # Raw XLA numbers (while bodies counted ONCE — kept for reference).
    rec["cost_xla_raw"] = {
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
    }
    # Loop-aware analysis: while bodies scaled by known_trip_count.
    hlo_text = compiled.as_text()
    t0 = time.perf_counter()
    analysis = analyze_hlo(hlo_text)
    rec["analyze_s"] = round(time.perf_counter() - t0, 2)
    rec["cost"] = {
        "flops": analysis["flops"],
        "bytes_accessed": analysis["bytes_accessed"],
    }
    rec["collectives"] = {
        **analysis["collective_link_bytes"],
        "total": analysis["collective_link_total"],
        "operand_total": analysis["collective_operand_total"],
        "counts": analysis["collective_count"],
    }
    rec["roofline"] = roofline_from_compiled(cfg, shape, rec, chips=chips)
    if verbose:
        m = rec["memory"]
        r = rec["roofline"]
        print(f"[dryrun] {arch} x {shape_name} mesh={rec['mesh']}  "
              f"compile={rec['compile_s']}s  "
              f"args/dev={m['argument_bytes']/2**30:.2f}GiB "
              f"temp/dev={m['temp_bytes']/2**30:.2f}GiB  "
              f"compute={r['compute_s']*1e3:.2f}ms "
              f"memory={r['memory_s']*1e3:.2f}ms "
              f"collective={r['collective_s']*1e3:.2f}ms "
              f"bound={r['bound']}", flush=True)
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=sorted(ARCHS), default=None)
    ap.add_argument("--shape", choices=sorted(ALL_SHAPES), default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--perf", nargs="?", const="all", default=False,
                    choices=["all", "embed", "sp"],
                    help="apply the §Perf optimization set "
                         "(pin mode: all|embed|sp)")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape) cell on this mesh")
    ap.add_argument("--both-meshes", action="store_true",
                    help="with --all: run single-pod AND multi-pod")
    ap.add_argument("--out", default=None, help="JSON output path or dir")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args(argv)

    results, failures = [], []

    def save(rec, tag):
        if args.out:
            outdir = Path(args.out)
            outdir.mkdir(parents=True, exist_ok=True)
            (outdir / f"{tag}.json").write_text(json.dumps(rec, indent=1))

    if args.all:
        meshes = [False, True] if args.both_meshes else [args.multi_pod]
        todo = [(cfg.name, shape.name, mp)
                for mp in meshes for cfg, shape in cells()]
        for arch, shape_name, mp in todo:
            tag = (f"{arch}__{shape_name}__{'pod2' if mp else 'pod1'}"
                   + (f"__perf_{args.perf}" if args.perf else ""))
            if args.skip_existing and args.out and \
                    (Path(args.out) / f"{tag}.json").exists():
                print(f"[dryrun] skip existing {tag}", flush=True)
                continue
            try:
                rec = run_cell(arch, shape_name, multi_pod=mp,
                               perf=args.perf)
                results.append(rec)
                save(rec, tag)
            except Exception as e:   # record and continue
                traceback.print_exc()
                failures.append({"arch": arch, "shape": shape_name,
                                 "multi_pod": mp, "error": repr(e)})
                save({"arch": arch, "shape": shape_name, "multi_pod": mp,
                      "error": repr(e)}, tag + "__FAILED")
        for arch, shape, reason in skipped_cells():
            print(f"[dryrun] SKIP {arch} x {shape}: {reason}", flush=True)
        print(f"[dryrun] done: {len(results)} ok, {len(failures)} failed",
              flush=True)
        return 1 if failures else 0

    if not args.arch or not args.shape:
        ap.error("--arch and --shape required (or --all)")
    rec = run_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                   perf=args.perf)
    if args.out:
        save(rec, f"{args.arch}__{args.shape}__"
                  f"{'pod2' if args.multi_pod else 'pod1'}"
             + (f"__perf_{args.perf}" if args.perf else ""))
    else:
        print(json.dumps(rec, indent=1))
    return 0


if __name__ == "__main__":
    sys.exit(main())
