"""Static stream verifier (DESIGN.md §15).

itensor-typed analysis of a ``StreamPlan`` + config + mesh that checks
fusion legality, kernel block/VMEM budgets, sharding-claim coherence and
the serving path's paged-memory/donation invariants — all without
tracing a kernel or touching a device.
"""

from .diagnostics import (Diagnostic, PlanVerificationError, clean, errors,
                          warnings_)
from .effects import check_effects
from .itensor_check import check_itensors, stage_itensor, stage_itensors
from .kernel_lint import check_kernels, vmem_estimate
from .sharding_check import check_sharding
from .verify import verify_or_raise, verify_plan

__all__ = [
    "Diagnostic", "PlanVerificationError", "clean", "errors", "warnings_",
    "check_effects", "check_itensors", "check_kernels", "check_sharding",
    "stage_itensor", "stage_itensors", "verify_or_raise", "verify_plan",
    "vmem_estimate",
]
