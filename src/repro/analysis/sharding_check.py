"""Pass 3 — sharding-claim checker.

Validates every ``KernelChoice.sharding`` claim the plan carries against
the mesh it was built for, statically reproducing the decisions
``distributed/sharding.spec_for`` and the wrappers' ``_claim_axis``
would make at trace time:

  * claimed axes must exist on the mesh;
  * feature-dim claims must divide (quantum-aware: head/expert counts,
    never mid-head) — an indivisible claim would mis-slice operands;
  * no two dims of one stage may claim the same axis;
  * psum coherence between paired stages: the column-parallel qkv
    projections ("out" claim) must reduce over the SAME axis the
    row-parallel consumers use (attention's kv_heads slicing, the FFN's
    gate/up -> down psum, MoE's expert psum) — mismatched axes would
    psum partial sums over the wrong groups;
  * replication fallbacks are reported (info): token/batch claims whose
    extents a >1 axis doesn't divide degrade to replication at trace
    time (grouped ('pod','data') claims degrade suffix-first), and
    feature dims left unclaimed on a >1 'model' axis replicate — the
    declared, reachable fallback, never eager.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from ..configs.base import ModelConfig
from ..core.stream_plan import KernelChoice, StreamPlan
from .diagnostics import Diagnostic


def _axes_of(claim) -> Tuple[str, ...]:
    return claim if isinstance(claim, tuple) else (claim,)


def _size(mesh_axes: Dict[str, int], axes: Tuple[str, ...]) -> int:
    n = 1
    for a in axes:
        n *= int(mesh_axes.get(a, 1))
    return max(1, n)


def _dim_extents(cfg: ModelConfig, plan: StreamPlan, kind: str
                 ) -> Dict[str, Tuple[int, int, str]]:
    """dim -> (extent, quantum, class) for every claimable grid dim.
    class: "token" dims degrade to replication at trace time (info);
    "feature" dims must divide (error)."""
    heads = cfg.ssm_heads if cfg.is_mamba else cfg.rwkv_heads
    return {
        "tokens": (plan.tokens, 1, "token"),
        "batch": (plan.tokens, 1, "token"),
        "out": (min(cfg.q_dim, cfg.kv_dim), cfg.head_dim_, "feature"),
        "kv_heads": (cfg.num_kv_heads, 1, "feature"),
        "d_ff": (cfg.d_ff, 1, "feature"),
        "experts": (cfg.num_experts, 1, "feature"),
        "heads": (heads, 1, "feature"),
    }


def _reduction_claim(stage: str, choice: KernelChoice):
    """The tensor-parallel axis a stage reduces/slices over, if any."""
    if stage == "qkv":
        return choice.claim("out")
    if stage in ("attention", "decode_attn", "verify_attn"):
        return choice.claim("kv_heads")
    if stage == "ffn":
        return choice.claim("d_ff") or choice.claim("experts")
    return None


def check_sharding(plan: StreamPlan, cfg: ModelConfig,
                   mesh_axes: Dict[str, int]) -> List[Diagnostic]:
    diags: List[Diagnostic] = []
    model_size = int(mesh_axes.get("model", 1))

    for kind, stage, choice in plan.stage_choices():
        if not choice.fused:
            continue
        where = f"{kind}.{stage}"
        extents = _dim_extents(cfg, plan, kind)
        used: Dict[str, str] = {}

        for dim, claim in choice.sharding:
            axes = _axes_of(claim)
            missing = [a for a in axes if a not in mesh_axes]
            if missing:
                diags.append(Diagnostic(
                    "error", "sharding", where, "unknown-axis",
                    f"dim {dim!r} claims mesh axis {missing[0]!r} which "
                    f"the mesh {dict(mesh_axes)} does not have",
                    "claim only axes of the mesh the plan targets"))
                continue
            for a in axes:
                if a in used:
                    diags.append(Diagnostic(
                        "error", "sharding", where, "axis-collision",
                        f"dims {used[a]!r} and {dim!r} both claim mesh "
                        f"axis {a!r} — one shard_map spec cannot split "
                        "two grid dims over one axis",
                        "claim disjoint axes per stage"))
                used[a] = dim
            size = _size(mesh_axes, axes)
            if size <= 1:
                continue
            extent, quantum, klass = extents.get(dim, (0, 1, "feature"))
            if extent <= 0:
                diags.append(Diagnostic(
                    "error", "sharding", where, "unknown-dim",
                    f"claim on unknown grid dim {dim!r}",
                    "claim one of " + ", ".join(sorted(extents))))
                continue
            units = extent // quantum if quantum > 1 else extent
            if extent % max(1, quantum) != 0 or units % size != 0:
                if klass == "feature":
                    diags.append(Diagnostic(
                        "error", "sharding", where, "indivisible-claim",
                        f"dim {dim!r} (extent {extent}, quantum "
                        f"{quantum}) does not divide over "
                        f"{'x'.join(axes)}={size} — shards would split "
                        "mid-quantum",
                        "drop the claim (replicate) or choose a "
                        "dividing axis"))
                else:
                    # _claim_axis drops the claim at trace time; grouped
                    # ('pod','data') claims degrade suffix-first.
                    fallback = "replication"
                    for cut in range(1, len(axes)):
                        if extent % _size(mesh_axes, axes[cut:]) == 0:
                            fallback = f"axes {axes[cut:]}"
                            break
                    diags.append(Diagnostic(
                        "info", "sharding", where, "replication-fallback",
                        f"token dim {dim!r} (extent {extent}) does not "
                        f"divide {'x'.join(axes)}={size}; the wrapper "
                        f"degrades to {fallback} at trace time"))

        # Feature dims left unclaimed on a >1 model axis replicate — the
        # declared fallback; report reachability, never escalate.
        if model_size > 1 and stage in ("qkv", "attention", "decode_attn",
                                        "verify_attn", "ffn", "mixer"):
            if _reduction_claim(stage, choice) is None:
                diags.append(Diagnostic(
                    "info", "sharding", where, "replication-fallback",
                    f"stage has no tensor-parallel claim on the "
                    f"{model_size}-way model axis; it replicates "
                    "(never eager)"))

    # Psum coherence: the column-parallel qkv "out" claim and every
    # row-parallel consumer in the same layer must reduce over the SAME
    # axis — a different axis would psum over the wrong device groups.
    for kind, lp in plan.layers:
        out_ax = lp.qkv.claim("out") if lp.qkv.fused else None
        if out_ax is None:
            continue
        for stage, choice in lp.stages():
            if stage == "qkv" or not choice.fused:
                continue
            red = _reduction_claim(stage, choice)
            where = f"{kind}.{stage}"
            if red is None and stage in ("attention", "decode_attn",
                                         "verify_attn"):
                diags.append(Diagnostic(
                    "warning", "sharding", where, "implicit-regather",
                    f"qkv shards heads over {out_ax!r} but {stage} "
                    "carries no kv_heads claim — the head-sharded "
                    "projections are implicitly all-gathered",
                    "claim kv_heads on the same axis or drop the qkv "
                    "out claim"))
            elif red is not None and _axes_of(red) != _axes_of(out_ax):
                diags.append(Diagnostic(
                    "error", "sharding", where, "psum-mismatch",
                    f"column-parallel qkv reduces over {out_ax!r} but "
                    f"the row-parallel {stage} psums over {red!r} — "
                    "partial sums would combine across the wrong axis",
                    "use one tensor-parallel axis per layer"))
    return diags
