"""Pass 1 — itensor reconstruction + fusion legality (paper §3.1).

Every fused ``KernelChoice`` implies an iterative-tensor type: the block
targets are the ``elem_shape``, the grid over the stage's data extents is
the ``tripcounts`` (an itensor is the type-level twin of a Pallas
BlockSpec schedule — DESIGN.md §4).  This pass rebuilds those types from
the plan ALONE (no kernel is traced) and checks, for every adjacent
fused stage pair sharing the token stream, what fusing them actually
costs the way ``core/converter.py`` would:

  * ``match``       — identical stream layout; a raw FIFO fuses them.
  * ``regranulate`` — same element order, one token granule divides the
    other; a FIFO re-blocks for free (Algorithm 1's full-window answer
    is conservative here, so we refine it).
  * ``converter``   — a bounded ping-pong window re-orders the stream;
    reported with its analytic byte cost.
  * ``rebuffer``    — no shared loop prefix: the "fusion" silently
    materializes the whole intermediate tensor.  Flagged (warning when
    the ping-pong window exceeds the platform's fusion budget, info
    otherwise — small full windows are how the serving plan's tiny
    slot-count streams legitimately look).

The reconstruction itself is exposed (``stage_itensors``) so tests can
assert elem_shape == blocks and grid_shape == the stage grid.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from ..configs.base import ModelConfig
from ..core.converter import fusion_verdict, infer_converter
from ..core.itensor import ITensorType, itensor_from_tiling
from ..core.stream_plan import KernelChoice, StreamPlan
from ..kernels.common import pick_block
from .diagnostics import Diagnostic

# Token-dim block target per stage (the dim adjacent stages stream over).
_TOKEN_BLOCK = {"qkv": "block_t", "attention": "block_q", "ffn": "block_t",
                "mixer": "chunk", "lm_head": "block_t"}


def _feature_extents(cfg: ModelConfig, kind: str, stage: str,
                     choice: KernelChoice) -> List[Tuple[str, int]]:
    """(block_name, data extent) pairs for a stage's non-token dims."""
    if stage == "qkv":
        return [("block_n", min(cfg.q_dim, cfg.kv_dim))]
    if stage == "attention":
        return [("block_kv", 0)]        # extent filled in from kv_len
    if stage == "ffn":
        if choice.implementation == "moe_experts":
            return []
        return [("block_f", cfg.d_ff)]
    if stage == "lm_head":
        return [("block_v", cfg.vocab_size)]
    return []


def stage_itensor(cfg: ModelConfig, plan: StreamPlan, kind: str,
                  stage: str, choice: KernelChoice
                  ) -> Optional[ITensorType]:
    """Reconstruct one fused stage's OUTPUT/iteration itensor type from
    its block targets.  ``None`` for eager stages and the paged decode /
    verify twins (their stream is the page stream, checked in pass 2)."""
    if not choice.fused or stage in ("decode_attn", "verify_attn"):
        return None
    tokens = plan.tokens
    tname = _TOKEN_BLOCK.get(stage)
    tt = pick_block(tokens, choice.block(tname, tokens) or tokens)
    feats = _feature_extents(cfg, kind, stage, choice)
    if stage == "attention":
        feats = [("block_kv", plan.kv_len)]
    dims: List[int] = [tokens]
    tiles: List[int] = [tt]
    for bname, extent in feats:
        if extent <= 0:
            continue
        dims.append(extent)
        tiles.append(pick_block(extent, choice.block(bname, extent)
                                or extent))
    return itensor_from_tiling(tuple(dims), tuple(tiles), dtype=cfg.dtype)


def stage_itensors(plan: StreamPlan, cfg: ModelConfig
                   ) -> Dict[Tuple[str, str], ITensorType]:
    """Every fused stage's reconstructed itensor, keyed (owner, stage)."""
    out: Dict[Tuple[str, str], ITensorType] = {}
    for kind, stage, choice in plan.stage_choices():
        t = stage_itensor(cfg, plan, kind, stage, choice)
        if t is not None:
            out[(kind, stage)] = t
    return out


def _token_stream(plan: StreamPlan, stage: str,
                  choice: KernelChoice, dtype: str) -> ITensorType:
    """The 1-D token-stream type a stage produces/consumes."""
    tokens = plan.tokens
    tname = _TOKEN_BLOCK.get(stage, "block_t")
    tile = pick_block(tokens, choice.block(tname, tokens) or tokens)
    return itensor_from_tiling((tokens,), (tile,), dtype=dtype)


def _pair_verdict(src: ITensorType, res: ITensorType) -> str:
    """``fusion_verdict`` refined for same-order re-granulation."""
    v = fusion_verdict(src, res)
    if v != "rebuffer":
        return v
    # 1-D exact tilings stream elements in identical (row-major) order;
    # when one granule divides the other a FIFO re-blocks without any
    # window — Algorithm 1's full-extent answer is conservative there.
    if (src.rank == 1 and res.rank == 1
            and src.is_exact_tiling() and res.is_exact_tiling()):
        a, b = src.elem_shape[0], res.elem_shape[0]
        if max(a, b) % min(a, b) == 0:
            return "regranulate"
    return v


def check_itensors(plan: StreamPlan, cfg: ModelConfig,
                   fusion_budget: float) -> List[Diagnostic]:
    diags: List[Diagnostic] = []

    # Reconstruction sanity: every fused stage must admit an exact tiling
    # at its effective blocks (pick_block guarantees this for plans the
    # builder emitted; a hand-edited plan can violate it).
    for kind, stage, choice in plan.stage_choices():
        try:
            stage_itensor(cfg, plan, kind, stage, choice)
        except ValueError as e:
            diags.append(Diagnostic(
                "error", "itensor", f"{kind}.{stage}", "no-exact-tiling",
                f"cannot reconstruct an itensor for "
                f"{choice.implementation}: {e}",
                "use block targets whose pick_block clip divides the "
                "stage extents"))

    # Producer/consumer compatibility over the shared token stream, per
    # layer-kind pipeline (qkv -> attention -> ffn, wrapping to the next
    # layer), then the last stage into the LM head.
    for kind, lp in plan.layers:
        chain = [(s, c) for s, c in lp.stages()
                 if c.fused and s in ("qkv", "attention", "ffn", "mixer")]
        pairs = list(zip(chain, chain[1:]))
        if len(chain) > 1:
            pairs.append((chain[-1], chain[0]))       # layer l -> l+1
        if chain and plan.lm_head.fused:
            pairs.append((chain[-1], ("lm_head", plan.lm_head)))
        for (ps, pc), (cs, cc) in pairs:
            owner = kind if cs != "lm_head" else "final"
            src = _token_stream(plan, ps, pc, cfg.dtype)
            res = _token_stream(plan, cs, cc, cfg.dtype)
            v = _pair_verdict(src, res)
            if v in ("match", "regranulate"):
                continue
            if v == "incompatible":
                diags.append(Diagnostic(
                    "error", "itensor", f"{owner}.{cs}",
                    "incompatible-stream",
                    f"{kind}.{ps} streams {src} but {cs} consumes {res}: "
                    "no converter exists (different data space/dtype)",
                    "make producer and consumer agree on the token "
                    "stream's data space and dtype"))
                continue
            spec = infer_converter(src, res)
            cost = spec.pingpong_bytes if spec else 0.0
            if v == "rebuffer":
                sev = "warning" if cost > fusion_budget else "info"
                diags.append(Diagnostic(
                    sev, "itensor", f"{owner}.{cs}", "full-rebuffer",
                    f"fusing {kind}.{ps} (tile {src.elem_shape[0]}) into "
                    f"{cs} (tile {res.elem_shape[0]}) silently rebuffers "
                    f"the full token stream ({cost:.0f} B ping-pong)",
                    f"align the {_TOKEN_BLOCK.get(ps)} / "
                    f"{_TOKEN_BLOCK.get(cs, 'block_t')} targets so one "
                    "granule divides the other"))
            else:   # bounded converter
                diags.append(Diagnostic(
                    "info", "itensor", f"{owner}.{cs}", "layout-converter",
                    f"{kind}.{ps} -> {cs} needs a stream-layout converter "
                    f"({cost:.0f} B ping-pong window)"))
    return diags
