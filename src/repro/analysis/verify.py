"""Stream verifier: orchestrate the four static passes over a plan.

Library entry point::

    from repro.analysis import verify_plan
    diags = verify_plan(plan, cfg, mesh=mesh, slots=8, max_len=256)

and a deviceless CLI sweeping the configs registry::

    PYTHONPATH=src python -m repro.analysis.verify \\
        --config all --quant all --mesh 1,8

Nothing here traces a kernel or allocates a device array: plans come
from the pure DSE pipeline, 8-device sharding is checked against an
``AbstractMesh`` (axis names + sizes only), and the pool schema is the
``CacheDef`` tree, not the pools.  Exit status is non-zero when any
config produces an error or warning diagnostic — shipped plans must
verify *clean* (info-level fallback reports are fine).
"""

from __future__ import annotations

import argparse
import sys
from typing import Any, Dict, List, Optional, Tuple

from ..configs.base import ModelConfig
from ..core.platforms import PLATFORMS, TPU_V5E, Platform
from ..core.stream_plan import StreamPlan
from .diagnostics import Diagnostic, PlanVerificationError, clean, errors
from .effects import check_effects
from .itensor_check import check_itensors
from .kernel_lint import check_kernels
from .sharding_check import check_sharding

_SEV_ORDER = {"error": 0, "warning": 1, "info": 2}


def _platform_for(plan: StreamPlan) -> Platform:
    """Resolve the Platform a plan recorded (by display name)."""
    for p in PLATFORMS.values():
        if p.name == plan.platform:
            return p
    key = str(plan.platform).lower().replace("-", "_")
    return PLATFORMS.get(key, TPU_V5E)


def _mesh_axes_of(mesh) -> Dict[str, int]:
    return {str(a): int(mesh.shape[a]) for a in mesh.axis_names}


def _resolve_mesh(plan: StreamPlan, mesh
                  ) -> Tuple[Dict[str, int], List[Diagnostic]]:
    """Mesh axes to verify against: the plan's own record, cross-checked
    against an explicitly supplied mesh when both exist."""
    planned = dict(plan.mesh_axes)
    if mesh is None:
        return planned, []
    given = _mesh_axes_of(mesh)
    if planned and planned != given:
        return planned, [Diagnostic(
            "error", "sharding", "plan", "mesh-mismatch",
            f"plan was built for mesh {planned} but is verified against "
            f"{given} — claims would target the wrong axis sizes",
            "rebuild the plan for the mesh it will run under")]
    return given, []


def verify_plan(plan: StreamPlan, cfg: ModelConfig, mesh=None, *,
                slots: Optional[int] = None,
                max_len: Optional[int] = None,
                page_size: Optional[int] = None,
                signatures: Optional[Dict[str, Dict[str, Any]]] = None,
                cache_defs=None) -> List[Diagnostic]:
    """Run all four static passes; returns diagnostics, severest first.

    Pure: no kernel is traced, no array allocated.  ``mesh`` may be a
    real ``Mesh`` or a deviceless ``jax.sharding.AbstractMesh``; pool
    checks need ``slots``/``max_len`` (or an explicit ``cache_defs``)
    and are skipped otherwise.
    """
    platform = _platform_for(plan)
    mesh_axes, diags = _resolve_mesh(plan, mesh)
    diags += check_itensors(plan, cfg, platform.fusion_budget(0.5))
    diags += check_kernels(plan, cfg, platform)
    if mesh_axes:
        diags += check_sharding(plan, cfg, mesh_axes)
    diags += check_effects(plan, cfg, slots=slots, max_len=max_len,
                           page_size=page_size, signatures=signatures,
                           cache_defs=cache_defs)
    diags.sort(key=lambda d: _SEV_ORDER[d.severity])
    return diags


def verify_or_raise(plan: StreamPlan, cfg: ModelConfig, mesh=None,
                    **kw) -> List[Diagnostic]:
    """``verify_plan`` that raises ``PlanVerificationError`` on errors."""
    diags = verify_plan(plan, cfg, mesh, **kw)
    errs = errors(diags)
    if errs:
        raise PlanVerificationError(diags)
    return diags


# --------------------------------------------------------------------- #
# CLI
# --------------------------------------------------------------------- #

_QUANT_ALL = ("none", "kv_int8", "w8_kv8")


def _abstract_mesh(axes: Tuple[Tuple[str, int], ...]):
    """A deviceless mesh carrying only axis names + sizes."""
    from jax.sharding import AbstractMesh
    return AbstractMesh(axes)


def _mesh_for(devices: int):
    if devices <= 1:
        return None
    if devices % 2 == 0 and devices > 2:
        return _abstract_mesh((("data", 2), ("model", devices // 2)))
    return _abstract_mesh((("model", devices),))


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.analysis.verify",
        description="Statically verify StreamPlans for the config "
                    "registry (no kernels traced, no devices needed).")
    ap.add_argument("--config", default="all",
                    help="'all' or comma-separated arch names")
    ap.add_argument("--quant", default="all",
                    help="'all' (= %s) or comma-separated QuantModes"
                         % ",".join(_QUANT_ALL))
    ap.add_argument("--mesh", default="1",
                    help="comma-separated device counts, e.g. '1,8' "
                         "(8 -> a 2x4 data/model AbstractMesh)")
    ap.add_argument("--tokens", type=int, default=4)
    ap.add_argument("--kv-len", type=int, default=64)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--full", action="store_true",
                    help="verify the full-size configs instead of the "
                         "reduced smoke variants (slower DSE)")
    ap.add_argument("--tuned", action="store_true",
                    help="autotune every plan (in-memory hybrid table) "
                         "before verifying — checks that measured-"
                         "provenance plans also pass the verifier")
    ap.add_argument("-v", "--verbose", action="store_true",
                    help="also print info-level diagnostics")
    args = ap.parse_args(argv)

    import dataclasses

    from ..configs import ARCHS
    from ..core.stream_plan import build_stream_plan

    names = (sorted(ARCHS) if args.config == "all"
             else [c.strip() for c in args.config.split(",") if c.strip()])
    quants = (_QUANT_ALL if args.quant == "all"
              else [q.strip() for q in args.quant.split(",") if q.strip()])
    meshes = [int(m) for m in args.mesh.split(",") if m.strip()]

    unclean = 0
    for name in names:
        base = ARCHS[name] if args.full else ARCHS[name].reduced()
        for quant in quants:
            cfg = dataclasses.replace(base, quant=quant,
                                      use_fused_kernels=True)
            for nd in meshes:
                mesh = _mesh_for(nd)
                plan = build_stream_plan(cfg, tokens=args.tokens,
                                         kv_len=args.kv_len, mesh=mesh,
                                         tune=args.tuned or None)
                diags = verify_plan(plan, cfg, mesh,
                                    slots=args.slots, max_len=args.kv_len)
                tag = f"{name:<16} quant={quant:<8} mesh={nd}"
                if args.tuned:
                    tag += " tuned"
                if clean(diags):
                    infos = len(diags)
                    print(f"OK    {tag}  ({infos} info)")
                    shown = diags if args.verbose else []
                else:
                    unclean += 1
                    n_err = len(errors(diags))
                    print(f"FAIL  {tag}  ({n_err} errors, "
                          f"{len(diags) - n_err} warnings/info)")
                    shown = [d for d in diags
                             if args.verbose or d.severity != "info"]
                for d in shown:
                    print(f"      {d}")
    if unclean:
        print(f"{unclean} config/quant/mesh combinations did not verify "
              "clean", file=sys.stderr)
        return 1
    print("all plans verified clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
