"""Diagnostic objects shared by every verifier pass.

A ``Diagnostic`` is one statically-detected fact about a StreamPlan (or
the engine configuration around it).  Severities:

  * ``error``   — the plan is illegal: executing it would produce wrong
    results, alias a donated buffer, or exceed a hard hardware limit.
    ``verify="strict"`` refuses to build an engine on any error.
  * ``warning`` — legal but suspicious: the runtime will silently fall
    back (full-tensor rebuffer, unaligned block clip) and pay for it.
  * ``info``    — a declared fallback the plan is expected to take
    (e.g. token-dim replication on a mesh the slot count doesn't divide).

Every diagnostic names the pass that produced it, the plan stage it
anchors to (``<layer_kind>.<stage>``, ``final.lm_head``,
``dispatch.<name>`` or ``pool.<leaf>``), a stable ``code`` slug the tests
key on, and a fix hint.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, List, Sequence

SEVERITIES = ("error", "warning", "info")
PASSES = ("itensor", "kernel", "sharding", "effects", "tuning")


@dataclass(frozen=True)
class Diagnostic:
    severity: str       # "error" | "warning" | "info"
    pass_name: str      # "itensor" | "kernel" | "sharding" | "effects"
    stage: str          # "attn.ffn", "final.lm_head", "dispatch.decode", ...
    code: str           # stable slug, e.g. "non-divisible-block"
    message: str
    fix_hint: str = ""

    def __post_init__(self) -> None:
        if self.severity not in SEVERITIES:
            raise ValueError(f"unknown severity {self.severity!r}")
        if self.pass_name not in PASSES:
            raise ValueError(f"unknown pass {self.pass_name!r}")

    def __str__(self) -> str:
        hint = f" (fix: {self.fix_hint})" if self.fix_hint else ""
        return (f"[{self.severity}] {self.pass_name}:{self.code} "
                f"@ {self.stage}: {self.message}{hint}")


class PlanVerificationError(ValueError):
    """Raised by ``verify="strict"`` when a plan carries error diagnostics."""

    def __init__(self, diagnostics: Sequence[Diagnostic]):
        self.diagnostics = tuple(diagnostics)
        errs = [d for d in self.diagnostics if d.severity == "error"]
        lines = "\n  ".join(str(d) for d in errs)
        super().__init__(
            f"StreamPlan failed static verification with {len(errs)} "
            f"error(s):\n  {lines}")


def errors(diags: Iterable[Diagnostic]) -> List[Diagnostic]:
    return [d for d in diags if d.severity == "error"]


def warnings_(diags: Iterable[Diagnostic]) -> List[Diagnostic]:
    return [d for d in diags if d.severity == "warning"]


def clean(diags: Iterable[Diagnostic]) -> bool:
    """No errors and no warnings (info-level notes are fine)."""
    return not any(d.severity in ("error", "warning") for d in diags)
