"""Pass 4 — alias & donation checker over the serving dispatches.

A small effect system: each serving dispatch (prefill, chunked prefill,
decode, verify) is described by a declarative signature in
``models/layers.DISPATCH_EFFECTS`` — which buffers it donates, which ops
run in order, what each op reads/writes, and whether a write is
page-table-indexed.  This pass interprets those signatures (plus the
pool schema from ``serving/kv_cache.paged_cache_defs``) and statically
rejects the aliasing bugs the donated-jit serving path makes possible:

  * **donated-read-after-write** — an op reads a donated buffer's
    ORIGINAL contents (``reads_initial``) after an earlier op already
    wrote it; under donation the original storage is gone.
  * **cow-self-alias** — a copy-on-write op whose destination page is
    not guaranteed freshly allocated (``fresh_dst``): dst could alias
    src (self-copy) or a still-shared page (clobbering other slots).
  * **unguarded-null-page** — a page-table-indexed write that doesn't
    route dead/inactive rows onto the sacrificial ``NULL_PAGE``; pad
    lanes would scatter into live pages.
  * **scale-lockstep** — under a KV quant mode, a page-indexed value
    write that doesn't update the per-page scale twins; codes and
    scales would decode against stale statistics.
  * **missing-scale-pool / scale-shape / scale-dtype** — the pool
    schema itself: every quantized K/V pool leaf must carry a
    ``<name>_scale`` sibling of shape [G, num_pages, Hkv] float32
    indexed by the same physical page ids.

Everything here is data-driven so tests can seed bad signatures /
doctored pool trees through the ``signatures=`` / ``cache_defs=``
overrides without touching the shipped declarations.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional

import numpy as np

from ..configs.base import ModelConfig
from ..core.stream_plan import StreamPlan
from .diagnostics import Diagnostic


def _pool_groups(tree) -> List[Dict[str, Any]]:
    """Flatten a paged cache-def tree into its per-group leaf dicts."""
    if isinstance(tree, dict) and ("blocks" in tree or "rest" in tree):
        groups: List[Dict[str, Any]] = []
        for key in ("blocks", "rest"):
            for g in tree.get(key, ()):
                groups.append(g)
        return groups
    if isinstance(tree, dict):
        return [tree]
    return list(tree)


def _leaf_kind(name: str) -> str:
    from ..models.params import cache_leaf_kind
    try:
        return cache_leaf_kind(name)
    except ValueError:
        return "unknown"


def check_pools(cfg: ModelConfig, cache_defs,
                page_size: int) -> List[Diagnostic]:
    """Schema check over the paged pool tree (no arrays allocated)."""
    diags: List[Diagnostic] = []
    if cache_defs is None:
        return diags
    kv_quant = cfg.kv_quant is not None
    for group in _pool_groups(cache_defs):
        for name, cd in group.items():
            kind = _leaf_kind(name)
            where = f"pool.{name}"
            if kind != "kv":
                continue
            shape = tuple(cd.shape)
            if len(shape) == 5 and shape[2] != page_size:
                diags.append(Diagnostic(
                    "error", "effects", where, "page-granule-mismatch",
                    f"pool {name} has page granule {shape[2]} but the "
                    f"plan streams {page_size}-token pages",
                    "build pools and plan from one page_size"))
            if not kv_quant:
                continue
            twin = group.get(name + "_scale")
            if twin is None:
                diags.append(Diagnostic(
                    "error", "effects", where, "missing-scale-pool",
                    f"kv pool {name} stores quantized codes but has no "
                    f"{name}_scale sibling — pages could never be "
                    "dequantized",
                    "emit the [G, num_pages, Hkv] f32 scale leaf next "
                    "to every quantized pool"))
                continue
            want = (shape[0], shape[1], cfg.num_kv_heads)
            if tuple(twin.shape) != want:
                diags.append(Diagnostic(
                    "error", "effects", where, "scale-shape",
                    f"{name}_scale has shape {tuple(twin.shape)}; the "
                    f"page-id-indexed lockstep layout needs {want}",
                    "index scales by the same (group, page, kv_head) "
                    "ids as the pool"))
            if np.dtype(twin.dtype) != np.dtype("float32"):
                diags.append(Diagnostic(
                    "error", "effects", where, "scale-dtype",
                    f"{name}_scale is {np.dtype(twin.dtype).name}; "
                    "per-page scales must be float32",
                    "keep dequant statistics in f32"))
    return diags


def check_signatures(cfg: ModelConfig,
                     signatures: Dict[str, Dict[str, Any]]
                     ) -> List[Diagnostic]:
    """Interpret each dispatch signature, tracking the written set."""
    diags: List[Diagnostic] = []
    kv_quant = cfg.kv_quant is not None
    for sig_name, sig in signatures.items():
        where = f"dispatch.{sig_name}"
        donated = set(sig.get("donated", ()))
        written: set = set()
        for op in sig.get("ops", ()):
            op_name = op.get("name", "?")
            # Original-contents reads of a donated buffer after a write:
            # under donation the pre-dispatch storage no longer exists.
            for buf in op.get("reads_initial", ()):
                if buf in donated and buf in written:
                    diags.append(Diagnostic(
                        "error", "effects", where,
                        "donated-read-after-write",
                        f"op {op_name} reads the original contents of "
                        f"donated buffer {buf!r} after an earlier op "
                        "already wrote it — donation freed that storage",
                        "order the initial-contents read before every "
                        "write, or stop donating the buffer"))
            cow = op.get("cow")
            if cow is not None and not cow.get("fresh_dst", False):
                diags.append(Diagnostic(
                    "error", "effects", where, "cow-self-alias",
                    f"op {op_name} copies page {cow.get('src')!r} onto "
                    f"{cow.get('dst')!r} without a fresh-dst guarantee "
                    "— dst may alias src or a still-shared page",
                    "allocate cow_dst fresh (refs == 1) before the "
                    "divergent write (kv_cache.POOL_INVARIANTS)"))
            if op.get("page_indexed"):
                if not op.get("null_routed", False):
                    diags.append(Diagnostic(
                        "error", "effects", where, "unguarded-null-page",
                        f"op {op_name} scatters by page id without "
                        "routing dead rows onto NULL_PAGE — pad lanes "
                        "would corrupt live pages",
                        "mask inactive rows to the sacrificial page 0"))
                if kv_quant and not op.get("updates_scales", False):
                    diags.append(Diagnostic(
                        "error", "effects", where, "scale-lockstep",
                        f"op {op_name} writes quantized pages but not "
                        "their per-page scale twins — codes would "
                        "decode against stale scales",
                        "update <pool>_scale in the same dispatch as "
                        "the pool write"))
            written |= set(op.get("writes", ()))
    return diags


def check_effects(plan: StreamPlan, cfg: ModelConfig, *,
                  slots: Optional[int] = None,
                  max_len: Optional[int] = None,
                  page_size: Optional[int] = None,
                  signatures: Optional[Dict[str, Dict[str, Any]]] = None,
                  cache_defs=None) -> List[Diagnostic]:
    """Run the effect system over the dispatch signatures + pool schema.

    ``signatures`` defaults to the shipped ``DISPATCH_EFFECTS``;
    ``cache_defs`` defaults to the schema ``paged_cache_defs`` would
    build for (slots, max_len, page_size) when those are given.  Both
    are overridable so tests can seed bad fixtures.
    """
    ps = page_size or plan.decode_page_size()
    if signatures is None:
        from ..models.layers import DISPATCH_EFFECTS
        signatures = DISPATCH_EFFECTS
    if cache_defs is None and slots is not None and max_len is not None:
        from ..serving.kv_cache import paged_cache_defs
        cache_defs = paged_cache_defs(cfg, slots, max_len, ps)
    diags = check_pools(cfg, cache_defs, ps)
    diags += check_signatures(cfg, signatures)
    return diags
