"""Pass 2 — kernel lint: block legality, VMEM budgets, prefetch arity.

Checks every fused ``KernelChoice`` against the model dimensions and the
platform model in ``core/platforms.py`` WITHOUT tracing a kernel:

  * implementation names must be known kernels (a plan naming a kernel
    the runtime doesn't have dispatches nothing);
  * feature-dim block targets honor the 128-lane floor and either divide
    their extent or clip (``kernels/common.pick_block``) to an
    MXU-aligned divisor — a clip below the lane width on a lane-sized
    extent would hand the MXU an illegal tile;
  * a per-kernel VMEM footprint estimate (operand blocks resident per
    grid step, f32 accumulators, w8 scale rows) must fit the platform's
    on-chip memory;
  * the paged / verify kernels' scalar-prefetch operand arity must agree
    with the plan's quant mode (quantized pools ride two extra scale
    operands next to the page table), and the plan's recorded quant mode
    must agree with the config it is verified against — a cached plan
    from a different QuantMode would pick wrong kernel twins.
"""

from __future__ import annotations

import math
from typing import Dict, List, Optional, Tuple

from ..configs.base import ModelConfig
from ..core.itensor import dtype_bytes
from ..core.platforms import Platform
from ..core.stream_plan import KernelChoice, StreamPlan
from ..kernels.common import LANE, pick_block, round_up
from .diagnostics import Diagnostic

# Every implementation name a KernelChoice may carry -> the block names
# it understands.  (Extra block entries like "fuse_norm"/"w8" are flags.)
KNOWN_KERNELS: Dict[str, Tuple[str, ...]] = {
    "eager": (),
    "rmsnorm_matmul": ("block_t", "block_n", "w8"),
    "block_matmul": ("block_t", "block_n"),
    "flash_attention": ("block_q", "block_kv"),
    "paged_attention": ("page_size",),
    "verify_attention": ("page_size",),
    "streamed_ffn": ("block_t", "block_f", "fuse_norm", "w8"),
    "streamed_mlp": ("block_t", "block_f", "fuse_norm", "w8"),
    "moe_experts": ("block_t",),
    "mamba2_scan": ("chunk",),
    "rwkv6_wkv": ("chunk",),
    "streamed_xent": ("block_t", "block_v"),
}

# Scalar-prefetch operand arity: (without, with) quantized KV pools.
# paged: lengths + page_table (+ k/v page scales); verify: q_off +
# page_table (+ scales); the chunked flash kernel packs its metadata
# into ONE prefetch vector and takes scales as regular operands.
SCALAR_PREFETCH: Dict[str, Tuple[int, int]] = {
    "paged_attention": (2, 4),
    "verify_attention": (2, 4),
    "flash_attention": (1, 1),
}


def _feature_blocks(cfg: ModelConfig, stage: str, choice: KernelChoice,
                    kv_len: int) -> List[Tuple[str, int]]:
    """(block_name, extent) for the LANE-sensitive dims of a stage."""
    impl = choice.implementation
    if stage == "qkv":
        return [("block_n", min(cfg.q_dim, cfg.kv_dim))]
    if stage == "attention":
        return [("block_kv", kv_len)]
    if stage == "ffn" and impl in ("streamed_ffn", "streamed_mlp"):
        return [("block_f", cfg.d_ff)]
    if stage == "lm_head":
        return [("block_v", cfg.vocab_size)]
    return []


def _shard_div(choice: KernelChoice, mesh_axes: Dict[str, int],
               dim: str) -> int:
    ax = choice.claim(dim)
    if ax is None:
        return 1
    axes = ax if isinstance(ax, tuple) else (ax,)
    n = 1
    for a in axes:
        n *= int(mesh_axes.get(a, 1))
    return max(1, n)


def vmem_estimate(cfg: ModelConfig, plan: StreamPlan, stage: str,
                  choice: KernelChoice) -> Optional[float]:
    """Resident bytes one grid step of the stage's kernel holds in VMEM:
    operand blocks + f32 accumulators/scratch (+ w8 codes and scales).
    ``None`` for eager stages.  Uses the EFFECTIVE blocks (post
    ``pick_block`` clip) and post-shard extents — what one program on
    one shard actually streams."""
    if not choice.fused:
        return None
    impl = choice.implementation
    dt = dtype_bytes(cfg.dtype)
    mesh = dict(plan.mesh_axes)
    d = cfg.d_model
    tokens = max(1, plan.tokens)
    kv_len = max(1, plan.kv_len)
    w8 = bool(choice.block("w8"))

    def eff(extent: int, name: str, default: int) -> int:
        return pick_block(max(1, extent), choice.block(name, default)
                          or default)

    if impl in ("rmsnorm_matmul", "block_matmul"):
        n = min(cfg.q_dim, cfg.kv_dim) // _shard_div(choice, mesh, "out")
        bt = eff(tokens, "block_t", tokens)
        bn = eff(n, "block_n", n)
        wbytes = d * bn * (1 if w8 else dt) + (bn * 4 if w8 else 0)
        return bt * d * dt + wbytes + bt * bn * 4
    if impl in ("streamed_ffn", "streamed_mlp"):
        f = cfg.d_ff // _shard_div(choice, mesh, "d_ff")
        bt = eff(tokens, "block_t", tokens)
        bf = eff(f, "block_f", f)
        mats = 3 if impl == "streamed_ffn" else 2
        per_mat = d * bf * (1 if w8 else dt) + (bf * 4 if w8 else 0)
        return (bt * d * dt + mats * per_mat
                + bt * bf * 4 + bt * d * 4)
    if impl == "moe_experts":
        bt = eff(tokens, "block_t", tokens)
        return (bt * d * dt + 3 * d * cfg.d_ff * dt
                + bt * cfg.d_ff * 4 + bt * d * 4)
    if impl == "flash_attention":
        dp = round_up(cfg.head_dim_, LANE)
        bq = eff(tokens, "block_q", tokens)
        bkv = eff(kv_len, "block_kv", kv_len)
        return (bq + 2 * bkv) * dp * dt + bq * (dp + 2) * 4
    if impl in ("paged_attention", "verify_attention"):
        dp = round_up(cfg.head_dim_, LANE)
        g = max(1, cfg.num_heads // max(1, cfg.num_kv_heads))
        rows = g
        if impl == "verify_attention":
            rows = g * plan.verify_window(plan.decode_page_size())
        ps = max(1, choice.block("page_size", 16))
        return rows * dp * dt + 2 * ps * dp * dt + rows * (dp + 2) * 4
    if impl == "mamba2_scan":
        chunk = eff(tokens, "chunk", tokens)
        return 4.0 * chunk * max(cfg.d_inner, 1) * dt
    if impl == "rwkv6_wkv":
        chunk = eff(tokens, "chunk", tokens)
        return 4.0 * chunk * d * dt
    if impl == "streamed_xent":
        v = cfg.vocab_size // _shard_div(choice, mesh, "vocab")
        bt = eff(tokens, "block_t", tokens)
        bv = eff(v, "block_v", v)
        return bt * d * dt + d * bv * dt + bt * bv * 4 + 8 * bt
    return None     # unknown kernel: reported separately


def check_kernels(plan: StreamPlan, cfg: ModelConfig,
                  platform: Platform) -> List[Diagnostic]:
    diags: List[Diagnostic] = []

    if plan.quant != cfg.quant:
        diags.append(Diagnostic(
            "error", "kernel", "plan", "quant-mismatch",
            f"plan was built under quant mode {plan.quant!r} but is "
            f"verified against a config in mode {cfg.quant!r} — kernel "
            "twins and pool dtypes would disagree",
            "rebuild the plan with the config's quant mode "
            "(plans are cached per config)"))

    kv_quant = cfg.kv_quant is not None
    for kind, stage, choice in plan.stage_choices():
        if not choice.fused:
            continue
        where = f"{kind}.{stage}"
        impl = choice.implementation

        if impl not in KNOWN_KERNELS:
            diags.append(Diagnostic(
                "error", "kernel", where, "unknown-kernel",
                f"implementation {impl!r} is not a known Pallas kernel",
                f"one of {sorted(k for k in KNOWN_KERNELS if k != 'eager')}"))
            continue

        # w8 flags must agree with the config's weight-quant mode.
        if choice.block("w8") and not cfg.weight_quant:
            diags.append(Diagnostic(
                "error", "kernel", where, "w8-without-weight-quant",
                f"{impl} carries the w8 flag but cfg.quant={cfg.quant!r} "
                "has no weight quantization — the wrapper would "
                "quantize weights the checkpoint math doesn't expect",
                "drop the w8 block flag or set quant=w8/w8_kv8"))

        # Feature-dim block targets: lane floor + divisibility.
        for bname, extent in _feature_blocks(cfg, stage, choice,
                                             plan.kv_len):
            target = choice.block(bname)
            if target <= 0 or extent <= 0:
                continue
            if extent >= LANE and target < LANE:
                diags.append(Diagnostic(
                    "error", "kernel", where, "lane-floor",
                    f"{bname}={target} is below the {LANE}-lane floor "
                    f"for a {extent}-wide dim — the MXU tile would be "
                    "lane-misaligned",
                    f"raise {bname} to a multiple of {LANE}"))
                continue
            if target <= extent and extent % target != 0:
                eff = pick_block(extent, target)
                diags.append(Diagnostic(
                    "warning", "kernel", where, "non-divisible-block",
                    f"{bname}={target} does not divide the {extent}-wide "
                    f"dim; the wrapper will clip it to {eff}",
                    f"use a {bname} that divides {extent} so the plan's "
                    "tile is the tile that runs"))
                if extent >= LANE and eff % LANE != 0:
                    diags.append(Diagnostic(
                        "error", "kernel", where, "unaligned-block",
                        f"no lane-aligned divisor of {extent} exists at "
                        f"or below {bname}={target}; the clip lands on "
                        f"{eff}, an MXU-illegal tile",
                        f"pad the dim to a multiple of {LANE} or pick a "
                        "dividing block"))

        # Paged stream granule sanity.
        if impl in ("paged_attention", "verify_attention"):
            ps = choice.block("page_size", 0)
            if ps <= 0:
                diags.append(Diagnostic(
                    "error", "kernel", where, "bad-page-size",
                    f"{impl} carries page_size={ps}",
                    "page_size must be a positive KV stream granule"))

        # VMEM footprint vs the platform budget.
        est = vmem_estimate(cfg, plan, stage, choice)
        if est is not None:
            if est > platform.onchip_bytes:
                diags.append(Diagnostic(
                    "error", "kernel", where, "vmem-exceeded",
                    f"{impl} needs ~{est / 2**20:.1f} MiB of VMEM per "
                    f"grid step; {platform.name} has "
                    f"{platform.onchip_bytes / 2**20:.0f} MiB",
                    "shrink the stage's block targets"))
            elif est > platform.fusion_budget(0.5):
                diags.append(Diagnostic(
                    "warning", "kernel", where, "vmem-pressure",
                    f"{impl} needs ~{est / 2**20:.1f} MiB of VMEM per "
                    "grid step — over half the on-chip budget, leaving "
                    "no room for double-buffering",
                    "shrink the stage's block targets"))

        # Scalar-prefetch operand arity for the paged/verify/chunk path.
        if impl in SCALAR_PREFETCH:
            base, quant_arity = SCALAR_PREFETCH[impl]
            expect = quant_arity if kv_quant else base
            have = quant_arity if plan.quant in ("kv_int8", "kv_fp8",
                                                 "w8_kv8") else base
            if impl != "flash_attention" and have != expect:
                diags.append(Diagnostic(
                    "error", "kernel", where, "prefetch-arity",
                    f"{impl} would prefetch {have} scalar operands under "
                    f"plan quant {plan.quant!r} but the config's pools "
                    f"need {expect} (page table ± per-page scales)",
                    "rebuild the plan under the config's quant mode"))
    return diags
