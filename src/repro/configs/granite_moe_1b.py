"""granite-moe-1b-a400m — MoE, 32 experts top-8.

[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]  24L d_model=1024 16H
(GQA kv=8) d_ff=512 (per expert) vocab=49155, MoE 32e top-8.
"""

from .base import MOE, ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family=MOE,
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    num_experts=32,
    top_k=8,
    rope="rope",
    rope_theta=10_000.0,
    tie_embeddings=True,
)
