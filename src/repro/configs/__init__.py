"""Architecture registry: ``--arch <id>`` resolution + the cell matrix.

``ARCHS`` maps the assignment's architecture ids to their exact configs;
``cells()`` enumerates every runnable (arch x shape) dry-run cell with the
skips documented in DESIGN.md §Arch-applicability.
"""

from __future__ import annotations

from typing import Dict, Iterator, List, Tuple

from .base import (ALL_SHAPES, DECODE_32K, LONG_500K, PREFILL_32K, TRAIN_4K,
                   ModelConfig, ShapeConfig, shapes_for, skipped_shapes_for)
from .gemma3_4b import CONFIG as GEMMA3_4B
from .gpt2 import CONFIG as GPT2
from .gpt2 import PAPER_GEMMA, PAPER_LLAMA, PAPER_QWEN
from .granite_moe_1b import CONFIG as GRANITE_MOE_1B
from .granite_moe_3b import CONFIG as GRANITE_MOE_3B
from .hubert_xlarge import CONFIG as HUBERT_XLARGE
from .llama3_8b import CONFIG as LLAMA3_8B
from .qwen1p5_0p5b import CONFIG as QWEN1P5_0P5B
from .qwen2_vl_2b import CONFIG as QWEN2_VL_2B
from .qwen3_0p6b import CONFIG as QWEN3_0P6B
from .rwkv6_7b import CONFIG as RWKV6_7B
from .zamba2_2p7b import CONFIG as ZAMBA2_2P7B

ARCHS: Dict[str, ModelConfig] = {
    "zamba2-2.7b": ZAMBA2_2P7B,
    "qwen2-vl-2b": QWEN2_VL_2B,
    "qwen1.5-0.5b": QWEN1P5_0P5B,
    "gemma3-4b": GEMMA3_4B,
    "qwen3-0.6b": QWEN3_0P6B,
    "llama3-8b": LLAMA3_8B,
    "granite-moe-1b-a400m": GRANITE_MOE_1B,
    "granite-moe-3b-a800m": GRANITE_MOE_3B,
    "hubert-xlarge": HUBERT_XLARGE,
    "rwkv6-7b": RWKV6_7B,
    # The paper's own models (benchmarks, not dry-run cells).
    "gpt2": GPT2,
}

PAPER_MODELS: Dict[str, ModelConfig] = {
    "gpt2": GPT2,
    "paper-qwen": PAPER_QWEN,
    "paper-llama": PAPER_LLAMA,
    "paper-gemma": PAPER_GEMMA,
}

ASSIGNED_ARCHS: Tuple[str, ...] = tuple(a for a in ARCHS if a != "gpt2")


def get_config(arch: str) -> ModelConfig:
    try:
        return ARCHS[arch]
    except KeyError:
        raise KeyError(f"unknown arch {arch!r}; have {sorted(ARCHS)}")


def get_shape(name: str) -> ShapeConfig:
    return ALL_SHAPES[name]


def cells() -> Iterator[Tuple[ModelConfig, ShapeConfig]]:
    """Every runnable (arch x shape) dry-run cell."""
    for arch in ASSIGNED_ARCHS:
        cfg = ARCHS[arch]
        for shape in shapes_for(cfg):
            yield cfg, shape


def skipped_cells() -> Iterator[Tuple[str, str, str]]:
    """(arch, shape, reason) for documented skips."""
    for arch in ASSIGNED_ARCHS:
        cfg = ARCHS[arch]
        for shape, reason in skipped_shapes_for(cfg):
            yield arch, shape, reason


__all__ = [
    "ARCHS", "ASSIGNED_ARCHS", "PAPER_MODELS", "ModelConfig", "ShapeConfig",
    "get_config", "get_shape", "cells", "skipped_cells", "shapes_for",
    "ALL_SHAPES", "TRAIN_4K", "PREFILL_32K", "DECODE_32K", "LONG_500K",
]
