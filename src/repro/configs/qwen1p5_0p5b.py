"""qwen1.5-0.5b — dense transformer with QKV bias.

[hf:Qwen/Qwen1.5-0.5B; hf]  24L d_model=1024 16H (GQA kv=16) d_ff=2816
vocab=151936.
"""

from .base import DENSE, ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b",
    family=DENSE,
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=2816,
    vocab_size=151936,
    qkv_bias=True,
    rope="rope",
    rope_theta=1e6,
    tie_embeddings=True,
)
