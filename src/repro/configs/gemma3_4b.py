"""gemma3-4b — dense transformer, 5:1 local:global attention, 128k context.

[hf:google/gemma-3-1b-pt; unverified]  34L d_model=2560 8H (GQA kv=4)
d_ff=10240 vocab=262144.  Every 6th layer is global attention; the other five
use a 1024-token sliding window.  GeGLU FFN, qk-norm.
"""

from .base import DENSE, ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b",
    family=DENSE,
    num_layers=34,
    d_model=2560,
    num_heads=8,
    num_kv_heads=4,
    d_ff=10240,
    vocab_size=262144,
    activation="gelu",
    qk_norm=True,
    rope="rope",
    rope_theta=1e6,
    sliding_window=1024,
    global_attn_every=6,
    tie_embeddings=True,
)
