"""Architecture + shape configuration dataclasses.

Pure-Python (no JAX import): the StreamTensor compiler core (``repro.core``)
consumes these to trace dataflow graphs, and ``repro.models`` consumes them to
build the executable JAX model.  One ``<arch>.py`` per assigned architecture
lives next to this module; the registry is in ``__init__``.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Tuple

DENSE, MOE, HYBRID, SSM, VLM, AUDIO = (
    "dense", "moe", "hybrid", "ssm", "vlm", "audio")


@dataclass(frozen=True)
class ModelConfig:
    """Config for every assigned architecture family.

    Attention fields are ignored by pure-SSM archs (``rwkv=True``); SSM fields
    are ignored by pure-attention archs.  ``shared_attn_every`` > 0 selects the
    Zamba2-style hybrid: Mamba2 backbone with one *shared-parameter*
    attention+MLP block applied every k layers.
    """

    name: str
    family: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // num_heads
    activation: str = "silu"          # silu | gelu
    gated_ffn: bool = True            # SwiGLU/GeGLU (3 mats) vs MLP (2 mats)
    norm: str = "rmsnorm"             # rmsnorm | layernorm
    qkv_bias: bool = False
    qk_norm: bool = False
    rope: str = "rope"                # rope | mrope | none
    rope_theta: float = 10_000.0
    tie_embeddings: bool = True
    encoder_only: bool = False
    causal: bool = True
    # Gemma-3 interleaved local:global attention.
    sliding_window: int = 0           # 0 = full attention
    global_attn_every: int = 0        # k: every k-th layer is global
    # Mixture-of-Experts.
    num_experts: int = 0
    top_k: int = 0
    # Mamba2 / hybrid.
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_width: int = 4
    shared_attn_every: int = 0
    # RWKV6 (Finch).
    rwkv: bool = False
    rwkv_head_dim: int = 64
    # --- §Perf knobs (EXPERIMENTS.md; 0/False = paper-faithful baseline) ---
    rwkv_chunk: int = 0           # chunked wkv6 (state traffic / chunk)
    remat_attn_chunk: bool = False  # remat per KV chunk inside attention
    # StreamPlan fused execution: the model entry points resolve a
    # ``core.stream_plan.StreamPlan`` (trace -> tiling DSE -> fusion ->
    # lowering) and dispatch blocks to the fused Pallas kernels it selected
    # instead of the eager jnp path.
    use_fused_kernels: bool = False
    kv_cache_layout: str = "bshd"   # "bhsd" = attention-native (no per-token
    #                                 full-cache transpose at decode)
    # Modality frontend stub (VLM patch / audio frame embeddings).
    frontend: str = "none"            # none | patch | frame
    dtype: str = "bfloat16"
    # QuantMode (DESIGN.md §14): serving-side quantization, composable
    # KV-side x weight-side.  "kv_int8"/"kv_fp8" store the paged K/V pools
    # as int8 / fp8-e4m3 with per-page per-kv-head f32 scales; "w8" runs
    # the plan's rmsnorm_matmul / streamed_ffn stages weight-only int8
    # with per-output-channel scales; "w8_kv8" composes both.
    quant: str = "none"               # none | kv_int8 | kv_fp8 | w8 | w8_kv8
    max_seq_len: int = 524_288

    # ------------------------------------------------------------- derived
    QUANT_MODES = ("none", "kv_int8", "kv_fp8", "w8", "w8_kv8")

    def __post_init__(self):
        if self.quant not in self.QUANT_MODES:
            raise ValueError(
                f"unknown quant mode {self.quant!r}: one of "
                f"{self.QUANT_MODES}")

    @property
    def kv_quant(self) -> Optional[str]:
        """KV-pool storage format ("int8" | "fp8" | None)."""
        if self.quant in ("kv_int8", "w8_kv8"):
            return "int8"
        if self.quant == "kv_fp8":
            return "fp8"
        return None

    @property
    def weight_quant(self) -> bool:
        """Weight-only int8 on the plan's matmul stages."""
        return self.quant in ("w8", "w8_kv8")

    @property
    def head_dim_(self) -> int:
        return self.head_dim or (self.d_model // max(1, self.num_heads))

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim_

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim_

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def is_mamba(self) -> bool:
        return self.ssm_state > 0

    @property
    def d_inner(self) -> int:
        """Mamba2 inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim

    def layer_kind(self, i: int) -> str:
        """What block sits at layer ``i`` (pattern-aware)."""
        if self.rwkv:
            return "rwkv"
        if self.is_mamba:
            if (self.shared_attn_every
                    and (i + 1) % self.shared_attn_every == 0):
                return "mamba+shared_attn"
            return "mamba"
        if self.global_attn_every:
            return ("global_attn"
                    if (i + 1) % self.global_attn_every == 0
                    else "local_attn")
        return "attn"

    @property
    def layer_pattern(self) -> Tuple[str, ...]:
        """One repeating group of layer kinds (scan unit)."""
        period = (self.shared_attn_every or self.global_attn_every or 1)
        return tuple(self.layer_kind(i) for i in range(period))

    # ----------------------------------------------------------- counting
    def param_count(self) -> int:
        """Total parameters (embedding included once if tied)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        emb = v * d * (1 if self.tie_embeddings else 2)
        total = emb + d  # final norm
        for i in range(self.num_layers):
            kind = self.layer_kind(i)
            if kind in ("attn", "local_attn", "global_attn"):
                total += self._attn_params() + self._ffn_params() + 2 * d
            elif kind == "rwkv":
                total += self._rwkv_params() + 2 * d
            elif kind.startswith("mamba"):
                total += self._mamba_params() + d
        if self.shared_attn_every:
            total += self._attn_params() + self._ffn_params() + 2 * d
        return int(total)

    def _attn_params(self) -> int:
        d = self.d_model
        p = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        if self.qkv_bias:
            p += self.q_dim + 2 * self.kv_dim
        if self.qk_norm:
            p += 2 * self.head_dim_
        return p

    def _ffn_params(self) -> int:
        d = self.d_model
        if self.is_moe:
            route = d * self.num_experts
            expert = 3 * d * self.d_ff
            return route + self.num_experts * expert
        gates = 3 if self.gated_ffn else 2
        return gates * d * self.d_ff

    def _mamba_params(self) -> int:
        d, di = self.d_model, self.d_inner
        h, n = self.ssm_heads, self.ssm_state
        in_proj = d * (2 * di + 2 * h * n + h)   # x, z, B, C, dt
        conv = self.conv_width * (di + 2 * h * n)
        out = di * d
        return in_proj + conv + out + 2 * h      # A, D

    def _rwkv_params(self) -> int:
        d, f = self.d_model, self.d_ff
        tm = 6 * d * d + 6 * d                   # r k v g w o (+ mixes)
        cm = 2 * d * f + 2 * d
        return tm + cm

    def active_param_count(self) -> int:
        """MoE: parameters touched per token (for 6*N_active*D FLOPs)."""
        if not self.is_moe:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        inactive = (self.num_experts - self.top_k) * 3 * d * f
        return int(self.param_count() - self.num_layers * inactive)

    # ------------------------------------------------------------ reduced
    def reduced(self) -> "ModelConfig":
        """Small same-family config for CPU smoke tests.

        Keeps the layer *pattern* (shared-attn / local:global periods shrink
        but stay > 1) so pattern code paths are exercised.
        """
        return replace(
            self,
            name=self.name + "-smoke",
            num_layers=max(2, min(4, (self.shared_attn_every
                                      or self.global_attn_every or 1) * 2)),
            d_model=64,
            num_heads=4,
            num_kv_heads=2 if self.num_kv_heads < self.num_heads else 4,
            head_dim=16,
            d_ff=128,
            vocab_size=256,
            num_experts=4 if self.is_moe else 0,
            top_k=2 if self.is_moe else 0,
            ssm_state=16 if self.is_mamba else 0,
            ssm_head_dim=32,
            rwkv_head_dim=16,
            sliding_window=32 if self.sliding_window else 0,
            global_attn_every=2 if self.global_attn_every else 0,
            shared_attn_every=2 if self.shared_attn_every else 0,
            max_seq_len=512,
        )


@dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell from the assignment."""
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

ALL_SHAPES: Dict[str, ShapeConfig] = {
    s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
}


def shapes_for(cfg: ModelConfig) -> List[ShapeConfig]:
    """The runnable shape cells for an arch (skips documented in DESIGN.md):

    * encoder-only archs have no decode step -> drop decode/long shapes;
    * ``long_500k`` needs sub-quadratic attention -> only SSM / hybrid /
      sliding-window archs run it (gemma3's 5:1 local:global qualifies:
      local layers are O(w), and decode against the global KV is O(S) and
      sequence-sharded).
    """
    out = [TRAIN_4K, PREFILL_32K]
    if cfg.encoder_only:
        return out
    out.append(DECODE_32K)
    sub_quadratic = (cfg.family in (SSM, HYBRID)) or cfg.sliding_window > 0
    if sub_quadratic:
        out.append(LONG_500K)
    return out


def skipped_shapes_for(cfg: ModelConfig) -> List[Tuple[str, str]]:
    """(shape, reason) pairs for the dry-run report."""
    have = {s.name for s in shapes_for(cfg)}
    out = []
    for name in ALL_SHAPES:
        if name in have:
            continue
        if cfg.encoder_only:
            out.append((name, "encoder-only arch: no decode step"))
        else:
            out.append((name, "pure full-attention arch: no sub-quadratic "
                              "path for 500k decode"))
    return out
