"""qwen2-vl-2b — VLM transformer backbone with M-RoPE.

[arXiv:2409.12191; hf]  28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936.  The vision frontend is a STUB per the brief: ``input_specs``
provides precomputed patch embeddings; M-RoPE splits the head dim into
(temporal, height, width) rotary sections.
"""

from .base import VLM, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family=VLM,
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    qkv_bias=True,
    rope="mrope",
    rope_theta=1e6,
    frontend="patch",
    tie_embeddings=True,
)
