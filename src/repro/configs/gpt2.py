"""GPT-2 (medium) — the paper's primary evaluation model (Table 4/5, §6.1).

Paper Table 7: 24L hidden=1024 16H d_ff=4096 vocab=50257, GELU MLP,
LayerNorm, learned positions (modeled as rope="none").
"""

from .base import DENSE, ModelConfig

CONFIG = ModelConfig(
    name="gpt2",
    family=DENSE,
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=50257,
    activation="gelu",
    gated_ffn=False,
    norm="layernorm",
    rope="none",
    tie_embeddings=True,
)

# Paper Table 7 companions (Fig. 9 / Fig. 10 studies).
PAPER_QWEN = ModelConfig(
    name="paper-qwen2.5-0.5b", family=DENSE, num_layers=24, d_model=896,
    num_heads=14, num_kv_heads=2, d_ff=4864, vocab_size=151936,
    qkv_bias=True, rope="rope", tie_embeddings=True)

PAPER_LLAMA = ModelConfig(
    name="paper-llama3.2-1b", family=DENSE, num_layers=22, d_model=2048,
    num_heads=32, num_kv_heads=4, d_ff=5632, vocab_size=128256,
    rope="rope", rope_theta=500_000.0, tie_embeddings=True)

PAPER_GEMMA = ModelConfig(
    name="paper-gemma-2b", family=DENSE, num_layers=26, d_model=1152,
    num_heads=4, num_kv_heads=1, d_ff=6912, vocab_size=262144,
    activation="gelu", rope="rope", tie_embeddings=True)
