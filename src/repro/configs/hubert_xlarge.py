"""hubert-xlarge — encoder-only audio transformer (wav2vec2-style backbone).

[arXiv:2106.07447; unverified]  48L d_model=1280 16H (kv=16) d_ff=5120
vocab=504 (target codebook).  The conv feature extractor is a STUB per the
brief: ``input_specs`` provides precomputed frame embeddings.  Bidirectional
(non-causal) attention; no decode shapes.
"""

from .base import AUDIO, ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family=AUDIO,
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    activation="gelu",
    gated_ffn=False,
    norm="layernorm",
    rope="none",
    encoder_only=True,
    causal=False,
    frontend="frame",
    tie_embeddings=False,
)
