"""zamba2-2.7b — hybrid Mamba2 backbone + shared attention blocks.

[arXiv:2411.15242; hf]  54L d_model=2560 32H (GQA kv=32) d_ff=10240
vocab=32000, ssm_state=64.  A single *shared-parameter* attention+MLP block
is applied every 6 Mamba2 layers (Zamba-style parameter sharing).
"""

from .base import HYBRID, ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family=HYBRID,
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    activation="gelu",
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    conv_width=4,
    shared_attn_every=6,
    rope="rope",
    tie_embeddings=True,
)
