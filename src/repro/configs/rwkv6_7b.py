"""rwkv6-7b (Finch) — attention-free RNN with data-dependent decay.

[arXiv:2404.05892; hf]  32L d_model=4096 d_ff=14336 vocab=65536.
Time-mix (wkv6 recurrence, 64 heads of dim 64) + channel-mix blocks;
O(1) state per token at decode.
"""

from .base import SSM, ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family=SSM,
    num_layers=32,
    d_model=4096,
    num_heads=0,
    num_kv_heads=0,
    d_ff=14336,
    vocab_size=65536,
    rwkv=True,
    rwkv_head_dim=64,
    rope="none",
    norm="layernorm",
    tie_embeddings=False,
)
