"""Serving engine: continuous batching over a paged KV cache.

Requests enter a queue and are admitted to cache slots *individually*, the
moment a slot frees up — there is no wave barrier.  Each slot carries its
own write position, so a request prefilled at length 11 decodes next to
one at length 300 inside the same jitted dispatch, and a request that
finishes mid-stream hands its slot (and its KV pages) to the next pending
request while the others keep decoding.

Decode hot loop (§Perf):

  * The KV cache is PAGED (``kv_cache.PagedKVCache``): fixed-size pages,
    a ``[slots, max_pages]`` device page table, host-side free-list
    allocation.  Bytes-in-use is ``pages_used * page_bytes`` instead of
    the contiguous ``slots * max_len`` worst case; pages are allocated
    just ahead of each decode block and returned the moment a request
    retires.  ``paged=False`` keeps the PR-1 contiguous slot cache (same
    continuous scheduler) for A/B benchmarking.
  * Decode attention streams K/V pages through the page-table indirection
    in the ``paged_attention`` Pallas kernel when the StreamPlan selects
    it (``use_fused_kernels``); eager configs run the gather-pages
    reference path.  Either way the math bit-matches the contiguous
    eager decode.
  * The cache is DONATED through prefill placement and decode dispatches,
    so K/V updates happen in place; decode runs ``decode_block`` ticks
    per jitted dispatch as a ``lax.scan`` over ``decode_step`` with
    per-slot position/length vectors.
  * Prefill is per-request (batch 1) at the request's own length and is
    placed at the slot's own offset — no same-length-wave assumption.
    Inactive slots ride along in decode dispatches writing into the NULL
    page (paged) or their own masked rows (contiguous); their outputs are
    discarded on the host.

Metrics count REAL work: ``generated`` is tokens actually delivered to
requests (padding slots and past-budget scan ticks excluded), ``ticks``
is the per-dispatch maximum of useful ticks, and ``scan_ticks`` is what
the hardware executed — their ratio is the block-decode efficiency.
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ..configs.base import ModelConfig
from ..models import decode_step, init_cache, prefill, resolve_plan
from ..models.params import cache_leaf_kind, cache_leaf_name
from .kv_cache import PagedKVCache, place_prefill

Tree = Any


@dataclass
class Request:
    rid: int
    prompt: np.ndarray              # [S] int32 (or embeds [S, D])
    max_new_tokens: int = 16
    out_tokens: List[int] = field(default_factory=list)
    done: bool = False
    submitted_at: float = 0.0
    first_token_at: float = 0.0
    finished_at: float = 0.0

    @property
    def ttft_s(self) -> float:
        return self.first_token_at - self.submitted_at

    @property
    def latency_s(self) -> float:
        return self.finished_at - self.submitted_at


def _place_cache_slot(cache: Tree, fresh: Tree, slot: jax.Array) -> Tree:
    """Write a batch-1 prefill cache into one slot of the contiguous cache.

    Every leaf places at ``(0, slot, 0, ...)``: K/V leaves fill the slot's
    sequence prefix (an in-place ``dynamic_update_slice`` under donation),
    state leaves replace the slot row.  Leaf classification goes through
    the shared schema — an unregistered leaf raises instead of being
    silently whole-replaced.
    """
    def place(path, big, small):
        cache_leaf_kind(cache_leaf_name(path))      # validate: kv or state
        start = (jnp.int32(0), slot) + (jnp.int32(0),) * (big.ndim - 2)
        return lax.dynamic_update_slice(big, small.astype(big.dtype), start)
    return jax.tree_util.tree_map_with_path(place, cache, fresh)


class ServingEngine:
    """Continuously-batched greedy generation over a fixed slot count."""

    def __init__(self, cfg: ModelConfig, params: Tree, *,
                 batch_slots: int = 4, max_len: int = 256,
                 decode_block: int = 16, paged: bool = True,
                 page_size: Optional[int] = None):
        self.cfg = cfg
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        self.decode_block = max(1, decode_block)
        self.paged = paged

        if page_size is None:
            # Page size = the StreamPlan's KV stream granule (the raw DSE
            # tile its paged-attention choice carries); 16 when eager.
            plan = resolve_plan(cfg, batch_slots, kv_len=max_len)
            page_size = (plan.decode_page_size(16) if plan is not None
                         else 16)

        if paged:
            self.kv: Optional[PagedKVCache] = PagedKVCache(
                cfg, slots=batch_slots, max_len=max_len,
                page_size=page_size)
            self._slot_cache = self.kv.init_cache()

            def _prefill_into(p, batch, slot_cache, slot, pages):
                logits, fresh = prefill(p, cfg, batch)
                placed = place_prefill(slot_cache, fresh, slot, pages,
                                       layout=cfg.kv_cache_layout)
                return (jnp.argmax(logits, axis=-1).astype(jnp.int32),
                        placed)

            def _decode_n(p, tok, cache, table, pos, lengths):
                def tick(carry, _):
                    tok, cache, pos, lengths = carry
                    nt, _lg, cache = decode_step(p, cfg, tok, cache, pos,
                                                 lengths, page_table=table)
                    return (nt, cache, pos + 1, lengths + 1), nt[:, 0]

                carry, toks = lax.scan(tick, (tok, cache, pos, lengths),
                                       None, length=self.decode_block)
                return carry[0], carry[1], toks          # toks: [N, B]
        else:
            self.kv = None
            self._slot_cache = init_cache(cfg, batch_slots, max_len)

            def _prefill_into(p, batch, slot_cache, slot):
                logits, fresh = prefill(p, cfg, batch)
                placed = _place_cache_slot(slot_cache, fresh, slot)
                return (jnp.argmax(logits, axis=-1).astype(jnp.int32),
                        placed)

            def _decode_n(p, tok, cache, pos, lengths):
                def tick(carry, _):
                    tok, cache, pos, lengths = carry
                    nt, _lg, cache = decode_step(p, cfg, tok, cache, pos,
                                                 lengths)
                    return (nt, cache, pos + 1, lengths + 1), nt[:, 0]

                carry, toks = lax.scan(tick, (tok, cache, pos, lengths),
                                       None, length=self.decode_block)
                return carry[0], carry[1], toks

        # Donate the slot cache through both dispatches: K/V page scatters
        # and state-row updates happen in place, not as full-pool copies.
        self._prefill = jax.jit(_prefill_into, donate_argnums=(2,))
        self._decode = jax.jit(_decode_n, donate_argnums=(2,))

        # Reserved K/V bytes: pool size (paged) / worst-case slot rows
        # (contiguous) — the paged win is measured against bytes-IN-USE.
        self.kv_bytes_reserved = sum(
            leaf.nbytes for path, leaf in
            jax.tree_util.tree_flatten_with_path(self._slot_cache)[0]
            if cache_leaf_kind(cache_leaf_name(path)) == "kv")
        self.metrics: Dict[str, float] = {
            "dispatches": 0, "ticks": 0, "scan_ticks": 0, "generated": 0,
            "prefills": 0, "decode_block": self.decode_block,
            "paged": int(paged),
            "page_size": self.kv.page_size if self.kv else 0,
            "kv_bytes_reserved": self.kv_bytes_reserved,
            "kv_bytes_peak": 0,
        }

    # -------------------------------------------------------------- API
    def generate(self, prompts: List[np.ndarray],
                 max_new_tokens: int = 16) -> List[Request]:
        """Serve a list of prompts (any mix of lengths) to completion."""
        reqs = [Request(rid=i, prompt=np.asarray(p),
                        max_new_tokens=max_new_tokens,
                        submitted_at=time.perf_counter())
                for i, p in enumerate(prompts)]
        pending = deque(reqs)
        active: List[Optional[Request]] = [None] * self.slots
        pos = np.zeros(self.slots, np.int32)        # == per-slot length
        tok = np.zeros((self.slots, 1), np.int32)

        while pending or any(r is not None for r in active):
            self._admit_pending(pending, active, pos, tok)
            if not any(r is not None for r in active):
                break                                # nothing admitted ran
            self._decode_block(active, pos, tok)
        if self.kv is not None:
            self.metrics["kv_bytes_peak"] = max(
                self.metrics["kv_bytes_peak"], self.kv.peak_bytes_in_use)
        else:
            self.metrics["kv_bytes_peak"] = self.kv_bytes_reserved
        return reqs

    # ------------------------------------------------------- scheduling
    def _admit_pending(self, pending, active, pos, tok) -> None:
        """Fill every free slot from the queue — called between decode
        dispatches, so requests join mid-stream."""
        for s in range(self.slots):
            while active[s] is None and pending:
                r = pending.popleft()
                self._admit(s, r, pos, tok)
                if (len(r.out_tokens) >= r.max_new_tokens
                        or pos[s] >= self.max_len):
                    self._retire(s, r, active, pos, tok)  # prefill-only
                else:
                    active[s] = r

    def _admit(self, slot: int, r: Request, pos, tok) -> None:
        plen = int(r.prompt.shape[0])
        if plen > self.max_len:
            raise ValueError(
                f"prompt length {plen} exceeds max_len {self.max_len}")
        batch = {"tokens": jnp.asarray(r.prompt)[None]}
        if self.kv is not None:
            pages = jnp.asarray(self.kv.ensure(slot, plen))
            next_tok, cache = self._prefill(
                self.params, batch, self._slot_cache, jnp.int32(slot),
                pages)
        else:
            next_tok, cache = self._prefill(
                self.params, batch, self._slot_cache, jnp.int32(slot))
        # Reassign immediately after every donating dispatch: the donated
        # input buffer is deleted on accelerator backends, and a mid-wave
        # exception must not leave the engine holding a dead reference.
        self._slot_cache = cache
        t = int(np.asarray(next_tok)[0, 0])
        r.out_tokens.append(t)
        r.first_token_at = time.perf_counter()
        pos[slot] = plen
        tok[slot, 0] = t
        self.metrics["prefills"] += 1
        self.metrics["generated"] += 1

    def _retire(self, slot: int, r: Request, active, pos, tok) -> None:
        r.done = True
        r.finished_at = time.perf_counter()
        active[slot] = None
        pos[slot] = 0
        tok[slot, 0] = 0
        if self.kv is not None:
            self.kv.release(slot)

    def _decode_block(self, active, pos, tok) -> None:
        """One jitted dispatch: ``decode_block`` scan ticks across all
        slots, each at its own position; harvest real tokens after."""
        if self.kv is not None:
            for s, r in enumerate(active):
                if r is not None:
                    # Allocate only what the request's remaining budget can
                    # validly read back: scan ticks past the budget write
                    # into unallocated positions, which route to the NULL
                    # page, and their outputs are discarded below.
                    h = min(self.decode_block,
                            r.max_new_tokens - len(r.out_tokens))
                    self.kv.ensure(s, min(int(pos[s]) + h, self.max_len))
            next_tok, cache, toks = self._decode(
                self.params, jnp.asarray(tok), self._slot_cache,
                self.kv.page_table, jnp.asarray(pos), jnp.asarray(pos))
        else:
            next_tok, cache, toks = self._decode(
                self.params, jnp.asarray(tok), self._slot_cache,
                jnp.asarray(pos), jnp.asarray(pos))
        self._slot_cache = cache
        toks_np = np.asarray(toks)                   # [N, slots]
        last_np = np.asarray(next_tok)               # [slots, 1]
        useful = 0
        for s, r in enumerate(list(active)):
            if r is None:
                continue
            h = min(self.decode_block,
                    r.max_new_tokens - len(r.out_tokens),
                    self.max_len - int(pos[s]))
            r.out_tokens.extend(int(t) for t in toks_np[:h, s])
            useful = max(useful, h)
            self.metrics["generated"] += h
            pos[s] = min(int(pos[s]) + self.decode_block, self.max_len)
            tok[s, 0] = last_np[s, 0]
            if (len(r.out_tokens) >= r.max_new_tokens
                    or pos[s] >= self.max_len):
                self._retire(s, r, active, pos, tok)
        self.metrics["dispatches"] += 1
        self.metrics["ticks"] += useful
        self.metrics["scan_ticks"] += self.decode_block
