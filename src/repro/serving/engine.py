"""Serving engine: continuous batching over a paged KV cache.

Requests enter a queue and are admitted to cache slots *individually*, the
moment a slot frees up — there is no wave barrier.  Each slot carries its
own write position, so a request prefilled at length 11 decodes next to
one at length 300 inside the same jitted dispatch, and a request that
finishes mid-stream hands its slot (and its KV pages) to the next pending
request while the others keep decoding.

Chunked prefill (§Perf, DESIGN.md §8b): with the paged cache, prompts are
prefilled in FIXED-SIZE chunks — a plan-derived multiple of the KV page
size, so chunk boundaries land on page boundaries — through ONE compiled
``prefill_chunk`` program whose offset/page-id operands are traced
scalars.  The compile count is therefore independent of the prompt-length
mix: a burst of 20 distinct lengths compiles one prefill program plus one
decode program, where the per-length path compiled 20.  Chunk *k* writes
its K/V into its pages and attends to chunks 0..k-1 through the same
pools the decode step appends to, and a half-prefilled request yields the
device between chunks: a token-budget scheduler hands each dispatch
either prefill chunks, a decode block, or both, so arrivals no longer
serialize behind whole-prompt prefills.  SSM/RWKV/hybrid configs (whose
recurrent state cannot yet resume mid-prompt) and the contiguous cache
fall back to whole-prompt prefill automatically.

Admission contract: an empty or over-long (``plen > max_len``) prompt is
FAILED at admission (``Request.failed`` + ``Request.error``) without ever
taking a slot or a page — it cannot strand the requests already decoding.
A slot abandoned MID-prefill (allocator failure between chunks) fails the
same way: its already-placed pages return to the allocator exactly once
(refcounted release — see ``kv_cache.assert_page_accounting``).

Prefix cache (DESIGN.md §10): in chunked mode the engine threads
admission through a radix-tree prefix walk (``serving/prefix_cache.py``)
— a request whose prompt shares page-aligned chunks with earlier traffic
claims the cached physical pages into its table row and prefills only
the divergent tail; on slot exit the pages stay cached in the tree until
memory pressure evicts them.  ``prefix_bootstrap=True`` additionally
claims partial tail pages and serves a fully-cached prompt through the
decode path alone (one dispatch to first token), copy-on-writing the
shared last page before the first append.  ``admission=`` picks the
queue order: FIFO (default), shortest-job-first, or
longest-cached-prefix-first.

Scheduler knobs: the chunked-prefill token budget is backlog-adaptive
(``_prefill_budget``), and ``adaptive_decode_block=True`` additionally
scales the decode scan length with the active-slot count — floored at
the static ``decode_block``, stepped in power-of-two multiples (bounded
compile count), pulled back by the ``decode_eff`` EMA when scan ticks
are being wasted.

Mesh-aware serving (DESIGN.md §9): constructed with ``mesh=``, the engine
resolves its StreamPlan against the mesh (per-stage sharding decisions),
creates the paged K/V pools ``kv_heads``-sharded over the model axis with
a replicated page table, replicates the weights onto the mesh, and traces
every dispatch under ``use_mesh`` so the plan-selected Pallas kernels run
inside ``shard_map`` — the same code path serves one device, the forced
8-virtual-device CPU mesh, and a real cluster, and greedy tokens match
the single-device engine.

Decode hot loop (§Perf):

  * The KV cache is PAGED (``kv_cache.PagedKVCache``): fixed-size pages,
    a ``[slots, max_pages]`` device page table, host-side free-list
    allocation.  Bytes-in-use is ``pages_used * page_bytes`` instead of
    the contiguous ``slots * max_len`` worst case; pages are allocated
    just ahead of each decode block and returned the moment a request
    retires.  ``paged=False`` keeps the PR-1 contiguous slot cache (same
    continuous scheduler) for A/B benchmarking.
  * Decode attention streams K/V pages through the page-table indirection
    in the ``paged_attention`` Pallas kernel when the StreamPlan selects
    it (``use_fused_kernels``); eager configs run the gather-pages
    reference path.  Either way the math bit-matches the contiguous
    eager decode.
  * The cache is DONATED through prefill placement and decode dispatches,
    so K/V updates happen in place; decode runs ``decode_block`` ticks
    per jitted dispatch as a ``lax.scan`` over ``decode_step`` with
    per-slot position/length vectors.
  * Slots that are idle — or parked mid-prefill with live pages — ride
    along in decode dispatches with their write position at the table
    extent, so ``paged_append`` routes their writes to the NULL page and
    a half-prefilled slot's K/V survives interleaved decode blocks; their
    outputs are discarded on the host.

Self-speculative decoding (DESIGN.md §11): with ``speculative=True`` the
decode scan is replaced by draft-then-verify.  Each pass drafts up to
``draft_len`` token guesses per slot from cheap host-side sources (the
prefix-cache radix tree via ``PrefixCache.suggest``, then n-gram
prompt-lookup over the slot's own history), stacks ``[pending, d1..dk]``
into a ``[slots, W]`` window, and scores every position with ONE paged
``verify_step`` dispatch.  A draft is accepted while it equals the
previous row's greedy argmax — acceptance can only ever keep tokens the
model itself would have produced, so greedy outputs are BIT-IDENTICAL to
the non-speculative engine; a repetitive stretch delivers up to
``draft_len + 1`` tokens per dispatch, a cold stretch still delivers one.
Rejected rows leave K/V garbage past the new write head; wholly-stale
pages roll back through ``PagedKVCache.rollback_extent`` (refcount-
checked: draft pages are freshly allocated and never tree-adopted, so
rollback can never free a shared prefix page).  Window widths come from
a <=3-rung ladder, so the verify program compiles at most three times.

Metrics count REAL work: ``generated`` is tokens actually delivered to
requests (padding slots and past-budget scan ticks excluded), ``ticks``
is the per-dispatch maximum of useful ticks, ``scan_ticks`` is what the
hardware executed — their ratio is the block-decode efficiency — and
``prefill_traces`` / ``decode_traces`` count jit RETRACES of the two
dispatch programs (a trace-time probe: the traced Python body bumps a
host counter), the compile-storm signal this engine exists to flatten.
"""

from __future__ import annotations

import os
import time
import warnings as _warnings
from collections import deque
from contextlib import ExitStack, nullcontext
from dataclasses import dataclass, field, replace
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig
from ..distributed.context import use_mesh
from ..models import (decode_step, init_cache, prefill, resolve_plan,
                      supports_chunked_prefill, supports_speculative,
                      verify_step)
from ..models import prefill_chunk as _model_prefill_chunk
from ..models.params import cache_leaf_kind, cache_leaf_name
from ..obs import (DISPATCH_DECODE, DISPATCH_PREFILL,
                   DISPATCH_PREFILL_CHUNK, DISPATCH_VERIFY, MetricsView,
                   Registry, REQ_ADMITTED, REQ_FINISHED, REQ_FIRST_TOKEN,
                   REQ_PREFILL_CHUNK, REQ_QUEUED, REQ_REJECTED,
                   SCHED_BUDGET, TRACE_DECODE, TRACE_PREFILL, TRACE_VERIFY,
                   TRACK_ENGINE, TRACK_SCHED, resolve_recorder, slot_track)
from .kv_cache import (NULL_PAGE, PagedKVCache, cdiv, place_prefill,
                       stage_chunk)
from .prefix_cache import PrefixCache

Tree = Any


@dataclass
class Request:
    rid: int
    prompt: np.ndarray              # [S] int32 (or embeds [S, D])
    max_new_tokens: int = 16
    out_tokens: List[int] = field(default_factory=list)
    done: bool = False
    failed: bool = False
    error: Optional[str] = None
    prefill_pos: int = 0            # prompt tokens already prefilled
    # Lifecycle stamps, all on the ENGINE's clock (``ServingEngine.clock``
    # — the injectable obs clock, ``time.perf_counter`` by default), so
    # request latencies and the trace's dispatch spans share one
    # timebase.  0.0 means "hasn't happened"; the derived properties
    # below return ``nan`` until their stamps exist and a finite value
    # forever after — an admission-REJECTED request still gets a real
    # ``finished_at`` (it failed AT a wall-clock time), so its
    # ``latency_s`` is finite while its ``ttft_s`` stays nan.
    submitted_at: float = 0.0
    admitted_at: float = 0.0
    first_token_at: float = 0.0
    finished_at: float = 0.0

    @property
    def ttft_s(self) -> float:
        """Time to first token; ``nan`` until a first token exists (never
        admitted, failed at admission, or still queued)."""
        if self.first_token_at <= 0.0 or self.submitted_at <= 0.0:
            return float("nan")
        return self.first_token_at - self.submitted_at

    @property
    def latency_s(self) -> float:
        """Submit-to-finish wall time; ``nan`` until the request finished
        (and for requests that never entered the engine)."""
        if self.finished_at <= 0.0 or self.submitted_at <= 0.0:
            return float("nan")
        return self.finished_at - self.submitted_at

    @property
    def queue_wait_s(self) -> float:
        """Submit-to-admission wait; ``nan`` until the request takes a
        slot (rejected requests never do)."""
        if self.admitted_at <= 0.0 or self.submitted_at <= 0.0:
            return float("nan")
        return self.admitted_at - self.submitted_at

    @property
    def tpot_s(self) -> float:
        """Time per output token AFTER the first (decode steady-state):
        ``(finished - first_token) / (n_tokens - 1)``.  ``nan`` until
        finished, and for requests that produced fewer than two tokens
        (a single token has no inter-token gap)."""
        n = len(self.out_tokens)
        if (n < 2 or self.finished_at <= 0.0
                or self.first_token_at <= 0.0):
            return float("nan")
        return (self.finished_at - self.first_token_at) / (n - 1)


def _ngram_continuation(hist: np.ndarray, k: int) -> List[int]:
    """Prompt-lookup drafting: find the most recent EARLIER occurrence of
    the history's trailing n-gram (n = 3, then 2) and return up to ``k``
    of the tokens that followed it.  Pure host work — one vectorized
    sliding-window compare per n."""
    n_tok = int(hist.shape[0])
    for n in (3, 2):
        if n_tok <= n:
            continue
        tail = hist[-n:]
        # Windows over hist[:-1] end strictly before the last token, so
        # the trailing n-gram can never match itself.
        win = np.lib.stride_tricks.sliding_window_view(hist[:-1], n)
        hits = np.nonzero((win == tail[None, :]).all(axis=1))[0]
        if hits.size:
            i = int(hits[-1])
            return [int(t) for t in hist[i + n:i + n + k]]
    return []


def _place_cache_slot(cache: Tree, fresh: Tree, slot: jax.Array) -> Tree:
    """Write a batch-1 prefill cache into one slot of the contiguous cache.

    Every leaf places at ``(0, slot, 0, ...)``: K/V leaves fill the slot's
    sequence prefix (an in-place ``dynamic_update_slice`` under donation),
    state leaves replace the slot row.  Leaf classification goes through
    the shared schema — an unregistered leaf raises instead of being
    silently whole-replaced.
    """
    def place(path, big, small):
        cache_leaf_kind(cache_leaf_name(path))      # validate: kv or state
        start = (jnp.int32(0), slot) + (jnp.int32(0),) * (big.ndim - 2)
        return lax.dynamic_update_slice(big, small.astype(big.dtype), start)
    return jax.tree_util.tree_map_with_path(place, cache, fresh)


class ServingEngine:
    """Continuously-batched greedy generation over a fixed slot count."""

    def __init__(self, cfg: ModelConfig, params: Tree, *,
                 batch_slots: int = 4, max_len: int = 256,
                 decode_block: int = 16, paged: bool = True,
                 page_size: Optional[int] = None,
                 prefill_chunk: Optional[int] = None,
                 chunked: Optional[bool] = None,
                 prefix_cache: Optional[bool] = None,
                 prefix_bootstrap: bool = False,
                 admission: str = "fifo",
                 adaptive_decode_block: bool = False,
                 speculative: bool = False, draft_len: int = 4,
                 quant: Optional[str] = None,
                 verify: Optional[str] = None,
                 autotune=None,
                 telemetry=None, clock=None,
                 mesh=None):
        # Quantized serving (DESIGN.md §14): ``quant=`` overrides the
        # config's QuantMode for this engine — the plan, kernel choices,
        # and paged pool dtypes all key off ``cfg.quant`` downstream.
        if quant is not None and quant != cfg.quant:
            cfg = replace(cfg, quant=quant)
        if cfg.kv_quant and not paged:
            raise ValueError("KV quantization requires the paged cache "
                             "(per-page scale pools ride next to the "
                             "page pools)")
        self.cfg = cfg
        self.mesh = mesh
        if admission not in ("fifo", "sjf", "prefix"):
            raise ValueError(f"unknown admission policy {admission!r} "
                             "(fifo | sjf | prefix)")
        self.admission = admission
        self.adaptive_decode_block = adaptive_decode_block
        if mesh is not None:
            # Replicate the weights onto the mesh's device set so every
            # dispatch (and the shard_maps inside) sees mesh-resident
            # inputs; the fused wrappers re-slice per the plan's claims.
            params = jax.device_put(
                params, jax.tree.map(lambda _: NamedSharding(mesh, P()),
                                     params))
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        self.decode_block = max(1, decode_block)
        self.paged = paged
        # Trace-time probes: the traced bodies below bump these counters,
        # so they count PROGRAMS BUILT, not dispatches — the engine's
        # compile-storm signal.
        self._traces: Dict[str, int] = {"prefill": 0, "decode": 0,
                                        "verify": 0}
        # Telemetry (DESIGN.md §17): ``telemetry=`` is None/False (off,
        # zero-overhead NULL recorder), True (fresh Recorder), or a
        # Recorder instance; ``clock=`` injects the monotonic clock BOTH
        # the recorder and the Request lifecycle stamps use, so spans and
        # latencies share one timebase (and tests run deterministic).
        self.obs = resolve_recorder(telemetry, clock=clock)
        self.clock = (clock if clock is not None
                      else (self.obs.clock if self.obs.enabled
                            else time.perf_counter))
        # EMA of per-dispatch useful-tick fraction — the adaptive prefill
        # budget's decode-pressure signal (1.0 = every scan tick useful).
        self.decode_eff = 1.0

        # Measured-latency autotuning (DESIGN.md §16): ``autotune=`` is a
        # bool / table path / TuneTable / Tuner.  The resolved tuner is
        # installed (via contextvar, like the mesh) around every plan
        # resolution AND dispatch trace, so the model entry points —
        # which re-resolve plans at their own token counts — pick up
        # tuned block/page choices too.  Tune once at first start,
        # load-and-reuse thereafter: a warm table scores every candidate
        # from disk and performs zero measurements.
        from ..tuning.autotune import resolve_tuner, use_tuner
        self._use_tuner = use_tuner
        self.tuner = resolve_tuner(autotune, cfg)
        if self.tuner is not None:
            self.tuner.obs = self.obs
            for d in self.tuner.table.diagnostics:
                _warnings.warn(f"autotune table degraded: {d}")

        # One plan resolution drives both stream granularities: the KV
        # page size (decode) and the prefill chunk size (a multiple of
        # it) — resolved under the mesh so the plan carries the per-stage
        # sharding decisions (kept on ``self.plan``: the stage records the
        # sharded-serving tests assert against).  None when eager.
        with self._mesh_ctx():
            plan = resolve_plan(cfg, batch_slots, kv_len=max_len)
        self.plan = plan
        if page_size is None:
            # Page size = the StreamPlan's KV stream granule (the raw DSE
            # tile its paged-attention choice carries); 16 when eager.
            page_size = (plan.decode_page_size(16) if plan is not None
                         else 16)

        # Static verification (DESIGN.md §15): run the stream verifier
        # over the resolved plan + pool schema + dispatch effect
        # signatures BEFORE anything is traced.  strict (default) refuses
        # to build an engine whose plan carries error diagnostics; warn
        # reports and proceeds; off skips.  The plan records the outcome
        # (``summary()["verified"]``/``["diagnostics"]``).
        vmode = (verify if verify is not None
                 else os.environ.get("REPRO_VERIFY", "strict"))
        if vmode not in ("strict", "warn", "off"):
            raise ValueError(f"unknown verify mode {vmode!r} "
                             "(strict | warn | off)")
        self.verify_mode = vmode
        if vmode != "off" and plan is not None:
            from ..analysis import (PlanVerificationError, errors as
                                    _diag_errors, verify_plan)
            diags = verify_plan(
                plan, cfg, mesh=mesh,
                slots=batch_slots if paged else None,
                max_len=max_len if paged else None,
                page_size=min(page_size, max_len) if paged else None)
            errs = _diag_errors(diags)
            plan = plan.with_verification(
                not errs, tuple(str(d) for d in diags))
            self.plan = plan
            if errs:
                if vmode == "strict":
                    raise PlanVerificationError(diags)
                _warnings.warn("StreamPlan failed static verification: "
                               + "; ".join(str(d) for d in errs))

        if chunked is None:
            chunked = paged and supports_chunked_prefill(cfg)
        if chunked and not paged:
            raise ValueError("chunked prefill requires the paged cache "
                             "(chunks carry between dispatches in the "
                             "page pools)")
        if chunked and not supports_chunked_prefill(cfg):
            raise ValueError(
                f"config {cfg.name!r} does not support chunked prefill "
                "(SSM/RWKV state or mrope positions)")
        self.chunked = chunked

        # Self-speculative decoding (DESIGN.md §11): draft cheap guesses
        # on the host, score draft_len + 1 positions with one verify
        # dispatch, keep the longest prefix matching the model's own
        # greedy argmax.  Acceptance can only keep tokens greedy decode
        # would have produced, so outputs bit-match the plain engine.
        self.draft_len = int(draft_len)
        if speculative:
            if not paged:
                raise ValueError("speculative decoding requires the paged "
                                 "cache (rejection rolls back the slot's "
                                 "page-table extent)")
            if not supports_speculative(cfg):
                raise ValueError(
                    f"config {cfg.name!r} does not support speculative "
                    "decoding (recurrent state cannot roll back)")
            if self.draft_len < 1:
                raise ValueError("draft_len must be >= 1")
            if plan is not None:
                # The plan clamps the verify window to its KV stream
                # granule: a window wider than one page spans page
                # boundaries mid-row for no measured gain.
                self.draft_len = min(
                    self.draft_len, plan.verify_window(self.draft_len) - 1)
        self.speculative = bool(speculative)
        # Verify-window ladder: each distinct width W is one compiled
        # verify program, so per-pass widths snap UP to a <=3-rung ladder
        # instead of tracking the exact draft count (which would compile
        # once per distinct count).
        self._w_ladder = tuple(sorted(
            {2, self.draft_len // 2 + 1, self.draft_len + 1} - {0, 1}))
        # Tests flip this on to run the allocator's full accounting
        # audit after every rollback (churn soaks).
        self._debug_check_pages = False

        if paged:
            self.kv: Optional[PagedKVCache] = PagedKVCache(
                cfg, slots=batch_slots, max_len=max_len,
                page_size=page_size, mesh=mesh, obs=self.obs)
            self._slot_cache = self.kv.init_cache()

            def _prefill_into(p, batch, slot_cache, slot, pages):
                self._traces["prefill"] += 1
                self.obs.instant(TRACE_PREFILL, track=TRACK_ENGINE)
                logits, fresh = prefill(p, cfg, batch)
                placed = place_prefill(slot_cache, fresh, slot, pages,
                                       layout=cfg.kv_cache_layout)
                return (jnp.argmax(logits, axis=-1).astype(jnp.int32),
                        placed)

            def _decode_n(p, tok, cache, table, pos, lengths, cow_src,
                          cow_dst, block):
                self._traces["decode"] += 1
                self.obs.instant(TRACE_DECODE, track=TRACK_ENGINE)
                # Copy-on-write step (prefix bootstrap): slots whose next
                # append lands inside a shared page carry a (src, dst)
                # page pair; the shared page is duplicated onto the
                # private dst in every K/V pool BEFORE the scan — inside
                # the donated dispatch, so no extra host round trip.
                # Idle slots carry NULL pairs (the NULL page copied onto
                # itself).  ``table`` already points at dst.  Traced in
                # only when bootstrap can actually produce a COW — a
                # non-bootstrap engine must not pay the no-op page
                # gather/scatter on every decode dispatch.
                if prefix_bootstrap:
                    # Page-indexed leaves — K/V pools AND their per-page
                    # scale rows — copy together (dim 1 is pages on
                    # both); state rows are slot-indexed and skip.
                    def cow(path, leaf):
                        if cache_leaf_kind(cache_leaf_name(path)) == "state":
                            return leaf
                        return leaf.at[:, cow_dst].set(leaf[:, cow_src])

                    cache = jax.tree_util.tree_map_with_path(cow, cache)

                def tick(carry, _):
                    tok, cache, pos, lengths = carry
                    nt, _lg, cache = decode_step(p, cfg, tok, cache, pos,
                                                 lengths, page_table=table)
                    return (nt, cache, pos + 1, lengths + 1), nt[:, 0]

                carry, toks = lax.scan(tick, (tok, cache, pos, lengths),
                                       None, length=block)
                return carry[0], carry[1], toks          # toks: [N, B]
        else:
            self.kv = None
            self._slot_cache = init_cache(cfg, batch_slots, max_len)

            def _prefill_into(p, batch, slot_cache, slot):
                self._traces["prefill"] += 1
                self.obs.instant(TRACE_PREFILL, track=TRACK_ENGINE)
                logits, fresh = prefill(p, cfg, batch)
                placed = _place_cache_slot(slot_cache, fresh, slot)
                return (jnp.argmax(logits, axis=-1).astype(jnp.int32),
                        placed)

            def _decode_n(p, tok, cache, pos, lengths, block):
                self._traces["decode"] += 1
                self.obs.instant(TRACE_DECODE, track=TRACK_ENGINE)

                def tick(carry, _):
                    tok, cache, pos, lengths = carry
                    nt, _lg, cache = decode_step(p, cfg, tok, cache, pos,
                                                 lengths)
                    return (nt, cache, pos + 1, lengths + 1), nt[:, 0]

                carry, toks = lax.scan(tick, (tok, cache, pos, lengths),
                                       None, length=block)
                return carry[0], carry[1], toks

        # Donate the slot cache through both dispatches: K/V page scatters
        # and state-row updates happen in place, not as full-pool copies.
        # The scan length is a STATIC arg so the adaptive decode block can
        # step it (each distinct value is one compiled program; the
        # power-of-two ladder bounds the count at three).
        self._prefill = jax.jit(_prefill_into, donate_argnums=(2,))
        self._decode = jax.jit(_decode_n, donate_argnums=(2,),
                               static_argnums=(8,) if paged else (5,))

        self._verify = None
        if self.speculative:
            def _verify_fwd(p, toks, cache, table, pos, lengths, cow_src,
                            cow_dst):
                self._traces["verify"] += 1
                self.obs.instant(TRACE_VERIFY, track=TRACK_ENGINE)
                # Same pre-scan COW as the decode dispatch: a bootstrap
                # slot's first append may land inside a shared page.
                if prefix_bootstrap:
                    def cow(path, leaf):
                        if cache_leaf_kind(cache_leaf_name(path)) == "state":
                            return leaf
                        return leaf.at[:, cow_dst].set(leaf[:, cow_src])

                    cache = jax.tree_util.tree_map_with_path(cow, cache)
                greedy, _lg, cache = verify_step(p, cfg, toks, cache, pos,
                                                 lengths, page_table=table)
                return greedy, cache

            # The window width W is baked in from ``toks.shape[1]``, so
            # each ladder rung is one compiled program (<=3 total) —
            # counted by the ``verify`` trace probe.
            self._verify = jax.jit(_verify_fwd, donate_argnums=(2,))

        if self.chunked:
            assert self.kv is not None
            ps = self.kv.page_size
            # Chunk size: the plan's prefill granule (attention block_q
            # rounded up to whole pages), page-aligned when overridden,
            # clamped to the slot's page-table extent.
            want = (prefill_chunk if prefill_chunk is not None
                    else (plan.prefill_chunk_size(ps) if plan is not None
                          else 4 * ps))
            want = cdiv(max(1, int(want)), ps) * ps
            self.chunk = max(ps, min(want, self.kv.extent))
            # The per-pass prefill token budget is adaptive — see
            # ``_prefill_budget`` (scaled by the decode backlog and the
            # measured ticks/scan_ticks block-decode efficiency).

            def _chunk_fwd(p, toks, slot_cache, row, cpages, off, last,
                           cow_src, cow_dst):
                self._traces["prefill"] += 1
                self.obs.instant(TRACE_PREFILL, track=TRACK_ENGINE)
                nt, _lg, placed = _model_prefill_chunk(
                    p, cfg, toks, slot_cache, row, cpages, off, last,
                    cow_src, cow_dst)
                return nt, placed

            self._prefill_chunk = jax.jit(_chunk_fwd, donate_argnums=(2,))
        else:
            self.chunk = 0
            self._prefill_chunk = None

        # Prefix cache: radix-tree page sharing over the paged pools
        # (DESIGN.md §10).  Defaults ON whenever chunked prefill runs —
        # the default chunk-aligned matching keeps greedy tokens
        # bit-identical to a cold engine, so sharing is a pure traffic
        # win.  ``prefix_bootstrap`` switches to page-granular matching
        # with the decode-path fast admission for fully-cached prompts.
        if prefix_cache is None:
            prefix_cache = self.chunked
        if prefix_cache and not self.chunked:
            raise ValueError("prefix_cache requires chunked prefill "
                             "(pages are shared at chunk granularity)")
        if prefix_bootstrap and not prefix_cache:
            raise ValueError("prefix_bootstrap requires prefix_cache")
        if admission == "prefix" and not prefix_cache:
            raise ValueError('admission="prefix" requires prefix_cache')
        self.prefix: Optional[PrefixCache] = None
        if prefix_cache:
            self.prefix = PrefixCache(self.kv, chunk=self.chunk,
                                      bootstrap=prefix_bootstrap,
                                      obs=self.obs)
        # Pending copy-on-write per slot: the LOGICAL page whose next
        # write must swap in a private copy (the physical src is read
        # from the table row at swap time — never cached here).
        self._cow: List[Optional[int]] = [None] * batch_slots

        # Reserved K/V bytes: pool size (paged) / worst-case slot rows
        # (contiguous) — the paged win is measured against bytes-IN-USE.
        self.kv_bytes_reserved = sum(
            leaf.nbytes for path, leaf in
            jax.tree_util.tree_flatten_with_path(self._slot_cache)[0]
            if cache_leaf_kind(cache_leaf_name(path)) in ("kv", "scale"))
        # Typed metric registry (DESIGN.md §17).  Every number the old
        # ad-hoc ``self.metrics`` dict carried is declared here with an
        # EXPLICIT lifetime — Counter (accumulates for the engine's whole
        # life), Gauge (point-in-time), Info (config/provenance string) —
        # plus the new latency histograms.  ``self.metrics`` stays a live
        # read-only Mapping over the lifetime view, so every existing
        # consumer (``dict(eng.metrics)``, key reads, counter deltas)
        # works unchanged; ``snapshot("last_generate")`` adds the
        # windowed view (``Registry.mark()`` at the top of ``generate``).
        reg = self.registry = Registry()
        for name, help in (
            ("dispatches", "decode+verify dispatches (not prefill)"),
            ("ticks", "useful decode scan ticks (max per dispatch)"),
            ("scan_ticks", "total decode scan ticks incl. wasted tail"),
            ("generated", "tokens delivered to requests"),
            ("prefills", "prompt prefills completed (incl. final chunk)"),
            ("prefill_chunks", "chunked-prefill dispatches"),
            ("rejected", "requests failed at admission or allocation"),
            ("prefill_traces", "prefill programs BUILT (trace probe)"),
            ("decode_traces", "decode programs BUILT (trace probe)"),
            ("verify_traces", "verify programs BUILT (trace probe)"),
            ("prefix_hit_pages", "prompt pages served from prefix cache"),
            ("prompt_pages", "prompt pages needed by admitted requests"),
            ("cow_copies", "copy-on-write page copies"),
            ("prefix_bootstraps", "fully-cached prompts decode-bootstrapped"),
            ("prefix_evictions", "pages evicted from the prefix cache"),
            ("draft_tokens", "speculative draft tokens proposed"),
            ("accepted_tokens", "draft tokens accepted by verify"),
            ("spec_tokens", "tokens delivered by speculative dispatches"),
            ("verify_dispatches", "speculative verify dispatches"),
            ("rollbacks", "KV extent rollbacks after rejected drafts"),
            ("rollback_pages", "pages freed by rollbacks"),
            ("tune_hits", "tune-table lookups served"),
            ("tune_misses", "tune-table lookups missed"),
            ("tune_measured", "tuner measurement dispatches"),
            ("tune_pruned", "tuner candidates pruned by lint"),
        ):
            reg.counter(name, help)
        reg.gauge("decode_block", value=self.decode_block)
        reg.gauge("paged", value=int(paged))
        reg.gauge("chunked", value=int(self.chunked))
        reg.gauge("prefill_chunk", value=self.chunk)
        reg.gauge("page_size",
                  value=self.kv.page_size if self.kv else 0)
        reg.gauge("kv_bytes_reserved", value=self.kv_bytes_reserved)
        reg.gauge("kv_bytes_peak")
        reg.gauge("kv_bytes_cached")
        reg.info("quant", value=cfg.quant)
        reg.gauge("verified", value=int(bool(self.plan.verified))
                  if self.plan is not None else 0)
        reg.gauge("kv_itemsize_effective", value=(
            self.kv.kv_itemsize_effective if self.kv is not None
            else (2.0 if cfg.dtype == "bfloat16" else 4.0)))
        reg.gauge("sched_budget")
        reg.gauge("sharded", value=int(mesh is not None))
        reg.gauge("kv_shards", value=self.kv.kv_shards if self.kv else 1)
        reg.gauge("prefix_enabled", value=int(self.prefix is not None))
        reg.gauge("prefix_hit_rate")
        reg.gauge("prefix_cached_pages")
        reg.gauge("pages_in_use")
        reg.gauge("decode_block_last", value=self.decode_block)
        reg.gauge("speculative", value=int(self.speculative))
        reg.gauge("draft_len",
                  value=self.draft_len if self.speculative else 0)
        reg.gauge("accept_rate")
        reg.gauge("dispatches_per_token")
        # Plan provenance (DESIGN.md §16): where the plan's kernel
        # latencies came from, and what the tuner did to get them.
        reg.info("plan_source",
                 value=(self.plan.cost_source if self.plan is not None
                        else "analytic"))
        reg.gauge("autotuned", value=int(self.tuner is not None))
        reg.info("tune_table",
                 value=(self.tuner.table.path or ""
                        if self.tuner is not None else ""))
        reg.gauge("tune_entries")
        # Latency distributions (log-spaced buckets, exported with
        # p50/p90/p99): request-level TTFT / TPOT / queue wait, plus
        # per-dispatch wall times for each dispatch kind.
        reg.histogram("ttft_s", "time to first token")
        reg.histogram("tpot_s", "time per output token after the first")
        reg.histogram("queue_wait_s", "submit-to-admission wait")
        reg.histogram("chunk_latency_s", "prefill-chunk dispatch wall")
        reg.histogram("prefill_dispatch_s",
                      "whole-prompt prefill dispatch wall")
        reg.histogram("decode_dispatch_s", "decode-block dispatch wall")
        reg.histogram("verify_dispatch_s", "verify dispatch wall")
        self.metrics = MetricsView(reg)
        self._refresh_tune_metrics()

    def _sync_counter(self, name: str, total: float) -> None:
        """Catch a lifetime counter up to an externally-maintained total
        (trace probes, prefix evictions, tune stats) — the delta lands in
        the current ``last_generate`` window."""
        c = self.registry[name]
        d = total - c.value()
        if d > 0:
            c.inc(d)

    def _refresh_tune_metrics(self) -> None:
        if self.tuner is None:
            return
        self._sync_counter("tune_hits", self.tuner.table.hits)
        self._sync_counter("tune_misses", self.tuner.table.misses)
        self._sync_counter("tune_measured", self.tuner.stats.measured)
        self._sync_counter("tune_pruned", self.tuner.stats.pruned)
        self.registry["tune_entries"].set(len(self.tuner.table))
        if self.plan is not None:
            self.registry["plan_source"].set(self.plan.cost_source)

    def snapshot(self, view: str = "lifetime") -> Dict[str, Any]:
        """Materialized metrics for ``view`` (``"lifetime"`` |
        ``"last_generate"``).  The lifetime view equals
        ``dict(self.metrics)``; the windowed view recomputes the derived
        rates from the WINDOW's counters (the stored gauges are lifetime
        rates — the conflation this method exists to fix)."""
        out = self.registry.snapshot(view)
        if view == "last_generate":
            reg = self.registry
            hits = reg["prefix_hit_pages"].value(view)
            out["prefix_hit_rate"] = (
                hits / max(reg["prompt_pages"].value(view), 1))
            out["accept_rate"] = (
                reg["accepted_tokens"].value(view)
                / max(reg["draft_tokens"].value(view), 1))
            out["dispatches_per_token"] = (
                reg["verify_dispatches"].value(view)
                / max(reg["spec_tokens"].value(view), 1))
        return out

    def _mesh_ctx(self):
        """Context installing the engine's mesh AND tuner for plan
        resolution and fused-wrapper shard_map dispatch (trace-time;
        no-op without either).  Every jitted call runs inside it so a
        first-call retrace always sees both."""
        stack = ExitStack()
        if self.mesh is not None:
            stack.enter_context(use_mesh(self.mesh))
        if self.tuner is not None:
            stack.enter_context(self._use_tuner(self.tuner))
        return stack

    # -------------------------------------------------------------- API
    def generate(self, prompts: List[np.ndarray],
                 max_new_tokens: int = 16) -> List[Request]:
        """Serve a list of prompts (any mix of lengths) to completion."""
        self.registry.mark()        # open the ``last_generate`` window
        reqs = [Request(rid=i, prompt=np.asarray(p),
                        max_new_tokens=max_new_tokens,
                        submitted_at=self.clock())
                for i, p in enumerate(prompts)]
        if self.obs.enabled:
            for r in reqs:
                self.obs.instant(REQ_QUEUED, track=TRACK_SCHED,
                                 ts=r.submitted_at, rid=r.rid,
                                 plen=int(r.prompt.shape[0])
                                 if r.prompt.ndim >= 1 else 0)
        pending = deque(reqs)
        active: List[Optional[Request]] = [None] * self.slots
        decoding = [False] * self.slots     # False: idle or mid-prefill
        pos = np.zeros(self.slots, np.int32)        # == per-slot length
        tok = np.zeros((self.slots, 1), np.int32)

        while pending or any(r is not None for r in active):
            self._admit_pending(pending, active, decoding, pos, tok)
            if not any(r is not None for r in active):
                break                               # nothing admitted ran
            progressed = False
            if self.chunked:
                budget = self._prefill_budget(active, decoding)
                for s in range(self.slots):
                    r = active[s]
                    if r is None or decoding[s]:
                        continue
                    if progressed and budget < self.chunk:
                        break       # budget spent; the rest wait a pass
                    self._dispatch_chunk(s, r, active, decoding, pos, tok)
                    budget -= self.chunk
                    progressed = True
            if any(active[s] is not None and decoding[s]
                   for s in range(self.slots)):
                if self.speculative:
                    self._speculative_block(active, decoding, pos, tok)
                else:
                    self._decode_block(active, decoding, pos, tok)
                progressed = True
            if not progressed:                      # defensive: no work
                break
        reg = self.registry
        if self.kv is not None:
            reg["kv_bytes_peak"].max(self.kv.peak_bytes_in_use)
            reg["pages_in_use"].set(self.kv.pages_in_use)
        else:
            reg["kv_bytes_peak"].set(self.kv_bytes_reserved)
        if self.prefix is not None:
            # Derived-rate gauges keep their historical LIFETIME
            # semantics (hit pages over ALL prompt pages ever admitted);
            # ``snapshot("last_generate")`` recomputes them per window.
            reg["prefix_hit_rate"].set(
                reg["prefix_hit_pages"].value()
                / max(reg["prompt_pages"].value(), 1))
            self._sync_counter("prefix_evictions", self.prefix.evictions)
            reg["prefix_cached_pages"].set(self.kv.pages_cached)
            reg["kv_bytes_cached"].set(self.kv.bytes_cached)
        self._sync_counter("prefill_traces", self._traces["prefill"])
        self._sync_counter("decode_traces", self._traces["decode"])
        self._sync_counter("verify_traces", self._traces["verify"])
        if self.speculative:
            reg["accept_rate"].set(
                reg["accepted_tokens"].value()
                / max(reg["draft_tokens"].value(), 1))
            reg["dispatches_per_token"].set(
                reg["verify_dispatches"].value()
                / max(reg["spec_tokens"].value(), 1))
        self._refresh_tune_metrics()
        return reqs

    # ------------------------------------------------------- scheduling
    def _prefill_budget(self, active, decoding) -> int:
        """Adaptive prefill token budget for one scheduler pass.

        The static budget, ``max(chunk, slots * decode_block)``, spends
        the same share on prefill whether zero or all other slots are
        mid-decode.  Scale by the actual split instead: each slot waiting
        on prefill contributes one chunk of budget, and the decode
        backlog (slots mid-decode) contributes only the fraction the
        measured block-decode efficiency says decode is NOT using — a
        saturated decode stream (eff ~ 1) keeps prefill to the waiting
        slots' share, a draining one (eff -> 0) lends its slack to
        prompt ingestion.  Efficiency is an EMA over recent dispatches'
        useful-tick fraction (the cumulative ``ticks``/``scan_ticks``
        counters stay pure metrics), so the signal tracks the CURRENT
        split; a cold engine counts as fully efficient so TTFT behavior
        starts at the conservative split.  At least one chunk always
        advances (the dispatch loop's ``progressed`` guard), so prefill
        can't starve either.
        """
        waiting = sum(1 for s in range(self.slots)
                      if active[s] is not None and not decoding[s])
        if not waiting:
            self.registry["sched_budget"].set(0)
            return 0
        backlog = sum(1 for s in range(self.slots)
                      if active[s] is not None and decoding[s])
        # ``decode_eff`` is an EMA of per-dispatch useful-tick fraction
        # (not the lifetime ticks/scan_ticks ratio, which would stop
        # responding once enough history accumulated).
        slack = (1.0 - self.decode_eff) * backlog    # unused decode capacity
        share = min(float(self.slots), waiting + slack)
        budget = int(self.chunk * max(1.0, share))
        self.registry["sched_budget"].set(budget)
        if self.obs.enabled:
            self.obs.instant(SCHED_BUDGET, track=TRACK_SCHED,
                             budget=budget, waiting=waiting,
                             backlog=backlog,
                             decode_eff=round(self.decode_eff, 4))
        return budget

    def _next_request(self, pending, scores=None) -> Request:
        """Pop the next request per the admission policy.  ``fifo`` is
        arrival order; ``sjf`` picks the shortest prompt (classic
        shortest-job-first: small jobs stop queueing behind big ones);
        ``prefix`` picks the longest-cached-prefix prompt (its prefill is
        mostly free NOW, and serving it while its prefix is hot avoids
        re-computing it after eviction).  Ties fall back to arrival
        order.  ``scores`` is the per-admission-pass radix-walk memo —
        the tree only changes between scheduler passes, so one walk per
        request per pass suffices (not one per slot fill)."""
        if self.admission == "fifo" or len(pending) <= 1:
            return pending.popleft()
        if self.admission == "sjf":
            idx = min(range(len(pending)),
                      key=lambda i: (int(pending[i].prompt.shape[0]), i))
        else:                                       # "prefix"
            def score(i):
                r = pending[i]
                if r.rid not in scores:
                    scores[r.rid] = self.prefix.lookup_pages(r.prompt)
                return scores[r.rid]

            idx = max(range(len(pending)), key=lambda i: (score(i), -i))
        r = pending[idx]
        del pending[idx]
        return r

    def _validate(self, r: Request) -> Optional[str]:
        """Admission check: a bad prompt must fail HERE, not mid-dispatch
        where it would strand every active request with its pages held."""
        plen = int(r.prompt.shape[0]) if r.prompt.ndim >= 1 else 0
        if plen == 0:
            return "empty prompt"
        if plen > self.max_len:
            return f"prompt length {plen} exceeds max_len {self.max_len}"
        return None

    def _admit_pending(self, pending, active, decoding, pos, tok) -> None:
        """Fill every free slot from the queue — called between dispatches,
        so requests join mid-stream.  Invalid prompts are marked failed and
        skipped; the engine keeps serving.  Chunked mode only ASSIGNS the
        slot (prefill work is scheduled chunk-by-chunk); the fallback path
        prefills the whole prompt at its own length, as before."""
        scores: Dict[int, int] = {}
        for s in range(self.slots):
            while active[s] is None and pending:
                r = self._next_request(pending, scores)
                err = self._validate(r)
                if err is not None:
                    r.failed = True
                    r.error = err
                    r.done = True
                    # A rejected request failed AT a real wall-clock time:
                    # latency_s is finite, ttft_s stays nan (no token).
                    r.finished_at = self.clock()
                    self.registry["rejected"].inc()
                    if self.obs.enabled:
                        self.obs.instant(REQ_REJECTED, track=TRACK_SCHED,
                                         ts=r.finished_at, rid=r.rid,
                                         error=err)
                    continue
                self._stamp_admitted(r, s)
                if self.chunked:
                    r.prefill_pos = 0
                    self._cow[s] = None
                    if self.prefix is not None:
                        self._admit_prefix(s, r, active, decoding, pos,
                                           tok)
                        continue
                    active[s] = r
                    decoding[s] = False
                    continue
                self._admit(s, r, pos, tok)
                if (len(r.out_tokens) >= r.max_new_tokens
                        or pos[s] >= self.max_len):
                    self._retire(s, r, active, decoding, pos, tok)
                else:
                    active[s] = r
                    decoding[s] = True

    def _stamp_admitted(self, r: Request, slot: int) -> None:
        """Request takes a slot: stamp ``admitted_at`` on the engine
        clock, observe the queue wait, emit the lifecycle instant."""
        r.admitted_at = self.clock()
        self.registry["queue_wait_s"].observe(r.queue_wait_s)
        if self.obs.enabled:
            self.obs.instant(REQ_ADMITTED, track=slot_track(slot),
                             ts=r.admitted_at, rid=r.rid, slot=slot)

    def _stamp_first_token(self, r: Request, slot: int) -> None:
        """First output token exists: stamp it, observe TTFT, emit the
        lifecycle instant.  Call sites guard on ``first_token_at <= 0``
        where a slot can reach this more than once."""
        r.first_token_at = self.clock()
        self.registry["ttft_s"].observe(r.ttft_s)
        if self.obs.enabled:
            self.obs.instant(REQ_FIRST_TOKEN, track=slot_track(slot),
                             ts=r.first_token_at, rid=r.rid,
                             ttft_s=round(r.ttft_s, 6))

    def _admit_prefix(self, slot: int, r: Request, active, decoding, pos,
                      tok) -> None:
        """Chunked admission through the prefix walk: claim every cached
        prefix page into the slot's table row and resume prefill at the
        first non-cached chunk.  Under ``prefix_bootstrap`` a fully
        cached prompt (coverage >= plen - 1) skips prefill entirely — the
        final prompt token is fed through the decode path, whose first
        append copy-on-writes the shared tail page."""
        hit = self.prefix.claim(slot, r.prompt)
        r.prefill_pos = hit.prefill_start
        self._cow[slot] = hit.cow
        self.registry["prefix_hit_pages"].inc(hit.hit_pages)
        self.registry["prompt_pages"].inc(hit.prompt_pages)
        active[slot] = r
        if not hit.full:
            decoding[slot] = False
            return
        # Bootstrap fast path: TTFT = one decode dispatch.  The claimed
        # pages hold KV for tokens 0..plen-2; the decode step computes
        # (and appends, post-COW) the final prompt token's KV and emits
        # the first output token.
        plen = int(r.prompt.shape[0])
        self.prefix.insert(slot, r.prompt)      # re-stamp; nothing new
        r.prefill_pos = plen
        decoding[slot] = True
        pos[slot] = plen - 1
        tok[slot, 0] = int(r.prompt[-1])
        self.registry["prefix_bootstraps"].inc()
        self.registry["prefills"].inc()

    def _admit(self, slot: int, r: Request, pos, tok) -> None:
        """Whole-prompt prefill at the request's own length (fallback path:
        contiguous cache, or SSM/RWKV/mrope configs).  Compiles once per
        distinct prompt length."""
        plen = int(r.prompt.shape[0])
        if plen > self.max_len:                     # guarded by _validate
            raise ValueError(
                f"prompt length {plen} exceeds max_len {self.max_len}")
        batch = {"tokens": jnp.asarray(r.prompt)[None]}
        t0 = self.clock()
        with self._mesh_ctx():
            if self.kv is not None:
                pages = jnp.asarray(self.kv.ensure(slot, plen))
                next_tok, cache = self._prefill(
                    self.params, batch, self._slot_cache, jnp.int32(slot),
                    pages)
            else:
                next_tok, cache = self._prefill(
                    self.params, batch, self._slot_cache, jnp.int32(slot))
        # Reassign immediately after every donating dispatch: the donated
        # input buffer is deleted on accelerator backends, and a mid-wave
        # exception must not leave the engine holding a dead reference.
        self._slot_cache = cache
        t = int(np.asarray(next_tok)[0, 0])
        dt = self.clock() - t0       # host-visible dispatch wall (the
        #                              np.asarray read-back synchronizes)
        self.registry["prefill_dispatch_s"].observe(dt)
        if self.obs.enabled:
            self.obs.complete(DISPATCH_PREFILL, t0, dt,
                              track=TRACK_ENGINE, slot=slot, rid=r.rid,
                              tokens=plen)
            self.obs.complete("prefill", t0, dt, track=slot_track(slot),
                              rid=r.rid, tokens=plen)
        r.out_tokens.append(t)
        self._stamp_first_token(r, slot)
        r.prefill_pos = plen
        pos[slot] = plen
        tok[slot, 0] = t
        self.registry["prefills"].inc()
        self.registry["generated"].inc()

    def _dispatch_chunk(self, slot: int, r: Request, active, decoding,
                        pos, tok) -> None:
        """One fixed-size prefill chunk through the single compiled
        ``prefill_chunk`` program; the final chunk emits the first token
        and flips the slot to decoding.  The first dispatch of a
        prefix-hit request starts at a NONZERO page-aligned offset
        against the pre-claimed table row."""
        assert self.kv is not None and self._prefill_chunk is not None
        c = self.chunk
        plen = int(r.prompt.shape[0])
        off = r.prefill_pos
        if self.prefix is not None and self._cow[slot] is None:
            # Catch-up walk: pages for our NEXT chunks may have appeared
            # since admission (a same-wave request computing the shared
            # prefix inserts as it completes) — claim them and skip ahead.
            off, caught = self.prefix.extend_claim(slot, r.prompt, off)
            if caught:
                r.prefill_pos = off
                self.registry["prefix_hit_pages"].inc(caught)
        # Pages for the chunk's span (page-aligned by construction); the
        # portion of a final chunk past max_len maps to the NULL page.
        # An allocator failure here (pool pressure with every cached page
        # still referenced) fails THIS request without stranding the
        # stream — its already-placed pages return exactly once.
        try:
            self.kv.ensure(slot, min(off + c, self.max_len))
        except RuntimeError as e:
            r.failed = True
            r.error = str(e)
            self.registry["rejected"].inc()
            self._retire(slot, r, active, decoding, pos, tok)
            return
        row = self.kv.table_row(slot)
        toks, cpages, last = stage_chunk(r.prompt, off, c, row,
                                         self.kv.page_size)
        t0 = self.clock()
        with self._mesh_ctx():
            # The COW operands ride as NULL here: the engine's matching
            # policies never hand a chunk a shared write target (default
            # mode restarts on fresh pages; bootstrap full hits COW on
            # the decode path).  The operands stay in the program for
            # API-level sub-chunk sharing (tests drive them; ROADMAP
            # names the bit-exact sub-chunk follow-on).
            next_tok, cache = self._prefill_chunk(
                self.params, jnp.asarray(toks)[None], self._slot_cache,
                jnp.asarray(row), jnp.asarray(cpages), jnp.int32(off),
                jnp.int32(last), jnp.int32(NULL_PAGE),
                jnp.int32(NULL_PAGE))
        self._slot_cache = cache
        dt = self.clock() - t0
        self.registry["chunk_latency_s"].observe(dt)
        if self.obs.enabled:
            ci = off // c
            self.obs.complete(DISPATCH_PREFILL_CHUNK, t0, dt,
                              track=TRACK_ENGINE, slot=slot, rid=r.rid,
                              chunk=ci, off=off)
            self.obs.complete("prefill_chunk", t0, dt,
                              track=slot_track(slot), rid=r.rid, chunk=ci)
            self.obs.instant(REQ_PREFILL_CHUNK, track=slot_track(slot),
                             rid=r.rid, chunk=ci, off=off)
        r.prefill_pos = min(off + c, plen)
        self.registry["prefill_chunks"].inc()
        if r.prefill_pos < plen:
            return                                  # more chunks to go
        if self.prefix is not None:
            # Prefill done: the full prompt pages are final — index them
            # so concurrent and future requests share them.
            self.prefix.insert(slot, r.prompt)
        t = int(np.asarray(next_tok)[0, 0])
        r.out_tokens.append(t)
        self._stamp_first_token(r, slot)
        pos[slot] = plen
        tok[slot, 0] = t
        decoding[slot] = True
        self.registry["prefills"].inc()
        self.registry["generated"].inc()
        if (len(r.out_tokens) >= r.max_new_tokens
                or pos[slot] >= self.max_len):
            self._retire(slot, r, active, decoding, pos, tok)

    def _retire(self, slot: int, r: Request, active, decoding, pos,
                tok) -> None:
        r.done = True
        r.finished_at = self.clock()
        if not r.failed:
            # Latency/TPOT only count completed requests; Histogram
            # ignores the nan a rejected or single-token request yields.
            self.registry["tpot_s"].observe(r.tpot_s)
        if self.obs.enabled:
            self.obs.instant(REQ_FINISHED, track=slot_track(slot),
                             ts=r.finished_at, rid=r.rid,
                             tokens=len(r.out_tokens), failed=r.failed)
        active[slot] = None
        decoding[slot] = False
        pos[slot] = 0
        tok[slot, 0] = 0
        self._cow[slot] = None
        if self.prefix is not None:
            # Slot exit: drop the tree references first (re-stamps the
            # prefix as most-recently-used), then release — exclusive
            # pages free, tree pages stay CACHED until eviction.
            self.prefix.release_slot(slot)
        if self.kv is not None:
            self.kv.release(slot)

    def _decode_block_size(self, n_active: int) -> int:
        """Scan ticks for the next decode dispatch.  Static by default;
        with ``adaptive_decode_block`` the block scales with the active-
        slot count — more slots decoding efficiently means each dispatch
        retires more real tokens, so a longer scan amortizes the fixed
        host round-trip further — floored at the static ``decode_block``
        and pulled back by the ``decode_eff`` EMA when ticks are being
        wasted (slots retiring mid-block).  Power-of-two steps capped at
        4x bound the compiled-program count at three."""
        if not self.adaptive_decode_block:
            return self.decode_block
        scale = n_active * max(self.decode_eff, 0.0)
        k = 0
        while k < 2 and (2 << k) <= scale:
            k += 1
        return self.decode_block << k

    def _decode_block(self, active, decoding, pos, tok) -> None:
        """One jitted dispatch: a block of scan ticks across all slots,
        each at its own position; harvest real tokens after."""
        runnable = [s for s in range(self.slots)
                    if active[s] is not None and decoding[s]]
        block = self._decode_block_size(len(runnable))
        self.registry["decode_block_last"].set(block)
        if self.kv is not None:
            # Pending copy-on-write pairs (prefix bootstrap: the next
            # append lands inside a shared page) — resolve them to
            # (src, dst) physical pages now so the dispatch copies the
            # shared page onto the private one before the scan; the
            # re-uploaded table already points at dst.
            cow_src = np.full(self.slots, NULL_PAGE, np.int32)
            cow_dst = np.full(self.slots, NULL_PAGE, np.int32)
            for s in list(runnable):
                r = active[s]
                try:
                    if self._cow[s] is not None:
                        cow_src[s], cow_dst[s] = self.kv.cow_page(
                            s, self._cow[s])
                        self._cow[s] = None
                        self.registry["cow_copies"].inc()
                        # The slot's reference moved off the shared src:
                        # refresh its eviction entry.
                        self.prefix.page_released(int(cow_src[s]))
                    # Allocate only what the request's remaining budget
                    # can validly read back: scan ticks past the budget
                    # write into unallocated positions, which route to
                    # the NULL page, and their outputs are discarded
                    # below.
                    h = min(block, r.max_new_tokens - len(r.out_tokens))
                    self.kv.ensure(s, min(int(pos[s]) + h, self.max_len))
                except RuntimeError as e:
                    # Pool pressure even after eviction — e.g. every
                    # page referenced across slots while a bootstrap COW
                    # needs its one transient extra page.  Fail THIS
                    # request (pages returned exactly once via the
                    # refcounted release) and keep the stream alive —
                    # same contract as the chunk path.
                    r.failed = True
                    r.error = str(e)
                    self.registry["rejected"].inc()
                    self._retire(s, r, active, decoding, pos, tok)
                    cow_src[s] = cow_dst[s] = NULL_PAGE
            runnable = [s for s in runnable
                        if active[s] is not None and decoding[s]]
            if not runnable:
                return
            # Idle slots AND slots parked mid-prefill ride along with
            # their write position at the table extent: paged_append
            # routes those writes to the NULL page, so a half-prefilled
            # slot's pages survive the decode blocks between its chunks.
            dpos = np.full(self.slots, self.kv.extent, np.int32)
            dlen = np.zeros(self.slots, np.int32)
            for s in runnable:
                dpos[s] = pos[s]
                dlen[s] = pos[s]
            t0 = self.clock()
            with self._mesh_ctx():
                next_tok, cache, toks = self._decode(
                    self.params, jnp.asarray(tok), self._slot_cache,
                    self.kv.page_table, jnp.asarray(dpos),
                    jnp.asarray(dlen), jnp.asarray(cow_src),
                    jnp.asarray(cow_dst), block)
        else:
            t0 = self.clock()
            with self._mesh_ctx():
                next_tok, cache, toks = self._decode(
                    self.params, jnp.asarray(tok), self._slot_cache,
                    jnp.asarray(pos), jnp.asarray(pos), block)
        self._slot_cache = cache
        toks_np = np.asarray(toks)                   # [N, slots]
        last_np = np.asarray(next_tok)               # [slots, 1]
        dt = self.clock() - t0       # the read-backs synchronize, so dt
        #                              is the real device+host block wall
        self.registry["decode_dispatch_s"].observe(dt)
        if self.obs.enabled:
            self.obs.complete(DISPATCH_DECODE, t0, dt, track=TRACK_ENGINE,
                              block=block, slots=len(runnable))
            for s in runnable:
                self.obs.complete("decode", t0, dt, track=slot_track(s),
                                  rid=active[s].rid, block=block)
        useful = 0
        for s in runnable:
            r = active[s]
            h = min(block,
                    r.max_new_tokens - len(r.out_tokens),
                    self.max_len - int(pos[s]))
            r.out_tokens.extend(int(t) for t in toks_np[:h, s])
            if r.out_tokens and r.first_token_at <= 0.0:
                # Bootstrap-admitted slots emit their first token here.
                self._stamp_first_token(r, s)
            useful = max(useful, h)
            self.registry["generated"].inc(h)
            pos[s] = min(int(pos[s]) + block, self.max_len)
            tok[s, 0] = last_np[s, 0]
            if (len(r.out_tokens) >= r.max_new_tokens
                    or pos[s] >= self.max_len):
                self._retire(s, r, active, decoding, pos, tok)
        self.registry["dispatches"].inc()
        self.registry["ticks"].inc(useful)
        self.registry["scan_ticks"].inc(block)
        if self.kv is not None:
            self.registry["pages_in_use"].set(self.kv.pages_in_use)
        self.decode_eff = (0.5 * self.decode_eff
                           + 0.5 * useful / block)

    # ------------------------------------------------ speculative decode
    def _draft(self, r: Request, limit: int) -> List[int]:
        """Host-side draft for one slot: up to ``min(draft_len, limit)``
        guesses for the tokens AFTER the pending one.  Sources, in
        order: the prefix-cache radix tree (what followed this history
        in earlier traffic — ``PrefixCache.suggest`` is read-only, so
        drafting never perturbs eviction order), then n-gram
        prompt-lookup (the history's trailing trigram/bigram matched
        backwards over the history itself).  Drafts are guesses — a
        wrong one costs its verify row, never correctness."""
        k = min(self.draft_len, limit)
        if k <= 0:
            return []
        hist = np.asarray(r.out_tokens, np.int32)
        if r.prompt.ndim == 1:                      # token prompts only
            hist = np.concatenate([r.prompt.astype(np.int32), hist])
        out: List[int] = []
        if self.prefix is not None:
            out = [int(t) for t in self.prefix.suggest(hist, k)]
        while len(out) < k:
            ext = _ngram_continuation(
                np.concatenate([hist, np.asarray(out, np.int32)]),
                k - len(out))
            if not ext:
                break
            out.extend(ext)
        return out[:k]

    def _speculative_block(self, active, decoding, pos, tok) -> None:
        """One draft-then-verify dispatch across all slots (DESIGN.md
        §11).  Stack ``[pending, d1..dk]`` per slot into a ``[slots, W]``
        window, score every position with ONE verify dispatch, accept
        the longest prefix of drafts matching the model's own greedy
        argmax, then roll the slot's KV extent back over the rejected
        tail.  W snaps up to the <=3-rung ladder; slots without drafts
        — and idle or parked mid-prefill slots — ride along on padding
        (their window writes route to the NULL page / their outputs are
        discarded, exactly like padded decode slots)."""
        assert self.kv is not None and self._verify is not None
        runnable = [s for s in range(self.slots)
                    if active[s] is not None and decoding[s]]
        drafts: Dict[int, List[int]] = {}
        caps: Dict[int, int] = {}
        need = 1
        for s in runnable:
            r = active[s]
            # A slot may deliver at most ``cap`` tokens this dispatch:
            # its remaining budget, clamped to max_len (positions past
            # max_len write to the NULL page and verify garbage).
            caps[s] = min(r.max_new_tokens - len(r.out_tokens),
                          self.max_len - int(pos[s]))
            drafts[s] = self._draft(r, caps[s] - 1)
            need = max(need, len(drafts[s]) + 1)
        w = next(x for x in self._w_ladder if x >= need)
        # COW resolution and page provisioning: same contract as the
        # decode block (allocator failure fails THIS request only).
        cow_src = np.full(self.slots, NULL_PAGE, np.int32)
        cow_dst = np.full(self.slots, NULL_PAGE, np.int32)
        for s in list(runnable):
            r = active[s]
            try:
                if self._cow[s] is not None:
                    cow_src[s], cow_dst[s] = self.kv.cow_page(
                        s, self._cow[s])
                    self._cow[s] = None
                    self.registry["cow_copies"].inc()
                    self.prefix.page_released(int(cow_src[s]))
                self.kv.ensure(s, min(int(pos[s]) + w, self.max_len))
            except RuntimeError as e:
                r.failed = True
                r.error = str(e)
                self.registry["rejected"].inc()
                self._retire(s, r, active, decoding, pos, tok)
                cow_src[s] = cow_dst[s] = NULL_PAGE
        runnable = [s for s in runnable
                    if active[s] is not None and decoding[s]]
        if not runnable:
            return
        toks = np.zeros((self.slots, w), np.int32)
        dpos = np.full(self.slots, self.kv.extent, np.int32)
        dlen = np.zeros(self.slots, np.int32)
        for s in runnable:
            toks[s, 0] = tok[s, 0]
            d = drafts[s]
            toks[s, 1:1 + len(d)] = d
            dpos[s] = pos[s]
            dlen[s] = pos[s]
        t0 = self.clock()
        with self._mesh_ctx():
            greedy, cache = self._verify(
                self.params, jnp.asarray(toks), self._slot_cache,
                self.kv.page_table, jnp.asarray(dpos), jnp.asarray(dlen),
                jnp.asarray(cow_src), jnp.asarray(cow_dst))
        self._slot_cache = cache
        g = np.asarray(greedy)                       # [slots, W]
        dt = self.clock() - t0
        self.registry["verify_dispatch_s"].observe(dt)
        if self.obs.enabled:
            self.obs.complete(DISPATCH_VERIFY, t0, dt, track=TRACK_ENGINE,
                              window=w, slots=len(runnable))
            for s in runnable:
                self.obs.complete("verify", t0, dt, track=slot_track(s),
                                  rid=active[s].rid, window=w,
                                  drafts=len(drafts[s]))
        useful = 0
        filled = 0
        for s in runnable:
            r = active[s]
            d = drafts[s]
            cap = caps[s]
            # Row i's output is the model's next token after consuming
            # toks[s, :i+1]; draft i is accepted while it EQUALS the
            # previous row's output — i.e. while the window tracks what
            # plain greedy decode would have produced anyway.  (A pad
            # token that happens to match is accepted too: it IS the
            # correct greedy token.)
            a = 0
            while (a < w - 1 and a + 1 < cap
                   and int(toks[s, a + 1]) == int(g[s, a])):
                a += 1
            delivered = a + 1                        # y0..ya
            r.out_tokens.extend(int(g[s, i]) for i in range(delivered))
            if r.first_token_at <= 0.0:
                self._stamp_first_token(r, s)
            self.registry["generated"].inc(delivered)
            self.registry["spec_tokens"].inc(delivered)
            self.registry["draft_tokens"].inc(len(d))
            self.registry["accepted_tokens"].inc(min(a, len(d)))
            useful = max(useful, delivered)
            filled += delivered
            pos[s] = int(pos[s]) + delivered
            tok[s, 0] = int(g[s, a])
            # The verify window appended K/V at pos..pos+W-1; positions
            # past the new write head are stale.  Wholly-stale pages are
            # returned now (freshly allocated and exclusively owned by
            # construction — rollback_extent asserts it); the stale tail
            # INSIDE the kept last page is masked by length and
            # overwritten as the slot advances.
            dropped = self.kv.rollback_extent(s, int(pos[s]))
            if dropped:
                self.registry["rollbacks"].inc()
                self.registry["rollback_pages"].inc(dropped)
            if self._debug_check_pages:
                self.kv.assert_page_accounting()
            if (len(r.out_tokens) >= r.max_new_tokens
                    or pos[s] >= self.max_len):
                self._retire(s, r, active, decoding, pos, tok)
        self.registry["dispatches"].inc()
        self.registry["verify_dispatches"].inc()
        self.registry["ticks"].inc(useful)
        self.registry["scan_ticks"].inc(w)
        self.registry["pages_in_use"].set(self.kv.pages_in_use)
        # The decode-pressure EMA counts ACCEPTED tokens per verify row,
        # not scan ticks — a rejected draft row is wasted capacity
        # exactly like a wasted scan tick.
        self.decode_eff = (0.5 * self.decode_eff
                           + 0.5 * filled / (w * len(runnable)))
