"""Serving engine: prefill + batched greedy decode with slot management.

A deliberately small continuous-batching engine (the serving twin of the
trainer): requests enter a queue, get assigned cache slots, prefill fills a
slot's KV/state, and one jitted decode step advances every active slot per
tick.  Works on CPU for the examples/tests and under any mesh for a real
deployment (the decode step is the dry-run's serve_step).

Decode-cache note: slots share one max_len cache allocation; prefill caches
(sized at the prompt) are padded in.  All sequences in a tick share the
write position (static-shape decode); per-slot lengths mask attention.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models import decode_step, init_cache, prefill

Tree = Any


@dataclass
class Request:
    rid: int
    prompt: np.ndarray              # [S] int32 (or embeds [S, D])
    max_new_tokens: int = 16
    out_tokens: List[int] = field(default_factory=list)
    done: bool = False
    submitted_at: float = 0.0
    first_token_at: float = 0.0
    finished_at: float = 0.0

    @property
    def ttft_s(self) -> float:
        return self.first_token_at - self.submitted_at

    @property
    def latency_s(self) -> float:
        return self.finished_at - self.submitted_at


def _pad_cache_seq(cache: Tree, max_len: int) -> Tree:
    def pad(path, a):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if name in ("k", "v"):
            pad_n = max_len - a.shape[2]
            return jnp.pad(a, ((0, 0), (0, 0), (0, pad_n), (0, 0), (0, 0)))
        return a
    return jax.tree_util.tree_map_with_path(pad, cache)


class ServingEngine:
    """Batched greedy generation over a fixed slot count."""

    def __init__(self, cfg: ModelConfig, params: Tree, *,
                 batch_slots: int = 4, max_len: int = 256):
        self.cfg = cfg
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        def _step(p, t, c, pos, lens):
            nt, _logits, new_cache = decode_step(p, cfg, t, c, pos, lens)
            return nt, new_cache
        self._decode = jax.jit(_step)
        self._prefill = jax.jit(lambda p, b: prefill(p, cfg, b))
        self.metrics: Dict[str, float] = {"ticks": 0, "generated": 0}

    # -------------------------------------------------------------- API
    def generate(self, prompts: List[np.ndarray],
                 max_new_tokens: int = 16) -> List[Request]:
        """Serve a list of same-length prompts with continuous batching."""
        reqs = [Request(rid=i, prompt=p, max_new_tokens=max_new_tokens,
                        submitted_at=time.perf_counter())
                for i, p in enumerate(prompts)]
        pending = list(reqs)
        while pending:
            wave, pending = (pending[:self.slots], pending[self.slots:])
            self._serve_wave(wave)
        return reqs

    # ------------------------------------------------------------ waves
    def _serve_wave(self, wave: List[Request]) -> None:
        b = len(wave)
        plen = wave[0].prompt.shape[0]
        batch = {"tokens": jnp.asarray(np.stack([r.prompt for r in wave]))}
        logits, cache = self._prefill(self.params, batch)
        cache = _pad_cache_seq(cache, self.max_len)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        now = time.perf_counter()
        for r, t in zip(wave, np.asarray(next_tok)[:, 0]):
            r.out_tokens.append(int(t))
            r.first_token_at = now
        lengths = jnp.full((b,), plen, jnp.int32)
        pos = plen
        steps = max(r.max_new_tokens for r in wave) - 1
        for _ in range(steps):
            if pos >= self.max_len:
                break
            next_tok, cache = self._decode(self.params, next_tok, cache,
                                           jnp.int32(pos), lengths)
            now = time.perf_counter()
            for r, t in zip(wave, np.asarray(next_tok)[:, 0]):
                if len(r.out_tokens) < r.max_new_tokens:
                    r.out_tokens.append(int(t))
            pos += 1
            lengths = lengths + 1
            self.metrics["ticks"] += 1
            self.metrics["generated"] += b
        now = time.perf_counter()
        for r in wave:
            r.done = True
            r.finished_at = now
