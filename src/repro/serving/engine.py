"""Serving engine: prefill + batched greedy decode with slot management.

A deliberately small continuous-batching engine (the serving twin of the
trainer): requests enter a queue, get assigned cache slots, prefill fills a
slot's KV/state, and jitted decode dispatches advance every active slot.
Works on CPU for the examples/tests and under any mesh for a real
deployment (the decode step is the dry-run's serve_step).

Decode fast path (§Perf, this is the hot loop):

  * The slot cache is allocated ONCE at ``max_len`` (``init_cache``) and
    prefill results are *placed into it* inside the prefill jit via
    ``dynamic_update_slice`` — the old per-wave host-side
    ``_pad_cache_seq`` materialized a fresh full-size padded copy of every
    K/V buffer per wave.  Stale K/V beyond the prompt length is never read:
    decode attention masks strictly by per-slot ``lengths``.
  * The cache is DONATED through both the placement and decode dispatches
    (``donate_argnums``), so XLA updates the K/V buffers in place instead
    of copying the whole cache every step.
  * Decode runs ``decode_block`` (>= 8) ticks per jitted dispatch as a
    ``lax.scan`` over ``decode_step`` — one host round-trip per block of
    tokens instead of per token.  The scan always runs the full block
    (single compiled program); host-side bookkeeping discards tokens past a
    request's budget or ``max_len`` (writes past ``max_len`` clamp into the
    final cache rows, which is safe: the wave terminates there and the
    cache is re-placed at the next prefill).

All sequences in a tick share the write position (static-shape decode);
per-slot lengths mask attention.  Tail waves are padded to the slot count
with a dummy prompt so every dispatch reuses the same compiled program.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ..configs.base import ModelConfig
from ..models import decode_step, init_cache, prefill

Tree = Any


@dataclass
class Request:
    rid: int
    prompt: np.ndarray              # [S] int32 (or embeds [S, D])
    max_new_tokens: int = 16
    out_tokens: List[int] = field(default_factory=list)
    done: bool = False
    submitted_at: float = 0.0
    first_token_at: float = 0.0
    finished_at: float = 0.0

    @property
    def ttft_s(self) -> float:
        return self.first_token_at - self.submitted_at

    @property
    def latency_s(self) -> float:
        return self.finished_at - self.submitted_at


def _seq_axis(path, layout: str) -> Optional[int]:
    """Sequence axis of a stacked K/V cache leaf, None for non-KV leaves.

    Leaves carry a leading layer-group axis: [G, B, S, Hkv, hd] ("bshd")
    or [G, B, Hkv, S, hd] ("bhsd").
    """
    name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
    if name not in ("k", "v"):
        return None
    return 3 if layout == "bhsd" else 2


def _place_cache(cache: Tree, fresh: Tree, layout: str) -> Tree:
    """Write prompt-length prefill caches into the max-length slot cache.

    K/V leaves are placed at sequence offset 0 of the preallocated buffer
    (an in-place ``dynamic_update_slice`` under donation); state leaves
    (SSM / conv / wkv / shifts) carry no sequence axis and replace the slot
    buffer wholesale.
    """
    def place(path, big, small):
        ax = _seq_axis(path, layout)
        if ax is None:
            return small.astype(big.dtype)
        return lax.dynamic_update_slice_in_dim(
            big, small.astype(big.dtype), 0, axis=ax)
    return jax.tree_util.tree_map_with_path(place, cache, fresh)


class ServingEngine:
    """Batched greedy generation over a fixed slot count."""

    def __init__(self, cfg: ModelConfig, params: Tree, *,
                 batch_slots: int = 4, max_len: int = 256,
                 decode_block: int = 16):
        self.cfg = cfg
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        self.decode_block = max(1, decode_block)

        def _prefill_into(p, batch, slot_cache):
            logits, fresh = prefill(p, cfg, batch)
            placed = _place_cache(slot_cache, fresh, cfg.kv_cache_layout)
            next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return next_tok, placed

        def _decode_n(p, tok, cache, pos, lengths):
            def tick(carry, _):
                tok, cache, pos, lengths = carry
                nt, _logits, cache = decode_step(p, cfg, tok, cache, pos,
                                                 lengths)
                return (nt, cache, pos + 1, lengths + 1), nt[:, 0]

            carry, toks = lax.scan(
                tick, (tok, cache, pos, lengths), None,
                length=self.decode_block)
            tok, cache, pos, lengths = carry
            return tok, cache, pos, lengths, toks      # toks: [N, B]

        # Donate the slot cache through both dispatches: K/V updates happen
        # in place instead of copying the max_len buffers every call.
        self._prefill = jax.jit(_prefill_into, donate_argnums=(2,))
        self._decode = jax.jit(_decode_n, donate_argnums=(2,))
        self._slot_cache = init_cache(cfg, batch_slots, max_len)
        self.metrics: Dict[str, float] = {
            "ticks": 0, "generated": 0, "dispatches": 0,
            "decode_block": self.decode_block,
        }

    # -------------------------------------------------------------- API
    def generate(self, prompts: List[np.ndarray],
                 max_new_tokens: int = 16) -> List[Request]:
        """Serve a list of same-length prompts with continuous batching."""
        reqs = [Request(rid=i, prompt=p, max_new_tokens=max_new_tokens,
                        submitted_at=time.perf_counter())
                for i, p in enumerate(prompts)]
        pending = list(reqs)
        while pending:
            wave, pending = (pending[:self.slots], pending[self.slots:])
            self._serve_wave(wave)
        return reqs

    # ------------------------------------------------------------ waves
    def _serve_wave(self, wave: List[Request]) -> None:
        b = len(wave)
        plen = wave[0].prompt.shape[0]
        # Pad tail waves to the slot count: one compiled program for every
        # wave; padded rows are computed and discarded.
        prompts = [r.prompt for r in wave]
        prompts += [wave[0].prompt] * (self.slots - b)
        batch = {"tokens": jnp.asarray(np.stack(prompts))}
        next_tok, cache = self._prefill(self.params, batch, self._slot_cache)
        # Reassign immediately after every donating dispatch: the donated
        # input buffer is deleted on accelerator backends, and a mid-wave
        # exception must not leave the engine holding a dead reference.
        self._slot_cache = cache
        now = time.perf_counter()
        for r, t in zip(wave, np.asarray(next_tok)[:b, 0]):
            r.out_tokens.append(int(t))
            r.first_token_at = now

        lengths = jnp.full((self.slots,), plen, jnp.int32)
        pos = plen
        steps = max(r.max_new_tokens for r in wave) - 1
        done = 0
        while done < steps and pos < self.max_len:
            next_tok, cache, _pos, lengths, toks = self._decode(
                self.params, next_tok, cache, jnp.int32(pos), lengths)
            self._slot_cache = cache
            now = time.perf_counter()
            usable = min(self.decode_block, steps - done,
                         self.max_len - pos)
            toks_np = np.asarray(toks)                  # [N, slots]
            for j in range(usable):
                for r, t in zip(wave, toks_np[j, :b]):
                    if len(r.out_tokens) < r.max_new_tokens:
                        r.out_tokens.append(int(t))
            done += usable
            pos += self.decode_block
            self.metrics["dispatches"] += 1
            self.metrics["ticks"] += self.decode_block
            self.metrics["generated"] += b * usable
        now = time.perf_counter()
        for r in wave:
            r.done = True
            r.finished_at = now
